"""Reversible-adjoint training on adaptive grids: memory + wall-clock.

The workload the realized-grid refactor unlocked: a training step whose
forward pass places steps adaptively (PI controller on a Virtual Brownian
Tree, stiff-transient drift) and whose backward pass runs the O(1)-memory
reversible adjoint over the realized grid.  Compares the three adjoints on
one jit'd loss-gradient computation:

* ``temp_bytes`` — peak XLA scratch of the compiled step (the paper's memory
  metric; full grows O(n_steps), recursive O(sqrt), reversible stays flat);
* ``us_per_step`` — median wall-clock per gradient evaluation;
* ``grad_rel_err_vs_full`` — max relative gradient deviation from the full
  adjoint on the same realized grids (recursive is a pure remat ~1e-16;
  reversible pays only the O(h^{m+1}) reconstruction drift).

Since PR 4 solves default to **bulk Brownian realization** (all increments
materialised up front — the throughput configuration, see
``docs/performance.md``).  The memory-lean training configuration this
benchmark exists to chart opts out (``bulk_increments=False``): the
O(n_steps x noise) buffer would otherwise dominate the reversible adjoint's
scratch and mask its O(1)-memory story.  The ``reversible-bulk`` record
measures the default (bulk) configuration alongside, so the
memory-vs-throughput trade is visible in one JSON.

Emits ``BENCH_reversible_adaptive.json`` next to the repo root (referenced
from ROADMAP.md).

Run:  PYTHONPATH=src python -m benchmarks.bench_reversible_adaptive
      [--out PATH] [--max-steps N] [--paths B] [--dim D]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SDETerm, sdeint

from .common import emit, temp_bytes, time_fn

jax.config.update("jax_enable_x64", True)

# (name, adjoint, bulk_increments): the three PR-3 memory-lean configs plus
# the PR-4 bulk default for the reversible adjoint.
CONFIGS = (
    ("full", "full", False),
    ("recursive", "recursive", False),
    ("reversible", "reversible", False),
    ("reversible-bulk", "reversible", True),
)
RTOL = 1e-3
T1 = 2.0

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_reversible_adaptive.json",
)


def transient_term() -> SDETerm:
    """Mean-reverting process with a sharp stiff transient around t = 1
    (same workload class as bench_adaptive: the tolerance-driven grid earns
    its keep only where step placement matters)."""
    def rate(t, a):
        return a["nu"] * (1.0 + 40.0 * jnp.exp(-(((t - 1.0) / 0.08) ** 2)))

    return SDETerm(
        drift=lambda t, y, a: rate(t, a) * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y)),
        noise="diagonal",
    )


def run(out_path: str = DEFAULT_OUT, max_steps: int = 512, n_paths: int = 32,
        dim: int = 16):
    term = transient_term()
    args = {"nu": jnp.float64(0.7), "mu": jnp.float64(0.2),
            "sigma": jnp.float64(0.4)}
    y0 = jnp.ones(dim, jnp.float64)
    keys = jax.random.split(jax.random.PRNGKey(0), n_paths)

    def make_grad(adjoint, bulk):
        def loss(a):
            r = sdeint(term, "ees25:adaptive", 0.0, T1, max_steps, y0, None,
                       args=a, adjoint=adjoint, rtol=RTOL, batch_keys=keys,
                       bulk_increments=bulk)
            return jnp.mean((r.y_final - 0.2) ** 2)

        return jax.jit(jax.value_and_grad(loss))

    records = []
    grads = {}
    for name, adjoint, bulk in CONFIGS:
        fn = make_grad(adjoint, bulk)
        mem = temp_bytes(fn, args)
        us = time_fn(fn, args, warmup=1, iters=3)
        loss, g = fn(args)
        grads[name] = {k: float(v) for k, v in g.items()}
        records.append({
            "adjoint": name,
            "bulk_increments": bulk,
            "temp_bytes": mem,
            "us_per_step": us,
            "loss": float(loss),
        })
        emit(f"bench_reversible_adaptive/{name}", us,
             f"temp_bytes={mem},loss={float(loss):.6f}")

    for rec in records:
        rel = max(
            abs(grads[rec["adjoint"]][k] - grads["full"][k])
            / (abs(grads["full"][k]) + 1e-30)
            for k in grads["full"]
        )
        rec["grad_rel_err_vs_full"] = rel
        emit(f"bench_reversible_adaptive/graderr/{rec['adjoint']}", 0.0,
             f"rel={rel:.3e}")

    by = {r["adjoint"]: r for r in records}
    if by["full"]["temp_bytes"] and by["reversible"]["temp_bytes"]:
        ratio = by["full"]["temp_bytes"] / by["reversible"]["temp_bytes"]
        emit("bench_reversible_adaptive/mem_ratio_full_over_reversible", 0.0,
             f"{ratio:.1f}x")

    payload = {
        "device": jax.devices()[0].platform,
        "n_paths": n_paths,
        "dim": dim,
        "t1": T1,
        "rtol": RTOL,
        "max_steps": max_steps,
        "records": records,
        "grads": grads,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--max-steps", type=int, default=512)
    ap.add_argument("--paths", type=int, default=32)
    ap.add_argument("--dim", type=int, default=16)
    args = ap.parse_args()
    run(args.out, args.max_steps, args.paths, args.dim)


if __name__ == "__main__":
    main()
