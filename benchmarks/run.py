"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit):
  table1_ou        — Table 1: high-vol OU stability under training
  table2_vol       — Table 2/H.2: runtime at fixed NFE (2N recurrence win)
  table3_kuramoto  — Table 3 + Fig 5b: T*T^N energy score + adjoint memory
  table4_sphere    — Table 4 + Fig 6: sphere latent SDE + adjoint memory
  table7_gbm       — Table 7/H.1: stiff-GBM stability separation
  fig_convergence  — Figs 7/8 + App. G: strong/backward rates on fBm RDEs
  bench_throughput — beyond-paper: batched sdeint trajectories/sec vs batch
"""
import time
import traceback


def main() -> None:
    from . import (
        bench_throughput,
        fig_convergence,
        table1_ou,
        table2_vol,
        table3_kuramoto,
        table4_sphere,
        table7_gbm,
    )

    t00 = time.time()
    for mod in (table7_gbm, table1_ou, table2_vol, table3_kuramoto,
                table4_sphere, fig_convergence, bench_throughput):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — keep the suite going
            print(f"{name},nan,ERROR")
            traceback.print_exc()
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t00:.1f}s")


if __name__ == "__main__":
    main()
