"""Serving-core throughput: requests/sec and host dispatches vs queue depth.

Drives a real :class:`repro.serving.SDESampleEngine` (scheduler + executor,
not a bare ``sdeint``) over a queue of same-signature sampling requests and
measures the thing the PR-5 refactor changes: how many **host round trips**
it takes to drain a queue, and what that does to requests/sec.  Each record
serves ``queue_depth`` requests of ``slots`` paths each (one engine tick per
request) at a given ``ticks_per_dispatch``:

    {"queue_depth": 8, "slots": 64, "ticks_per_dispatch": 8,
     "n_ticks": 8, "host_dispatches": 1, "dispatches_per_tick": 0.125,
     "requests_per_sec": ..., "paths_per_sec": ..., "us_per_tick": ...}

``ticks_per_dispatch=1`` is the pre-refactor behaviour — one host dispatch
per tick, ``dispatches_per_tick == 1`` (O(ticks) round trips per signature).
Deeper stacks run the same ticks inside one on-device ``lax.map`` loop, so
``host_dispatches`` collapses toward O(1) per signature; results are
bitwise-identical either way (tested in ``tests/test_serving.py``), so this
sweep changes dispatch cost only, never samples.

Timing excludes compilation: every configuration is served twice and only
the second (fully cache-warm) run is measured.

``--profile DIR`` wraps the measured sweep in ``jax.profiler.trace(DIR)``
(inspect with TensorBoard or Perfetto).

Run:  PYTHONPATH=src python -m benchmarks.bench_serving [--out PATH]
      [--slots N] [--depths 4,8,16] [--ticks-per-dispatch 1,4,16]
      [--profile DIR]
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import SDETerm
from repro.serving import SDESampleConfig, SDESampleEngine

from .common import emit

SLOTS = 64
QUEUE_DEPTHS = (4, 8, 16)
TICKS_PER_DISPATCH = (1, 4, 16)
N_STEPS = 64
DIM = 16
SOLVER = "ees25"

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json",
)


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
        noise="diagonal",
    )


def serve_queue(term, args, y0, *, depth: int, slots: int, tpd: int,
                n_steps: int, solver: str):
    """Serve ``depth`` requests of ``slots`` paths; return (secs, engine)."""
    eng = SDESampleEngine(
        term, y0, SDESampleConfig(slots=slots, ticks_per_dispatch=tpd),
        args=args,
    )

    def one_pass():
        for i in range(depth):
            eng.submit(solver, t1=1.0, n_steps=n_steps, n_paths=slots, seed=i)
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0

    one_pass()            # warm: compiles the full-stack + tail executables
    secs = one_pass()     # measured: identical plan sequence, cache-warm
    return secs, eng


def run(out_path: str = DEFAULT_OUT, *, slots: int = SLOTS,
        depths=QUEUE_DEPTHS, ticks_per_dispatch=TICKS_PER_DISPATCH,
        n_steps: int = N_STEPS, dim: int = DIM, solver: str = SOLVER,
        profile_dir=None):
    term = ou_term()
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    y0 = jnp.ones(dim, jnp.float32)
    records = []
    ctx = (jax.profiler.trace(profile_dir) if profile_dir
           else contextlib.nullcontext())
    with ctx:
        for depth in depths:
            for tpd in ticks_per_dispatch:
                if tpd > depth:
                    continue  # a stack deeper than the queue adds nothing
                secs, eng = serve_queue(
                    term, args, y0, depth=depth, slots=slots, tpd=tpd,
                    n_steps=n_steps, solver=solver)
                # counters cover both passes; each pass served `depth` ticks
                n_ticks = eng.executor.n_ticks // 2
                dispatches = eng.executor.n_dispatches // 2
                records.append({
                    "solver": solver,
                    "queue_depth": depth,
                    "slots": slots,
                    "ticks_per_dispatch": tpd,
                    "n_steps": n_steps,
                    "dim": dim,
                    "n_ticks": n_ticks,
                    "host_dispatches": dispatches,
                    "dispatches_per_tick": dispatches / n_ticks,
                    "seconds": secs,
                    "requests_per_sec": depth / secs,
                    "paths_per_sec": depth * slots / secs,
                    "us_per_tick": secs * 1e6 / n_ticks,
                })
                emit(f"bench_serving/D{depth}/S{slots}/T{tpd}",
                     secs * 1e6 / n_ticks,
                     f"req_per_sec={depth / secs:.1f} "
                     f"dispatches={dispatches}/{n_ticks}")
    with open(out_path, "w") as f:
        json.dump({"device": jax.devices()[0].platform, "records": records},
                  f, indent=2)
    print(f"# wrote {out_path}")
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--depths", default=",".join(map(str, QUEUE_DEPTHS)))
    ap.add_argument("--ticks-per-dispatch",
                    default=",".join(map(str, TICKS_PER_DISPATCH)))
    ap.add_argument("--n-steps", type=int, default=N_STEPS)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the measured sweep in jax.profiler.trace(DIR)")
    args = ap.parse_args()
    run(args.out, slots=args.slots,
        depths=tuple(int(d) for d in args.depths.split(",")),
        ticks_per_dispatch=tuple(
            int(t) for t in args.ticks_per_dispatch.split(",")),
        n_steps=args.n_steps, dim=args.dim, profile_dir=args.profile)


if __name__ == "__main__":
    main()
