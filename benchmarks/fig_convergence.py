"""Figs 7/8 + Appendix G: strong convergence and backward-recovery rates.

Euclidean EES(2,5)/(2,7) on the 2-driver RDE dy = cos(y) dX1 + sin(y) dX2
driven by fBm (H in {0.5, 0.6}), and CF-EES(2,5) on the SO(3) RDE of
Appendix G.  Measured: global strong error slope vs a fine reference
(expect ~min(2H-1/2-eps, (p+1)alpha-1) forward) and the backward-recovery
slope (expect ~6H-1 for EES(2,5): the effective-symmetry order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ManifoldSDETerm, SDETerm, SO3, cfees25_solver, ees25_solver, ees27_solver
from repro.nsde.fbm import fbm_increments

from .common import emit


def _drive(solver, term, y0, incs, reverse=False, manifold=False):
    """Integrate with explicit per-step 2-channel increments (h folded in)."""
    n = incs.shape[0]
    y = y0
    for i in range(n):
        y = solver.step(term, y, 0.0, 0.0, incs[i], None)
    if not reverse:
        return y
    for i in range(n - 1, -1, -1):
        y = solver.reverse(term, y, 0.0, 0.0, incs[i], None)
    return y


def euclidean_rates(H: float, solver, name: str):
    # time is absorbed as a third driver channel with increment h.
    term = SDETerm(
        drift=lambda t, y, a: jnp.zeros_like(y),
        diffusion=lambda t, y, a: jnp.stack([jnp.cos(y), jnp.sin(y)], -1),
        noise="general",
    )
    rng = np.random.default_rng(5)
    M = 3
    n_ref = 1024
    ns = [32, 64, 128, 256]
    errs = {n: [] for n in ns}
    rerrs = {n: [] for n in ns}
    for m in range(M):
        # 2-channel fBm increments on the fine grid
        fine = np.stack(
            [fbm_increments(rng, n_ref, H, 1.0)[0] for _ in range(2)], -1
        )  # (n_ref, 2)
        y0 = jnp.asarray([1.0])
        ref = _drive(solver, term, y0, jnp.asarray(fine))
        for n in ns:
            k = n_ref // n
            coarse = fine.reshape(n, k, 2).sum(1)
            inc = jnp.asarray(coarse)
            y = _drive(solver, term, y0, inc)
            errs[n].append(float(jnp.abs(y - ref)[0]))
            yb = _drive(solver, term, y0, inc, reverse=True)
            rerrs[n].append(float(jnp.abs(yb - y0)[0]))
    log_n = np.log([1.0 / n for n in ns])
    fwd = np.polyfit(log_n, np.log([np.mean(errs[n]) + 1e-16 for n in ns]), 1)[0]
    bwd = np.polyfit(log_n, np.log([np.mean(rerrs[n]) + 1e-16 for n in ns]), 1)[0]
    emit(f"fig7_convergence/{name}/H={H}", 0.0,
         f"fwd_rate={fwd:.2f};bwd_recovery_rate={bwd:.2f}")
    return fwd, bwd


def so3_rates(H: float):
    def xi(t, y, a):
        g1 = jnp.stack([0.1 + 0.3 * y[..., 2, 0], -(0.25 + 0.2 * y[..., 1, 2]),
                        0.9 + 0.2 * y[..., 0, 0]], -1)
        g2 = jnp.stack([0.8 + 0.15 * y[..., 2, 2], 0.15 + 0.25 * y[..., 0, 1],
                        0.35 - 0.2 * y[..., 1, 1]], -1)
        return jnp.stack([g1, g2], -1)  # (..., 3, 2)

    term = ManifoldSDETerm(
        group=SO3(),
        drift=lambda t, y, a: jnp.zeros((3,)),
        diffusion=xi,
        noise="general",
        noise_apply=lambda g, dw: jnp.einsum("...ij,...j->...i", g, dw),
    )
    solver = cfees25_solver()
    rng = np.random.default_rng(7)
    n_ref = 512
    ns = [32, 64, 128]
    fine = np.stack([fbm_increments(rng, n_ref, H, 1.0)[0] for _ in range(2)], -1)
    y0 = jnp.eye(3)
    ref = _drive(solver, term, y0, jnp.asarray(fine))
    errs, rerrs = [], []
    for n in ns:
        k = n_ref // n
        inc = jnp.asarray(fine.reshape(n, k, 2).sum(1))
        y = _drive(solver, term, y0, inc)
        errs.append(float(jnp.max(jnp.abs(y - ref))))
        yb = _drive(solver, term, y0, inc, reverse=True)
        rerrs.append(float(jnp.max(jnp.abs(yb - y0))))
    log_n = np.log([1.0 / n for n in ns])
    fwd = np.polyfit(log_n, np.log(np.asarray(errs) + 1e-16), 1)[0]
    bwd = np.polyfit(log_n, np.log(np.asarray(rerrs) + 1e-16), 1)[0]
    emit(f"fig8_convergence/CF-EES25-SO3/H={H}", 0.0,
         f"fwd_rate={fwd:.2f};bwd_recovery_rate={bwd:.2f}")


def run():
    # x64 needed to resolve 1e-12-scale backward-recovery errors; enabled
    # here (module runs LAST in the suite) rather than at import so earlier
    # benchmarks keep f32 numerics.
    jax.config.update("jax_enable_x64", True)
    for H in (0.5, 0.6):
        euclidean_rates(H, ees25_solver(), "EES25")
    euclidean_rates(0.5, ees27_solver(), "EES27")
    so3_rates(0.5)


if __name__ == "__main__":
    run()
