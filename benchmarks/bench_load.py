"""Async serving-plane load test: latency percentiles under Poisson arrivals.

Where :mod:`benchmarks.bench_serving` measures the *drain* cost of a
pre-filled queue (host dispatches per tick), this benchmark measures what a
client of the **async plane** actually sees: per-request latency when
requests arrive as a seeded Poisson process over a *mixed* population of
solver/horizon/tolerance signatures, and the closed-loop saturation
throughput of the engine.  Two phases, one warm-up:

* **warm** — every signature in the mix is served once so XLA compiles are
  out of the measured path (same discipline as ``bench_serving``);
* **open loop** — ``--requests`` arrivals with exponential inter-arrival
  times at ``--rate`` req/s (``random.Random(seed)``: reproducible arrival
  pattern AND signature mix); each client awaits ``submit`` → ``result``
  and records wall latency.  Reported as ``p50_ms`` / ``p99_ms``;
* **closed loop** — the same request mix submitted all at once and drained:
  completed requests / second is the ``saturation_rps`` ceiling.

Results merge into the ``"load"`` section of ``BENCH_serving.json`` next to
the drain sweep's ``"records"`` — including ``dispatches_per_tick`` over the
measured phases, the PR-5 regression guard (continuous batching must not
cost extra host round trips per device tick).

A second sweep (the ``"bucketing"`` section, PR 8) serves a **mixed-horizon**
population — two solvers x six horizons sharing one step size, most of them
off the power-of-two ladder — from cold, with signature coalescing on and
off, and records what bucketing buys: ``n_executables`` (compile-cache
entries after the drain), cold-start saturation rps for both modes, and the
cold-vs-warm compile seconds of an AOT ``warmup()`` against a persistent
compilation cache directory.  The CI bench-smoke gate asserts
``n_executables_bucketed <= n_buckets < n_signatures`` and
``warm_compile_s < cold_compile_s`` on this section.

``--profile DIR`` wraps the measured phases in ``jax.profiler.trace(DIR)``
(inspect with TensorBoard or Perfetto).

Run:  PYTHONPATH=src python -m benchmarks.bench_load [--out PATH]
      [--requests N] [--rate RPS] [--slots N] [--ticks-per-dispatch N]
      [--seed S] [--profile DIR] [--skip-bucketing]
"""
from __future__ import annotations

import argparse
import asyncio
import contextlib
import json
import os
import random
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.serving import AsyncSDESampleEngine, SDESampleConfig

from .bench_serving import DEFAULT_OUT, ou_term
from .common import emit

SLOTS = 32
TICKS_PER_DISPATCH = 4
N_REQUESTS = 40
RATE = 50.0
SEED = 0

# Mixed signature population: solver x horizon x tolerance.  Weights bias
# toward the cheap fixed-grid solve the way a real mix would.
POPULATION = (
    {"name": "ees25/short", "weight": 4, "solver": "ees25",
     "kw": dict(t1=1.0, n_steps=32)},
    {"name": "ees25/long", "weight": 2, "solver": "ees25",
     "kw": dict(t1=2.0, n_steps=64)},
    {"name": "heun/short", "weight": 2, "solver": "heun",
     "kw": dict(t1=1.0, n_steps=32)},
    {"name": "ees25/adaptive", "weight": 1, "solver": "ees25:adaptive",
     "kw": dict(t1=1.0, n_steps=128, rtol=1e-3)},
)


# Mixed-horizon population for the bucketing sweep: every signature shares
# the step size h = 1/32 (the coalescing condition) but takes a different
# number of steps, most off the power-of-two ladder.  12 signatures land on
# 4 buckets (per solver: 24,32 -> rung 32; 40,48,56,64 -> rung 64).
BUCKET_SOLVERS = ("ees25", "heun")
BUCKET_HORIZON_STEPS = (24, 32, 40, 48, 56, 64)


def _bucket_specs():
    return [{"solver": s, "t1": n / 32.0, "n_steps": n}
            for s in BUCKET_SOLVERS for n in BUCKET_HORIZON_STEPS]


def _profile_ctx(profile_dir):
    if profile_dir:
        return jax.profiler.trace(profile_dir)
    return contextlib.nullcontext()


def _percentile(sorted_xs, q: float) -> float:
    if not sorted_xs:
        return float("nan")
    k = min(len(sorted_xs) - 1, max(0, round(q * (len(sorted_xs) - 1))))
    return sorted_xs[k]


def _draw_mix(rng: random.Random, n: int):
    choices = [s for s in POPULATION for _ in range(s["weight"])]
    return [rng.choice(choices) for _ in range(n)]


def _make_engine(slots: int, tpd: int):
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    cfg = SDESampleConfig(slots=slots, ticks_per_dispatch=tpd,
                          max_queue_paths=64 * slots)
    return AsyncSDESampleEngine(ou_term(), jnp.ones(16, jnp.float32), cfg,
                                args=args)


async def _warm(eng, slots: int):
    for spec in POPULATION:
        rid = await eng.submit(spec["solver"], n_paths=slots, seed=0,
                               **spec["kw"])
        await eng.result(rid)


async def _open_loop(eng, mix, rng: random.Random, rate: float, slots: int):
    latencies = []

    async def client(k, spec):
        t0 = time.perf_counter()
        rid = await eng.submit(spec["solver"], n_paths=slots, seed=k,
                               **spec["kw"])
        await eng.result(rid)
        latencies.append(time.perf_counter() - t0)

    tasks = []
    for k, spec in enumerate(mix):
        await asyncio.sleep(rng.expovariate(rate))
        tasks.append(asyncio.create_task(client(k, spec)))
    await asyncio.gather(*tasks)
    return sorted(latencies)


async def _closed_loop(eng, mix, slots: int) -> float:
    t0 = time.perf_counter()
    rids = [await eng.submit(spec["solver"], n_paths=slots, seed=k,
                             **spec["kw"])
            for k, spec in enumerate(mix)]
    for rid in rids:
        await eng.result(rid)
    return len(mix) / (time.perf_counter() - t0)


async def _run(slots: int, tpd: int, n_requests: int, rate: float,
               seed: int):
    rng = random.Random(seed)
    mix = _draw_mix(rng, n_requests)
    async with _make_engine(slots, tpd) as eng:
        await _warm(eng, slots)
        d0, t0 = eng.executor.n_dispatches, eng.executor.n_ticks
        lat = await _open_loop(eng, mix, rng, rate, slots)
        sat = await _closed_loop(eng, mix, slots)
        d1, t1 = eng.executor.n_dispatches, eng.executor.n_ticks
    return {
        "slots": slots,
        "ticks_per_dispatch": tpd,
        "n_requests": n_requests,
        "offered_rps": rate,
        "seed": seed,
        "mix": sorted({s["name"] for s in mix}),
        "p50_ms": _percentile(lat, 0.50) * 1e3,
        "p99_ms": _percentile(lat, 0.99) * 1e3,
        "saturation_rps": sat,
        # PR-5 regression guard: host round trips per device tick across the
        # measured phases (1/tpd in steady state; tails/interleave add a bit)
        "dispatches_per_tick": (d1 - d0) / max(1, t1 - t0),
    }


async def _bucket_drain(bucketing: bool, slots: int):
    """Cold drain of the mixed-horizon population; returns (rps, n_exec,
    n_buckets_observed).  Cold on purpose: the executable count — what
    coalescing changes — dominates a fresh engine's drain on every backend."""
    specs = _bucket_specs()
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    cfg = SDESampleConfig(slots=slots, ticks_per_dispatch=1,
                          bucketing=bucketing, max_queue_paths=64 * slots)
    t0 = time.perf_counter()
    async with AsyncSDESampleEngine(ou_term(), jnp.ones(16, jnp.float32),
                                    cfg, args=args) as eng:
        rids = [await eng.submit(s["solver"], t1=s["t1"],
                                 n_steps=s["n_steps"], n_paths=slots, seed=k)
                for k, s in enumerate(specs)]
        results = [await eng.result(rid) for rid in rids]
        secs = time.perf_counter() - t0
        n_exec = len(eng.executor._compiled)
    buckets = {r.bucket for r in results if r.bucket is not None}
    return len(specs) / secs, n_exec, len(buckets)


def _bucket_compile_times(slots: int):
    """Cold vs warm AOT ``warmup()`` seconds against a persistent compile
    cache: the warm engine is a fresh process stand-in (its executor cache
    is empty), so its compiles deserialize from disk instead of re-running
    XLA."""
    from repro.serving import SDESampleEngine

    specs = [dict(s) for s in _bucket_specs()]
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    with tempfile.TemporaryDirectory() as cache_dir:
        cfg = SDESampleConfig(slots=slots, ticks_per_dispatch=1,
                              compile_cache_dir=cache_dir)

        def timed_warmup():
            eng = SDESampleEngine(ou_term(), jnp.ones(16, jnp.float32), cfg,
                                  args=args)
            t0 = time.perf_counter()
            n = eng.warmup(specs)
            return time.perf_counter() - t0, n

        cold_s, n_exec = timed_warmup()
        warm_s, _ = timed_warmup()
    return cold_s, warm_s, n_exec


def run_bucketing(out_path: str = DEFAULT_OUT, *, slots: int = SLOTS,
                  profile_dir=None):
    """The PR-8 coalescing sweep; merges the ``"bucketing"`` section."""
    n_signatures = len(_bucket_specs())
    with _profile_ctx(profile_dir):
        rps_on, exec_on, n_buckets = asyncio.run(_bucket_drain(True, slots))
        rps_off, exec_off, _ = asyncio.run(_bucket_drain(False, slots))
    # Compile-cache timing LAST: enabling the persistent cache flips global
    # jax config, which must not touch the drains above.
    cold_s, warm_s, _ = _bucket_compile_times(slots)
    section = {
        "slots": slots,
        "n_signatures": n_signatures,
        "n_buckets": n_buckets,
        "n_executables_bucketed": exec_on,
        "n_executables_unbucketed": exec_off,
        "saturation_rps_bucketed": rps_on,
        "saturation_rps_unbucketed": rps_off,
        "speedup_bucketed": rps_on / rps_off,
        "cold_compile_s": cold_s,
        "warm_compile_s": warm_s,
    }
    emit(f"bench_load/bucketing/S{slots}", (1.0 / rps_on) * 1e6,
         f"exec {exec_on}/{exec_off} rps {rps_on:.1f}/{rps_off:.1f} "
         f"speedup={section['speedup_bucketed']:.2f} "
         f"compile cold={cold_s:.2f}s warm={warm_s:.2f}s")
    data = {"device": jax.devices()[0].platform, "records": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["bucketing"] = section
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"# wrote {out_path}")
    return section


def run(out_path: str = DEFAULT_OUT, *, slots: int = SLOTS,
        tpd: int = TICKS_PER_DISPATCH, n_requests: int = N_REQUESTS,
        rate: float = RATE, seed: int = SEED, profile_dir=None):
    with _profile_ctx(profile_dir):
        load = asyncio.run(_run(slots, tpd, n_requests, rate, seed))
    emit(f"bench_load/R{n_requests}/S{slots}/T{tpd}",
         load["p50_ms"] * 1e3,
         f"p99_ms={load['p99_ms']:.1f} sat_rps={load['saturation_rps']:.1f} "
         f"dpt={load['dispatches_per_tick']:.3f}")
    data = {"device": jax.devices()[0].platform, "records": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["load"] = load
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"# wrote {out_path}")
    return load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--ticks-per-dispatch", type=int,
                    default=TICKS_PER_DISPATCH)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--rate", type=float, default=RATE)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap measured phases in jax.profiler.trace(DIR)")
    ap.add_argument("--skip-bucketing", action="store_true",
                    help="skip the mixed-horizon coalescing sweep")
    args = ap.parse_args()
    run(args.out, slots=args.slots, tpd=args.ticks_per_dispatch,
        n_requests=args.requests, rate=args.rate, seed=args.seed,
        profile_dir=args.profile)
    if not args.skip_bucketing:
        run_bucketing(args.out, slots=args.slots, profile_dir=args.profile)


if __name__ == "__main__":
    main()
