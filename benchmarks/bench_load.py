"""Async serving-plane load test: latency percentiles under Poisson arrivals.

Where :mod:`benchmarks.bench_serving` measures the *drain* cost of a
pre-filled queue (host dispatches per tick), this benchmark measures what a
client of the **async plane** actually sees: per-request latency when
requests arrive as a seeded Poisson process over a *mixed* population of
solver/horizon/tolerance signatures, and the closed-loop saturation
throughput of the engine.  Two phases, one warm-up:

* **warm** — every signature in the mix is served once so XLA compiles are
  out of the measured path (same discipline as ``bench_serving``);
* **open loop** — ``--requests`` arrivals with exponential inter-arrival
  times at ``--rate`` req/s (``random.Random(seed)``: reproducible arrival
  pattern AND signature mix); each client awaits ``submit`` → ``result``
  and records wall latency.  Reported as ``p50_ms`` / ``p99_ms``;
* **closed loop** — the same request mix submitted all at once and drained:
  completed requests / second is the ``saturation_rps`` ceiling.

Results merge into the ``"load"`` section of ``BENCH_serving.json`` next to
the drain sweep's ``"records"`` — including ``dispatches_per_tick`` over the
measured phases, the PR-5 regression guard (continuous batching must not
cost extra host round trips per device tick).

Run:  PYTHONPATH=src python -m benchmarks.bench_load [--out PATH]
      [--requests N] [--rate RPS] [--slots N] [--ticks-per-dispatch N]
      [--seed S]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import random
import time

import jax
import jax.numpy as jnp

from repro.serving import AsyncSDESampleEngine, SDESampleConfig

from .bench_serving import DEFAULT_OUT, ou_term
from .common import emit

SLOTS = 32
TICKS_PER_DISPATCH = 4
N_REQUESTS = 40
RATE = 50.0
SEED = 0

# Mixed signature population: solver x horizon x tolerance.  Weights bias
# toward the cheap fixed-grid solve the way a real mix would.
POPULATION = (
    {"name": "ees25/short", "weight": 4, "solver": "ees25",
     "kw": dict(t1=1.0, n_steps=32)},
    {"name": "ees25/long", "weight": 2, "solver": "ees25",
     "kw": dict(t1=2.0, n_steps=64)},
    {"name": "heun/short", "weight": 2, "solver": "heun",
     "kw": dict(t1=1.0, n_steps=32)},
    {"name": "ees25/adaptive", "weight": 1, "solver": "ees25:adaptive",
     "kw": dict(t1=1.0, n_steps=128, rtol=1e-3)},
)


def _percentile(sorted_xs, q: float) -> float:
    if not sorted_xs:
        return float("nan")
    k = min(len(sorted_xs) - 1, max(0, round(q * (len(sorted_xs) - 1))))
    return sorted_xs[k]


def _draw_mix(rng: random.Random, n: int):
    choices = [s for s in POPULATION for _ in range(s["weight"])]
    return [rng.choice(choices) for _ in range(n)]


def _make_engine(slots: int, tpd: int):
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    cfg = SDESampleConfig(slots=slots, ticks_per_dispatch=tpd,
                          max_queue_paths=64 * slots)
    return AsyncSDESampleEngine(ou_term(), jnp.ones(16, jnp.float32), cfg,
                                args=args)


async def _warm(eng, slots: int):
    for spec in POPULATION:
        rid = await eng.submit(spec["solver"], n_paths=slots, seed=0,
                               **spec["kw"])
        await eng.result(rid)


async def _open_loop(eng, mix, rng: random.Random, rate: float, slots: int):
    latencies = []

    async def client(k, spec):
        t0 = time.perf_counter()
        rid = await eng.submit(spec["solver"], n_paths=slots, seed=k,
                               **spec["kw"])
        await eng.result(rid)
        latencies.append(time.perf_counter() - t0)

    tasks = []
    for k, spec in enumerate(mix):
        await asyncio.sleep(rng.expovariate(rate))
        tasks.append(asyncio.create_task(client(k, spec)))
    await asyncio.gather(*tasks)
    return sorted(latencies)


async def _closed_loop(eng, mix, slots: int) -> float:
    t0 = time.perf_counter()
    rids = [await eng.submit(spec["solver"], n_paths=slots, seed=k,
                             **spec["kw"])
            for k, spec in enumerate(mix)]
    for rid in rids:
        await eng.result(rid)
    return len(mix) / (time.perf_counter() - t0)


async def _run(slots: int, tpd: int, n_requests: int, rate: float,
               seed: int):
    rng = random.Random(seed)
    mix = _draw_mix(rng, n_requests)
    async with _make_engine(slots, tpd) as eng:
        await _warm(eng, slots)
        d0, t0 = eng.executor.n_dispatches, eng.executor.n_ticks
        lat = await _open_loop(eng, mix, rng, rate, slots)
        sat = await _closed_loop(eng, mix, slots)
        d1, t1 = eng.executor.n_dispatches, eng.executor.n_ticks
    return {
        "slots": slots,
        "ticks_per_dispatch": tpd,
        "n_requests": n_requests,
        "offered_rps": rate,
        "seed": seed,
        "mix": sorted({s["name"] for s in mix}),
        "p50_ms": _percentile(lat, 0.50) * 1e3,
        "p99_ms": _percentile(lat, 0.99) * 1e3,
        "saturation_rps": sat,
        # PR-5 regression guard: host round trips per device tick across the
        # measured phases (1/tpd in steady state; tails/interleave add a bit)
        "dispatches_per_tick": (d1 - d0) / max(1, t1 - t0),
    }


def run(out_path: str = DEFAULT_OUT, *, slots: int = SLOTS,
        tpd: int = TICKS_PER_DISPATCH, n_requests: int = N_REQUESTS,
        rate: float = RATE, seed: int = SEED):
    load = asyncio.run(_run(slots, tpd, n_requests, rate, seed))
    emit(f"bench_load/R{n_requests}/S{slots}/T{tpd}",
         load["p50_ms"] * 1e3,
         f"p99_ms={load['p99_ms']:.1f} sat_rps={load['saturation_rps']:.1f} "
         f"dpt={load['dispatches_per_tick']:.3f}")
    data = {"device": jax.devices()[0].platform, "records": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["load"] = load
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"# wrote {out_path}")
    return load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--ticks-per-dispatch", type=int,
                    default=TICKS_PER_DISPATCH)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--rate", type=float, default=RATE)
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    run(args.out, slots=args.slots, tpd=args.ticks_per_dispatch,
        n_requests=args.requests, rate=args.rate, seed=args.seed)


if __name__ == "__main__":
    main()
