"""Monte-Carlo sampling throughput: trajectories/sec vs batch size.

Times the jit'd batched ``sdeint`` fan-out (the serving engine's hot path)
for registry solvers across batch sizes, and emits ``BENCH_throughput.json``
next to the repo root with one record per (solver, batch size):

    {"solver": "ees25", "batch_size": 256, "n_steps": 64,
     "traj_per_sec": ..., "steps_per_sec": ..., "us_per_call": ...,
     "us_per_call_per_step_noise": ..., "speedup_bulk": ...}

``us_per_call`` / ``steps_per_sec`` measure the PR-4 default — bulk Brownian
realization (all increments in one batched pass, streamed through the scan);
``us_per_call_per_step_noise`` re-times the same solve with
``bulk_increments=False`` (the pre-PR-4 per-step RNG), so every record
carries its own before/after (``speedup_bulk``).

With more than one visible device the same batch ladder additionally runs
sharded over a 1-D sampling mesh (``sdeint(..., mesh_axis=...)`` over
``repro.launch.mesh.make_sample_mesh()``) and the JSON gains a
``mesh_records`` list (one record per solver x divisible batch size, with
``devices`` and ``speedup_vs_single``) — the multi-device scaling chart.
On a single device ``mesh_records`` is empty and ``records`` is unchanged,
so single-device CI keeps its current numbers.

Run:  PYTHONPATH=src python -m benchmarks.bench_throughput [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core import SDETerm, sdeint

from .common import emit, time_fn

SOLVERS = ("ees25", "reversible_heun")
BATCH_SIZES = (16, 64, 256, 1024)
N_STEPS = 64
DIM = 16

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_throughput.json",
)


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
        noise="diagonal",
    )


def run(out_path: str = DEFAULT_OUT, *, batch_sizes=BATCH_SIZES,
        solvers=SOLVERS, n_steps: int = N_STEPS, dim: int = DIM):
    term = ou_term()
    args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}
    y0 = jnp.ones(dim, jnp.float32)
    records = []
    for solver in solvers:
        for batch in batch_sizes:
            fn = jax.jit(lambda keys, a, s=solver: sdeint(
                term, s, 0.0, 1.0, n_steps, y0, None, args=a, batch_keys=keys
            ).y_final)
            fn_per_step = jax.jit(lambda keys, a, s=solver: sdeint(
                term, s, 0.0, 1.0, n_steps, y0, None, args=a, batch_keys=keys,
                bulk_increments=False
            ).y_final)
            keys = jax.random.split(jax.random.PRNGKey(0), batch)
            us = time_fn(fn, keys, args, warmup=3, iters=11)
            us_per_step = time_fn(fn_per_step, keys, args, warmup=3, iters=11)
            traj_per_sec = batch / (us * 1e-6)
            records.append({
                "solver": solver,
                "batch_size": batch,
                "n_steps": n_steps,
                "dim": dim,
                "us_per_call": us,
                "traj_per_sec": traj_per_sec,
                "steps_per_sec": traj_per_sec * n_steps,
                "us_per_call_per_step_noise": us_per_step,
                "speedup_bulk": us_per_step / us,
            })
            emit(f"bench_throughput/{solver}/B{batch}", us,
                 f"traj_per_sec={traj_per_sec:.0f} "
                 f"speedup_bulk={us_per_step / us:.2f}")
    mesh_records = run_mesh_ladder(term, args, y0, records,
                                   batch_sizes=batch_sizes, solvers=solvers,
                                   n_steps=n_steps, dim=dim)
    with open(out_path, "w") as f:
        json.dump({"device": jax.devices()[0].platform,
                   "n_devices": jax.device_count(),
                   "records": records,
                   "mesh_records": mesh_records}, f, indent=2)
    print(f"# wrote {out_path}")
    return records


def run_mesh_ladder(term, args, y0, single_records, *, batch_sizes, solvers,
                    n_steps, dim):
    """The same ladder sharded over every visible device (devices > 1 only).

    Uses the existing ``sdeint`` shard_map fan-out — key-based batching is
    placement-independent, so these runs draw the exact samples the
    single-device ladder drew; only the wall-clock changes.
    """
    n_devices = jax.device_count()
    if n_devices < 2:
        return []
    from repro.launch.mesh import make_sample_mesh

    mesh = make_sample_mesh()
    single_us = {(r["solver"], r["batch_size"]): r["us_per_call"]
                 for r in single_records}
    records = []
    for solver in solvers:
        for batch in batch_sizes:
            if batch % n_devices != 0:
                continue  # axis must divide the batch
            fn = jax.jit(lambda keys, a, s=solver: sdeint(
                term, s, 0.0, 1.0, n_steps, y0, None, args=a, batch_keys=keys,
                mesh=mesh, mesh_axis="mc",
            ).y_final)
            keys = jax.random.split(jax.random.PRNGKey(0), batch)
            us = time_fn(fn, keys, args, warmup=3, iters=11)
            traj_per_sec = batch / (us * 1e-6)
            ref = single_us.get((solver, batch))
            records.append({
                "solver": solver,
                "batch_size": batch,
                "n_steps": n_steps,
                "dim": dim,
                "devices": n_devices,
                "us_per_call": us,
                "traj_per_sec": traj_per_sec,
                "steps_per_sec": traj_per_sec * n_steps,
                "speedup_vs_single": None if ref is None else ref / us,
            })
            emit(f"bench_throughput/{solver}/B{batch}/mesh{n_devices}", us,
                 f"traj_per_sec={traj_per_sec:.0f}")
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.out)


if __name__ == "__main__":
    main()
