"""Table 3 + Fig 5b: stochastic Kuramoto on T*T^N — CF-EES vs CG2, and the
memory-complexity separation across adjoints.

Quality: multi-horizon wrapped energy score after a short training run.
Memory: peak XLA scratch bytes (temp_size) of the compiled grad step as a
function of n_steps — the paper's Fig 5b metric: CF-EES+Reversible is flat,
CG2+Full grows linearly, CG2+Recursive grows ~sqrt.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrouchGrossman2, brownian_path, cfees25_solver, solve
from repro.nsde import init_kuramoto_nsde, kuramoto_nsde_term, wrapped_energy_score
from repro.nsde.data import kuramoto_paths
from repro.optim import adamw

from .common import emit, temp_bytes

N, BATCH, T = 16, 32, 2.0


def make_loss(solver, adjoint, n_steps, target_th, target_om):
    term = kuramoto_nsde_term()
    m_samples = 4

    def loss(p, k, th0, om0):
        def one(key):
            bm = brownian_path(key, 0.0, T, n_steps, shape=((BATCH, N), (BATCH, N)))
            r = solve(solver, term, (th0, om0), bm, p, adjoint=adjoint)
            return r.y_final

        keys = jax.random.split(k, m_samples)
        ths, oms = jax.vmap(one)(keys)  # (m, batch, N)
        es = jax.vmap(
            lambda i: wrapped_energy_score(
                ths[:, i], oms[:, i], target_th[i], target_om[i]
            )
        )(jnp.arange(BATCH))
        return jnp.mean(es)

    return loss


def run():
    rng = np.random.default_rng(3)
    ths, oms = kuramoto_paths(rng, N, BATCH, 400, T=T, subsample=400)
    th0 = jnp.asarray(ths[:, 0], jnp.float32)
    om0 = jnp.asarray(oms[:, 0], jnp.float32)
    tgt_th = jnp.asarray(ths[:, -1], jnp.float32)
    tgt_om = jnp.asarray(oms[:, -1], jnp.float32)

    n_steps = 30
    cases = [
        ("CG2+Full", CrouchGrossman2(), "full", 2 * n_steps // 2),
        ("CG2+Recursive", CrouchGrossman2(), "recursive", 2 * n_steps // 2),
        ("CF-EES(2,5)+Reversible", cfees25_solver(), "reversible", 2 * n_steps // 3),
    ]
    key = jax.random.PRNGKey(0)
    for name, solver, adjoint, steps in cases:
        params = init_kuramoto_nsde(key, N, width=64)
        loss = make_loss(solver, adjoint, steps, tgt_th, tgt_om)
        opt = adamw(2e-3)
        state = opt.init(params)
        step = jax.jit(
            lambda p, s, k: (lambda l, g: (l, *opt.update(g, s, p)))(
                *jax.value_and_grad(loss)(p, k, th0, om0)
            )
        )
        t0 = time.time()
        val = float("nan")
        for e in range(15):
            key, sub = jax.random.split(key)
            val, params, state, _ = step(params, state, sub)
        emit(f"table3_kuramoto/{name}", (time.time() - t0) / 15 * 1e6,
             f"energy_score={float(val):.3f}")

    # Fig 5b analogue: temp bytes vs n_steps per adjoint.
    params = init_kuramoto_nsde(key, N, width=64)
    for adjoint, solver in [
        ("reversible", cfees25_solver()),
        ("recursive", CrouchGrossman2()),
        ("full", CrouchGrossman2()),
    ]:
        series = []
        for steps in (32, 128, 512):
            loss = make_loss(solver, adjoint, steps, tgt_th, tgt_om)
            jitted = jax.jit(jax.grad(loss))
            series.append(temp_bytes(jitted, params, key, th0, om0))
        growth = series[-1] / max(series[0], 1)
        emit(
            f"fig5b_memory/{adjoint}",
            0.0,
            f"temp_bytes_32_128_512={series};growth16x={growth:.2f}",
        )


if __name__ == "__main__":
    run()
