"""Large-step stability frontiers: EES vs Reversible Heun vs Milstein.

Integrates the contractive linear test SDE

    dy = -lam * y dt + mu * y dW          (diagonal multiplicative noise)

across a stiffness sweep ``lam`` x a dyadic *evaluation budget* sweep, with
every solver spending the same number of vector-field evaluations per unit
time (matched cost: ``n_steps = budget / evals_per_step``, so a 5-stage EES
scheme takes 5x larger steps than Euler-family schemes at the same budget).
The true solution is mean-square contractive
(``E|y_T|^2 = exp((-2 lam + mu^2) T)``), so a run is classified **stable**
iff its Monte-Carlo mean square is finite and non-expansive
(``E|y_T|^2 <= E|y_0|^2``).

Per solver the **blow-up frontier** records, for each stiffness, the largest
stable step size (and the smallest stable budget).  The paper's headline
(Theorem 2.1 + Section 3): Reversible Heun's linear stability region is the
imaginary segment [-i, i], so *any* real negative ``lam * h`` is unstable at
any step size — its frontier is empty — while the EES(2,m) schemes hold a
real-axis interval (EES25 reaches ``lam * h ~ 3.2``), so their frontiers
dominate at every stiffness.  The CI bench lane gates on exactly that
containment plus finiteness of every EES frontier entry.

Emits ``BENCH_stability.json`` next to the repo root.

Run:  PYTHONPATH=src python -m benchmarks.bench_stability [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp

from repro.core import SDETerm, sdeint

from .common import emit

jax.config.update("jax_enable_x64", True)

SOLVERS = ("ees25", "ees27", "reversible-heun", "milstein")
STIFFNESS = (2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
BUDGETS = (32, 64, 128, 256, 512, 1024, 2048)  # evals over [0, T1] per path
N_PATHS = 64
DIM = 4
T1 = 1.0
MU = 0.5          # multiplicative noise level
MS_THRESHOLD = 1.0  # stable iff E[y_T^2] <= E[y_0^2] (y0 = 1, contractive SDE)

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_stability.json",
)


def linear_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -a * y,
        diffusion=lambda t, y, a: MU * y,
        noise="diagonal",
    )


def evals_per_step(spec: str) -> int:
    from repro.core import get_solver

    return int(get_solver(spec).evals_per_step)


def mean_square_final(spec, term, lam, n_steps, keys, y0):
    """E[y_T^2] (per-component mean over paths and dims) on a fixed grid."""
    out = jax.jit(jax.vmap(lambda k: sdeint(
        term, spec, 0.0, T1, n_steps, y0, k, args=jnp.float64(lam)
    ).y_final))(keys)
    return float(jnp.mean(out ** 2))


def run(out_path: str = DEFAULT_OUT):
    term = linear_term()
    y0 = jnp.ones(DIM, jnp.float64)
    keys = jax.random.split(jax.random.PRNGKey(0), N_PATHS)

    records = []
    frontiers = {}
    for spec in SOLVERS:
        eps = evals_per_step(spec)
        frontiers[spec] = {}
        for lam in STIFFNESS:
            max_stable_h = 0.0
            min_stable_budget = None
            for budget in BUDGETS:
                n_steps = max(1, round(budget / eps))
                h = T1 / n_steps
                ms = mean_square_final(spec, term, lam, n_steps, keys, y0)
                stable = math.isfinite(ms) and ms <= MS_THRESHOLD
                records.append({
                    "solver": spec,
                    "stiffness": lam,
                    "budget": budget,
                    "n_steps": n_steps,
                    "h": h,
                    "ms_final": ms if math.isfinite(ms) else None,
                    "stable": stable,
                })
                if stable:
                    max_stable_h = max(max_stable_h, h)
                    if min_stable_budget is None or budget < min_stable_budget:
                        min_stable_budget = budget
            frontiers[spec][f"{lam:g}"] = {
                "max_stable_h": max_stable_h,
                "min_stable_budget": min_stable_budget,
            }
            emit(f"bench_stability/{spec}/lam{lam:g}", 0.0,
                 f"max_stable_h={max_stable_h:.4g},"
                 f"min_budget={min_stable_budget}")

    payload = {
        "device": jax.devices()[0].platform,
        "n_paths": N_PATHS,
        "dim": DIM,
        "t1": T1,
        "mu": MU,
        "ms_threshold": MS_THRESHOLD,
        "stiffness": list(STIFFNESS),
        "budgets": list(BUDGETS),
        "solvers": list(SOLVERS),
        "records": records,
        "frontiers": frontiers,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.out)


if __name__ == "__main__":
    main()
