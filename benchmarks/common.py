"""Shared benchmark harness: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived`` carries
the benchmark-specific quality metric (MSE, energy score, slope, bytes, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def emit(name: str, us_per_call: float, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def temp_bytes(jitted, *args) -> int:
    """Peak XLA scratch bytes of a compiled callable — the paper's memory
    metric (Appendix I.8 uses exactly temp_bytes)."""
    c = jitted.lower(*args).compile()
    m = c.memory_analysis()
    return int(getattr(m, "temp_size_in_bytes", 0) or 0)
