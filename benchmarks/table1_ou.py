"""Table 1: high-volatility OU — stability of reversible solvers in training.

Neural Langevin SDE trained against OU(nu=0.2, mu=0.1, sigma=2) moments with
a *fixed vector-field evaluation budget* per integration (paper's protocol:
step sizes chosen so all solvers use the same number of f,g evaluations).
Reported: terminal moment-MSE + wall time.  The paper's claim: EES(2,5)
remains stable where Reversible Heun / MCF degrade in the high-vol regime.

Solvers are registry spec strings and the Monte-Carlo batch runs through
``make_sde_train_step`` / ``sdeint`` — the same path serving uses.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nsde import init_lsde, lsde_readout, lsde_term, moment_mse
from repro.nsde.data import ou_paths
from repro.optim import adamw
from repro.train.trainer import make_sde_train_step

from .common import emit

T, NFE = 2.0, 24
D_OBS, D_Z = 1, 16
EPOCHS, BATCH = 60, 256


def solvers():
    # (label, registry spec, steps at the common NFE budget)
    return [
        ("RevHeun", "reversible_heun", NFE),
        ("MCF-Euler", "mcf-euler", NFE // 2),
        ("MCF-Midpoint", "mcf-midpoint", NFE // 4),
        ("EES(2,5)", "ees25", NFE // 3),
    ]


def train_one(solver_spec, n_steps, target, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_lsde(key, D_OBS, D_Z, width=32)
    opt = adamw(1e-2)
    state = opt.init(params)
    tgt = jnp.asarray(target, jnp.float32)
    n_saves = target.shape[1]

    def loss_of_result(p, r):
        ys = lsde_readout(p, r.ys)[..., 0]  # (n_paths, n_saves)
        return moment_mse(ys, tgt)

    step = jax.jit(make_sde_train_step(
        solver_spec, lsde_term(), opt,
        y0_fn=lambda p: jnp.zeros(D_Z) + p["encoder"]["b"],
        loss_fn_result=loss_of_result,
        t0=0.0, t1=T, n_steps=n_steps, n_paths=BATCH,
        adjoint="reversible", save_every=n_steps // n_saves,
    ))
    t0 = time.time()
    loss = float("nan")
    for e in range(EPOCHS):
        key, sub = jax.random.split(key)
        params, state, m = step(params, state, sub)
        loss = m["loss"]
    return float(loss), time.time() - t0


def run():
    rng = np.random.default_rng(0)
    n_saves = 2  # common divisor of every solver's step count
    target_full = ou_paths(rng, 4096, n_saves, T=T)  # exact OU marginals
    target = target_full[:, 1:]  # drop t=0
    for name, spec, n_steps in solvers():
        loss, wall = train_one(spec, n_steps, target)
        tag = "nan" if not np.isfinite(loss) else f"{loss:.4f}"
        emit(f"table1_ou/{name}", wall / EPOCHS * 1e6, f"terminal_mse={tag}")


if __name__ == "__main__":
    run()
