"""Table 1: high-volatility OU — stability of reversible solvers in training.

Neural Langevin SDE trained against OU(nu=0.2, mu=0.1, sigma=2) moments with
a *fixed vector-field evaluation budget* per integration (paper's protocol:
step sizes chosen so all solvers use the same number of f,g evaluations).
Reported: terminal moment-MSE + wall time.  The paper's claim: EES(2,5)
remains stable where Reversible Heun / MCF degrade in the high-vol regime.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MCFSolver,
    ReversibleHeun,
    brownian_path,
    ees25_solver,
    euler,
    midpoint,
    solve,
)
from repro.nsde import init_lsde, lsde_readout, lsde_term, moment_mse
from repro.nsde.data import ou_paths
from repro.optim import adamw

from .common import emit

T, NFE = 2.0, 24
D_OBS, D_Z = 1, 16
EPOCHS, BATCH = 60, 256


def solvers():
    return [
        ("RevHeun", ReversibleHeun(), NFE),
        ("MCF-Euler", MCFSolver(euler), NFE // 2),
        ("MCF-Midpoint", MCFSolver(midpoint), NFE // 4),
        ("EES(2,5)", ees25_solver(), NFE // 3),
    ]


def train_one(solver, n_steps, target, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_lsde(key, D_OBS, D_Z, width=32)
    term = lsde_term()
    opt = adamw(1e-2)
    state = opt.init(params)
    tgt = jnp.asarray(target, jnp.float32)
    n_saves = target.shape[1]
    save_every = n_steps // n_saves

    def loss_fn(p, k):
        bm = brownian_path(k, 0.0, T, n_steps, shape=(BATCH, D_Z))
        z0 = jnp.zeros((BATCH, D_Z)) + p["encoder"]["b"]
        r = solve(solver, term, z0, bm, p, adjoint="reversible", save_every=save_every)
        ys = lsde_readout(p, r.ys)[..., 0]  # (n_saves, batch)
        return moment_mse(ys.T, tgt)

    step = jax.jit(
        lambda p, s, k: (lambda l, g: (l, *opt.update(g, s, p)))(
            *jax.value_and_grad(loss_fn)(p, k)
        )
    )
    t0 = time.time()
    loss = float("nan")
    for e in range(EPOCHS):
        key, sub = jax.random.split(key)
        loss, params, state, _ = step(params, state, sub)
    return float(loss), time.time() - t0


def run():
    rng = np.random.default_rng(0)
    n_saves = 2  # common divisor of every solver's step count
    target_full = ou_paths(rng, 4096, n_saves, T=T)  # exact OU marginals
    target = target_full[:, 1:]  # drop t=0
    for name, solver, n_steps in solvers():
        loss, wall = train_one(solver, n_steps, target)
        tag = "nan" if not np.isfinite(loss) else f"{loss:.4f}"
        emit(f"table1_ou/{name}", wall / EPOCHS * 1e6, f"terminal_mse={tag}")


if __name__ == "__main__":
    run()
