"""Table 4 + Fig 6: latent SDE on the sphere S^{n-1}.

Synthetic stand-in for the UCI-HAR pipeline (dataset not available offline):
a latent SDE on S^7 is trained to carry a 4-class signal readable from the
terminal latent state by a linear head.  Compared: Geo Euler-Maruyama with the
Full adjoint (Zeng et al. baseline) vs CF-EES(2,5) with the Reversible adjoint
at matched NN-evaluation budget, plus the Fig-6 memory-vs-steps curve.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GeoEulerMaruyama, brownian_path, cfees25_solver, solve
from repro.nsde import init_sphere_nsde, sphere_nsde_term
from repro.optim import adamw

from .common import emit, temp_bytes

N_SPHERE, BATCH, T, CLASSES = 8, 64, 1.0, 4
M_NOISE = N_SPHERE * (N_SPHERE - 1) // 2
NFE = 30


def make_loss(solver, adjoint, n_steps):
    term = sphere_nsde_term(N_SPHERE)

    def loss(p, k, y0, labels):
        bm = brownian_path(k, 0.0, T, n_steps, shape=(BATCH, M_NOISE))
        r = solve(solver, term, y0, bm, p["sde"], adjoint=adjoint)
        logits = r.y_final @ p["head"]  # (batch, classes)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    return loss


def data(key):
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (BATCH,), 0, CLASSES)
    # class-dependent initial points on the sphere + noise
    anchors = jax.random.normal(k2, (CLASSES, N_SPHERE))
    anchors = anchors / jnp.linalg.norm(anchors, axis=-1, keepdims=True)
    y0 = anchors[labels] + 0.1 * jax.random.normal(k2, (BATCH, N_SPHERE))
    y0 = y0 / jnp.linalg.norm(y0, axis=-1, keepdims=True)
    return y0, labels


def run():
    key = jax.random.PRNGKey(0)
    y0, labels = data(key)
    cases = [
        ("GeoEM+Full", GeoEulerMaruyama(), "full", NFE),
        ("CF-EES(2,5)+Reversible", cfees25_solver(), "reversible", NFE // 3),
    ]
    for name, solver, adjoint, steps in cases:
        k1, _ = jax.random.split(key)
        params = {
            "sde": init_sphere_nsde(k1, N_SPHERE, width=32),
            "head": 0.1 * jax.random.normal(k1, (N_SPHERE, CLASSES)),
        }
        loss = make_loss(solver, adjoint, steps)
        opt = adamw(5e-3)
        state = opt.init(params)
        step = jax.jit(
            lambda p, s, k: (lambda l, g: (l, *opt.update(g, s, p)))(
                *jax.value_and_grad(loss)(p, k, y0, labels)
            )
        )
        kk = key
        t0 = time.time()
        val = float("nan")
        for e in range(25):
            kk, sub = jax.random.split(kk)
            val, params, state, _ = step(params, state, sub)
        # accuracy
        term = sphere_nsde_term(N_SPHERE)
        bm = brownian_path(kk, 0.0, T, steps, shape=(BATCH, M_NOISE))
        yf = solve(solver, term, y0, bm, params["sde"]).y_final
        acc = float(jnp.mean((yf @ params["head"]).argmax(-1) == labels))
        emit(f"table4_sphere/{name}", (time.time() - t0) / 25 * 1e6,
             f"loss={float(val):.3f};acc={acc:.2f}")

    # Fig 6 analogue: memory vs steps.
    k1, _ = jax.random.split(key)
    params = {
        "sde": init_sphere_nsde(k1, N_SPHERE, width=32),
        "head": 0.1 * jax.random.normal(k1, (N_SPHERE, CLASSES)),
    }
    for adjoint, solver in [
        ("reversible", cfees25_solver()),
        ("full", GeoEulerMaruyama()),
    ]:
        series = []
        for steps in (32, 128, 512):
            jitted = jax.jit(jax.grad(make_loss(solver, adjoint, steps)))
            series.append(temp_bytes(jitted, params, key, y0, labels))
        emit(f"fig6_memory/{adjoint}", 0.0,
             f"temp_bytes_32_128_512={series};growth16x={series[-1]/max(series[0],1):.2f}")


if __name__ == "__main__":
    run()
