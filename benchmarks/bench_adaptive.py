"""Adaptive vs fixed-grid solves: steps saved at matched strong error.

Integrates a batch of OU paths with the PI-controlled adaptive EES stepper on
a Virtual Brownian Tree, across a sweep of tolerances, and compares against
fixed uniform grids *on the same driver* (so strong error is measured
path-by-path against one shared fine reference).  Emits
``BENCH_adaptive.json`` next to the repo root:

* per-tolerance records — mean accepted/rejected steps, strong error, and
  accepted-steps/sec through the forward-only (``bounded=False``) stepper;
* per-grid fixed records — steps and strong error;
* ``steps_saved`` — for each tolerance, the interpolated number of fixed
  steps that would match the adaptive strong error, over the adaptive steps
  actually taken.

Run:  PYTHONPATH=src python -m benchmarks.bench_adaptive [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SDETerm,
    TimeGrid,
    get_solver,
    integrate_adaptive,
    solve,
    virtual_brownian_tree,
)

from .common import emit, time_fn

jax.config.update("jax_enable_x64", True)

RTOLS = (1e-2, 3e-3, 1e-3, 3e-4)
FIXED_STEPS = (8, 16, 32, 64, 128, 256, 512)
N_PATHS = 64
DIM = 4
T1 = 2.0
REF_STEPS = 8192
MAX_STEPS = 512

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_adaptive.json",
)


def transient_term() -> SDETerm:
    """Mean-reverting process with a sharp stiff transient around t = 1.

    The drift rate spikes by 40x inside a window of width ~0.08 — a uniform
    grid must resolve the spike everywhere, while the adaptive controller
    shrinks steps only inside the window.  This is the workload class the
    tolerance-driven path exists for; on a homogeneous process a uniform
    grid is already step-optimal and adaptivity only pays its rejection
    overhead.
    """
    def rate(t, a):
        return a["nu"] * (1.0 + 40.0 * jnp.exp(-(((t - 1.0) / 0.08) ** 2)))

    return SDETerm(
        drift=lambda t, y, a: rate(t, a) * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y)),
        noise="diagonal",
    )


def fixed_solve(spec, term, y0, driver, n_steps, args):
    """Uniform-grid solve on a matched driver through the unified solve()."""
    grid = TimeGrid.uniform(driver.t0, driver.t1, n_steps, driver)
    return solve(get_solver(spec), term, y0, grid, args).y_final


def run(out_path: str = DEFAULT_OUT):
    term = transient_term()
    args = {"nu": jnp.float64(0.7), "mu": jnp.float64(0.2),
            "sigma": jnp.float64(0.4)}
    y0 = jnp.ones(DIM, jnp.float64)
    keys = jax.random.split(jax.random.PRNGKey(0), N_PATHS)

    def tree(k):
        return virtual_brownian_tree(k, 0.0, T1, shape=(DIM,),
                                     dtype=jnp.float64, tol=T1 * 2.0 ** -14)

    # One fine fixed-grid reference per path, on the SAME driver every other
    # run queries — strong error is an apples-to-apples pathwise comparison.
    ref = jax.jit(jax.vmap(
        lambda k: fixed_solve("ees25", term, y0, tree(k), REF_STEPS, args)
    ))(keys)

    def strong_err(y):
        return float(jnp.sqrt(jnp.mean(jnp.sum((y - ref) ** 2, axis=-1))))

    records = {"adaptive": [], "fixed": []}
    for n in FIXED_STEPS:
        fn = jax.jit(jax.vmap(
            lambda k: fixed_solve("ees25", term, y0, tree(k), n, args)
        ))
        err = strong_err(fn(keys))
        records["fixed"].append({"n_steps": n, "strong_err": err})
        emit(f"bench_adaptive/fixed/N{n}", 0.0, f"strong_err={err:.3e}")

    for rtol in RTOLS:
        def solve_batch(ks, rtol=rtol):
            return jax.vmap(lambda k: integrate_adaptive(
                "ees25", term, y0, tree(k), args, rtol=rtol, atol=rtol * 1e-2,
                max_steps=MAX_STEPS, bounded=False,
            ))(ks)

        fn = jax.jit(solve_batch)
        out = fn(keys)
        err = strong_err(out.y_final)
        acc = float(jnp.mean(out.n_accepted))
        rej = float(jnp.mean(out.n_rejected))
        us = time_fn(fn, keys, warmup=1, iters=3)
        acc_per_sec = acc * N_PATHS / (us * 1e-6)
        records["adaptive"].append({
            "rtol": rtol,
            "mean_accepted": acc,
            "mean_rejected": rej,
            "strong_err": err,
            "us_per_batch": us,
            "accepted_steps_per_sec": acc_per_sec,
        })
        emit(f"bench_adaptive/rtol{rtol:g}", us,
             f"acc={acc:.1f},rej={rej:.1f},strong_err={err:.3e}")

    # Steps saved: log-log interpolate the fixed-grid error curve to find the
    # grid size matching each adaptive run's error.
    fx_n = np.array([r["n_steps"] for r in records["fixed"]], float)
    fx_e = np.array([r["strong_err"] for r in records["fixed"]], float)
    for rec in records["adaptive"]:
        matched = float(np.exp(np.interp(
            np.log(rec["strong_err"]), np.log(fx_e[::-1]), np.log(fx_n[::-1])
        )))
        rec["matched_fixed_steps"] = matched
        rec["steps_saved_ratio"] = matched / max(
            rec["mean_accepted"] + rec["mean_rejected"], 1.0
        )
        emit(f"bench_adaptive/saved/rtol{rec['rtol']:g}", 0.0,
             f"matched_fixed={matched:.1f},ratio={rec['steps_saved_ratio']:.2f}")

    payload = {
        "device": jax.devices()[0].platform,
        "n_paths": N_PATHS,
        "dim": DIM,
        "t1": T1,
        "ref_steps": REF_STEPS,
        "records": records,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.out)


if __name__ == "__main__":
    main()
