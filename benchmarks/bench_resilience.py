"""Resilience benchmark: guard overhead, goodput under faults, trainer skips.

Three sections, merged into ``BENCH_resilience.json`` (PR 9 — see
``docs/robustness.md``):

* **guard_overhead** — the blow-up guard is on by default in every serving
  drain, so its cost on *clean* traffic is the tax every request pays.  The
  same queue is drained by a sync engine with guards + retry policy enabled
  and by one with both disabled (``guard_threshold=None, retry_policy=None``);
  best-of-``--reps`` wall time each, cache-warm.  The CI gate asserts
  ``guard_overhead_frac < 0.05`` (and the bitwise-identity of the two drains
  is property-tested in ``tests/test_divergence_guard.py`` — this section
  only prices it).
* **serving** — closed-loop async drains of one fixed request mix, clean and
  with a seeded NaN-injection schedule (:func:`repro.serving.inject_faults`
  at ``--nan-rate``).  Faulted paths retry down the degradation ladder, so
  every request still completes; what degrades is **goodput** (completed
  requests / second) and tail latency.  Records ``goodput_clean`` /
  ``goodput_faulty``, ``p50_ms`` / ``p99_ms`` for both, and the engine's
  retry/divergence counters.  CI gates ``goodput_clean >= goodput_faulty``
  and that every field is finite.
* **trainer** — a guarded ``make_sde_train_step`` driven by
  :func:`repro.train.resilient_train_loop` under a deterministic NaN-loss
  schedule (three consecutive blown batches per cycle, enough to trip the
  ``skip_patience`` rollback).  Records skips, rollbacks, and training
  goodput (productive steps / total).

Run:  PYTHONPATH=src python -m benchmarks.bench_resilience [--out PATH]
      [--slots N] [--requests N] [--n-steps N] [--nan-rate R] [--seed S]
      [--reps N] [--train-steps N]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import math
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.serving import (
    AsyncSDESampleEngine,
    FaultConfig,
    SDESampleConfig,
    SDESampleEngine,
    inject_faults,
)

from .bench_serving import ou_term
from .common import emit

SLOTS = 8
N_REQUESTS = 8
# Long enough that fixed per-dispatch host costs are a small fraction of a
# drain — the guard-overhead gate compares wall times at the few-% level.
N_STEPS = 1024
# The guard-overhead section solves even longer: guarded and unguarded are
# two *different* XLA programs, and on CPU their fixed per-executable
# scheduling deltas run a few ms either way — at 1024 steps (~33 ms/drain)
# that masquerades as ±5-9% "overhead"; at 4096 the step loop dominates and
# the measured delta collapses to the true per-segment guard cost (~0-2%).
GUARD_N_STEPS = 4096
DIM = 16
SOLVER = "ees25"
NAN_RATE = 0.3
SEED = 0
REPS = 5
TRAIN_STEPS = 21

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_resilience.json",
)


def _term_args():
    return {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(2.0)}


def _percentile(sorted_xs, q: float) -> float:
    if not sorted_xs:
        return float("nan")
    k = min(len(sorted_xs) - 1, max(0, round(q * (len(sorted_xs) - 1))))
    return sorted_xs[k]


# ---------------------------------------------------------------- section 1

def _drain_pass(eng, *, requests: int, slots: int, n_steps: int) -> float:
    for i in range(requests):
        eng.submit(SOLVER, t1=1.0, n_steps=n_steps, n_paths=slots, seed=i)
    t0 = time.perf_counter()
    eng.run()
    return time.perf_counter() - t0


def run_guard_overhead(*, slots: int = SLOTS, requests: int = N_REQUESTS,
                       n_steps: int = GUARD_N_STEPS, dim: int = DIM,
                       reps: int = REPS) -> dict:
    y0 = jnp.ones(dim, jnp.float32)
    eng_on = SDESampleEngine(ou_term(), y0, SDESampleConfig(slots=slots),
                             args=_term_args())
    eng_off = SDESampleEngine(
        ou_term(), y0,
        SDESampleConfig(slots=slots, guard_threshold=None, retry_policy=None),
        args=_term_args())
    kw = dict(requests=requests, slots=slots, n_steps=n_steps)
    _drain_pass(eng_on, **kw)   # warm: compile out of the measured path
    _drain_pass(eng_off, **kw)
    # Interleave the measured passes so machine drift (turbo, background
    # load) hits both engines symmetrically, and compare best-of-reps: min
    # is the noise-robust wall-time estimator (noise only ever adds).
    ts_on, ts_off = [], []
    for _ in range(reps):
        ts_on.append(_drain_pass(eng_on, **kw))
        ts_off.append(_drain_pass(eng_off, **kw))
    on, off = min(ts_on), min(ts_off)
    frac = on / off - 1.0
    section = {
        "slots": slots,
        "requests": requests,
        "n_steps": n_steps,
        "secs_guarded": on,
        "secs_unguarded": off,
        "guard_overhead_frac": frac,
    }
    emit(f"bench_resilience/guard/S{slots}/N{n_steps}", on * 1e6,
         f"overhead_frac={frac:+.4f}")
    return section


# ---------------------------------------------------------------- section 2

def _request_mix(requests: int, n_steps: int):
    # Two horizons of the same solver: enough signature diversity to exercise
    # co-batching, small enough that CI compiles stay cheap.
    return [dict(t1=1.0 if k % 2 == 0 else 2.0, n_steps=n_steps)
            for k in range(requests)]


class _LoopHarness:
    """One async engine + its warm state, driven pass-by-pass.

    ``fault_cfg`` set ⇒ every pass runs under a FRESH injector around the
    same clean executor, so each pass replays the identical
    dispatch-indexed fault schedule."""

    def __init__(self, mix, *, slots: int, dim: int, fault_cfg=None):
        self.mix = mix
        self.slots = slots
        self.fault_cfg = fault_cfg
        self.latencies = []
        self.injector = None
        cfg = SDESampleConfig(slots=slots, max_queue_paths=64 * slots)
        self.eng = AsyncSDESampleEngine(
            ou_term(), jnp.ones(dim, jnp.float32), cfg, args=_term_args())
        self._base_exec = None
        self._pass_no = 0

    async def warm(self):
        # Every signature in the mix, plus its first ladder degradation
        # (halved steps), then one full-mix pass under the fault schedule:
        # co-batched plan shapes and retry-ladder executables all compile
        # here, so measured passes price guards and retries, not XLA.
        pairs = {(s["t1"], s["n_steps"]) for s in self.mix}
        pairs |= {(t1, n // 2) for t1, n in pairs}
        for t1, n in sorted(pairs):
            rid = await self.eng.submit(SOLVER, t1=t1, n_steps=n,
                                        n_paths=self.slots, seed=0)
            await self.eng.result(rid)
        self._base_exec = self.eng._eng.executor
        await self.run_pass(record=False)
        for c in self.eng._eng.counters:
            self.eng._eng.counters[c] = 0

    async def run_pass(self, record=True) -> float:
        self.eng._eng.executor = self._base_exec
        self.eng.executor = self._base_exec
        if self.fault_cfg:
            self.injector = inject_faults(self.eng, self.fault_cfg)
        seed0 = 1000 * self._pass_no
        self._pass_no += 1

        async def client(k, spec):
            t0 = time.perf_counter()
            rid = await self.eng.submit(SOLVER, n_paths=self.slots,
                                        seed=seed0 + k, **spec)
            await self.eng.result(rid)
            if record:
                self.latencies.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        await asyncio.gather(*(client(k, s)
                               for k, s in enumerate(self.mix)))
        return time.perf_counter() - t0

    def summary(self, pass_secs) -> dict:
        lat = sorted(self.latencies)
        return {
            "goodput_rps": len(self.mix) / min(pass_secs),
            "p50_ms": _percentile(lat, 0.50) * 1e3,
            "p99_ms": _percentile(lat, 0.99) * 1e3,
            "counters": dict(self.eng._eng.counters),
            "n_injected_nans": self.injector.n_nans if self.injector else 0,
        }


async def _clean_vs_faulty(mix, *, slots: int, dim: int, fault_cfg,
                           passes: int = 3):
    """Alternate clean/faulty measured passes over co-resident engines so
    machine drift hits both symmetrically; best-of-``passes`` each."""
    clean = _LoopHarness(mix, slots=slots, dim=dim)
    faulty = _LoopHarness(mix, slots=slots, dim=dim, fault_cfg=fault_cfg)
    async with clean.eng, faulty.eng:
        await clean.warm()
        await faulty.warm()
        secs_c, secs_f = [], []
        for _ in range(passes):
            secs_c.append(await clean.run_pass())
            secs_f.append(await faulty.run_pass())
        return clean.summary(secs_c), faulty.summary(secs_f)


def run_serving(*, slots: int = SLOTS, requests: int = N_REQUESTS,
                n_steps: int = N_STEPS, dim: int = DIM,
                nan_rate: float = NAN_RATE, seed: int = SEED) -> dict:
    mix = _request_mix(requests, n_steps)
    clean, faulty = asyncio.run(_clean_vs_faulty(
        mix, slots=slots, dim=dim,
        fault_cfg=FaultConfig(seed=seed, nan_rate=nan_rate)))
    section = {
        "slots": slots,
        "requests": requests,
        "n_steps": n_steps,
        "nan_rate": nan_rate,
        "seed": seed,
        "goodput_clean": clean["goodput_rps"],
        "goodput_faulty": faulty["goodput_rps"],
        "p50_ms_clean": clean["p50_ms"],
        "p99_ms_clean": clean["p99_ms"],
        "p50_ms_faulty": faulty["p50_ms"],
        "p99_ms_faulty": faulty["p99_ms"],
        "n_injected_nans": faulty["n_injected_nans"],
        "retries": faulty["counters"]["retries"],
        "diverged_requests": faulty["counters"]["diverged_requests"],
        "diverged_paths": faulty["counters"]["diverged_paths"],
        "timeouts": faulty["counters"]["timeouts"],
        "clean_counters": clean["counters"],
    }
    emit(f"bench_resilience/faults/R{requests}/rate{nan_rate}",
         faulty["p99_ms"] * 1e3,
         f"goodput {clean['goodput_rps']:.1f}->{faulty['goodput_rps']:.1f} "
         f"retries={section['retries']} nans={section['n_injected_nans']}")
    return section


# ---------------------------------------------------------------- section 3

def run_trainer(*, train_steps: int = TRAIN_STEPS, dim: int = DIM) -> dict:
    from repro.core import SDETerm
    from repro.optim import adamw, cosine_schedule
    from repro.train.trainer import (
        ResilienceConfig,
        make_sde_train_step,
        resilient_train_loop,
    )

    term = SDETerm(
        drift=lambda t, y, p: p["nu"] * (p["mu"] - y),
        diffusion=lambda t, y, p: p["sigma"] * jnp.ones_like(y),
        noise="diagonal",
    )
    params = {"nu": jnp.float32(0.5), "mu": jnp.float32(0.0),
              "sigma": jnp.float32(0.5)}
    optimizer = adamw(cosine_schedule(1e-3, 5, train_steps))
    opt_state = optimizer.init(params)

    def loss(p, r):
        return jnp.mean(r.y_final ** 2)

    common = dict(t0=0.0, t1=1.0, n_steps=32, n_paths=8)
    clean_step = jax.jit(make_sde_train_step(
        SOLVER, term, optimizer, lambda p: jnp.zeros(dim, jnp.float32),
        loss, **common))
    blown_step = jax.jit(make_sde_train_step(
        SOLVER, term, optimizer, lambda p: jnp.zeros(dim, jnp.float32),
        lambda p, r: loss(p, r) * jnp.nan, **common))

    # Deterministic fault schedule: a 3-step NaN streak every 7 steps — long
    # enough to trip the default skip_patience=3 rollback each cycle.
    fault_steps = {s for s in range(train_steps) if s % 7 in (3, 4, 5)}
    counter = {"step": 0}

    def step_fn(p, s, key):
        step = counter["step"]
        counter["step"] += 1
        fn = blown_step if step in fault_steps else clean_step
        return fn(p, s, key)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        t0 = time.perf_counter()
        out = resilient_train_loop(
            step_fn, params, opt_state, jax.random.PRNGKey(0),
            res=ResilienceConfig(steps=train_steps, ckpt_every=2,
                                 ckpt_dir=ckpt_dir))
        secs = time.perf_counter() - t0
    section = {
        "steps": train_steps,
        "fault_steps": sorted(fault_steps),
        "skips": int(sum(out["skipped"])),
        "rollbacks": out["rollbacks"],
        "goodput": out["goodput"],
        "final_loss": out["losses"][-1],
        "seconds": secs,
    }
    emit(f"bench_resilience/trainer/T{train_steps}",
         secs * 1e6 / train_steps,
         f"skips={section['skips']} rollbacks={section['rollbacks']} "
         f"goodput={section['goodput']:.2f}")
    return section


# ------------------------------------------------------------------- driver

def run(out_path: str = DEFAULT_OUT, *, slots: int = SLOTS,
        requests: int = N_REQUESTS, n_steps: int = N_STEPS, dim: int = DIM,
        nan_rate: float = NAN_RATE, seed: int = SEED, reps: int = REPS,
        train_steps: int = TRAIN_STEPS,
        guard_n_steps: int = GUARD_N_STEPS) -> dict:
    data = {"device": jax.devices()[0].platform}
    data["guard_overhead"] = run_guard_overhead(
        slots=slots, requests=requests, n_steps=guard_n_steps, dim=dim,
        reps=reps)
    data["serving"] = run_serving(
        slots=slots, requests=requests, n_steps=n_steps, dim=dim,
        nan_rate=nan_rate, seed=seed)
    data["trainer"] = run_trainer(train_steps=train_steps, dim=dim)
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"# wrote {out_path}")
    return data


def check(data: dict) -> None:
    """The CI bench-smoke gate (also importable from tests)."""
    def finite(x, path):
        if isinstance(x, dict):
            for k, v in x.items():
                finite(v, f"{path}.{k}")
        elif isinstance(x, (int, float)):
            assert math.isfinite(x), f"non-finite field {path}={x}"

    finite({k: v for k, v in data.items() if k != "device"}, "bench")
    g = data["guard_overhead"]
    assert g["guard_overhead_frac"] < 0.05, (
        f"clean-traffic guard overhead {g['guard_overhead_frac']:.3f} >= 5%")
    s = data["serving"]
    assert s["goodput_clean"] >= s["goodput_faulty"], (
        f"faulty goodput {s['goodput_faulty']:.2f} beat clean "
        f"{s['goodput_clean']:.2f} — timing is broken")
    assert s["n_injected_nans"] > 0, "fault schedule injected nothing"
    assert s["retries"] > 0, "injected NaNs produced no retries"
    t = data["trainer"]
    assert t["skips"] == len(t["fault_steps"]), "guard missed a blown batch"
    assert t["rollbacks"] >= 1, "skip streak never tripped a rollback"
    assert 0 < t["goodput"] < 1, f"trainer goodput {t['goodput']} out of range"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--n-steps", type=int, default=N_STEPS)
    ap.add_argument("--guard-n-steps", type=int, default=GUARD_N_STEPS)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--nan-rate", type=float, default=NAN_RATE)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--train-steps", type=int, default=TRAIN_STEPS)
    ap.add_argument("--check", action="store_true",
                    help="run the CI gate assertions on the fresh results")
    args = ap.parse_args()
    data = run(args.out, slots=args.slots, requests=args.requests,
               n_steps=args.n_steps, dim=args.dim, nan_rate=args.nan_rate,
               seed=args.seed, reps=args.reps, train_steps=args.train_steps,
               guard_n_steps=args.guard_n_steps)
    if args.check:
        check(data)
        print("# bench_resilience gates passed")


if __name__ == "__main__":
    main()
