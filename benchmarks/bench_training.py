"""Training throughput: optimizer steps/sec vs ``steps_per_call`` chunking.

The PR-10 scanned train step fuses K optimizer updates into one jit'd
``lax.scan`` dispatch (:func:`repro.train.trainer.make_scanned_step`).  This
bench measures what that buys on the host-dispatch-bound axis: for each
(adjoint x n_paths x microbatches) configuration it times K sequential
un-scanned steps against one scanned chunk of the same K steps — which is
**bitwise the same trajectory** (tested), so the comparison is pure dispatch
accounting — and emits ``BENCH_training.json``:

    {"device": "cpu", "n_devices": 1,
     "records": [{"adjoint": "reversible", "n_paths": 32, "microbatches": 1,
                  "steps_per_call": 8, "us_per_step_sequential": ...,
                  "us_per_step_scanned": ..., "steps_per_sec_sequential": ...,
                  "steps_per_sec_scanned": ..., "speedup_scan": ...}, ...],
     "speedup_scan_k8": <max speedup at K=8>,   # CI gate: > 1 on CPU
     "mesh_records": [...]}                     # devices > 1 only

With more than one visible device the reversible configuration additionally
runs the mesh-sharded data-parallel step
(``make_sde_train_step(..., mesh=make_train_mesh(), mesh_axis="dp")``) and
``mesh_records`` carries, per config, the sharded step time plus
``grads_bitwise_vs_single`` — the post-update params must be bit-equal to
the single-device step's (the PR-10 DP invariant; CI-gated).

Run:  PYTHONPATH=src python -m benchmarks.bench_training [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SDETerm
from repro.optim import adamw, cosine_schedule
from repro.train.trainer import (
    init_scan_counters,
    make_scanned_step,
    make_sde_train_step,
)

from .common import emit, time_fn

N_STEPS = 16
DIM = 4
K_SWEEP = (2, 8)
# (adjoint, n_paths, microbatches)
CONFIGS = (
    ("reversible", 8, 1),
    ("reversible", 32, 1),
    ("reversible", 32, 4),
    ("full", 8, 1),
    ("full", 32, 1),
)

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_training.json",
)


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, p: p["nu"] * (p["mu"] - y),
        diffusion=lambda t, y, p: p["sigma"] * jnp.ones_like(y),
        noise="diagonal",
    )


def _setup(n_steps: int, dim: int):
    term = ou_term()
    params = {"nu": jnp.float32(0.5), "mu": jnp.float32(0.0),
              "sigma": jnp.float32(0.5)}
    opt = adamw(cosine_schedule(1e-3, 2, 1024))
    y0_fn = lambda p: jnp.zeros(dim, jnp.float32)  # noqa: E731
    loss = lambda p, r: jnp.mean(r.y_final ** 2)  # noqa: E731
    return term, params, opt, y0_fn, loss


def _fresh(tree):
    return jax.tree_util.tree_map(lambda x: jnp.array(x), tree)


def run(out_path: str = DEFAULT_OUT, *, configs=CONFIGS, k_sweep=K_SWEEP,
        n_steps: int = N_STEPS, dim: int = DIM):
    term, params, opt, y0_fn, loss = _setup(n_steps, dim)
    key = jax.random.PRNGKey(0)
    records = []
    k_max = max(k_sweep)
    for adjoint, n_paths, microbatches in configs:
        step = make_sde_train_step(
            "ees25", term, opt, y0_fn, loss, t0=0.0, t1=1.0,
            n_steps=n_steps, n_paths=n_paths, adjoint=adjoint,
            microbatches=microbatches,
        )
        jstep = jax.jit(step)

        def seq_chunk(k_steps):
            # K un-scanned dispatches, params threaded on host — the
            # pre-PR-10 cost model (one round trip per optimizer step)
            p, s = _fresh(params), opt.init(params)
            for i in range(k_steps):
                p, s, _ = jstep(p, s, jax.random.fold_in(key, i))
            return p

        us_seq = time_fn(seq_chunk, k_max, warmup=1, iters=5) / k_max
        tag = f"{adjoint}/P{n_paths}/M{microbatches}"
        for k in k_sweep:
            scanned = make_scanned_step(step, k)

            def scan_chunk():
                # fresh copies feed the donated carry each call
                return scanned(_fresh(params), opt.init(params),
                               init_scan_counters(), key, jnp.asarray(0))[0]

            us_scan = time_fn(scan_chunk, warmup=1, iters=5) / k
            speedup = us_seq / us_scan
            records.append({
                "adjoint": adjoint,
                "n_paths": n_paths,
                "microbatches": microbatches,
                "n_steps": n_steps,
                "dim": dim,
                "steps_per_call": k,
                "us_per_step_sequential": us_seq,
                "us_per_step_scanned": us_scan,
                "steps_per_sec_sequential": 1e6 / us_seq,
                "steps_per_sec_scanned": 1e6 / us_scan,
                "speedup_scan": speedup,
            })
            emit(f"bench_training/{tag}/K{k}", us_scan,
                 f"steps_per_sec={1e6 / us_scan:.1f} speedup_scan={speedup:.2f}")

    mesh_records = run_mesh_ladder(records, n_steps=n_steps, dim=dim)
    out = {
        "device": jax.devices()[0].platform,
        "n_devices": jax.device_count(),
        "records": records,
        "speedup_scan_k8": max(r["speedup_scan"] for r in records
                               if r["steps_per_call"] == k_max),
        "mesh_records": mesh_records,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {out_path}")
    return out


def run_mesh_ladder(single_records, *, n_steps, dim):
    """Data-parallel step timing + bitwise parity vs single device
    (devices > 1 only; single-device CI emits an empty list)."""
    n_devices = jax.device_count()
    if n_devices < 2:
        return []
    from repro.launch.mesh import make_train_mesh

    term, params, opt, y0_fn, loss = _setup(n_steps, dim)
    key = jax.random.PRNGKey(0)
    mesh = make_train_mesh()
    mesh_records = []
    for adjoint, n_paths, microbatches in (("reversible", 32, 1),
                                           ("full", 32, 1)):
        if (n_paths // microbatches) % n_devices:
            continue
        common = dict(t0=0.0, t1=1.0, n_steps=n_steps, n_paths=n_paths,
                      adjoint=adjoint, microbatches=microbatches)
        single = jax.jit(make_sde_train_step(
            "ees25", term, opt, y0_fn, loss, **common))
        dp = jax.jit(make_sde_train_step(
            "ees25", term, opt, y0_fn, loss, mesh=mesh, mesh_axis="dp",
            **common))
        pa, sa, _ = single(params, opt.init(params), key)
        pb, sb, _ = dp(params, opt.init(params), key)
        bitwise = all(
            np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
            zip(jax.tree_util.tree_leaves((pa, sa)),
                jax.tree_util.tree_leaves((pb, sb))))
        us_single = time_fn(single, params, opt.init(params), key,
                            warmup=1, iters=5)
        us_dp = time_fn(dp, params, opt.init(params), key, warmup=1, iters=5)
        mesh_records.append({
            "adjoint": adjoint,
            "n_paths": n_paths,
            "microbatches": microbatches,
            "devices": n_devices,
            "us_per_step_single": us_single,
            "us_per_step_sharded": us_dp,
            "speedup_vs_single": us_single / us_dp,
            "grads_bitwise_vs_single": bool(bitwise),
        })
        emit(f"bench_training/mesh/{adjoint}/P{n_paths}", us_dp,
             f"devices={n_devices} bitwise={bitwise}")
    return mesh_records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--n-steps", type=int, default=N_STEPS)
    ap.add_argument("--dim", type=int, default=DIM)
    args = ap.parse_args()
    run(args.out, n_steps=args.n_steps, dim=args.dim)


if __name__ == "__main__":
    main()
