"""Fused-vs-unfused step-kernel benchmark + numerical drift gate.

Times the jit'd solve hot loop (``lax.scan`` of ``solver.step``) with the
:mod:`repro.kernels.sde_step` fused path on and off, per noise mode x solver
x batch size, and emits ``BENCH_kernels.json`` next to the repo root::

    {"solver": "ees25", "noise": "diagonal", "batch_size": 256,
     "us_per_call_unfused": ..., "us_per_call_fused": ...,
     "steps_per_sec_fused": ..., "speedup_fused": ...}

On a TPU the fused records measure the Pallas kernels; on CPU/GPU they
measure the restructured ``ref.py``-twin arithmetic (XLA fallback), so the
benchmark runs — and the JSON regenerates — everywhere.

``--interpret-check`` additionally forces every fused op through its Pallas
kernel body in interpret mode and FAILS (exit 1) if the fused solve drifts
from the unfused reference beyond tolerance — the CI bench-smoke gate
against kernel/ref divergence.

Run:  PYTHONPATH=src python -m benchmarks.bench_step_kernels [--out PATH]
      [--interpret-check]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SDETerm, get_solver, sdeint
from repro.kernels.sde_step import ops as sde_step_ops

from .common import emit, time_fn

SOLVERS = ("ees25", "ees27", "reversible_heun")
# "prediffused" records the additive-noise fast path (PR 7): an
# ``noise="additive"`` term whose diffusion is hoisted out of the scan
# (adjoint._maybe_prediffuse), so the hot loop combines ``f*h + w`` through
# the "prediffused" fused kernel variants.
NOISES = ("diagonal", "general", "prediffused")
BATCH_SIZES = (64, 1024)
N_STEPS = 64
DIM = 16
N_CHANNELS = 4  # general-noise driving channels

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json",
)


def make_term(noise: str) -> SDETerm:
    if noise == "diagonal":
        return SDETerm(
            drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
            diffusion=lambda t, y, a: a["sigma"] * jnp.cos(y),
            noise="diagonal",
        )
    if noise == "prediffused":
        # Additive contract: diffusion independent of t/y, so solve() hoists
        # g.dW into one bulk pass and the scan runs the prediffused variant.
        return SDETerm(
            drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
            diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
            noise="additive",
        )
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.stack(
            [jnp.ones_like(y)] * N_CHANNELS, axis=-1),
        noise="general",
    )


def term_args():
    return {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
            "sigma": jnp.float32(0.8)}


def _solve_fn(term, solver, noise, n_steps, dim):
    nshape = (N_CHANNELS,) if noise == "general" else (dim,)
    y0 = jnp.ones(dim, jnp.float32)

    def fn(keys, a):
        return sdeint(term, solver, 0.0, 1.0, n_steps, y0, None, args=a,
                      batch_keys=keys, noise_shape=nshape).y_final

    return jax.jit(fn)


def interpret_check(*, n_steps: int = 16, dim: int = 8, batch: int = 4,
                    tol: float = 1e-5) -> int:
    """Fused (Pallas interpret) vs unfused reference; 0 == no drift."""
    failures = 0
    keys = jax.random.split(jax.random.PRNGKey(0), batch)
    for noise in NOISES:
        term = make_term(noise)
        for spec in SOLVERS:
            base = _solve_fn(term, get_solver(spec), noise, n_steps, dim)(
                keys, term_args())
            with sde_step_ops.force_interpret():
                fused = _solve_fn(term, get_solver(spec, use_kernels=True),
                                  noise, n_steps, dim)(keys, term_args())
            drift = float(np.max(np.abs(np.asarray(fused) - np.asarray(base))))
            ok = drift <= tol
            print(f"# interpret-check {spec}/{noise}: max drift {drift:.2e} "
                  f"{'OK' if ok else 'FAIL (tol %g)' % tol}")
            failures += 0 if ok else 1
    return failures


def run(out_path: str = DEFAULT_OUT, *, batch_sizes=BATCH_SIZES,
        solvers=SOLVERS, noises=NOISES, n_steps: int = N_STEPS,
        dim: int = DIM):
    args = term_args()
    records = []
    for noise in noises:
        term = make_term(noise)
        for spec in solvers:
            for batch in batch_sizes:
                keys = jax.random.split(jax.random.PRNGKey(0), batch)
                us_unfused = time_fn(
                    _solve_fn(term, get_solver(spec), noise, n_steps, dim),
                    keys, args, warmup=3, iters=11)
                us_fused = time_fn(
                    _solve_fn(term, get_solver(spec, use_kernels=True), noise,
                              n_steps, dim),
                    keys, args, warmup=3, iters=11)
                steps_fused = batch * n_steps / (us_fused * 1e-6)
                rec = {
                    "solver": spec,
                    "noise": noise,
                    "batch_size": batch,
                    "n_steps": n_steps,
                    "dim": dim,
                    "us_per_call_unfused": us_unfused,
                    "us_per_call_fused": us_fused,
                    "steps_per_sec_fused": steps_fused,
                    "speedup_fused": us_unfused / us_fused,
                }
                records.append(rec)
                emit(f"bench_kernels/{spec}/{noise}/B{batch}", us_fused,
                     f"speedup_fused={rec['speedup_fused']:.2f}")
    with open(out_path, "w") as f:
        json.dump({"device": jax.devices()[0].platform,
                   "fused_backend": "pallas" if jax.default_backend() == "tpu"
                   else "ref-twin (XLA fallback)",
                   "records": records}, f, indent=2)
    print(f"# wrote {out_path}")
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--interpret-check", action="store_true",
                    help="fail on fused-vs-ref numerical drift (CI gate)")
    ap.add_argument("--skip-timing", action="store_true",
                    help="with --interpret-check: run only the drift gate")
    ns = ap.parse_args()
    failures = 0
    if ns.interpret_check:
        failures = interpret_check()
    if not ns.skip_timing:
        run(ns.out)
    if failures:
        print(f"# {failures} fused-vs-ref drift failure(s)", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
