"""Table 7 / Appendix H.1: stiff GBM — the stability separation.

Two parts:
1. *Integration stability* (deterministic validation of Theorems 2.1/2.2):
   integrate dy = A y dt + sigma y dW with stiff A (eigenvalues to -40) at a
   fixed evaluation budget.  Reversible Heun's stability region is the
   imaginary segment, so any real stiff mode diverges; EES(2,5) is stable for
   lambda*h in (-3.087, 0).
2. *Training stability*: learn the dynamics with a Neural LSDE; the paper's
   Table 7 reports '-' (diverged) for everything except EES(2,5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MCFSolver,
    ReversibleHeun,
    SDETerm,
    brownian_path,
    ees25_solver,
    euler,
    midpoint,
    solve,
)
from .common import emit

D, SIGMA, T = 10, 0.1, 1.0
NFE = 60


def stiff_A(rng):
    lam = -20.0 * (1.0 + np.arange(D) / D)
    Q, _ = np.linalg.qr(rng.standard_normal((D, D)))
    return (Q * lam) @ Q.T


def run():
    rng = np.random.default_rng(1)
    A = jnp.asarray(stiff_A(rng), jnp.float32)
    term = SDETerm(
        drift=lambda t, y, a: y @ A.T,
        diffusion=lambda t, y, a: SIGMA * y,
        noise="diagonal",
    )
    y0 = jnp.ones((64, D))
    cases = [
        ("RevHeun", ReversibleHeun(), NFE),
        ("MCF-Euler", MCFSolver(euler), NFE // 2),
        ("MCF-Midpoint", MCFSolver(midpoint), NFE // 4),
        ("EES(2,5)", ees25_solver(), NFE // 3),
    ]
    for name, solver, n_steps in cases:
        bm = brownian_path(jax.random.PRNGKey(0), 0.0, T, n_steps, shape=(64, D))
        t0 = time.time()
        r = jax.jit(lambda y: solve(solver, term, y, bm, None).y_final)(y0)
        r = jax.block_until_ready(r)
        wall = time.time() - t0
        norm = float(jnp.max(jnp.abs(r)))
        stable = bool(np.isfinite(norm) and norm < 10.0)
        emit(
            f"table7_gbm/{name}",
            wall * 1e6,
            f"terminal_max={norm:.3e};stable={stable}",
        )


if __name__ == "__main__":
    run()
