"""Table 2 / H.2: stochastic volatility — runtime at a fixed evaluation budget.

The paper's long-horizon regime: all reversible solvers reach the same
terminal error (the driver regularity dominates), so the differentiator is
*runtime per integration* at matched NFE — where the 2N recurrence wins (the
paper reports EES(2,5) fastest by a clear margin, Table 2).

We integrate a neural SDE (untrained LSDE vector fields — runtime does not
depend on the weights) over a rough-Bergomi-calibrated horizon and time one
forward+reversible-backward pass per solver, plus the signature-MMD loss
against rough-vol target paths as the derived quality metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MCFSolver,
    ReversibleHeun,
    brownian_path,
    ees25_solver,
    euler,
    midpoint,
    solve,
)
from repro.nsde import init_lsde, lsde_readout, lsde_term, signature_mmd
from repro.nsde.data import rough_vol_paths

from .common import emit, time_fn

NFE = 504
BATCH, D_Z = 256, 8
T = 1.0


def run():
    rng = np.random.default_rng(2)
    S, _ = rough_vol_paths(rng, BATCH, 60, T=T, H=0.25)
    target = jnp.asarray(S[:, ::10][:, 1:], jnp.float32)  # 6 obs points

    key = jax.random.PRNGKey(0)
    params = init_lsde(key, 1, D_Z, width=16)
    term = lsde_term()
    cases = [
        ("RevHeun", ReversibleHeun(), NFE),
        ("MCF-Euler", MCFSolver(euler), NFE // 2),
        ("MCF-Midpoint", MCFSolver(midpoint), NFE // 4),
        ("EES(2,5)", ees25_solver(), NFE // 3),
    ]
    for name, solver, n_steps in cases:
        save_every = n_steps // 6

        def loss(p, k):
            bm = brownian_path(k, 0.0, T, n_steps, shape=(BATCH, D_Z))
            z0 = jnp.zeros((BATCH, D_Z)) + p["encoder"]["b"]
            r = solve(solver, term, z0, bm, p, adjoint="reversible",
                      save_every=save_every)
            ys = lsde_readout(p, r.ys)[..., 0].T  # (batch, 6)
            return signature_mmd(1.0 + 0.1 * ys, target)

        g = jax.jit(jax.value_and_grad(loss))
        us = time_fn(lambda: g(params, key))
        val = float(g(params, key)[0])
        emit(f"table2_vol/{name}", us, f"sig_mmd={val:.4f};nfe={NFE}")


if __name__ == "__main__":
    run()
