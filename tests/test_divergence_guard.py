"""Blow-up guard tests: solve-core divergence flags and the trainer guard.

The PR-9 contract (``docs/robustness.md``):

* ``guard`` on ``solve``/``sdeint``/``sdeint_ticks`` surfaces a per-solve
  (per-path under vmap) ``diverged`` bool with **no** change to the computed
  samples — guarded results are bitwise-identical to unguarded ones, across
  every adjoint and save mode, including gradients.
* Divergence is checked at save-segment boundaries; non-finites persist in
  the state, so every genuine blow-up is flagged.
* ``make_sde_train_step(guard=True)`` skips the optimizer update when the
  loss or any gradient leaf is non-finite (bitwise-inert on finite steps),
  and ``resilient_train_loop`` rolls back to the latest checkpoint after a
  skip streak.

Serving-plane fault injection (retries, deadlines, crash recovery) lives in
``tests/test_faults.py``.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm, sdeint, sdeint_ticks
from repro.core.pytree import tree_blowup

KEY = jax.random.PRNGKey(0)


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -0.5 * y,
        diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
        noise="diagonal",
    )


def explosive_term() -> SDETerm:
    # Deterministic exponential blow-up: dy = 80 y dt; Euler-family steps on
    # h = 4/64 grow by ~6x per step, overflowing float32 well inside the
    # horizon.
    return SDETerm(
        drift=lambda t, y, a: 80.0 * y,
        diffusion=lambda t, y, a: 0.0 * jnp.ones_like(y),
        noise="diagonal",
    )


class TestTreeBlowup:
    @pytest.mark.parametrize("value,thr,want", [
        (1.0, 1e6, False),
        (2e6, 1e6, True),
        (float("nan"), 1e6, True),
        (float("inf"), 1e6, True),
        (-float("inf"), 1e6, True),
        (float("nan"), None, True),
        (1e30, None, False),          # finite: no threshold, no flag
        (float("inf"), float("inf"), True),   # inf threshold = finiteness
        (1e30, float("inf"), False),
    ])
    def test_scalar_semantics(self, value, thr, want):
        x = {"a": jnp.array([1.0, value]), "n": jnp.arange(3)}  # int skipped
        assert bool(tree_blowup(x, thr)) is want

    def test_integer_only_tree_is_clean(self):
        assert not bool(tree_blowup({"n": jnp.arange(4)}, 1.0))


class TestSolveGuard:
    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    @pytest.mark.parametrize("save_every", [None, 16])
    def test_guarded_bitwise_identical_and_clean(self, adjoint, save_every):
        kw = {"remat_chunk": 8} if adjoint == "recursive" else {}
        on = sdeint(ou_term(), "ees25", 0.0, 1.0, 64, jnp.ones(4), KEY,
                    adjoint=adjoint, save_every=save_every, guard=1e6, **kw)
        off = sdeint(ou_term(), "ees25", 0.0, 1.0, 64, jnp.ones(4), KEY,
                     adjoint=adjoint, save_every=save_every, **kw)
        assert off.diverged is None
        assert not bool(on.diverged)
        np.testing.assert_array_equal(np.asarray(on.y_final),
                                      np.asarray(off.y_final))
        if save_every:
            np.testing.assert_array_equal(np.asarray(on.ys),
                                          np.asarray(off.ys))

    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    @pytest.mark.parametrize("save_every", [None, 16])
    def test_blowup_flagged(self, adjoint, save_every):
        kw = {"remat_chunk": 8} if adjoint == "recursive" else {}
        r = sdeint(explosive_term(), "ees25", 0.0, 4.0, 64, jnp.ones(4), KEY,
                   adjoint=adjoint, save_every=save_every, guard=1e6, **kw)
        assert bool(r.diverged)

    def test_threshold_without_nonfinite(self):
        # Shorter horizon: the trajectory exceeds 1e2 but stays finite, so
        # only the magnitude threshold can flag it.
        r = sdeint(explosive_term(), "ees25", 0.0, 0.25, 64, jnp.ones(4),
                   KEY, guard=1e2)
        assert bool(r.diverged) and bool(jnp.isfinite(r.y_final).all())
        assert not bool(sdeint(explosive_term(), "ees25", 0.0, 0.25, 64,
                               jnp.ones(4), KEY,
                               guard=float("inf")).diverged)

    def test_batched_per_path_flags(self):
        keys = jax.random.split(KEY, 4)
        r = sdeint(explosive_term(), "ees25", 0.0, 4.0, 64, jnp.ones(4),
                   None, batch_keys=keys, guard=1e6)
        assert r.diverged.shape == (4,) and bool(r.diverged.all())
        clean = sdeint(ou_term(), "ees25", 0.0, 1.0, 64, jnp.ones(4), None,
                       batch_keys=keys, guard=1e6)
        assert clean.diverged.shape == (4,) and not bool(clean.diverged.any())

    def test_gradients_bitwise_under_guard(self):
        def loss(scale, guard):
            t = SDETerm(
                drift=lambda t_, y, a: -a * y,
                diffusion=lambda t_, y, a: 0.2 * jnp.ones_like(y),
                noise="diagonal",
            )
            return sdeint(t, "ees25", 0.0, 1.0, 32, jnp.ones(4), KEY,
                          args=scale, adjoint="reversible",
                          guard=guard).y_final.sum()

        g_on = jax.grad(lambda s: loss(s, 1e6))(jnp.float32(0.5))
        g_off = jax.grad(lambda s: loss(s, None))(jnp.float32(0.5))
        np.testing.assert_array_equal(np.asarray(g_on), np.asarray(g_off))

    def test_adaptive_guard_clean_and_bitwise(self):
        on = sdeint(ou_term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(4),
                    KEY, rtol=1e-3, bounded=False, guard=1e6)
        off = sdeint(ou_term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(4),
                     KEY, rtol=1e-3, bounded=False)
        assert not bool(on.diverged) and off.diverged is None
        np.testing.assert_array_equal(np.asarray(on.y_final),
                                      np.asarray(off.y_final))

    def test_ticks_guard_threads_through_executor_shape(self):
        keys = jax.random.split(KEY, 6).reshape(2, 3, -1)
        r = sdeint_ticks(ou_term(), "ees25", 0.0, 1.0, 16, jnp.ones(4), keys,
                         dtype=jnp.float32, guard=1e6)
        assert r.diverged.shape == (2, 3) and not bool(r.diverged.any())
        off = sdeint_ticks(ou_term(), "ees25", 0.0, 1.0, 16, jnp.ones(4),
                           keys, dtype=jnp.float32)
        assert getattr(off, "diverged", None) is None
        np.testing.assert_array_equal(np.asarray(r.y_final),
                                      np.asarray(off.y_final))
        bad = sdeint_ticks(explosive_term(), "ees25", 0.0, 4.0, 16,
                           jnp.ones(4), keys, dtype=jnp.float32, guard=1e6)
        assert bool(bad.diverged.all())


class TestTrainerGuard:
    def _pieces(self, train_steps=4):
        from repro.optim import adamw, cosine_schedule
        from repro.train.trainer import make_sde_train_step

        term = SDETerm(
            drift=lambda t, y, p: p["nu"] * (p["mu"] - y),
            diffusion=lambda t, y, p: p["sigma"] * jnp.ones_like(y),
            noise="diagonal",
        )
        params = {"nu": jnp.float32(0.5), "mu": jnp.float32(0.0),
                  "sigma": jnp.float32(0.5)}
        opt = adamw(cosine_schedule(1e-3, 2, train_steps))
        return term, params, opt, make_sde_train_step

    def test_finite_step_bitwise_inert(self):
        term, params, opt, make = self._pieces()
        common = dict(t0=0.0, t1=1.0, n_steps=16, n_paths=4)
        y0_fn = lambda p: jnp.zeros(4, jnp.float32)  # noqa: E731
        loss = lambda p, r: jnp.mean(r.y_final ** 2)  # noqa: E731
        guarded = jax.jit(make("ees25", term, opt, y0_fn, loss, **common))
        bare = jax.jit(make("ees25", term, opt, y0_fn, loss, guard=False,
                            **common))
        s0 = opt.init(params)
        pg, sg, mg = guarded(params, s0, KEY)
        pb, sb, mb = bare(params, opt.init(params), KEY)
        assert not bool(mg["skipped"])
        for a, b in zip(jax.tree_util.tree_leaves(pg),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(sg),
                        jax.tree_util.tree_leaves(sb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_nonfinite_loss_skips_update(self):
        term, params, opt, make = self._pieces()
        blown = jax.jit(make(
            "ees25", term, opt, lambda p: jnp.zeros(4, jnp.float32),
            lambda p, r: jnp.mean(r.y_final ** 2) * jnp.nan,
            t0=0.0, t1=1.0, n_steps=16, n_paths=4))
        s0 = opt.init(params)
        p1, s1, m = blown(params, s0, KEY)
        assert bool(m["skipped"]) and not bool(jnp.isfinite(m["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(s1),
                        jax.tree_util.tree_leaves(s0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resilient_loop_rolls_back_after_skip_streak(self):
        from repro.train.trainer import ResilienceConfig, resilient_train_loop

        term, params, opt, make = self._pieces(train_steps=10)
        common = dict(t0=0.0, t1=1.0, n_steps=16, n_paths=4)
        y0_fn = lambda p: jnp.zeros(4, jnp.float32)  # noqa: E731
        loss = lambda p, r: jnp.mean(r.y_final ** 2)  # noqa: E731
        clean = jax.jit(make("ees25", term, opt, y0_fn, loss, **common))
        blown = jax.jit(make("ees25", term, opt, y0_fn,
                             lambda p, r: loss(p, r) * jnp.nan, **common))
        fault_steps = {3, 4, 5}
        calls = {"i": 0}

        def step_fn(p, s, k):
            i = calls["i"]
            calls["i"] += 1
            return (blown if i in fault_steps else clean)(p, s, k)

        with tempfile.TemporaryDirectory() as d:
            out = resilient_train_loop(
                step_fn, params, opt.init(params), KEY,
                res=ResilienceConfig(steps=10, ckpt_every=2, ckpt_dir=d,
                                     skip_patience=3))
        assert out["skipped"] == [False, False, False, True, True, True,
                                  False, False, False, False]
        assert out["rollbacks"] == 1
        assert out["goodput"] == pytest.approx(0.7)
        assert all(jnp.isfinite(jnp.asarray(p)).all()
                   for p in jax.tree_util.tree_leaves(out["params"]))

    def test_resilient_loop_records_fleet_health(self):
        from repro.train.fault_tolerance import HeartbeatMonitor, StragglerTracker
        from repro.train.trainer import ResilienceConfig, resilient_train_loop

        term, params, opt, make = self._pieces()
        step = jax.jit(make(
            "ees25", term, opt, lambda p: jnp.zeros(4, jnp.float32),
            lambda p, r: jnp.mean(r.y_final ** 2),
            t0=0.0, t1=1.0, n_steps=16, n_paths=4))
        monitor = HeartbeatMonitor(hosts=[], deadline_s=1e9)
        tracker = StragglerTracker(hosts=[])
        out = resilient_train_loop(
            step, params, opt.init(params), KEY,
            res=ResilienceConfig(steps=3), monitor=monitor, tracker=tracker,
            host=7)
        # Lazy registration: host 7 was never pre-declared on either.
        assert 7 in monitor._last and len(tracker._times[7]) == 3
        assert out["rollbacks"] == 0 and out["goodput"] == 1.0
