"""Host-side serving-scheduler tests: FIFO fairness, slot plans, partial
delivery, retirement order, cancellation — no jit, no device, no keys.

The scheduler is the pure-Python half of the serving core; everything here
fabricates dispatch outputs with numpy, so the whole file runs in
milliseconds and proves the queueing logic independently of jax.
"""
import numpy as np
import pytest

from repro.serving.scheduler import QueueFull, Scheduler, make_request

pytest.importorskip("jax")  # registry parsing imports jax (no device init)


def submit(sched: Scheduler, solver="ees25", n_paths=1, n_steps=8, t1=1.0,
           **kw) -> int:
    req = make_request(sched.new_request_id(), solver, term_kind="euclidean",
                       t1=t1, n_steps=n_steps, n_paths=n_paths, **kw)
    return sched.enqueue(req)


def fake_outputs(plan, dim=2):
    """Dispatch outputs whose value encodes (request id, path index), so the
    scatter can be checked path-for-path."""
    y = np.zeros((plan.n_ticks, plan.slots, dim))
    for t, tick in enumerate(plan.ticks):
        for s, (p, i) in enumerate(tick):
            y[t, s] = p.request.request_id * 1000 + i
    return {"y_final": y, "ys": None}


def drain(sched: Scheduler, slots: int, max_ticks: int = 1):
    """Run plan/deliver until idle, returning the dispatched plans."""
    plans = []
    while True:
        plan = sched.plan(slots, max_ticks)
        if plan is None:
            return plans
        sched.deliver(plan, fake_outputs(plan))
        plans.append(plan)


def plan_layout(plan):
    return [[(p.request.request_id, i) for p, i in tick] for tick in plan.ticks]


class TestPlanning:
    def test_fifo_fairness_across_mixed_signatures(self):
        """Grouping by the head signature never reorders requests: sig-A work
        ahead of a sig-B request is drained first (FIFO over requests,
        contiguous over paths), and interleaved sig-A requests share ticks."""
        s = Scheduler()
        a = submit(s, "ees25", n_paths=5)
        b = submit(s, "reversible_heun", n_paths=3)
        c = submit(s, "ees25", n_paths=4)
        plan1 = s.plan(slots=4, max_ticks=10)
        assert plan_layout(plan1) == [
            [(a, 0), (a, 1), (a, 2), (a, 3)],
            [(a, 4), (c, 0), (c, 1), (c, 2)],
            [(c, 3)],
        ]
        s.deliver(plan1, fake_outputs(plan1))
        plan2 = s.plan(slots=4, max_ticks=10)
        assert plan_layout(plan2) == [[(b, 0), (b, 1), (b, 2)]]

    def test_multi_tick_plan_equals_repeated_single_tick_plans(self):
        """Within one signature group, planning T ticks at once allocates
        slot-for-slot what T successive single-tick plan/deliver rounds
        would — the invariant that makes multi-tick dispatch bitwise-safe.
        (Across signatures only service *order* may differ: the stack keeps
        draining the head signature before the queue head moves on.)"""
        def fill(sched):
            submit(sched, "ees25", n_paths=6, seed=0)
            submit(sched, "ees25", n_paths=3, seed=1)
            submit(sched, "ees25", n_paths=2, seed=2)

        multi, single = Scheduler(), Scheduler()
        fill(multi), fill(single)
        layout_multi = [lay for p in drain(multi, slots=4, max_ticks=16)
                        for lay in plan_layout(p)]
        layout_single = [lay for p in drain(single, slots=4, max_ticks=1)
                         for lay in plan_layout(p)]
        assert layout_multi == layout_single
        assert multi.done.keys() == single.done.keys()

    def test_slot_plan_padding(self):
        """Trailing slots of the last tick stay unassigned (the engine pads
        them with dummy keys); assigned paths never exceed the slot budget."""
        s = Scheduler()
        submit(s, n_paths=6)
        plan = s.plan(slots=4, max_ticks=2)
        assert plan.n_ticks == 2 and plan.slots == 4
        assert [len(t) for t in plan.ticks] == [4, 2]  # 2 padded slots
        assert plan.n_paths == 6

    def test_plan_stops_at_signature_boundary(self):
        s = Scheduler()
        submit(s, "ees25", n_paths=2)
        submit(s, "reversible_heun", n_paths=2)
        plan = s.plan(slots=2, max_ticks=8)  # budget allows 8 ticks...
        assert plan.n_ticks == 1             # ...but the sig group has 1
        assert plan.signature[0] == "ees25"

    def test_idle_plan_is_none(self):
        s = Scheduler()
        assert s.plan(slots=4, max_ticks=2) is None


class TestDelivery:
    def test_partial_delivery_across_dispatches(self):
        """A request larger than one dispatch resumes at the right path index
        and exposes its remaining count via pending()."""
        s = Scheduler()
        rid = submit(s, n_paths=7, seed=3)
        plan = s.plan(slots=3, max_ticks=1)
        s.deliver(plan, fake_outputs(plan))
        assert s.pending() == {rid: 4}
        plan = s.plan(slots=3, max_ticks=1)
        assert plan_layout(plan) == [[(rid, 3), (rid, 4), (rid, 5)]]
        s.deliver(plan, fake_outputs(plan))
        plan = s.plan(slots=3, max_ticks=1)
        s.deliver(plan, fake_outputs(plan))
        assert s.pending() == {} and list(s.done) == [rid]
        # scatter check: row i of the stacked result is path i's output
        np.testing.assert_array_equal(
            s.done[rid].y_final[:, 0], rid * 1000 + np.arange(7)
        )

    def test_retirement_order_follows_queue_order(self):
        """Requests retiring in the same dispatch land in ``done`` in queue
        order, even when a later (smaller) request finishes in an earlier
        tick of the stack."""
        s = Scheduler()
        big = submit(s, n_paths=5)
        small = submit(s, n_paths=1)
        plan = s.plan(slots=3, max_ticks=2)
        # both finish inside this one dispatch; done order = queue order
        retired = s.deliver(plan, fake_outputs(plan))
        assert retired == [big, small]
        assert list(s.done) == [big, small]

    def test_stat_fields_scattered_when_present(self):
        s = Scheduler()
        rid = submit(s, "ees25:adaptive", n_paths=2, n_steps=32, rtol=1e-3)
        plan = s.plan(slots=2, max_ticks=1)
        out = fake_outputs(plan)
        out["t_final"] = np.full((1, 2), 1.0)
        out["n_accepted"] = np.array([[10, 12]])
        out["n_rejected"] = np.array([[1, 0]])
        s.deliver(plan, out)
        res = s.done[rid]
        np.testing.assert_array_equal(res.n_accepted, [10, 12])
        np.testing.assert_array_equal(res.n_rejected, [1, 0])
        np.testing.assert_array_equal(res.t_final, [1.0, 1.0])


class TestCancellation:
    def test_cancelled_entries_are_skipped_and_pruned(self):
        s = Scheduler()
        a = submit(s, n_paths=2)
        b = submit(s, n_paths=2)
        assert s.cancel(a) is True
        assert s.cancel(a) is False          # second cancel is a no-op
        assert s.pending() == {b: 2}
        plan = s.plan(slots=4, max_ticks=1)  # prunes a, plans b only
        assert plan_layout(plan) == [[(b, 0), (b, 1)]]
        s.deliver(plan, fake_outputs(plan))
        assert list(s.done) == [b]

    def test_pruning_keeps_queue_object_stable(self):
        """The queue is an exposed view (the engine façade re-exports it);
        pruning must mutate it in place, never rebind it."""
        s = Scheduler()
        view = s.queue
        s.cancel(submit(s, n_paths=2))
        live = submit(s, n_paths=1)
        assert s.plan(slots=2, max_ticks=1) is not None  # prunes
        assert s.queue is view
        assert [p.request.request_id for p in view] == [live]

    def test_queue_of_only_cancelled_requests_plans_none(self):
        """The queued-then-cancelled state an idle engine must not spin on."""
        s = Scheduler()
        for rid in (submit(s, n_paths=9), submit(s, n_paths=9)):
            s.cancel(rid)
        assert s.plan(slots=4, max_ticks=100) is None
        assert not s.queue  # husks pruned, not just skipped

    def test_cancel_after_prune_returns_false(self):
        """A client retrying cancel() after the planner pruned the cancelled
        entry gets False (already cancelled), not KeyError."""
        s = Scheduler()
        rid = submit(s, n_paths=3)
        live = submit(s, n_paths=1)
        assert s.cancel(rid) is True
        drain(s, slots=2)              # plan() prunes the cancelled entry
        assert list(s.done) == [live]
        assert s.cancel(rid) is False  # pruned, but still a known id

    def test_cancel_completed_and_unknown(self):
        s = Scheduler()
        rid = submit(s, n_paths=1)
        drain(s, slots=1)
        assert s.cancel(rid) is False  # completed: result stays in done
        assert rid in s.done
        with pytest.raises(KeyError, match="unknown request id"):
            s.cancel(12345)


class TestMakeRequest:
    def test_canonicalises_spec(self):
        r1 = make_request(0, "Reversible-Heun", term_kind="euclidean",
                          t1=1.0, n_steps=8, n_paths=1)
        r2 = make_request(1, "reversible_heun", term_kind="euclidean",
                          t1=1.0, n_steps=8, n_paths=1)
        assert r1.signature == r2.signature

    def test_rejects_malformed_requests(self):
        def bad(match, *a, **kw):
            with pytest.raises((ValueError, KeyError), match=match):
                make_request(0, *a, term_kind="euclidean", **kw)

        bad("unknown solver", "ees2", t1=1.0, n_steps=8, n_paths=1)
        bad("n_paths", "ees25", t1=1.0, n_steps=8, n_paths=0)
        bad("t1 > t0", "ees25", t1=0.0, n_steps=8, n_paths=1)
        bad("save_every", "ees25", t1=1.0, n_steps=8, n_paths=1, save_every=3)
        bad("manifold", "geo-em", t1=1.0, n_steps=8, n_paths=1)
        bad("adaptive", "ees25", t1=1.0, n_steps=8, n_paths=1, rtol=1e-3)
        bad("save_at", "ees25:adaptive", t1=1.0, n_steps=8, n_paths=1,
            save_at=[2.0])
        bad("save_at", "ees25:adaptive", t1=1.0, n_steps=8, n_paths=1,
            save_at=[])
        bad("save_every", "ees25:adaptive", t1=1.0, n_steps=8, n_paths=1,
            save_every=2)

    def test_seed_defaults_to_request_id(self):
        r = make_request(7, "ees25", term_kind="euclidean", t1=1.0,
                         n_steps=8, n_paths=1)
        assert r.seed == 7
        r = make_request(7, "ees25", term_kind="euclidean", t1=1.0,
                         n_steps=8, n_paths=1, seed=42)
        assert r.seed == 42


class TestPriority:
    def test_higher_priority_plans_first_equal_priority_keeps_fifo(self):
        s = Scheduler()
        a = submit(s, n_paths=2)                 # default priority 0
        b = submit(s, n_paths=2, priority=5)
        c = submit(s, n_paths=2, priority=5)     # same class as b: FIFO
        plan = s.plan(slots=4, max_ticks=2)
        assert plan_layout(plan) == [
            [(b, 0), (b, 1), (c, 0), (c, 1)],
            [(a, 0), (a, 1)],
        ]

    def test_priority_not_part_of_signature(self):
        """Priority says when a request runs, not what executable runs it:
        different classes still share one compiled batch."""
        s = Scheduler()
        lo = submit(s, n_paths=1)
        hi = submit(s, n_paths=1, priority=9)
        assert (s.queue[0].request.signature == s.queue[1].request.signature)
        plan = s.plan(slots=2, max_ticks=1)
        assert plan_layout(plan) == [[(hi, 0), (lo, 0)]]

    def test_signatures_lists_plannable_groups_in_service_order(self):
        s = Scheduler()
        a = submit(s, "ees25", n_paths=2)
        submit(s, "reversible_heun", n_paths=2, priority=3)
        sigs = s.signatures()
        assert [sig[0] for sig, _ in sigs] == ["reversible-heun", "ees25"]
        assert [prio for _, prio in sigs] == [3, 0]
        s.cancel(a)
        assert [prio for _, prio in s.signatures()] == [3]

    def test_plan_pinned_to_signature(self):
        s = Scheduler()
        submit(s, "ees25", n_paths=2)
        b = submit(s, "reversible_heun", n_paths=2)
        plan = s.plan(slots=4, max_ticks=1,
                      signature=s.queue[1].request.signature)
        assert plan_layout(plan) == [[(b, 0), (b, 1)]]


class TestReservations:
    def test_reserved_plan_advances_the_planning_cursor(self):
        """plan(reserve=True) then plan() must hand out disjoint paths —
        the double-buffering invariant (staged and live stacks never
        overlap)."""
        s = Scheduler()
        rid = submit(s, n_paths=6)
        first = s.plan(slots=2, max_ticks=1, reserve=True)
        second = s.plan(slots=2, max_ticks=1, reserve=True)
        assert plan_layout(first) == [[(rid, 0), (rid, 1)]]
        assert plan_layout(second) == [[(rid, 2), (rid, 3)]]
        # pending() reports owed paths by *delivered* count — reservations
        # are in flight, not done
        assert s.pending() == {rid: 6}
        s.deliver(first, fake_outputs(first))
        assert s.pending() == {rid: 4}
        s.deliver(second, fake_outputs(second))
        third = s.plan(slots=2, max_ticks=1)
        assert plan_layout(third) == [[(rid, 4), (rid, 5)]]

    def test_release_returns_paths_to_the_queue(self):
        s = Scheduler()
        rid = submit(s, n_paths=4)
        staged = s.plan(slots=2, max_ticks=1, reserve=True)
        s.release(staged)
        replan = s.plan(slots=4, max_ticks=1)
        assert plan_layout(replan) == [[(rid, i) for i in range(4)]]

    def test_release_rejects_unreserved_plans(self):
        s = Scheduler()
        submit(s, n_paths=2)
        plan = s.plan(slots=2, max_ticks=1)
        with pytest.raises(ValueError, match="reserve=True"):
            s.release(plan)

    def test_dead_staged_plan_detected_and_released(self):
        """Cancel every owner of a staged stack: the plan goes non-live (the
        engine skips dispatch), release unwinds the husk reservations, and
        the queue drains clean."""
        s = Scheduler()
        a = submit(s, n_paths=2)
        b = submit(s, n_paths=2)
        staged = s.plan(slots=4, max_ticks=1, reserve=True)
        assert staged.live
        s.cancel(a), s.cancel(b)
        assert not staged.live
        s.release(staged)
        assert s.plan(slots=4, max_ticks=1) is None
        assert not s.queue


class TestAdmission:
    def test_max_requests_bounds_live_queue(self):
        s = Scheduler(max_requests=2)
        submit(s, n_paths=1)
        rid = submit(s, n_paths=1)
        with pytest.raises(QueueFull, match="max_requests=2"):
            submit(s, n_paths=1)
        s.cancel(rid)  # cancelled entries do not count against admission
        submit(s, n_paths=1)

    def test_max_paths_counts_owed_not_submitted(self):
        s = Scheduler(max_paths=4)
        rid = submit(s, n_paths=3)
        with pytest.raises(QueueFull, match="max_paths=4"):
            submit(s, n_paths=2)
        submit(s, n_paths=1)  # exactly fits
        plan = s.plan(slots=3, max_ticks=1)
        s.deliver(plan, fake_outputs(plan))  # retires rid: 3 paths freed
        assert rid in s.done
        submit(s, n_paths=3)

    def test_rejected_enqueue_leaves_queue_untouched(self):
        s = Scheduler(max_requests=1)
        submit(s, n_paths=1)
        before = list(s.queue)
        with pytest.raises(QueueFull):
            submit(s, n_paths=1)
        assert list(s.queue) == before
        assert s.pending() == {before[0].request.request_id: 1}
