"""Signature coalescing (PR 8): bucketed dispatch must be bitwise-exact.

The contract under test, layer by layer:

* ``PaddedBrownianPath`` — row ``n`` of a padded driver's increments is
  bit-equal to the unpadded ``BrownianPath`` on the same key (the masked
  executable consumes the same noise the exact one would);
* ``TimeGrid.padded_uniform`` — clamped time grid, static uniform ``h``;
* ``sdeint_ticks(..., active_steps=, step_size=)`` — the padded multi-tick
  executable equals per-tick jitted ``sdeint`` at each tick's true step
  count, across solvers and adjoints (the ``lax.cond`` step mask's live
  branch compiles to exactly the unpadded solve);
* the serving engines — ``bucketing=True`` (default) returns
  ``SampleResult``s bitwise-identical to ``bucketing=False`` for every
  request in a mixed population, including off-ladder step counts and
  ineligible (saved-trajectory / adaptive) requests that fall back to exact
  dispatch — while compiling strictly fewer executables;
* ``warmup()`` — AOT compilation changes no sample and leaves nothing to
  compile at dispatch time;
* introspection — ``pending(detail=True)`` and retired results surface the
  bucket, padded steps, and dead-slot counts.

References are jitted: on CPU an eager reference drifts from any compiled
executable by an ulp through fusion differences, which would make this test
measure XLA's whims instead of the coalescing layer.
"""
import asyncio

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import (
    BrownianPath,
    PaddedBrownianPath,
    TimeGrid,
    sdeint,
    sdeint_ticks,
)
from repro.core.solvers import SDETerm
from repro.serving import (
    AsyncSDESampleEngine,
    BucketKey,
    SDESampleConfig,
    SDESampleEngine,
)
from repro.serving.bucketing import (
    BucketingConfig,
    bucket_eligible,
    bucket_key,
    group_key,
    ladder_rung,
)

DIM = 3


def make_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.cos(y),
        noise="diagonal",
    )


TERM_ARGS = {"nu": jnp.float32(1.2), "mu": jnp.float32(0.3),
             "sigma": jnp.float32(0.4)}
Y0 = jnp.full((DIM,), 0.7, jnp.float32)
# Engine tests use the ambient precision (f64 under the test-suite x64 flag),
# matching test_serving's y0 idiom — the adaptive controller's time/step
# arithmetic runs in ambient precision and expects y0 to match.
ENGINE_Y0 = jnp.full((DIM,), 0.7)


# -- bucketing pure functions -------------------------------------------------

def test_ladder_rung():
    assert ladder_rung(1) == 8 and ladder_rung(8) == 8
    assert ladder_rung(9) == 16 and ladder_rung(16) == 16
    assert ladder_rung(17) == 32 and ladder_rung(100) == 128
    assert ladder_rung(3, min_steps=2) == 4  # doubling from the floor


def _sig(solver="ees25", t0=0.0, t1=1.0, n_steps=32, save_every=None,
         rtol=None, atol=None, save_at=None):
    return (solver, t0, t1, n_steps, save_every, rtol, atol, save_at)


def test_bucket_eligibility_and_keys():
    cfg = BucketingConfig()
    assert bucket_eligible(_sig())
    assert not bucket_eligible(_sig(save_every=8))
    assert not bucket_eligible(_sig(save_at=(0.5,)))
    assert not bucket_eligible(_sig(rtol=1e-3))
    assert not bucket_eligible(_sig(solver="ees25:adaptive"))

    bk = bucket_key(_sig(n_steps=37), cfg)
    assert bk == BucketKey("ees25", 0.0, 1.0 / 37, 64)
    # coalescing condition: same exact-double h, different horizon, one rung
    a = bucket_key(_sig(t1=1.0, n_steps=40), cfg)
    b = bucket_key(_sig(t1=1.6, n_steps=64), cfg)
    assert a == b  # 1/40 == 1.6/64 bitwise
    # disabled / ineligible -> exact group, tagged so it can't collide
    assert bucket_key(_sig(), BucketingConfig(enabled=False)) is None
    g = group_key(_sig(save_every=8), cfg)
    assert g == ("exact", _sig(save_every=8))


# -- padded driver + grid -----------------------------------------------------

def test_padded_brownian_rows_bitwise():
    key = jax.random.PRNGKey(7)
    exact = BrownianPath(key=key, t0=0.0, t1=1.25, n_steps=10,
                         shape=(DIM,), dtype=jnp.float32)
    padded = PaddedBrownianPath(key=key, t0=0.0, h=0.125, n_steps=16,
                                shape=(DIM,), dtype=jnp.float32)
    for n in range(10):
        assert np.array_equal(np.asarray(exact.increment(n)),
                              np.asarray(padded.increment(n)))


def test_padded_uniform_grid():
    g = TimeGrid.padded_uniform(0.0, 0.25, 3, 8)
    assert g.is_padded
    ts = np.asarray(g.ts)
    # active steps advance, padding steps freeze at t0 + n_active*h
    assert np.allclose(ts[:4], [0.0, 0.25, 0.5, 0.75])
    assert np.allclose(ts[4:], 0.75)
    assert g.uniform_h == 0.25  # static: the step mask never touches h
    with pytest.raises(ValueError):
        TimeGrid.padded_uniform(0.0, 0.25, jnp.arange(2), 8)  # non-scalar


# -- core layer: padded sdeint_ticks vs exact per-tick sdeint ----------------

CORE_CASES = [
    ("ees25", "full"),
    ("ees25", "recursive"),
    ("milstein", "full"),
    ("mcf-rk4", "full"),
    ("reversible-heun", "reversible"),
]


@pytest.mark.parametrize("solver,adjoint", CORE_CASES)
def test_padded_ticks_bitwise_vs_exact(solver, adjoint):
    term = make_term()
    n_pad, slots = 32, 4
    actives = (20, 32, 9)
    h = 1.0 / 32
    tick_keys = jax.vmap(
        lambda t: jax.vmap(lambda s: jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), t), s)
        )(jnp.arange(slots))
    )(jnp.arange(len(actives)))

    got = sdeint_ticks(term, solver, 0.0, n_pad * h, n_pad, Y0, tick_keys,
                       active_steps=jnp.asarray(actives), step_size=h,
                       args=TERM_ARGS, adjoint=adjoint)

    for t, n in enumerate(actives):
        ref = jax.jit(lambda keys, n=n: sdeint(
            term, solver, 0.0, n * h, n, Y0, None, batch_keys=keys,
            args=TERM_ARGS, adjoint=adjoint))(tick_keys[t])
        assert np.array_equal(np.asarray(got.y_final[t]),
                              np.asarray(ref.y_final)), \
            f"tick {t} (n_active={n}) diverged from exact dispatch"


def test_padded_ticks_rejects_bad_args():
    term = make_term()
    keys = jax.random.split(jax.random.PRNGKey(0), 4).reshape(1, 4, 2)
    with pytest.raises(ValueError):  # step_size without active_steps
        sdeint_ticks(term, "ees25", 0.0, 1.0, 8, Y0, keys, step_size=0.125,
                     args=TERM_ARGS)
    with pytest.raises(ValueError):  # active_steps without step_size
        sdeint_ticks(term, "ees25", 0.0, 1.0, 8, Y0, keys,
                     active_steps=jnp.asarray([4]), args=TERM_ARGS)
    with pytest.raises(ValueError):  # saved trajectories can't be padded
        sdeint_ticks(term, "ees25", 0.0, 1.0, 8, Y0, keys,
                     active_steps=jnp.asarray([4]), step_size=0.125,
                     save_every=2, args=TERM_ARGS)


# -- engine layer: bucketed == unbucketed, fewer executables ------------------

# Mixed population: two ees25 horizons sharing h AND a rung (coalesce into
# one bucket), an off-ladder heun, a saved-trajectory request and an
# adaptive request (both exact fallback).
POP = [
    dict(solver="ees25", t1=20 / 32, n_steps=20, n_paths=11, seed=1),
    dict(solver="ees25", t1=1.0, n_steps=32, n_paths=5, seed=2),
    dict(solver="heun", t1=1.0, n_steps=27, n_paths=19, seed=3),
    dict(solver="ees25", t1=1.0, n_steps=32, n_paths=6, seed=4,
         save_every=16),
    dict(solver="ees25:adaptive", t1=1.0, n_steps=64, n_paths=3, seed=5,
         rtol=1e-3, atol=1e-6),
]


def _run_engine(bucketing, *, slots=8, tpd=2, warm_specs=None):
    eng = SDESampleEngine(
        make_term(), ENGINE_Y0,
        SDESampleConfig(slots=slots, ticks_per_dispatch=tpd,
                        bucketing=bucketing, dtype=ENGINE_Y0.dtype),
        args=TERM_ARGS)
    if warm_specs is not None:
        eng.warmup(warm_specs)
    rids = [eng.submit(**p) for p in POP]
    done = eng.run()
    return eng, [done[r] for r in rids]


def _assert_results_bitwise(got, want):
    for k, (a, b) in enumerate(zip(got, want)):
        assert np.array_equal(np.asarray(a.y_final),
                              np.asarray(b.y_final)), f"request {k} y_final"
        assert (a.ys is None) == (b.ys is None)
        if a.ys is not None:
            assert np.array_equal(np.asarray(a.ys), np.asarray(b.ys)), \
                f"request {k} ys"


def test_engine_bucketed_bitwise_and_fewer_executables():
    eb, rb = _run_engine(True)
    eu, ru = _run_engine(False)
    _assert_results_bitwise(rb, ru)
    # the two rung-32 ees25 signatures share one bucket executable (cache
    # entries are (key, depth) pairs, so count unique dispatch keys)
    exec_keys = {k for k, _ in eb._compiled}
    assert len(exec_keys) < len({k for k, _ in eu._compiled})
    n_buckets = sum(isinstance(k, BucketKey) for k in exec_keys)
    assert n_buckets == 2  # ees25 rung 32 (shared) + heun rung 32
    # introspection: coalesced requests carry their bucket + padding
    assert isinstance(rb[0].bucket, BucketKey)
    assert rb[0].bucket == rb[1].bucket  # coalesced
    assert rb[0].n_padded_steps == 12 and rb[1].n_padded_steps == 0
    assert rb[2].n_padded_steps == 32 - 27
    assert rb[3].bucket is None and rb[4].bucket is None  # exact fallback
    assert ru[0].bucket is None  # opt-out: nothing coalesces


def test_engine_warmup_is_aot_and_bitwise():
    _, ref = _run_engine(True)
    eng, got = _run_engine(True, warm_specs=[dict(p) for p in POP])
    _assert_results_bitwise(got, ref)
    # warmup covered every executable the run needed: dispatch compiled
    # nothing (all cache entries are AOT Compiled objects, not jit wrappers)
    assert all(not hasattr(fn, "lower") for fn in eng._compiled.values())


def test_async_engine_bucketed_bitwise():
    _, ref = _run_engine(True)

    async def serve():
        cfg = SDESampleConfig(slots=8, ticks_per_dispatch=2,
                              dtype=ENGINE_Y0.dtype)
        async with AsyncSDESampleEngine(make_term(), ENGINE_Y0, cfg,
                                        args=TERM_ARGS) as eng:
            rids = [await eng.submit(**p) for p in POP]
            return [await eng.result(rid, numpy=True) for rid in rids]

    got = asyncio.run(serve())
    _assert_results_bitwise(got, ref)
    assert isinstance(got[0].bucket, BucketKey)
    assert got[0].n_padded_steps == 12


def test_pending_detail_introspection():
    eng = SDESampleEngine(
        make_term(), ENGINE_Y0, SDESampleConfig(slots=4, ticks_per_dispatch=1, dtype=ENGINE_Y0.dtype),
        args=TERM_ARGS)
    rid = eng.submit("ees25", t1=20 / 32, n_steps=20, n_paths=10, seed=0)
    assert eng.pending() == {rid: 10}
    detail = eng.pending(detail=True)
    assert detail[rid]["remaining"] == 10
    assert detail[rid]["bucket"] is None  # not planned yet
    eng.tick()
    detail = eng.pending(detail=True)
    assert detail[rid]["remaining"] == 6
    assert isinstance(detail[rid]["bucket"], BucketKey)
    assert detail[rid]["bucket"].n_padded == 32
    assert detail[rid]["n_padded_steps"] == 12
    res = eng.run()[rid]
    assert res.n_padded_steps == 12
    # 10 paths over 4-wide ticks: the last tick carries 2 dead slots
    assert res.n_padded_paths == 2
