"""Noise-mode taxonomy: construction/parse-time validation, the additive
fast path's bitwise equality to the general route, the scalar single-channel
contract, prediffused-kernel parity, and engine round-trips for every new
registry spec.

Four layers of the PR-7 solver zoo under one roof:

* **Validation** — every malformed noise mode, solver form, or spec kwarg
  fails at construction/parse time with the offending name in the message
  (not a ``TypeError`` from deep inside a factory or a trace).
* **Additive fast path** — declaring ``noise="additive"`` pre-weights the
  bulk diffusion increments once (``_PrediffusedTerm``); results must be
  *bitwise* equal to the same callables declared ``"diagonal"`` and to the
  per-step (non-bulk) route, across all three adjoints and with fused
  kernels on/off.
* **Scalar noise** — one shared Brownian channel: the inferred increment is
  a scalar, so every state component sees the same noise.
* **Serving** — each new spec string (``"milstein"``, ``"strat-milstein"``,
  ``"srk:noise=additive"``, ``"auto"``, ``"auto:stiffness=..."``) round-trips
  through the engine; ``"auto"`` resolves to the same executable as the
  explicit spec it selects.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Milstein,
    SDETerm,
    SRKAdditive,
    get_solver,
    sdeint,
    select_solver,
)
from repro.core.grid import TimeGrid
from repro.kernels.sde_step import ops as sops
from repro.kernels.sde_step import ref as sref
from repro.serving import SDESampleConfig, SDESampleEngine

KEY = jax.random.PRNGKey(0)


def _args():
    return {"nu": jnp.asarray(0.4), "mu": jnp.asarray(0.1),
            "sigma": jnp.asarray(0.7)}


def _term(noise):
    """OU-type term whose diffusion is t/y-independent (additive-eligible),
    so the same callables can be declared additive or diagonal."""
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
        noise=noise,
    )


def _general_term():
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.stack(
            [jnp.ones_like(y), 0.5 * y], axis=-1),
        noise="general",
    )


def _n(i, shape, dtype=jnp.float64):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


# ---------------------------------------------------------------------------
# Validation: every error names the offender.
# ---------------------------------------------------------------------------


class TestValidation:
    def test_sdeterm_unknown_noise(self):
        with pytest.raises(ValueError,
                           match=re.escape("unknown noise mode 'bogus' for SDETerm")):
            SDETerm(drift=lambda t, y, a: y, noise="bogus")

    def test_sdeterm_noise_without_diffusion(self):
        with pytest.raises(ValueError,
                           match=re.escape("requires a diffusion callable")):
            SDETerm(drift=lambda t, y, a: y, noise="additive")

    def test_ode_mode_omits_diffusion(self):
        SDETerm(drift=lambda t, y, a: y, noise="none")  # must not raise

    def test_milstein_unknown_form(self):
        with pytest.raises(ValueError,
                           match=re.escape("unknown Milstein form 'heun'")):
            Milstein(form="heun")

    def test_milstein_rejects_general_noise(self):
        with pytest.raises(ValueError,
                           match=re.escape("Milstein does not support noise='general'")):
            Milstein().init(_general_term(), 0.0, jnp.ones(4), _args())

    def test_srk_unknown_noise_kwarg(self):
        with pytest.raises(ValueError,
                           match=re.escape("srk supports noise='additive' only")):
            SRKAdditive(noise="diagonal")

    def test_srk_rejects_non_additive_term(self):
        with pytest.raises(ValueError,
                           match=re.escape("SRA1 requires an SDETerm with noise='additive'")):
            SRKAdditive().init(_term("diagonal"), 0.0, jnp.ones(4), _args())

    @pytest.mark.parametrize("spec,name", [
        ("ees25:bogus=1", "ees25"),
        ("milstein:from=ito", "milstein"),
        ("srk:stiffness=2", "srk"),
    ])
    def test_registry_unknown_spec_key(self, spec, name):
        key = spec.partition(":")[2].partition("=")[0]
        with pytest.raises(ValueError, match=re.escape(
                f"unknown option {key!r} for solver {name!r}; valid keys:")):
            get_solver(spec)

    def test_registry_adaptive_flag_still_accepted(self):
        assert get_solver("ees25:adaptive").adaptive is True

    def test_select_solver_unknown_noise(self):
        with pytest.raises(ValueError, match=re.escape(
                "unknown noise mode 'weird' for select_solver")):
            select_solver(noise="weird")

    def test_engine_auto_unknown_key(self):
        eng = SDESampleEngine(_term("diagonal"), jnp.ones(3),
                              SDESampleConfig(slots=2))
        with pytest.raises(ValueError, match=re.escape(
                "unknown option 'foo' for solver 'auto'")):
            eng.submit("auto:foo=1", t1=1.0, n_steps=8, n_paths=2)

    def test_grid_levy_requires_driver(self):
        grid = TimeGrid.uniform(0.0, 1.0, 4)
        with pytest.raises(ValueError,
                           match=re.escape("no Brownian driver (ODE mode)")):
            grid.levy_increment(0)

    def test_grid_levy_requires_capable_driver(self):
        class NoLevy:
            t0, t1 = 0.0, 1.0

            def increment_over(self, s, t):
                return jnp.zeros(())

            def grid_increment(self, ts, n):
                return jnp.zeros(())

        grid = TimeGrid.uniform(0.0, 1.0, 4, driver=NoLevy())
        with pytest.raises(ValueError, match=re.escape(
                "NoLevy has no grid_levy_increment")):
            grid.levy_increment(0)


class TestSelectSolver:
    @pytest.mark.parametrize("kw,expect", [
        (dict(noise="additive", stiffness=0.5, dt=0.01), "srk:noise=additive"),
        (dict(noise="diagonal", stiffness=0.5, dt=0.01), "milstein"),
        (dict(noise="scalar", stiffness=0.5, dt=0.01), "milstein"),
        (dict(noise="general", stiffness=0.5, dt=0.01), "ees25"),
        (dict(noise="none"), "ees25"),
        (dict(noise="additive", stiffness=30.0, dt=0.05), "ees25"),
        (dict(noise="diagonal", stiffness=100.0, dt=0.05), "ees27"),
    ])
    def test_decision_table(self, kw, expect):
        spec = select_solver(**kw)
        assert spec == expect
        get_solver(spec)  # every selectable spec must resolve


# ---------------------------------------------------------------------------
# Additive fast path: bitwise-equal to the general (diagonal) route.
# ---------------------------------------------------------------------------

ADJOINTS = ("full", "recursive", "reversible")


class TestAdditiveFastPath:
    def _run(self, noise, *, adjoint, use_kernels=None, bulk=True,
             spec="ees25"):
        keys = jax.random.split(KEY, 3)
        overrides = {} if use_kernels is None else {"use_kernels": use_kernels}
        return sdeint(
            _term(noise), get_solver(spec, **overrides),
            0.0, 1.0, 16, jnp.ones(4, jnp.float64), None, args=_args(),
            batch_keys=keys, adjoint=adjoint, bulk_increments=bulk,
        ).y_final

    @pytest.mark.parametrize("adjoint", ADJOINTS)
    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_bitwise_vs_diagonal_relabel(self, adjoint, use_kernels):
        """Same callables, same keys: declaring additive must not move a bit
        (the fast path hoists the identical IEEE multiply out of the scan)."""
        add = self._run("additive", adjoint=adjoint, use_kernels=use_kernels)
        diag = self._run("diagonal", adjoint=adjoint, use_kernels=use_kernels)
        np.testing.assert_array_equal(np.asarray(add), np.asarray(diag))

    @pytest.mark.parametrize("adjoint", ADJOINTS)
    def test_per_step_route_bitwise_vs_diagonal(self, adjoint):
        """The per-step route never prediffuses: additive must STILL match
        the diagonal relabel bitwise there, and bulk-vs-per-step drift stays
        at the same sub-ulp level the diagonal route already exhibits (the
        streamed-buffer scan compiles to a slightly different fusion than the
        inline-RNG scan — pre-existing, not a fast-path artifact)."""
        add_step = self._run("additive", adjoint=adjoint, bulk=False)
        diag_step = self._run("diagonal", adjoint=adjoint, bulk=False)
        np.testing.assert_array_equal(np.asarray(add_step),
                                      np.asarray(diag_step))
        bulk = self._run("additive", adjoint=adjoint, bulk=True)
        np.testing.assert_allclose(np.asarray(bulk), np.asarray(add_step),
                                   rtol=1e-13, atol=1e-13)

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_bitwise_under_interpret_kernels(self, use_kernels):
        with sops.force_interpret():
            add = self._run("additive", adjoint="full",
                            use_kernels=use_kernels)
            diag = self._run("diagonal", adjoint="full",
                             use_kernels=use_kernels)
        np.testing.assert_array_equal(np.asarray(add), np.asarray(diag))

    @pytest.mark.parametrize("adjoint", ADJOINTS)
    def test_gradients_match_diagonal_relabel(self, adjoint):
        keys = jax.random.split(KEY, 3)

        def loss(sigma, noise):
            a = {"nu": jnp.asarray(0.4), "mu": jnp.asarray(0.1),
                 "sigma": sigma}
            out = sdeint(_term(noise), "ees25", 0.0, 1.0, 16,
                         jnp.ones(4, jnp.float64), None, args=a,
                         batch_keys=keys, adjoint=adjoint)
            return jnp.sum(out.y_final ** 2)

        sig = jnp.asarray(0.7)
        g_add = jax.grad(loss)(sig, "additive")
        g_diag = jax.grad(loss)(sig, "diagonal")
        assert np.isfinite(g_add) and float(g_add) != 0.0
        np.testing.assert_allclose(np.asarray(g_add), np.asarray(g_diag),
                                   rtol=1e-12)

    def test_milstein_and_srk_bypass_prediffusion(self):
        """Solvers that read term.diffusion directly (needs_diffusion) must
        keep the raw term — the run still completes and stays finite."""
        for spec in ("milstein", "srk:noise=additive"):
            out = self._run("additive", adjoint="full", spec=spec)
            assert np.isfinite(np.asarray(out)).all()


class TestScalarNoise:
    def test_one_shared_channel(self):
        """Scalar noise draws ONE increment per step: with zero drift and
        unit diffusion every state component integrates the same W."""
        term = SDETerm(drift=lambda t, y, a: jnp.zeros_like(y),
                       diffusion=lambda t, y, a: jnp.ones_like(y),
                       noise="scalar")
        yf = sdeint(term, "euler", 0.0, 1.0, 64,
                    jnp.zeros(4, jnp.float64), KEY).y_final
        assert yf.shape == (4,)
        np.testing.assert_array_equal(np.asarray(yf),
                                      np.full(4, float(yf[0])))
        assert float(yf[0]) != 0.0

    def test_milstein_runs_on_scalar_noise(self):
        term = SDETerm(drift=lambda t, y, a: 0.3 * y,
                       diffusion=lambda t, y, a: 0.4 * y,
                       noise="scalar")
        yf = sdeint(term, "milstein", 0.0, 1.0, 32,
                    jnp.ones(3, jnp.float64), KEY).y_final
        assert np.isfinite(np.asarray(yf)).all()


# ---------------------------------------------------------------------------
# Prediffused kernel variants: interpret-mode parity vs ref, incl. gradients.
# ---------------------------------------------------------------------------


class TestPrediffusedKernels:
    def test_increment_pre_parity(self):
        f, w = _n(1, (37,)), _n(2, (37,))
        h = jnp.asarray(0.01, f.dtype)
        ref = sref.increment_pre_ref(f, w, h)
        with sops.force_interpret():
            got = sops.fused_increment(f, None, w, h, noise="prediffused")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-12, atol=1e-12)

    def test_increment_pre_gradients(self):
        f, w = _n(3, (37,)), _n(4, (37,))
        h = jnp.asarray(0.01, f.dtype)

        def loss(op):
            return lambda fa, wa, ha: jnp.sum(jnp.sin(op(fa, wa, ha)))

        g_ref = jax.grad(loss(sref.increment_pre_ref), argnums=(0, 1, 2))(
            f, w, h)
        with sops.force_interpret():
            g_fus = jax.grad(
                loss(lambda fa, wa, ha: sops.fused_increment(
                    fa, None, wa, ha, noise="prediffused")),
                argnums=(0, 1, 2))(f, w, h)
        for a, b in zip(g_fus, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-10, atol=1e-12)

    def test_ws_stage_pre_parity(self):
        delta, y, f, w = (_n(5 + i, (41,)) for i in range(4))
        h = jnp.asarray(0.02, f.dtype)
        a, b = 0.3, 0.7
        d_ref, y_ref = sref.ws_stage_pre_ref(delta, y, f, w, h, a, b)
        with sops.force_interpret():
            d_got, y_got = sops.fused_ws_stage(
                delta, y, f, None, w, h, a=a, b=b, noise="prediffused")
        np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_ref),
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref),
                                   rtol=1e-12, atol=1e-12)

    def test_ws_stage_pre_gradients(self):
        delta, y, f, w = (_n(15 + i, (41,)) for i in range(4))
        h = jnp.asarray(0.02, f.dtype)
        a, b = 0.3, 0.7

        def loss(op):
            def run(da, ya, fa, wa, ha):
                d2, y2 = op(da, ya, fa, wa, ha)
                return jnp.sum(jnp.cos(d2)) + jnp.sum(jnp.sin(y2))
            return run

        g_ref = jax.grad(
            loss(lambda da, ya, fa, wa, ha: sref.ws_stage_pre_ref(
                da, ya, fa, wa, ha, a, b)),
            argnums=(0, 1, 2, 3, 4))(delta, y, f, w, h)
        with sops.force_interpret():
            g_fus = jax.grad(
                loss(lambda da, ya, fa, wa, ha: sops.fused_ws_stage(
                    da, ya, fa, None, wa, ha, a=a, b=b, noise="prediffused")),
                argnums=(0, 1, 2, 3, 4))(delta, y, f, w, h)
        for got, ref in zip(g_fus, g_ref):
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-10, atol=1e-12)

    def test_unknown_kernel_noise_mode(self):
        f = _n(25, (8,))
        with pytest.raises(ValueError, match=re.escape(
                "unknown noise mode 'weird'")):
            sops.fused_increment(f, f, f, 0.1, noise="weird")


# ---------------------------------------------------------------------------
# Serving round-trips: every new spec string through the engine.
# ---------------------------------------------------------------------------


class TestEngineSpecs:
    def _engine(self, noise):
        term = SDETerm(
            drift=lambda t, y, a: -0.5 * y,
            diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
            noise=noise,
        )
        return SDESampleEngine(term, jnp.ones(3), SDESampleConfig(slots=4))

    @pytest.mark.parametrize("spec,noise", [
        ("milstein", "diagonal"),
        ("strat-milstein", "diagonal"),
        ("srk:noise=additive", "additive"),
    ])
    def test_round_trip(self, spec, noise):
        eng = self._engine(noise)
        rid = eng.submit(spec, t1=1.0, n_steps=16, n_paths=4, seed=3)
        out = eng.run()[rid]
        assert out.y_final.shape == (4, 3)
        assert np.isfinite(np.asarray(out.y_final)).all()

    def test_auto_matches_explicit_srk(self):
        """An additive-term engine auto-selects SRA1; the resolved spec is
        what compiles, so 'auto' and the explicit spec are bit-identical."""
        eng = self._engine("additive")
        r_auto = eng.submit("auto", t1=1.0, n_steps=16, n_paths=4, seed=3)
        r_expl = eng.submit("srk:noise=additive", t1=1.0, n_steps=16,
                            n_paths=4, seed=3)
        done = eng.run()
        np.testing.assert_array_equal(done[r_auto].y_final,
                                      done[r_expl].y_final)

    def test_auto_stiffness_picks_ees27(self):
        """z = 100 * (1/16) = 6.25 > 2.8: stiff requests land on EES27."""
        eng = self._engine("diagonal")
        r_auto = eng.submit("auto:stiffness=100", t1=1.0, n_steps=16,
                            n_paths=4, seed=3)
        r_expl = eng.submit("ees27", t1=1.0, n_steps=16, n_paths=4, seed=3)
        done = eng.run()
        np.testing.assert_array_equal(done[r_auto].y_final,
                                      done[r_expl].y_final)
