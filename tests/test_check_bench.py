"""tools/check_bench.py gates the committed BENCH artifacts correctly.

The CI bench lane now funnels every benchmark JSON through one checker
(``python tools/check_bench.py --file <json>``) instead of per-step inline
snippets.  Two invariants keep that consolidation honest:

* every **committed** ``BENCH_*.json`` at the repo root passes its gate
  (so the checker encodes the same invariants the artifacts were produced
  under), and
* **tampered** copies fail — dropped records, sub-1 scan speedup, a broken
  DP-bitwise flag — so the gates still have teeth.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECK = os.path.join(REPO, "tools", "check_bench.py")

COMMITTED = sorted(
    f for f in os.listdir(REPO)
    if f.startswith("BENCH_") and f.endswith(".json")
)


def run_check(*files):
    return subprocess.run(
        [sys.executable, CHECK] + [x for f in files for x in ("--file", f)],
        cwd=REPO, capture_output=True, text=True,
    )


def test_all_committed_bench_files_pass():
    assert COMMITTED, "no BENCH_*.json at repo root"
    assert "BENCH_training.json" in COMMITTED
    res = run_check(*COMMITTED)
    assert res.returncode == 0, res.stdout + res.stderr
    for f in COMMITTED:
        assert f"OK {f}" in res.stdout, res.stdout


def test_unknown_file_is_an_error(tmp_path):
    p = tmp_path / "BENCH_mystery.json"
    p.write_text("{}")
    res = run_check(str(p))
    assert res.returncode != 0
    assert "no gate registered" in res.stderr


def _tamper(tmp_path, src_name, mutate, out_name=None):
    with open(os.path.join(REPO, src_name)) as f:
        data = json.load(f)
    mutate(data)
    p = tmp_path / (out_name or src_name)
    p.write_text(json.dumps(data))
    return str(p)


@pytest.mark.parametrize(
    "src,mutate",
    [
        ("BENCH_throughput.json", lambda d: d["records"].clear()),
        ("BENCH_training.json", lambda d: d["records"].clear()),
        ("BENCH_training.json",
         lambda d: d.__setitem__("speedup_scan_k8", 0.5)),
        ("BENCH_training.json",
         lambda d: d["records"][0].__setitem__("us_per_step_scanned",
                                               float("nan"))),
        ("BENCH_training.json",
         lambda d: d["mesh_records"].append(
             {"adjoint": "reversible", "grads_bitwise_vs_single": False})),
        ("BENCH_serving.json",
         lambda d: d["load"].__setitem__("dispatches_per_tick", 2.0)),
        ("BENCH_reversible_adaptive.json",
         lambda d: [r for r in d["records"]
                    if r["adjoint"] == "reversible"][0]
         .__setitem__("grad_rel_err_vs_full", 1.0)),
    ],
    ids=["throughput-empty", "training-empty", "training-slow-scan",
         "training-nan-field", "training-dp-not-bitwise",
         "serving-multi-dispatch", "revadaptive-grad-drift"],
)
def test_tampered_bench_files_fail(tmp_path, src, mutate):
    path = _tamper(tmp_path, src, mutate)
    res = run_check(path)
    assert res.returncode != 0, res.stdout
    assert "AssertionError" in res.stderr or "Error" in res.stderr, res.stderr


def test_ci_workflow_routes_every_bench_through_checker():
    """The bench lane must not regrow inline ``python -c`` gate snippets."""
    with open(os.path.join(REPO, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    bench_lane = ci.split("bench-smoke:")[1]
    assert "python -c" not in bench_lane, "inline gate snippet crept back in"
    for artifact in ("bench.json", "bench_serving.json", "bench_kernels.json",
                     "bench_stability.json", "bench_adaptive.json",
                     "bench_rev_adaptive.json", "bench_resilience.json",
                     "bench_training.json"):
        assert f"check_bench.py --file {artifact}" in bench_lane, artifact
