"""Substrate tests: data pipeline, optimizer, checkpointing, fault-tolerance,
serving engine, MoE properties, EES residual stream."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens, prefetch
from repro.models import ModelOptions, init_params, loss_fn
from repro.models.moe import moe_block
from repro.models.reversible import ees_depth_solve, euler_depth_solve
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import (
    HeartbeatMonitor,
    StragglerTracker,
    recovery_plan,
)
from repro.train.trainer import TrainLoopConfig, train_loop
from repro.serving.engine import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_deterministic_by_step(self):
        dc = DataConfig(global_batch=4, seq_len=16, vocab=100)
        d = SyntheticTokens(dc)
        a, b = d.batch_at(3), d.batch_at(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(d.batch_at(3)["tokens"], d.batch_at(4)["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        dc = DataConfig(global_batch=8, seq_len=8, vocab=50)
        full = SyntheticTokens(dc).batch_at(5)["tokens"]
        parts = [
            SyntheticTokens(
                DataConfig(global_batch=8, seq_len=8, vocab=50, num_hosts=4, host_id=h)
            ).batch_at(5)["tokens"]
            for h in range(4)
        ]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_prefetch_preserves_order(self):
        out = list(prefetch(iter(range(10)), depth=3))
        assert out == list(range(10))

    def test_labels_are_shifted_tokens(self):
        dc = DataConfig(global_batch=2, seq_len=16, vocab=100)
        b = SyntheticTokens(dc).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestOptim:
    def test_adamw_converges_quadratic(self):
        opt = adamw(0.1, max_grad_norm=None)
        params = {"x": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum((p["x"] - 1.0) ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        np.testing.assert_allclose(params["x"], [1.0, 1.0], atol=1e-2)

    def test_clip_global_norm(self):
        g = {"a": jnp.ones(4) * 10.0}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(20.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)

    def test_cosine_schedule_shape(self):
        lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(lr(0)) == pytest.approx(0.0)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)

    def test_bf16_params_f32_state(self):
        opt = adamw(1e-2)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        p2, _, _ = opt.update(g, state, params)
        assert p2["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, tree)
            assert latest_step(d) == 7
            got = restore_checkpoint(d, 7, tree)
            for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
                np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
                assert x.dtype == y.dtype

    def test_restore_reshards_onto_named_sharding(self):
        # Elastic restore: a checkpoint written plain (host-local arrays)
        # comes back placed onto whatever sharding the new mesh prescribes —
        # per-leaf NamedShardings here, bf16 bit-exact through the uint16
        # round-trip, and latest_step picks the newest complete save.
        from jax.sharding import Mesh, NamedSharding, PartitionSpec

        tree = {
            "w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
            "emb": (jnp.arange(6, dtype=jnp.bfloat16) / 3.0).reshape(3, 2),
        }
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        shardings = {
            "w": NamedSharding(mesh, PartitionSpec("data", None)),
            "emb": NamedSharding(mesh, PartitionSpec()),
        }
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, tree)
            save_checkpoint(d, 11, tree)
            assert latest_step(d) == 11
            got = restore_checkpoint(d, 11, tree, shardings=shardings)
        assert got["w"].sharding == shardings["w"]
        assert got["emb"].sharding == shardings["emb"]
        assert got["emb"].dtype == jnp.bfloat16
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(got[k], np.float32), np.asarray(tree[k], np.float32))

    def test_resume_exact_training(self):
        cfg = get_arch("olmo-1b").smoke()
        key = jax.random.PRNGKey(42)
        data = SyntheticTokens(DataConfig(global_batch=2, seq_len=16, vocab=cfg.vocab))
        with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
            # uninterrupted 6 steps
            outA = train_loop(
                cfg, init_params(cfg, key), data, optimizer=adamw(1e-3),
                loop=TrainLoopConfig(steps=6, ckpt_every=100, ckpt_dir=d1),
            )
            # interrupted at 3, resumed to 6
            train_loop(
                cfg, init_params(cfg, key), data, optimizer=adamw(1e-3),
                loop=TrainLoopConfig(steps=3, ckpt_every=3, ckpt_dir=d2),
            )
            outB = train_loop(
                cfg, init_params(cfg, key), data, optimizer=adamw(1e-3),
                loop=TrainLoopConfig(steps=6, ckpt_every=100, ckpt_dir=d2),
            )
        for a, b in zip(
            jax.tree_util.tree_leaves(outA["params"]),
            jax.tree_util.tree_leaves(outB["params"]),
        ):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


class TestFaultTolerance:
    def test_heartbeat(self):
        hb = HeartbeatMonitor(hosts=[0, 1, 2], deadline_s=10.0)
        hb.beat(0, now=100.0)
        hb.beat(1, now=100.0)
        hb.beat(2, now=50.0)
        assert hb.dead_hosts(now=105.0) == [2]

    def test_straggler_detection(self):
        tr = StragglerTracker(hosts=[0, 1, 2, 3], k=4.0)
        for _ in range(16):
            for h in range(3):
                tr.record(h, 1.0 + 0.01 * h)
            tr.record(3, 5.0)
        assert tr.stragglers() == [3]

    def test_recovery_plan_drops_whole_pod(self):
        plan = recovery_plan((4, 16, 16), hosts_per_pod=32, dead_hosts=[40], latest_ckpt_step=1200)
        assert plan.new_mesh_shape == (3, 16, 16)
        assert plan.resume_step == 1200

    def test_recovery_plan_all_dead_raises(self):
        with pytest.raises(RuntimeError):
            recovery_plan((1, 16, 16), 32, dead_hosts=[0], latest_ckpt_step=0)

    def test_recovery_plan_rejects_host_outside_fleet(self):
        # A dead-host id the mesh can't contain means the failure report and
        # the mesh disagree — silently dropping it would keep a dead pod.
        with pytest.raises(ValueError, match="outside the fleet"):
            recovery_plan((4, 16, 16), hosts_per_pod=32, dead_hosts=[128],
                          latest_ckpt_step=100)
        with pytest.raises(ValueError, match="outside the fleet"):
            recovery_plan((4, 16, 16), hosts_per_pod=32, dead_hosts=[-1],
                          latest_ckpt_step=100)

    def test_lazy_registration_of_unseen_hosts(self):
        # Elastic fleets add hosts mid-run: first contact from an undeclared
        # host must register it, not KeyError.
        hb = HeartbeatMonitor(hosts=[], deadline_s=10.0)
        hb.beat(7, now=100.0)
        assert hb.dead_hosts(now=105.0) == []
        assert hb.dead_hosts(now=200.0) == [7]
        tr = StragglerTracker(hosts=[0, 1, 2], k=4.0)
        for _ in range(16):
            for h in range(3):
                tr.record(h, 1.0 + 0.01 * h)
            tr.record(9, 5.0)  # never pre-declared
        assert tr.stragglers() == [9]


class TestServing:
    def test_engine_continuous_batching(self):
        cfg = get_arch("qwen3-1.7b").smoke()
        eng = Engine(cfg, init_params(cfg, KEY), ServeConfig(slots=2, max_len=12))
        rids = [eng.submit([3, 1, 4]) for _ in range(5)]  # more requests than slots
        done = eng.run()
        assert sorted(done) == sorted(rids)
        assert all(len(v) <= 12 for v in done.values())

    def test_encoder_only_rejected(self):
        cfg = get_arch("hubert-xlarge").smoke()
        with pytest.raises(ValueError):
            Engine(cfg, init_params(cfg, KEY))


class TestMoEProperties:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_combine_weights_sum_to_one(self, seed):
        """Router gate weights are renormalised over the top-k."""
        key = jax.random.PRNGKey(seed)
        logits = jax.random.normal(key, (16, 8))
        probs = jax.nn.softmax(logits, -1)
        vals, _ = jax.lax.top_k(probs, 2)
        vals = vals / vals.sum(-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, rtol=1e-5)

    def test_moe_matches_dense_single_expert(self):
        """E=1, k=1, huge capacity == a plain SwiGLU MLP."""
        import dataclasses as dc

        from repro.models.layers import init_mlp, mlp_block
        from repro.models.moe import init_moe

        cfg = dc.replace(
            get_arch("olmoe-1b-7b").smoke(), n_experts=1, moe_top_k=1,
            capacity_factor=64.0, moe_d_ff=32,
        )
        p = init_moe(cfg, KEY, jnp.float32)
        x = jax.random.normal(KEY, (2, 8, cfg.d_model))
        out, aux = moe_block(cfg, p, x, ModelOptions())
        mlp_p = {"ln": p["ln"], "wg": p["wg"][0], "wu": p["wu"][0], "wd": p["wd"][0]}
        cfg_sw = dc.replace(cfg, mlp="swiglu")
        want = mlp_block(cfg_sw, mlp_p, x, ModelOptions())
        np.testing.assert_allclose(out, want, atol=1e-5)


class TestEESResidualStream:
    def _block(self):
        def block_fn(lp, y):
            return jnp.tanh(y @ lp["w"]) * 0.1

        L, d = 6, 8
        layers = {"w": 0.5 * jax.random.normal(KEY, (L, d, d))}
        y0 = jax.random.normal(jax.random.fold_in(KEY, 1), (2, d))
        return block_fn, layers, y0

    def test_reversible_matches_full(self):
        block_fn, layers, y0 = self._block()

        def loss(layers, adjoint):
            y = ees_depth_solve(block_fn, layers, y0, step=1.0, adjoint=adjoint)
            return jnp.sum(y ** 2)

        gf = jax.grad(lambda l: loss(l, "full"))(layers)
        gr = jax.grad(lambda l: loss(l, "reversible"))(layers)
        np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-7)

    def test_small_step_approaches_euler(self):
        block_fn, layers, y0 = self._block()
        ye = euler_depth_solve(block_fn, layers, y0, step=0.01)
        ys = ees_depth_solve(block_fn, layers, y0, step=0.01, adjoint="full")
        np.testing.assert_allclose(ye, ys, atol=1e-4)
