"""Interpret-mode parity for EVERY Pallas kernel vs its ``ref.py`` twin.

Tier-1 (default lane, no optional deps): each kernel body runs in Pallas
interpret mode — Python on CPU, the same code the TPU path compiles — and
must match the pure-jnp oracle.  Kernels with a fused ``custom_vjp`` backward
(``williamson2n``, ``sde_step``) are additionally checked against autodiff
*through the reference*, so the hand-written cotangents can never drift from
the arithmetic they shortcut.  (The hypothesis-based property sweeps live in
``test_kernels.py``; this module is the dependency-free gate.)
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.sde_step import ops as sops
from repro.kernels.sde_step import ref as sref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.williamson2n.ops import williamson2n_update
from repro.kernels.williamson2n.ref import williamson2n_ref


@functools.lru_cache(maxsize=None)
def KEY():
    return jax.random.PRNGKey(0)


def _n(i, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY(), i), shape, dtype)


class TestFlashAttentionParity:
    def test_matches_ref(self):
        q, k, v = (_n(10 + i, (1, 2, 256, 64)) for i in range(3))
        got = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(got, attention_ref(q, k, v, causal=True),
                                   atol=2e-5)

    def test_non_causal(self):
        q, k, v = (_n(20 + i, (2, 2, 128, 32)) for i in range(3))
        got = flash_attention(q, k, v, causal=False, interpret=True)
        np.testing.assert_allclose(got, attention_ref(q, k, v, causal=False),
                                   atol=2e-5)


class TestSSDScanParity:
    def test_matches_ref(self):
        b, l, h, dh, ds = 1, 128, 2, 16, 32
        ks = jax.random.split(jax.random.fold_in(KEY(), 30), 5)
        x = jax.random.normal(ks[0], (b, l, h, dh))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B = jax.random.normal(ks[3], (b, l, ds))
        C = jax.random.normal(ks[4], (b, l, ds))
        y = ssd_scan(x, dt, A, B, C, chunk=64, interpret=True)
        y_seq, _ = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(y, y_seq, atol=5e-4)


class TestWilliamson2NParity:
    @pytest.mark.parametrize("shape", [(129,), (8, 128), (3, 5)])
    def test_matches_ref(self, shape):
        d, k, y = (_n(40 + i, shape) for i in range(3))
        a, b = -35 / 32, 2 / 5
        got = williamson2n_update(d, k, y, a, b, True)
        want = williamson2n_ref(d, k, y, a, b)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=1e-5)

    def test_custom_vjp_vs_autodiff_through_ref(self):
        d, k, y = (_n(50 + i, (200,)) for i in range(3))
        f_k = lambda *xs: jnp.sum(williamson2n_update(*xs, -0.46, 0.93, True)[1] ** 2)
        f_r = lambda *xs: jnp.sum(williamson2n_ref(*xs, -0.46, 0.93)[1] ** 2)
        gk = jax.grad(f_k, argnums=(0, 1, 2))(d, k, y)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(d, k, y)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, atol=1e-5)


class TestSDEStepParity:
    """The PR-4 fused step ops: forward and fused-VJP parity per noise mode."""

    @pytest.mark.parametrize("shape", [(7,), (128,), (1000,), (4, 33)])
    def test_increment_diag(self, shape):
        f, g, dW = (_n(60 + i, shape) for i in range(3))
        h = jnp.float32(0.03)
        want = sref.increment_diag_ref(f, g, dW, h)
        got = sops.fused_increment(f, g, dW, h, noise="diagonal", interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)
        # XLA fallback path IS the ref
        np.testing.assert_array_equal(
            sops.fused_increment(f, g, dW, h, noise="diagonal"), want)

    @pytest.mark.parametrize("bshape,d,m", [((5,), 3, 4), ((2, 9), 4, 2), ((), 6, 3)])
    def test_increment_general(self, bshape, d, m):
        f = _n(70, bshape + (d,))
        g = _n(71, bshape + (d, m))
        dW = _n(72, bshape + (m,))
        h = jnp.float32(0.05)
        want = sref.increment_general_ref(f, g, dW, h)
        got = sops.fused_increment(f, g, dW, h, noise="general", interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    @pytest.mark.parametrize("shape", [(129,), (8, 16)])
    def test_ws_stage_diag(self, shape):
        d, y, f, g, dW = (_n(80 + i, shape) for i in range(5))
        h = jnp.float32(0.02)
        a, b = -7 / 15, 15 / 16
        want = sref.ws_stage_diag_ref(d, y, f, g, dW, h, a, b)
        got = sops.fused_ws_stage(d, y, f, g, dW, h, a=a, b=b,
                                  noise="diagonal", interpret=True)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(gg, ww, atol=1e-6)

    def test_ws_stage_general(self):
        B, d, m = 6, 3, 5
        dlt, y, f = (_n(90 + i, (B, d)) for i in range(3))
        g = _n(93, (B, d, m))
        dW = _n(94, (B, m))
        h = jnp.float32(0.04)
        want = sref.ws_stage_general_ref(dlt, y, f, g, dW, h, -1.1, 0.4)
        got = sops.fused_ws_stage(dlt, y, f, g, dW, h, a=-1.1, b=0.4,
                                  noise="general", interpret=True)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(gg, ww, atol=1e-6)

    @pytest.mark.parametrize("s", [1, 3])
    def test_axpy_chain(self, s):
        y = _n(100, (11, 7))
        incs = jnp.stack([_n(101 + i, (11, 7)) for i in range(s)])
        coeffs = tuple(0.3 * (i + 1) * (-1) ** i for i in range(s))
        want = sref.axpy_chain_ref(y, incs, coeffs)
        got = sops.fused_axpy_chain(y, incs, coeffs, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    @pytest.mark.parametrize("noise", ["diagonal", "general"])
    def test_ws_stage_vjp_vs_autodiff_through_ref(self, noise):
        if noise == "diagonal":
            shp_g, shp_w = (17,), (17,)
            shp = (17,)
            ref_fn = sref.ws_stage_diag_ref
        else:
            shp = (4, 3)
            shp_g, shp_w = (4, 3, 5), (4, 5)
            ref_fn = sref.ws_stage_general_ref
        dlt, y, f = (_n(110 + i, shp) for i in range(3))
        g, dW = _n(113, shp_g), _n(114, shp_w)
        h = jnp.float32(0.07)
        a, b = -0.46, 0.93

        def loss_op(dlt, y, f, g, dW, h):
            d2, y2 = sops.fused_ws_stage(dlt, y, f, g, dW, h, a=a, b=b,
                                         noise=noise, interpret=True)
            return jnp.sum(d2 ** 2) + jnp.sum(jnp.sin(y2))

        def loss_ref(dlt, y, f, g, dW, h):
            d2, y2 = ref_fn(dlt, y, f, g, dW, h, a, b)
            return jnp.sum(d2 ** 2) + jnp.sum(jnp.sin(y2))

        gk = jax.grad(loss_op, argnums=tuple(range(6)))(dlt, y, f, g, dW, h)
        gr = jax.grad(loss_ref, argnums=tuple(range(6)))(dlt, y, f, g, dW, h)
        for got, want in zip(gk, gr):
            np.testing.assert_allclose(got, want, atol=1e-5)

    def test_increment_vjp_vs_autodiff_through_ref(self):
        f, g, dW = (_n(120 + i, (33,)) for i in range(3))
        h = jnp.float32(0.06)

        def loss_op(f, g, dW, h):
            return jnp.sum(sops.fused_increment(f, g, dW, h, noise="diagonal",
                                                interpret=True) ** 3)

        def loss_ref(f, g, dW, h):
            return jnp.sum(sref.increment_diag_ref(f, g, dW, h) ** 3)

        gk = jax.grad(loss_op, argnums=(0, 1, 2, 3))(f, g, dW, h)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(f, g, dW, h)
        for got, want in zip(gk, gr):
            np.testing.assert_allclose(got, want, atol=1e-5)

    def test_axpy_chain_vjp_vs_autodiff_through_ref(self):
        y = _n(130, (21,))
        incs = jnp.stack([_n(131 + i, (21,)) for i in range(3)])
        coeffs = (0.5, -1.25, 2.0)

        def loss_op(y, incs):
            return jnp.sum(sops.fused_axpy_chain(y, incs, coeffs,
                                                 interpret=True) ** 2)

        def loss_ref(y, incs):
            return jnp.sum(sref.axpy_chain_ref(y, incs, coeffs) ** 2)

        gk = jax.grad(loss_op, argnums=(0, 1))(y, incs)
        gr = jax.grad(loss_ref, argnums=(0, 1))(y, incs)
        for got, want in zip(gk, gr):
            np.testing.assert_allclose(got, want, atol=1e-5)

    def test_force_interpret_hook(self):
        """The CI drift gate relies on force_interpret() routing the ops
        through the kernel bodies; make sure the hook restores itself."""
        f, g, dW = (_n(140 + i, (9,)) for i in range(3))
        h = jnp.float32(0.01)
        want = sref.increment_diag_ref(f, g, dW, h)
        with sops.force_interpret():
            got = sops.fused_increment(f, g, dW, h, noise="diagonal")
        np.testing.assert_allclose(got, want, atol=1e-6)
        assert not sops._FORCE_INTERPRET
