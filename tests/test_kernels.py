"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes,
executed in Pallas interpret mode (kernel body runs in Python on CPU)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.williamson2n.ops import williamson2n_update
from repro.kernels.williamson2n.ref import williamson2n_ref

# Lazy PRNG key: creating a jax array at module scope initialises the
# XLA backend during *collection*, which the default (tier-1) lane pays
# even when this module's slow-marked cases are deselected — keep heavy
# device setup out of import time.
@functools.lru_cache(maxsize=None)
def KEY():
    return jax.random.PRNGKey(0)


class TestWilliamson2N:
    @pytest.mark.parametrize(
        "shape", [(128,), (1000,), (8, 128), (3, 5, 7), (4096,), (2, 1024)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        d, k, y = (
            jax.random.normal(jax.random.fold_in(KEY(), i), shape, dtype)
            for i in range(3)
        )
        a, b = -35 / 32, 2 / 5
        got = williamson2n_update(d, k, y, a, b, True)
        want = williamson2n_ref(d, k, y, a, b)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32), atol=tol
            )

    def test_vjp_matches_ref(self):
        shape = (513,)
        d, k, y = (
            jax.random.normal(jax.random.fold_in(KEY(), i), shape) for i in range(3)
        )
        f_k = lambda *xs: jnp.sum(williamson2n_update(*xs, -0.46, 0.93, True)[1] ** 2)
        f_r = lambda *xs: jnp.sum(williamson2n_ref(*xs, -0.46, 0.93)[1] ** 2)
        gk = jax.grad(f_k, argnums=(0, 1, 2))(d, k, y)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(d, k, y)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 3000),
        a=st.floats(-2.0, 2.0),
        b=st.floats(-2.0, 2.0),
    )
    def test_property_random_coeffs(self, n, a, b):
        d, k, y = (
            jax.random.normal(jax.random.fold_in(KEY(), 100 + i), (n,)) for i in range(3)
        )
        got = williamson2n_update(d, k, y, a, b, True)
        want = williamson2n_ref(d, k, y, a, b)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hk,s,d,causal",
        [
            (2, 4, 2, 256, 64, True),
            (1, 8, 1, 128, 128, True),   # MQA
            (2, 2, 2, 256, 64, False),
            (1, 4, 4, 384, 32, True),    # MHA, 3 kv blocks
            (1, 16, 4, 256, 64, True),   # GQA group 4
        ],
    )
    def test_matches_ref(self, b, hq, hk, s, d, causal):
        q = jax.random.normal(jax.random.fold_in(KEY(), 10), (b, hq, s, d))
        k = jax.random.normal(jax.random.fold_in(KEY(), 11), (b, hk, s, d))
        v = jax.random.normal(jax.random.fold_in(KEY(), 12), (b, hk, s, d))
        got = flash_attention(q, k, v, causal=causal, interpret=True)
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = (
            jax.random.normal(jax.random.fold_in(KEY(), 20 + i), (1, 2, 256, 64), dtype)
            for i in range(3)
        )
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
        )

    def test_block_sizes(self):
        q, k, v = (
            jax.random.normal(jax.random.fold_in(KEY(), 30 + i), (1, 2, 256, 64))
            for i in range(3)
        )
        base = attention_ref(q, k, v, causal=True)
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
            got = flash_attention(
                q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True
            )
            np.testing.assert_allclose(got, base, atol=2e-5)

    def test_sm_scale(self):
        q, k, v = (
            jax.random.normal(jax.random.fold_in(KEY(), 40 + i), (1, 2, 128, 64))
            for i in range(3)
        )
        got = flash_attention(q, k, v, causal=True, sm_scale=0.5, interpret=True)
        want = attention_ref(q, k, v, causal=True, sm_scale=0.5)
        np.testing.assert_allclose(got, want, atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,l,h,dh,ds,chunk",
        [
            (2, 128, 3, 16, 32, 32),
            (1, 256, 2, 64, 128, 128),
            (2, 64, 4, 8, 16, 64),   # single chunk
            (1, 512, 1, 32, 64, 64),
        ],
    )
    def test_matches_sequential(self, b, l, h, dh, ds, chunk):
        ks = jax.random.split(jax.random.fold_in(KEY(), l + h), 5)
        x = jax.random.normal(ks[0], (b, l, h, dh))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B = jax.random.normal(ks[3], (b, l, ds))
        C = jax.random.normal(ks[4], (b, l, ds))
        y_seq, S_seq = ssd_ref(x, dt, A, B, C)
        y_chk, S_chk = ssd_chunked_ref(x, dt, A, B, C, chunk=chunk)
        y_pal = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
        np.testing.assert_allclose(y_chk, y_seq, atol=5e-4)
        np.testing.assert_allclose(S_chk, S_seq, atol=5e-5)
        np.testing.assert_allclose(y_pal, y_seq, atol=5e-4)

    def test_decay_extremes(self):
        """Strong decay (dt large) must not produce NaN/inf."""
        b, l, h, dh, ds = 1, 128, 2, 8, 16
        ks = jax.random.split(KEY(), 5)
        x = jax.random.normal(ks[0], (b, l, h, dh))
        dt = jnp.full((b, l, h), 5.0)
        A = jnp.array([-8.0, -0.001])
        B = jax.random.normal(ks[3], (b, l, ds))
        C = jax.random.normal(ks[4], (b, l, ds))
        y = ssd_scan(x, dt, A, B, C, chunk=64, interpret=True)
        assert np.isfinite(np.asarray(y)).all()
        y_seq, _ = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(y, y_seq, atol=5e-4)
