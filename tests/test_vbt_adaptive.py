"""Virtual Brownian Tree + adaptive solve path: query reproducibility,
refinement consistency, adaptive-vs-fixed strong error on a matched driver,
gradients through realize-then-solve, and the sdeint/engine wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SDETerm,
    TimeGrid,
    get_solver,
    integrate_adaptive,
    parse_solver_spec,
    sdeint,
    solve,
    virtual_brownian_tree,
)
from repro.serving import SDESampleConfig, SDESampleEngine

KEY = jax.random.PRNGKey(0)


def fixed_solve(spec, term, y0, driver, n_steps, args=None):
    """Uniform-grid solve on a matched driver (what integrate_fixed used to
    do, routed through the unified solve())."""
    grid = TimeGrid.uniform(driver.t0, driver.t1, n_steps, driver)
    return solve(get_solver(spec), term, y0, grid, args).y_final


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y)),
        noise="diagonal",
    )


ARGS = {
    "nu": jnp.float64(0.7),
    "mu": jnp.float64(0.2),
    "sigma": jnp.float64(0.4),
}


def vbt(key=KEY, t0=0.0, t1=1.0, shape=(3,), tol=None):
    return virtual_brownian_tree(key, t0, t1, shape=shape, dtype=jnp.float64,
                                 tol=tol)


# ---------------------------------------------------------------------------
# Virtual Brownian Tree.
# ---------------------------------------------------------------------------

class TestVirtualBrownianTree:
    def test_same_query_is_bitwise_equal(self):
        """W(t) and increments are pure functions of (key, s, t)."""
        b = vbt()
        for s, t in [(0.0, 0.5), (0.3, 0.7), (0.123, 0.891)]:
            a1 = np.asarray(b.increment_over(s, t))
            a2 = np.asarray(b.increment_over(s, t))
            np.testing.assert_array_equal(a1, a2)
        # distinct keys give distinct paths
        other = vbt(jax.random.PRNGKey(1))
        assert not np.array_equal(np.asarray(b.increment_over(0.3, 0.7)),
                                  np.asarray(other.increment_over(0.3, 0.7)))

    def test_vmap_lane_bitwise_equals_solo_query(self):
        keys = jax.random.split(KEY, 8)
        t = 0.637
        batched = jax.vmap(lambda k: vbt(k).weval(t))(keys)
        for i in range(8):
            np.testing.assert_array_equal(np.asarray(vbt(keys[i]).weval(t)),
                                          np.asarray(batched[i]))

    def test_consistency_under_interval_refinement(self):
        """Refining [s, u] at any midpoint leaves the total increment fixed:
        the accept/reject property — a rejected step re-queries smaller
        intervals of the *same* path."""
        b = vbt()
        for (s, m, u) in [(0.0, 0.5, 1.0), (0.25, 0.375, 0.5),
                          (0.2, 0.33, 0.81)]:
            whole = np.asarray(b.increment_over(s, u))
            parts = np.asarray(b.increment_over(s, m)) + np.asarray(
                b.increment_over(m, u))
            np.testing.assert_allclose(whole, parts, rtol=0, atol=1e-12)

    def test_w_t0_is_exactly_zero(self):
        assert np.all(np.asarray(vbt().weval(0.0)) == 0.0)

    def test_increment_statistics(self):
        """Var[W(t) - W(s)] == t - s, independent increments (bridge sanity)."""
        keys = jax.random.split(KEY, 2000)
        f = jax.vmap(lambda k: vbt(k, shape=()).weval(jnp.array(1.0)))
        g = jax.vmap(lambda k: vbt(k, shape=()).increment_over(0.31, 0.55))
        w1, inc = f(keys), g(keys)
        assert abs(float(jnp.var(w1)) - 1.0) < 0.1
        assert abs(float(jnp.var(inc)) - 0.24) < 0.05
        # increment over [0.31, 0.55] independent of W up to 0.31
        w_pre = jax.vmap(lambda k: vbt(k, shape=()).weval(0.31))(keys)
        assert abs(float(jnp.mean(w_pre * inc))) < 0.03

    def test_pytree_shapes(self):
        b = vbt(shape=((2,), (4,)))
        inc = b.increment_over(0.2, 0.7)
        assert inc[0].shape == (2,) and inc[1].shape == (4,)
        # leaves come from independent streams
        b1 = vbt(shape=(2,))
        assert not np.array_equal(np.asarray(inc[0]),
                                  np.asarray(b1.increment_over(0.2, 0.7)))


# ---------------------------------------------------------------------------
# Adaptive vs fixed grid on a matched driver.
# ---------------------------------------------------------------------------

class TestAdaptiveStrongError:
    @pytest.mark.parametrize("spec", ["ees25", "ees27"])
    def test_adaptive_matches_fixed_grid_strong_error(self, spec):
        """At matched tolerance the adaptive solve's strong error (vs a fine
        reference on the SAME driver) is comparable to a fixed grid of the
        same step count, and tightening rtol tightens the error."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        keys = jax.random.split(KEY, 16)

        def tree(k):
            return vbt(k, tol=2.0 ** -14)

        ref = jax.jit(jax.vmap(
            lambda k: fixed_solve(spec, term, y0, tree(k), 1024, ARGS)
        ))(keys)

        def serr(y):
            return float(jnp.sqrt(jnp.mean(jnp.sum((y - ref) ** 2, axis=-1))))

        errs, steps = [], []
        for rtol in (1e-2, 1e-3):
            out = jax.jit(jax.vmap(lambda k: integrate_adaptive(
                spec, term, y0, tree(k), ARGS, rtol=rtol, atol=rtol * 1e-2,
                max_steps=512, bounded=False,
            )))(keys)
            np.testing.assert_allclose(np.asarray(out.t_final), 1.0)
            errs.append(serr(out.y_final))
            steps.append(float(jnp.mean(out.n_accepted)))
        assert errs[1] < errs[0], (errs, steps)  # tolerance actually controls
        fixed = jax.jit(jax.vmap(
            lambda k: fixed_solve(spec, term, y0, tree(k),
                                  int(round(steps[1])), ARGS)
        ))(keys)
        # same step budget, same ballpark error (within 4x either way)
        assert errs[1] < 4.0 * serr(fixed) + 1e-12, (errs, serr(fixed))

    def test_rejected_steps_do_not_perturb_the_path(self):
        """Runs with different initial h (different reject patterns) converge
        to the same pathwise solution — the VBT keeps the Brownian path fixed
        under re-queries."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        b = vbt(tol=2.0 ** -14)
        outs = [
            integrate_adaptive("ees25", term, y0, b, ARGS, rtol=1e-4,
                               atol=1e-6, h0=h0, max_steps=1024,
                               bounded=False).y_final
            for h0 in (0.5, 0.01)
        ]
        # different accepted grids → discretisation-level differences only
        # (a driver that resampled on rejection would diverge at O(1))
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                                   atol=2e-2)


# ---------------------------------------------------------------------------
# Gradients through the adaptive path.
# ---------------------------------------------------------------------------

class TestAdaptiveGradients:
    def test_full_adjoint_matches_matched_grid_gradient(self):
        """Adaptive full-adjoint gradients agree with the fixed-grid gradient
        on the same driver at tight tolerance (both approximate the same
        continuous adjoint)."""
        term = ou_term()
        y0 = jnp.ones(2, jnp.float64)
        b = vbt(shape=(2,), tol=2.0 ** -14)

        def aloss(a):
            out = integrate_adaptive("ees25", term, y0, b, a, rtol=1e-5,
                                     atol=1e-7, max_steps=1024)
            return jnp.sum(out.y_final ** 2)

        def floss(a):
            return jnp.sum(fixed_solve("ees25", term, y0, b, 1024, a) ** 2)

        ga = jax.grad(aloss)(ARGS)
        gf = jax.grad(floss)(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(ga[k], gf[k], rtol=2e-2)

    def test_recursive_adjoint_matches_full(self):
        """The recursive adjoint (remat over the realized-grid solve) is a
        pure remat: same gradients up to XLA re-fusion, less memory."""
        term = ou_term()
        y0 = jnp.ones(2, jnp.float64)
        keys = jax.random.split(KEY, 3)

        def loss(a, adjoint):
            r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y0, None,
                       args=a, adjoint=adjoint, rtol=1e-3, batch_keys=keys)
            return jnp.mean(r.y_final ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "recursive"))(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-9)

    def test_ode_gradient_matches_analytic(self):
        """d/da of e^{-a} through the adaptive loop, vs the analytic value."""
        def loss(a):
            term = SDETerm(drift=lambda t, y, p: -p * y, noise="none")
            out = integrate_adaptive("ees25", term, jnp.array([1.0]), None,
                                     args=a, t0=0.0, t1=1.0, rtol=1e-5,
                                     atol=1e-8, max_steps=1024)
            return out.y_final[0]

        g = float(jax.grad(loss)(jnp.float64(1.0)))
        np.testing.assert_allclose(g, -np.exp(-1.0), rtol=1e-3)


# ---------------------------------------------------------------------------
# sdeint wiring: spec flags, save_at dense output, batch fan-out, errors.
# ---------------------------------------------------------------------------

class TestSdeintAdaptive:
    def test_spec_flag_parses_and_marks_solver(self):
        assert parse_solver_spec("ees25:adaptive") == ("ees25", {"adaptive": True})
        s = get_solver("ees25:adaptive")
        assert getattr(s, "adaptive", False) is True
        assert not getattr(get_solver("ees25"), "adaptive", False)

    def test_save_at_dense_output_shapes_and_batch_bitwise(self):
        """Acceptance criterion: sdeint(term, "ees25:adaptive", ...,
        save_at=ts) returns trajectories on an arbitrary grid, bitwise equal
        across batch fan-out to the single-key solve."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        ts = jnp.array([0.0, 0.1, 0.25, 0.5, 0.9, 1.0])
        keys = jax.random.split(KEY, 4)
        r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 256, y0, None, args=ARGS,
                   save_at=ts, batch_keys=keys)
        assert r.ys.shape == (4, 6, 3) and r.y_final.shape == (4, 3)
        np.testing.assert_allclose(np.asarray(r.t_final), 1.0)
        np.testing.assert_array_equal(np.asarray(r.ys[:, 0]),
                                      np.ones((4, 3)))  # save at t0 holds y0
        solo = sdeint(term, "ees25:adaptive", 0.0, 1.0, 256, y0, keys[1],
                      args=ARGS, save_at=ts)
        np.testing.assert_array_equal(np.asarray(solo.ys), np.asarray(r.ys[1]))
        np.testing.assert_array_equal(np.asarray(solo.y_final),
                                      np.asarray(r.y_final[1]))
        # final save point coincides with y_final
        np.testing.assert_allclose(np.asarray(r.ys[:, -1]),
                                   np.asarray(r.y_final), atol=1e-12)

    def test_dense_output_tracks_solution(self):
        """save_at values match the analytic solution at off-step times (ODE
        mode, where the interpolation error is deterministic; the SDE wiring
        is pinned bitwise by the batch-fan-out test above)."""
        term = SDETerm(drift=lambda t, y, a: -5.0 * y, noise="none")
        y0 = jnp.array([1.0], dtype=jnp.float64)
        ts = jnp.array([0.0, 0.137, 0.25, 0.612, 0.9, 1.0])
        r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 2048, y0, KEY,
                   rtol=1e-5, atol=1e-8, save_at=ts)
        np.testing.assert_allclose(np.asarray(r.t_final), 1.0)
        np.testing.assert_allclose(np.asarray(r.ys[:, 0]),
                                   np.exp(-5.0 * np.asarray(ts)), atol=2e-4)

    def test_reversible_plus_adaptive_runs(self):
        """The old 'reversible requires a fixed grid' restriction is gone:
        the solve runs over the realized grid, so the reversible backward
        sweep replays the same non-uniform steps."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y0, KEY,
                   args=ARGS, rtol=1e-3, adjoint="reversible")
        f = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y0, KEY,
                   args=ARGS, rtol=1e-3, adjoint="full")
        # identical forward bits; gradient parity lives in
        # tests/test_realized_grid.py
        np.testing.assert_array_equal(np.asarray(r.y_final),
                                      np.asarray(f.y_final))

    def test_save_at_without_adaptive_raises(self):
        with pytest.raises(ValueError, match="adaptive"):
            sdeint(ou_term(), "ees25", 0.0, 1.0, 64, jnp.ones(3), KEY,
                   args=ARGS, save_at=jnp.array([0.5]))

    def test_tolerances_without_adaptive_raise(self):
        """A tolerance request must not silently run a fixed grid."""
        for kw in ({"rtol": 1e-3}, {"atol": 1e-5}, {"h0": 0.1},
                   {"bm_tol": 1e-3}):
            with pytest.raises(ValueError, match="adaptive"):
                sdeint(ou_term(), "ees25", 0.0, 1.0, 64, jnp.ones(3), KEY,
                       args=ARGS, **kw)

    def test_bounded_modes_bitwise_equal(self):
        """The single forward-only controller pass (bounded=False) and
        realize-then-solve (bounded=True) walk identical trial sequences —
        bitwise-equal outputs."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        ts = jnp.array([0.5, 1.0])
        a = sdeint(term, "ees25:adaptive", 0.0, 1.0, 256, y0, KEY, args=ARGS,
                   rtol=1e-3, save_at=ts)
        b = sdeint(term, "ees25:adaptive", 0.0, 1.0, 256, y0, KEY, args=ARGS,
                   rtol=1e-3, save_at=ts, bounded=False)
        np.testing.assert_array_equal(np.asarray(a.y_final),
                                      np.asarray(b.y_final))
        np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(b.ys))
        assert int(a.n_accepted) == int(b.n_accepted)

    def test_recursive_with_unbounded_raises(self):
        with pytest.raises(ValueError, match="forward-only"):
            sdeint(ou_term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3),
                   KEY, args=ARGS, adjoint="recursive", bounded=False)

    def test_save_every_with_adaptive_raises(self):
        with pytest.raises(ValueError, match="save_at"):
            sdeint(ou_term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3), KEY,
                   args=ARGS, save_every=8)

    def test_solver_without_estimator_raises(self):
        with pytest.raises(ValueError, match="embedded"):
            sdeint(ou_term(), "reversible_heun", 0.0, 1.0, 64, jnp.ones(3),
                   KEY, args=ARGS, adaptive=True)


# ---------------------------------------------------------------------------
# Serving-engine adaptive requests.
# ---------------------------------------------------------------------------

class TestEngineAdaptive:
    def term(self):
        return SDETerm(
            drift=lambda t, y, a: -0.5 * y,
            diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
            noise="diagonal",
        )

    def test_adaptive_request_served_with_save_at(self):
        eng = SDESampleEngine(self.term(), jnp.ones(3), SDESampleConfig(slots=4))
        rid = eng.submit("ees25:adaptive", t1=1.0, n_steps=128, n_paths=6,
                         rtol=1e-3, save_at=[0.5, 1.0], seed=11)
        done = eng.run()
        assert done[rid].y_final.shape == (6, 3)
        assert done[rid].ys.shape == (6, 2, 3)
        assert np.isfinite(done[rid].ys).all()
        # truncation is detectable: every path reports where it stopped
        assert done[rid].t_final.shape == (6,)
        np.testing.assert_allclose(done[rid].t_final, 1.0)
        # realized-grid stats come back per path
        assert done[rid].n_accepted.shape == (6,)
        assert done[rid].n_rejected.shape == (6,)
        assert (done[rid].n_accepted >= 1).all()
        # reproducible offline from the seed, like fixed-grid requests
        keys = jnp.stack(
            [jax.random.fold_in(jax.random.PRNGKey(11), i) for i in range(6)]
        )
        ref = sdeint(self.term(), "ees25:adaptive", 0.0, 1.0, 128,
                     jnp.ones(3), None, rtol=1e-3,
                     save_at=jnp.array([0.5, 1.0]), batch_keys=keys,
                     dtype=jnp.float32)
        np.testing.assert_array_equal(done[rid].y_final,
                                      np.asarray(ref.y_final))

    def test_adaptive_options_validated_at_submit(self):
        eng = SDESampleEngine(self.term(), jnp.ones(3))
        with pytest.raises(ValueError, match="adaptive"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1, rtol=1e-3)
        with pytest.raises(ValueError, match="adaptive"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1, save_at=[0.5])
        with pytest.raises(ValueError, match="save_at"):
            eng.submit("ees25:adaptive", t1=1.0, n_steps=8, n_paths=1,
                       save_every=4)
        with pytest.raises(ValueError, match="save_at"):
            eng.submit("ees25:adaptive", t1=1.0, n_steps=8, n_paths=1,
                       save_at=[2.5])  # outside [t0, t1]
        assert not eng.queue
