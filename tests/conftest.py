"""Shared test configuration.

NOTE: deliberately does NOT set XLA_FLAGS / host device count — smoke tests
and benchmarks must see the single real CPU device.  Only launch/dryrun.py
fakes 512 devices, in its own process.
"""
import jax
import pytest

# Numerical-order measurements need f64.
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
