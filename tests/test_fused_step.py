"""The PR-4 solve-hot-path optimisations, end to end.

Three invariants:

* **Fused step kernels** (``use_kernels=True``): every solver x noise mode x
  adjoint matches the unfused path to tolerance — in the XLA-fallback mode
  (where the fused ops ARE their ``ref.py`` twins) and with the Pallas kernel
  bodies forced on via interpret mode; the reversible solvers'
  ``reverse``/``step`` stays an exact inverse on the fused path.
* **Bulk Brownian realization** (the new default): bitwise-identical results
  and gradients to the per-step path (``bulk_increments=False``), on fixed
  and realized grids, and bitwise-equal stacked increments row-for-row.
* **Serving dispatch**: one compiled executable per signature, reused across
  ticks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_solver, sdeint, solve
from repro.core.brownian import brownian_path, virtual_brownian_tree
from repro.core.grid import TimeGrid
from repro.core.solvers import SDETerm
from repro.kernels.sde_step import ops as sops

SEED = jax.random.PRNGKey(11)


def diag_term():
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.cos(y),
        noise="diagonal",
    )


def general_term():
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * jnp.stack(
            [jnp.ones_like(y), 0.5 * y], axis=-1),
        noise="general",
    )


def args():
    return {"nu": jnp.asarray(0.4), "mu": jnp.asarray(0.1),
            "sigma": jnp.asarray(0.7)}


FUSED_SOLVERS = ("ees25", "ees27", "reversible_heun", "mcf-midpoint", "rk4")


class TestFusedSolverPath:
    @pytest.mark.parametrize("spec", FUSED_SOLVERS)
    @pytest.mark.parametrize("noise", ["diagonal", "general"])
    def test_step_matches_unfused(self, spec, noise):
        term = diag_term() if noise == "diagonal" else general_term()
        nshape = (4,) if noise == "diagonal" else (2,)  # (m,) channels
        keys = jax.random.split(SEED, 3)
        base = sdeint(term, spec, 0.0, 1.0, 24, jnp.ones(4), None, args=args(),
                      batch_keys=keys, noise_shape=nshape).y_final
        fused = sdeint(term, get_solver(spec, use_kernels=True), 0.0, 1.0, 24,
                       jnp.ones(4), None, args=args(), batch_keys=keys,
                       noise_shape=nshape).y_final
        np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                                   rtol=1e-10, atol=1e-10)
        with sops.force_interpret():
            interp = sdeint(term, get_solver(spec, use_kernels=True), 0.0, 1.0,
                            24, jnp.ones(4), None, args=args(),
                            batch_keys=keys, noise_shape=nshape).y_final
        np.testing.assert_allclose(np.asarray(interp), np.asarray(base),
                                   rtol=1e-6, atol=1e-8)

    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    def test_gradients_match_unfused(self, adjoint):
        term = diag_term()
        keys = jax.random.split(SEED, 2)

        def loss(a, solver):
            r = sdeint(term, solver, 0.0, 1.0, 16, jnp.ones(4), None, args=a,
                       batch_keys=keys, adjoint=adjoint)
            return jnp.sum(r.y_final ** 2)

        g0 = jax.grad(lambda a: loss(a, get_solver("ees25")))(args())
        g1 = jax.grad(lambda a: loss(a, get_solver("ees25", use_kernels=True)))(args())
        for k in g0:
            np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g0[k]),
                                       rtol=1e-9, atol=1e-10)

    @pytest.mark.parametrize("spec", ["reversible_heun", "mcf-midpoint"])
    def test_reverse_is_exact_inverse_on_fused_path(self, spec):
        """Algebraic reversibility survives fusion: combine(-h, -dW) is the
        exact negation of combine(h, dW) (IEEE negation), so reverse∘step
        reconstructs the pre-step state bit-for-bit modulo the solvers'
        documented algebra."""
        term = diag_term()
        solver = get_solver(spec, use_kernels=True)
        y0 = jnp.linspace(0.5, 1.5, 4)
        dW = 0.1 * jax.random.normal(SEED, (4,))
        with sops.force_interpret():
            state = solver.init(term, 0.0, y0, args())
            after = solver.step(term, state, 0.0, 0.05, dW, args())
            back = solver.reverse(term, after, 0.0, 0.05, dW, args())
        for a, b in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(state)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_ees_reverse_near_inverse_on_fused_path(self):
        term = diag_term()
        solver = get_solver("ees25", use_kernels=True)
        y0 = jnp.linspace(0.5, 1.5, 4)
        dW = 0.1 * jax.random.normal(SEED, (4,))
        h = 1e-3
        state = solver.init(term, 0.0, y0, args())
        after = solver.step(term, state, 0.0, h, dW, args())
        back = solver.reverse(term, after, 0.0, h, dW, args())
        # O(h^{m+1}) effective symmetry, far below the step size itself.
        np.testing.assert_allclose(np.asarray(back), np.asarray(y0), atol=1e-9)

    def test_spec_string_reaches_flag(self):
        assert get_solver("ees25:use_kernels=True").use_kernels
        assert get_solver("ees25:use_kernel=True").use_kernels  # old spelling
        assert not get_solver("ees25").use_kernels
        assert get_solver("reversible_heun:use_kernels=True").use_kernels
        assert get_solver("mcf-rk4:use_kernels=True").base.use_kernels
        # programmatic override pins the flag against the config string,
        # old spelling included
        assert not get_solver("ees25:use_kernel=True", use_kernels=False).use_kernels
        assert get_solver("ees25", use_kernels=True).use_kernels

    def test_tuple_state_fused_sweep(self):
        """Product-group states are tuples; the fused stage unzip must not
        mistake the state tuple for a (delta', y') pair."""
        term = SDETerm(
            drift=lambda t, y, a: (-y[0], 0.5 * y[1]),
            diffusion=lambda t, y, a: (jnp.ones_like(y[0]),
                                       0.2 * jnp.ones_like(y[1])),
            noise="diagonal",
        )
        y0 = (jnp.linspace(0.1, 1.0, 3), jnp.linspace(-1.0, 1.0, 5))
        r_base = sdeint(term, "ees25", 0.0, 1.0, 16, y0,
                        key=jax.random.PRNGKey(5))
        r_fused = sdeint(term, get_solver("ees25", use_kernels=True), 0.0,
                         1.0, 16, y0, key=jax.random.PRNGKey(5))
        for a, b in zip(jax.tree_util.tree_leaves(r_fused.y_final),
                        jax.tree_util.tree_leaves(r_base.y_final)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-10, atol=1e-12)

    def test_odd_row_count_blocks(self):
        """Leaves whose padded rows are not a multiple of the default block
        (e.g. 40960 elements -> 320 rows vs block 256) must still run."""
        x = [jax.random.normal(jax.random.fold_in(SEED, 200 + i), (40960,),
                               jnp.float32) for i in range(5)]
        h = jnp.float32(0.02)
        from repro.kernels.sde_step import ref as sref_local
        with sops.force_interpret():
            got = sops.fused_ws_stage(x[0], x[1], x[2], x[3], x[4], h,
                                      a=-0.4, b=0.9, noise="diagonal")
        want = sref_local.ws_stage_diag_ref(x[0], x[1], x[2], x[3], x[4], h,
                                            -0.4, 0.9)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_fused_adaptive_reversible(self):
        term = diag_term()
        keys = jax.random.split(SEED, 2)
        base = sdeint(term, "ees25:adaptive", 0.0, 1.0, 96, jnp.ones(3), None,
                      args=args(), batch_keys=keys, rtol=1e-3,
                      adjoint="reversible")
        fused = sdeint(term, get_solver("ees25:adaptive", use_kernels=True),
                       0.0, 1.0, 96, jnp.ones(3), None, args=args(),
                       batch_keys=keys, rtol=1e-3, adjoint="reversible")
        np.testing.assert_allclose(np.asarray(fused.y_final),
                                   np.asarray(base.y_final),
                                   rtol=1e-9, atol=1e-10)
        np.testing.assert_array_equal(np.asarray(fused.n_accepted),
                                      np.asarray(base.n_accepted))


class TestBulkIncrements:
    def test_path_rows_match_per_step(self):
        # Bit-stability is a *compiled-computation* property (the bulk pass
        # runs under its own jit precisely so its bits cannot depend on the
        # calling context); compare against the jitted per-step draw, which
        # is what every solve's scan body actually runs.
        bm = brownian_path(SEED, 0.0, 2.0, 17, shape=(3,))
        ts = bm.t0 + jnp.arange(18) * bm.h
        bulk = np.asarray(bm.grid_increments(ts))
        per_step = jax.jit(bm.increment)
        for n in (0, 7, 16):
            np.testing.assert_array_equal(bulk[n], np.asarray(per_step(n)))

    def test_vbt_rows_match_per_step(self):
        vbt = virtual_brownian_tree(SEED, 0.0, 1.0, shape=(2,))
        ts = jnp.asarray([0.0, 0.13, 0.4, 0.41, 0.9, 1.0])
        bulk = jax.tree_util.tree_leaves(vbt.grid_increments(ts))[0]
        for n in range(5):
            np.testing.assert_array_equal(
                np.asarray(bulk[n]), np.asarray(vbt.grid_increment(ts, n)))

    def test_foreign_grid_still_loud(self):
        bm = brownian_path(SEED, 0.0, 1.0, 8, shape=(3,))
        with pytest.raises(ValueError, match="native 8-step grid"):
            bm.grid_increments(jnp.linspace(0.0, 1.0, 6))

    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    def test_fixed_grid_matches_per_step(self, adjoint):
        # The two modes consume bit-identical Brownian increments (tested
        # above), but feed the scan body from a gather vs an in-body RNG —
        # two different XLA programs, whose FMA scheduling may differ by an
        # ulp.  Outputs must agree to that level; the *within-mode* bitwise
        # guarantees (batch == loop, engine == offline replay, adjoint
        # parity) are covered by the seed suite, which runs on bulk now.
        term = diag_term()
        keys = jax.random.split(SEED, 4)

        def run(bulk):
            r = sdeint(term, "ees25", 0.0, 1.0, 32, jnp.ones(4), None,
                       args=args(), batch_keys=keys, adjoint=adjoint,
                       save_every=8, bulk_increments=bulk)
            return r.y_final, r.ys

        yf_a, ys_a = jax.jit(lambda: run(True))()
        yf_b, ys_b = jax.jit(lambda: run(False))()
        np.testing.assert_allclose(np.asarray(yf_a), np.asarray(yf_b),
                                   rtol=1e-12, atol=1e-13)
        np.testing.assert_allclose(np.asarray(ys_a), np.asarray(ys_b),
                                   rtol=1e-12, atol=1e-13)

    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    def test_fixed_grid_gradients_match(self, adjoint):
        term = diag_term()
        keys = jax.random.split(SEED, 2)

        def loss(a, bulk):
            r = sdeint(term, "ees25", 0.0, 1.0, 16, jnp.ones(4), None, args=a,
                       batch_keys=keys, adjoint=adjoint, bulk_increments=bulk)
            return jnp.sum(r.y_final ** 2)

        ga = jax.jit(jax.grad(lambda a: loss(a, True)))(args())
        gb = jax.jit(jax.grad(lambda a: loss(a, False)))(args())
        for k in ga:
            np.testing.assert_allclose(np.asarray(ga[k]), np.asarray(gb[k]),
                                       rtol=1e-12, atol=1e-13)

    @pytest.mark.parametrize("adjoint", ["full", "reversible"])
    def test_realized_grid_bitwise(self, adjoint):
        term = diag_term()
        keys = jax.random.split(SEED, 3)
        ts = jnp.linspace(0.0, 1.0, 7)

        def run(bulk):
            r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 96, jnp.ones(3), None,
                       args=args(), batch_keys=keys, rtol=1e-3, save_at=ts,
                       adjoint=adjoint, bulk_increments=bulk)
            return np.asarray(r.y_final), np.asarray(r.ys)

        (yf_a, ys_a), (yf_b, ys_b) = run(True), run(False)
        np.testing.assert_allclose(yf_a, yf_b, rtol=1e-12, atol=1e-13)
        np.testing.assert_allclose(ys_a, ys_b, rtol=1e-12, atol=1e-13)

    def test_ode_mode_unaffected(self):
        term = SDETerm(drift=lambda t, y, a: -y, noise="none")
        grid = TimeGrid.uniform(0.0, 1.0, 16)
        assert grid.increments() is None
        out = solve(get_solver("rk4"), term, jnp.ones(3), grid)
        np.testing.assert_allclose(np.asarray(out.y_final),
                                   np.exp(-1.0) * np.ones(3), atol=1e-6)

    def test_prefix_sum_increment_over(self):
        """BrownianPath.increment_over: cumsum lookup == summed increments."""
        bm = brownian_path(SEED, 0.0, 1.0, 32, shape=(4,))
        want = np.sum(np.stack([np.asarray(bm.increment(n))
                                for n in range(4, 20)]), axis=0)
        got = np.asarray(bm.increment_over(bm.t_of(4), bm.t_of(20)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        # degenerate window: exactly zero
        np.testing.assert_array_equal(
            np.asarray(bm.increment_over(bm.t_of(5), bm.t_of(5))),
            np.zeros(4, np.float32))


class TestServingDispatch:
    def test_batch_fn_reused_across_ticks(self):
        from repro.serving import SDESampleConfig, SDESampleEngine

        # bucketing=False: this probes the exact-signature dispatch path
        # (the bucketed path's executable reuse is covered in
        # tests/test_bucketing.py)
        eng = SDESampleEngine(diag_term(), jnp.ones(3),
                              SDESampleConfig(slots=2, bucketing=False),
                              args=args())
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=5, seed=1)
        sig = eng.queue[0].request.signature
        fn_first = eng.executor._stack_fn(sig, 1)
        eng.run()
        assert eng.executor._stack_fn(sig, 1) is fn_first  # no per-tick re-jit
        assert len(eng._compiled) == 1
        assert eng.done[rid].y_final.shape == (5, 3)
