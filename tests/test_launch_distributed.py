"""Distribution-layer tests in a subprocess with 8 fake XLA devices.

Run in a child process because the host device count must stay 1 for every
other test (jax locks device count on first init).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Every case spawns a fresh interpreter with 8 fake XLA devices — tens of
# seconds of jax re-init each; slow lane only.
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mesh_and_param_shardings():
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import param_pspecs, batch_pspecs
        from repro.configs import get_arch
        from repro.models import init_params

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_arch("olmo-1b")
        ap = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        specs = param_pspecs(mesh, ap)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {"/".join(str(p) for p, _ in [(k.key, None) for k in path]): spec
             for path, spec in flat}
        # embed vocab-sharded; layer wq col-sharded with leading layer axis
        assert tuple(specs["embed"]) == ("model", None), specs["embed"]
        wq = specs["layers"]["attn"]["wq"]
        assert tuple(wq) == (None, None, "model"), wq
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    """Real (small) train step executed on an 8-device mesh: loss equals the
    unsharded single-device loss (SPMD correctness)."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import init_params, loss_fn, ModelOptions, ShardingPolicy
        from repro.launch.mesh import param_pspecs, shardings_for

        cfg = get_arch("qwen3-1.7b").smoke()
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab),
        }
        l_single = float(loss_fn(cfg, params, batch))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            p_sh = shardings_for(mesh, param_pspecs(mesh, params))
            b_sh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
            params_s = jax.device_put(params, p_sh)
            batch_s = jax.device_put(batch, b_sh)
            opts = ModelOptions(shard=ShardingPolicy(batch_axes=("data",), model_axis="model"))
            f = jax.jit(lambda p, b: loss_fn(cfg, p, b, opts),
                        in_shardings=(p_sh, b_sh))
            l_sharded = float(f(params_s, batch_s))
        assert abs(l_single - l_sharded) < 2e-2, (l_single, l_sharded)
        print("OK", l_single, l_sharded)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a (2,)-mesh, restore onto a (4,)-mesh (elastic recovery)."""
    out = run_py("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import restore_checkpoint, save_checkpoint

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh2 = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
        tree2 = jax.device_put(tree, {"w": NamedSharding(mesh2, P("data", None))})
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree2)
            mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("data",))
            sh4 = {"w": NamedSharding(mesh4, P("data", None))}
            got = restore_checkpoint(d, 1, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        assert len(got["w"].sharding.device_set) == 4
        print("OK")
    """)
    assert "OK" in out


def test_sdeint_mesh_fanout_matches_vmap():
    """shard_map Monte-Carlo fan-out over a device axis: same samples as the
    single-device vmap batch (sdeint's key-based batching is placement-free)."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SDETerm, sdeint

        term = SDETerm(
            drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
            diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
            noise="diagonal",
        )
        args = {"nu": jnp.float32(0.2), "mu": jnp.float32(0.1),
                "sigma": jnp.float32(2.0)}
        y0 = jnp.ones(4)
        keys = jax.random.split(jax.random.PRNGKey(0), 16)
        r_vmap = sdeint(term, "ees25", 0.0, 1.0, 8, y0, None, args=args,
                        save_every=4, batch_keys=keys)
        mesh = jax.make_mesh((8,), ("data",))
        r_sharded = sdeint(term, "ees25", 0.0, 1.0, 8, y0, None, args=args,
                           save_every=4, batch_keys=keys,
                           mesh=mesh, mesh_axis="data")
        np.testing.assert_allclose(np.asarray(r_vmap.y_final),
                                   np.asarray(r_sharded.y_final), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_vmap.ys),
                                   np.asarray(r_sharded.ys), rtol=1e-5)
        # ambient-mesh form: `with mesh:` supplies the mesh
        with mesh:
            r_ambient = sdeint(term, "ees25", 0.0, 1.0, 8, y0, None,
                               args=args, batch_keys=keys, mesh_axis="data")
        np.testing.assert_allclose(np.asarray(r_sharded.y_final),
                                   np.asarray(r_ambient.y_final), rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_engine_mesh_sharded_serving_bitwise():
    """Serving with mesh-sharded slots (slots = devices x per_device_slots)
    returns bit-identical SampleResults to plain single-device serving, for
    both single-tick and multi-tick dispatch — path keys are placement-
    independent, so sharding is invisible in the samples."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SDETerm
        from repro.launch.mesh import make_sample_mesh
        from repro.serving import SDESampleConfig, SDESampleEngine

        term = SDETerm(
            drift=lambda t, y, a: -0.5 * y,
            diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
            noise="diagonal",
        )

        def serve(cfg):
            eng = SDESampleEngine(term, jnp.ones(4), cfg)
            r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=20, seed=3)
            r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=5, seed=8)
            done = eng.run()
            return done[r1].y_final, done[r2].y_final

        mesh = make_sample_mesh()  # 8 fake devices on one "mc" axis
        plain = serve(SDESampleConfig(slots=8))
        sharded = serve(SDESampleConfig(slots=8, mesh=mesh, mesh_axis="mc"))
        sharded_multi = serve(SDESampleConfig(slots=8, mesh=mesh,
                                              mesh_axis="mc",
                                              ticks_per_dispatch=3))
        for a, b, c in zip(plain, sharded, sharded_multi):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)

        # indivisible slots are rejected up front, not at dispatch
        try:
            SDESampleEngine(term, jnp.ones(4),
                            SDESampleConfig(slots=6, mesh=mesh, mesh_axis="mc"))
        except ValueError as e:
            assert "multiple of mesh axis" in str(e)
        else:
            raise AssertionError("slots=6 on an 8-way axis should raise")
        print("OK")
    """)
    assert "OK" in out


def test_bench_throughput_mesh_ladder_emits_records():
    """With devices > 1 the throughput bench charts the sharded ladder into
    mesh_records (single-device runs keep records unchanged and empty
    mesh_records)."""
    out = run_py("""
        import os, json, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import benchmarks.bench_throughput as bt

        path = os.path.join(tempfile.mkdtemp(), "bench.json")
        bt.run(path, batch_sizes=(4, 16), solvers=("ees25",), n_steps=8, dim=4)
        data = json.load(open(path))
        assert data["n_devices"] == 8, data["n_devices"]
        assert len(data["records"]) == 2
        # batch 4 does not divide over 8 devices -> only batch 16 shards
        mesh = data["mesh_records"]
        assert [r["batch_size"] for r in mesh] == [16], mesh
        assert mesh[0]["devices"] == 8
        assert mesh[0]["speedup_vs_single"] is not None
        assert all("speedup_bulk" in r for r in data["records"])
        print("OK")
    """)
    assert "OK" in out


def test_sde_train_step_data_parallel_bitwise():
    """The PR-10 mesh-sharded SDE train step on 8 fake devices: loss,
    gradients (hence params and opt_state after the update) are BITWISE
    equal to the single-device step — per-path gradients are reduced
    replicated in vmap-transpose order, never psum'd per shard — and the
    scanned chunk preserves that equality."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import SDETerm
        from repro.launch.mesh import make_train_mesh
        from repro.optim import adamw, cosine_schedule
        from repro.train.trainer import (init_scan_counters, make_scanned_step,
                                         make_sde_train_step)

        term = SDETerm(
            drift=lambda t, y, p: p["nu"] * (p["mu"] - y),
            diffusion=lambda t, y, p: p["sigma"] * jnp.ones_like(y),
            noise="diagonal",
        )
        params = {"nu": jnp.float64(0.5), "mu": jnp.float64(0.0),
                  "sigma": jnp.float64(0.5)}
        opt = adamw(cosine_schedule(1e-3, 2, 64))
        key = jax.random.PRNGKey(0)
        # cross-path loss on purpose: the sharded step gathers the result
        # before the loss, so moment terms are exact
        loss = lambda p, r: (jnp.mean(r.y_final ** 2)
                             + 0.1 * jnp.mean(jnp.mean(r.y_final, 0) ** 2))
        y0 = lambda p: jnp.zeros(4, jnp.float64)
        common = dict(t0=0.0, t1=1.0, n_steps=16, n_paths=16)

        single = make_sde_train_step("ees25", term, opt, y0, loss, **common)
        mesh = make_train_mesh(8)
        dp = make_sde_train_step("ees25", term, opt, y0, loss,
                                 mesh=mesh, mesh_axis="dp", **common)

        eq = lambda a, b: all(
            np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
            zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))

        pa, sa, ma = jax.jit(single)(params, opt.init(params), key)
        pb, sb, mb = jax.jit(dp)(params, opt.init(params), key)
        assert eq((pa, sa), (pb, sb)), "dp step != single-device step"
        assert np.array_equal(np.asarray(ma["loss"]), np.asarray(mb["loss"]))

        # scanned K=4 chunk of the dp step == 4 sequential single steps
        js = jax.jit(single)
        p, s = params, opt.init(params)
        for i in range(4):
            p, s, _ = js(p, s, jax.random.fold_in(key, i))
        sc = make_scanned_step(dp, 4)
        p2, s2, _, _ = sc(jax.tree_util.tree_map(jnp.array, params),
                          opt.init(params), init_scan_counters(), key,
                          jnp.asarray(0))
        assert eq((p, s), (p2, s2)), "scanned dp chunk != sequential single"
        print("OK")
    """)
    assert "OK" in out


def test_compressed_gradient_allreduce():
    """int8-quantised all-reduce with error feedback under shard_map."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import compressed_psum_with_feedback

        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        err0 = jnp.zeros((8, 128))
        out, err = compressed_psum_with_feedback(mesh, "data", x, err0)
        want = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        rel = float(jnp.max(jnp.abs(out - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.05, rel  # int8 quantisation error bound
        # error feedback accumulates the residual for the next round
        out2, err2 = compressed_psum_with_feedback(mesh, "data", x, err)
        rel2 = float(jnp.max(jnp.abs(out2 + err2.sum(0) - want - err.sum(0))))
        print("OK", rel)
    """)
    assert "OK" in out
