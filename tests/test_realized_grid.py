"""Realized-grid solve core: TimeGrid plumbing, reversible-adjoint gradient
parity on adaptively realized (non-uniform) grids, bitwise batch fan-out
through realize+solve, reconstruction drift, and the end-to-end train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SDETerm,
    TimeGrid,
    brownian_path,
    get_solver,
    realize_grid,
    sdeint,
    solve,
    virtual_brownian_tree,
)
from repro.core.pytree import tree_sub

KEY = jax.random.PRNGKey(0)


def ou_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: a["nu"] * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y)),
        noise="diagonal",
    )


def stiff_term() -> SDETerm:
    """Sharp stiff transient around t = 0.5: the realized grid is genuinely
    non-uniform (the controller shrinks steps inside the spike)."""
    def rate(t, a):
        return a["nu"] * (1.0 + 40.0 * jnp.exp(-(((t - 0.5) / 0.05) ** 2)))

    return SDETerm(
        drift=lambda t, y, a: rate(t, a) * (a["mu"] - y),
        diffusion=lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y)),
        noise="diagonal",
    )


ARGS = {
    "nu": jnp.float64(0.7),
    "mu": jnp.float64(0.2),
    "sigma": jnp.float64(0.4),
}


def vbt(key=KEY, shape=(3,), tol=None):
    return virtual_brownian_tree(key, 0.0, 1.0, shape=shape,
                                 dtype=jnp.float64, tol=tol)


# ---------------------------------------------------------------------------
# TimeGrid plumbing.
# ---------------------------------------------------------------------------

class TestTimeGrid:
    def test_uniform_grid_from_path_matches_sdeint(self):
        """The explicit-grid spelling of a fixed solve is the same solve."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        bm = brownian_path(KEY, 0.0, 1.0, 32, shape=(3,), dtype=jnp.float64)
        via_sdeint = sdeint(term, "ees25", 0.0, 1.0, 32, y0, KEY, args=ARGS)
        via_grid = solve(get_solver("ees25"), term, y0,
                         TimeGrid.from_path(bm), ARGS)
        np.testing.assert_array_equal(np.asarray(via_sdeint.y_final),
                                      np.asarray(via_grid.y_final))

    def test_realized_grid_structure(self):
        """ts holds t0 + the accepted times then t_final padding; hs matches
        the step sizes with zeros on padding."""
        rg = realize_grid("ees25", stiff_term(), jnp.ones(3, jnp.float64),
                          vbt(), ARGS, rtol=1e-3, max_steps=256)
        ts = np.asarray(rg.grid.ts)
        hs = np.asarray(rg.grid.hs)
        na = int(rg.n_accepted)
        assert rg.grid.n_steps == 256 and not rg.grid.is_uniform
        assert ts[0] == 0.0 and np.all(np.diff(ts) >= 0)
        np.testing.assert_allclose(ts[na], float(rg.t_final))
        np.testing.assert_allclose(ts[na:], float(rg.t_final))
        np.testing.assert_allclose(np.diff(ts)[:na], hs[:na], rtol=1e-12)
        assert np.all(hs[:na] > 0) and np.all(hs[na:] == 0)
        # the stiff transient forced a genuinely non-uniform grid
        assert hs[:na].max() > 3 * hs[:na].min()

    def test_grid_increments_telescope(self):
        """Per-step grid increments over a realized grid sum to W(t_final)."""
        b = vbt(shape=())
        rg = realize_grid("ees25", ou_term(), jnp.float64(1.0), b, ARGS,
                          rtol=1e-3, max_steps=128)
        incs = np.asarray(b.grid_increments(rg.grid.ts))
        total = np.asarray(b.weval(rg.t_final))
        np.testing.assert_allclose(incs.sum(), total, atol=1e-12)

    def test_brownian_path_rejects_foreign_grid(self):
        bm = brownian_path(KEY, 0.0, 1.0, 32, shape=(3,))
        with pytest.raises(ValueError, match="native"):
            bm.grid_increment(jnp.linspace(0.0, 1.0, 17), 0)

    def test_save_at_and_save_every_mutually_exclusive(self):
        bm = brownian_path(KEY, 0.0, 1.0, 32, shape=(3,), dtype=jnp.float64)
        with pytest.raises(ValueError, match="mutually exclusive"):
            solve(get_solver("ees25"), ou_term(), jnp.ones(3, jnp.float64),
                  bm, ARGS, save_every=8, save_at=jnp.array([0.5]))

    def test_remat_chunk_without_recursive_raises(self):
        bm = brownian_path(KEY, 0.0, 1.0, 32, shape=(3,), dtype=jnp.float64)
        for adjoint in ("full", "reversible"):
            with pytest.raises(ValueError, match="recursive"):
                solve(get_solver("ees25"), ou_term(),
                      jnp.ones(3, jnp.float64), bm, ARGS,
                      adjoint=adjoint, remat_chunk=8)


# ---------------------------------------------------------------------------
# Gradient parity on adaptively realized (non-uniform) grids.
# ---------------------------------------------------------------------------

class TestRealizedGridAdjointParity:
    # ees25 pins the property in the default lane; the ees27 duplicate (same
    # code path, costlier compile) rides the slow lane.
    @pytest.mark.parametrize(
        "spec", ["ees25", pytest.param("ees27", marks=pytest.mark.slow)])
    def test_reversible_matches_full_and_recursive(self, spec):
        """Acceptance criterion: reversible-adjoint gradients on an
        adaptively realized grid match full/recursive to tight tolerance."""
        term = stiff_term()
        y0 = jnp.ones(2, jnp.float64)
        keys = jax.random.split(KEY, 2)

        def loss(a, adjoint):
            r = sdeint(term, f"{spec}:adaptive", 0.0, 1.0, 128, y0, None,
                       args=a, adjoint=adjoint, rtol=1e-3, atol=1e-5,
                       batch_keys=keys)
            return jnp.mean(r.y_final ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "reversible"))(ARGS)
        gc = jax.grad(lambda a: loss(a, "recursive"))(ARGS)
        for k in ARGS:
            # recursive is a pure remat of the same computation
            np.testing.assert_allclose(gf[k], gc[k], rtol=1e-9)
            # reversible reconstructs the trajectory: O(h^{m+1}) drift only
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-4)

    def test_reversible_heun_solves_a_realized_grid(self):
        """Solvers without an embedded estimator can't *realize* a grid but
        can solve over one: realize with ees25, solve with reversible_heun
        under all three adjoints."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        rg = realize_grid("ees25", term, y0, vbt(), ARGS, rtol=1e-3,
                          max_steps=64)
        rh = get_solver("reversible_heun")

        def loss(a, adjoint):
            out = solve(rh, term, y0, rg.grid, a, adjoint=adjoint)
            return jnp.sum(out.y_final ** 2)

        outs = {adj: solve(rh, term, y0, rg.grid, ARGS, adjoint=adj).y_final
                for adj in ("full", "recursive", "reversible")}
        np.testing.assert_array_equal(np.asarray(outs["full"]),
                                      np.asarray(outs["reversible"]))
        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "reversible"))(ARGS)
        for k in ARGS:
            # algebraically reversible: reconstruction is exact
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-8)

    def test_save_at_cotangents_on_realized_grid(self):
        """Dense-output cotangent injection along the reversible backward
        sweep matches full-adjoint autodiff (args and y0 alike)."""
        term = ou_term()
        y0 = jnp.ones(2, jnp.float64)
        ts = jnp.array([0.0, 0.23, 0.5, 0.77, 1.0])

        def loss(a, y, adjoint):
            r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y, KEY,
                       args=a, adjoint=adjoint, rtol=1e-3, save_at=ts)
            return jnp.sum(r.ys ** 2)

        ga_f, gy_f = jax.grad(lambda a, y: loss(a, y, "full"),
                              argnums=(0, 1))(ARGS, y0)
        ga_r, gy_r = jax.grad(lambda a, y: loss(a, y, "reversible"),
                              argnums=(0, 1))(ARGS, y0)
        for k in ARGS:
            np.testing.assert_allclose(ga_f[k], ga_r[k], rtol=1e-4)
        np.testing.assert_allclose(gy_f, gy_r, rtol=1e-4)

    def test_save_at_step_boundary_cotangent_not_double_counted(self):
        """A save time inside the eps slack above an interior step boundary
        is owned by exactly one step: the reversible backward injection must
        match full-adjoint autodiff (which is last-write-wins) there too."""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        bm = brownian_path(KEY, 0.0, 1.0, 8, shape=(3,), dtype=jnp.float64)
        # 2e-10 above the n=3 step boundary — within eps_end = 1e-9 * span.
        ts = jnp.array([0.375 + 2e-10, 1.0])

        def loss(a, adjoint):
            out = solve(get_solver("ees25"), term, y0, bm, a,
                        adjoint=adjoint, save_at=ts)
            return jnp.sum(out.ys ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "reversible"))(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-6)


# ---------------------------------------------------------------------------
# Bitwise batch fan-out through realize+solve.
# ---------------------------------------------------------------------------

class TestRealizeSolveBatching:
    def test_batch_vs_loop_bitwise(self):
        """Acceptance criterion: the batched realize+solve is bitwise equal
        to a Python loop of single-trajectory solves over the same keys.

        (On the OU term, like the seed's guarantee: terms whose drift
        contains transcendentals of *time* — e.g. the stiff transient's
        exp — lower differently vectorized vs scalar on CPU XLA, a
        pre-existing artifact independent of this stack.)"""
        term = ou_term()
        y0 = jnp.ones(3, jnp.float64)
        ts = jnp.array([0.5, 1.0])
        keys = jax.random.split(KEY, 3)
        batched = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y0, None,
                         args=ARGS, rtol=1e-3, save_at=ts,
                         adjoint="reversible", batch_keys=keys)
        for i in range(3):
            solo = sdeint(term, "ees25:adaptive", 0.0, 1.0, 128, y0, keys[i],
                          args=ARGS, rtol=1e-3, save_at=ts,
                          adjoint="reversible")
            np.testing.assert_array_equal(np.asarray(batched.y_final[i]),
                                          np.asarray(solo.y_final))
            np.testing.assert_array_equal(np.asarray(batched.ys[i]),
                                          np.asarray(solo.ys))
            assert int(batched.n_accepted[i]) == int(solo.n_accepted)


# ---------------------------------------------------------------------------
# Reconstruction drift of the reversible backward sweep.
# ---------------------------------------------------------------------------

class TestReconstructionDrift:
    def test_y0_reconstruction_bounded_on_stiff_term(self):
        """Acceptance criterion: running the solver's reverse step backward
        over the realized grid lands within O(h^{m+1})-accumulated distance
        of y0 on a stiff term (the quantity that controls reversible-adjoint
        gradient quality)."""
        term = stiff_term()
        y0 = jnp.ones(2, jnp.float64)
        b = vbt(shape=(2,))
        solver = get_solver("ees25")
        rg = realize_grid(solver, term, y0, b, ARGS, rtol=1e-4, atol=1e-6,
                          max_steps=256)
        grid = rg.grid
        y_final = solve(solver, term, y0, grid, ARGS).y_final

        def back(state, n):
            h = grid.h_of(n)
            prev = solver.reverse(term, state, grid.t_of(n), h,
                                  grid.increment(n), ARGS)
            return jax.tree_util.tree_map(
                lambda p, s: jnp.where(h > 0, p, s), prev, state), None

        y0_rec, _ = jax.lax.scan(back, y_final,
                                 jnp.arange(grid.n_steps - 1, -1, -1))
        drift = float(jnp.max(jnp.abs(tree_sub(y0_rec, y0))))
        assert drift < 1e-5, drift  # EES(2,5): O(h^3) per step, ~100 steps
        # and the drift is what separates reversible from full gradients:
        assert np.isfinite(drift)


# ---------------------------------------------------------------------------
# End-to-end: reversible-adjoint training step on an adaptive grid.
# ---------------------------------------------------------------------------

class TestReversibleAdaptiveTraining:
    def test_train_step_runs_and_matches_full_adjoint(self):
        """Acceptance criterion: sdeint(..., 'ees25:adaptive',
        adjoint='reversible') powers a full train step whose first-step
        gradients match adjoint='full' on the same realized grids."""
        from repro.optim import adamw
        from repro.train.trainer import make_sde_train_step

        term = stiff_term()

        def y0_fn(p):
            return jnp.full((4,), 1.0, jnp.float64) * p["scale"]

        def loss_fn_result(p, r):
            return jnp.mean((r.y_final - 0.2) ** 2)

        params0 = {"nu": jnp.float64(0.7), "mu": jnp.float64(0.2),
                   "sigma": jnp.float64(0.4), "scale": jnp.float64(1.0)}

        grads = {}
        for adjoint in ("reversible", "full"):
            opt = adamw(lambda step: 1e-2)
            step = make_sde_train_step(
                "ees25:adaptive", term, opt, y0_fn, loss_fn_result,
                t0=0.0, t1=1.0, n_steps=96, n_paths=8, adjoint=adjoint,
                rtol=1e-3, noise_shape=(4,),
            )
            step = jax.jit(step)
            params, opt_state = dict(params0), opt.init(params0)
            key = jax.random.PRNGKey(42)

            def grad_only(p):
                keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                    jnp.arange(8))
                r = sdeint(term, "ees25:adaptive", 0.0, 1.0, 96, y0_fn(p),
                           None, args=p, adjoint=adjoint, rtol=1e-3,
                           noise_shape=(4,), batch_keys=keys)
                return loss_fn_result(p, r)

            grads[adjoint] = jax.grad(grad_only)(params0)
            losses = []
            for i in range(2):
                params, opt_state, m = step(params, opt_state,
                                            jax.random.fold_in(key, 1000 + i))
                losses.append(float(m["loss"]))
            assert all(np.isfinite(l) for l in losses), losses

        for k in params0:
            np.testing.assert_allclose(grads["full"][k],
                                       grads["reversible"][k],
                                       rtol=1e-4, atol=1e-10)
