"""Strong-convergence-order harness: measured rates vs documented rates.

Every registry solver advertises its strong orders per noise mode
(``solver.strong_orders`` — see ``_rk_strong_orders`` and the Milstein/SRA1
classes).  This module *measures* them, seeded and tier-1-fast:

* **GBM references** — for ``dy = mu y dt + sigma y dW`` the exact solution
  is a closed form of ``W(T)`` alone (``y0 exp((mu - sigma^2/2) T + sigma W)``
  under Ito, ``y0 exp(mu T + sigma W)`` under Stratonovich), so one
  :class:`VirtualBrownianTree` pins the SAME underlying path across every
  refinement level and the pathwise RMS error at ``T`` is exact.  The fitted
  log-log slope over dyadic levels must land on the documented order:
  Euler 0.5 (Ito), Milstein 1.0 (Ito), Strat-Milstein / Heun / EES25 1.0
  (Stratonovich, commutative noise) — on diagonal AND single-channel scalar
  noise.
* **SRA1 reference** — additive-noise OU.  This repo's space-time Levy areas
  are exact in law per grid but deliberately do NOT chain pathwise across
  refinements (see ``VirtualBrownianTree.levy_area``), so a cross-level
  pathwise comparison would be bounded at order 1 by driver construction,
  not by the scheme.  Instead each level is compared against the exact
  conditional expansion driven by the SAME ``(dW, dH)`` realizations:
  ``y' = e^{-theta h} y + sigma (dW - theta h (dW/2 + dH))``, which matches
  the true solution to ``o(h^{3/2})`` per step.  Any error in SRA1's
  tableau — stage coefficients, the ``3/2 (dH + dW/2)`` Levy weighting, the
  ``1/3, 2/3`` output weights — breaks the match at order <= 1; the correct
  scheme agrees to order ~2, so the gate is one-sided at the documented 1.5.

Each case's finest-level error is also pinned (seeded error-constant
regression): a silent constant blow-up fails even if the slope survives.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm, get_solver, solve
from repro.core.brownian import brownian_path, virtual_brownian_tree
from repro.core.grid import TimeGrid

MU, SIG = 0.1, 0.8          # GBM drift / volatility
THETA, SIG_ADD = 1.0, 0.5   # OU rate / additive noise level
DIM = 2
N_PATHS = 32
LEVELS = (8, 16, 32, 64)
T1 = 1.0

# (spec, sde form of the reference, noise mode) -> measured by _gbm_errors.
GBM_CASES = [
    ("euler", "ito", "diagonal"),
    ("milstein", "ito", "diagonal"),
    ("strat-milstein", "stratonovich", "diagonal"),
    ("heun", "stratonovich", "diagonal"),
    ("ees25", "stratonovich", "diagonal"),
    ("euler", "ito", "scalar"),
    ("milstein", "ito", "scalar"),
]

# Seeded finest-level (h = 1/64) RMS error bounds: ~1.6-2x the measured
# constants, so a regression in the error constant trips even at the right
# slope.
ERROR_BOUNDS = {
    ("euler", "diagonal"): 9e-2,
    ("milstein", "diagonal"): 6e-3,
    ("strat-milstein", "diagonal"): 1.2e-2,
    ("heun", "diagonal"): 1e-2,
    ("ees25", "diagonal"): 4e-3,
    ("euler", "scalar"): 9e-2,
    ("milstein", "scalar"): 7e-3,
    ("srk", "additive"): 5e-5,
}


def _fit_slope(errs):
    hs = np.log([T1 / n for n in LEVELS])
    return float(np.polyfit(hs, np.log(errs), 1)[0])


@functools.lru_cache(maxsize=None)
def _gbm_errors(spec, form, noise):
    """RMS strong error at T per refinement level, one VBT path per key."""
    term = SDETerm(drift=lambda t, y, a: MU * y,
                   diffusion=lambda t, y, a: SIG * y, noise=noise)
    solver = get_solver(spec)
    keys = jax.random.split(jax.random.PRNGKey(7), N_PATHS)
    shape = () if noise == "scalar" else (DIM,)
    mu_eff = (MU - 0.5 * SIG ** 2) if form == "ito" else MU
    errs = []
    for n in LEVELS:
        def one(key):
            bm = virtual_brownian_tree(key, 0.0, T1, shape, dtype=jnp.float64)
            grid = TimeGrid.uniform(0.0, T1, n, driver=bm)
            y = solve(solver, term, jnp.ones(DIM, jnp.float64), grid).y_final
            return y, bm.weval(T1)
        ys, ws = jax.jit(jax.vmap(one))(keys)
        if noise == "scalar":
            ws = ws[..., None]  # ONE channel shared by every component
        ref = jnp.exp(mu_eff * T1 + SIG * ws)
        errs.append(float(jnp.sqrt(jnp.mean((ys - ref) ** 2))))
    return tuple(errs)


@functools.lru_cache(maxsize=None)
def _srk_errors():
    """SRA1 on additive OU vs the exact same-(dW,dH) conditional expansion."""
    term = SDETerm(drift=lambda t, y, a: -THETA * y,
                   diffusion=lambda t, y, a: SIG_ADD * jnp.ones_like(y),
                   noise="additive")
    solver = get_solver("srk:noise=additive")
    keys = jax.random.split(jax.random.PRNGKey(9), N_PATHS)
    errs = []
    for n in LEVELS:
        h = T1 / n

        def one(key):
            bm = brownian_path(key, 0.0, T1, n, (DIM,), dtype=jnp.float64)
            grid = TimeGrid.uniform(0.0, T1, n, driver=bm)
            y = solve(solver, term, jnp.ones(DIM, jnp.float64), grid).y_final
            dWs, dHs = bm.grid_levy_increments(grid.ts)

            def ref_step(yc, wh):
                dw, dh = wh
                yn = (jnp.exp(-THETA * h) * yc
                      + SIG_ADD * (dw - THETA * h * (0.5 * dw + dh)))
                return yn, None

            yr, _ = jax.lax.scan(ref_step, jnp.ones(DIM, jnp.float64),
                                 (dWs, dHs))
            return y, yr
        ys, yr = jax.jit(jax.vmap(one))(keys)
        errs.append(float(jnp.sqrt(jnp.mean((ys - yr) ** 2))))
    return tuple(errs)


class TestMeasuredStrongOrders:
    @pytest.mark.parametrize("spec,form,noise", GBM_CASES)
    def test_slope_matches_documented(self, spec, form, noise):
        documented = get_solver(spec).strong_orders[noise]
        errs = _gbm_errors(spec, form, noise)
        slope = _fit_slope(errs)
        assert abs(slope - documented) < 0.25, (
            f"{spec} on {noise} noise: measured strong order {slope:.3f}, "
            f"documented {documented} (errors {errs})")
        # errors must actually decay across the sweep (Monte-Carlo noise at
        # 32 paths allows one flat mid-level, never a level-to-level blow-up)
        assert errs[-1] < 0.5 * errs[0], errs
        assert all(b < 1.5 * a for a, b in zip(errs, errs[1:])), errs

    @pytest.mark.parametrize("spec,form,noise", GBM_CASES)
    def test_reference_form_matches_solver(self, spec, form, noise):
        """Each case's analytic reference uses the solver's declared SDE
        interpretation — keep the table honest against ``sde_form``."""
        assert get_solver(spec).sde_form == form

    def test_milstein_beats_euler(self):
        """Order 1 vs 0.5 must be visible in the raw finest-level errors,
        not just the fitted slopes."""
        e_eul = _gbm_errors("euler", "ito", "diagonal")[-1]
        e_mil = _gbm_errors("milstein", "ito", "diagonal")[-1]
        assert e_mil < 0.25 * e_eul, (e_mil, e_eul)

    def test_srk_order_at_least_documented(self):
        documented = get_solver("srk:noise=additive").strong_orders["additive"]
        assert documented == 1.5
        errs = _srk_errors()
        slope = _fit_slope(errs)
        assert slope > documented - 0.1, (
            f"SRA1 measured order {slope:.3f} below documented {documented} "
            f"(errors {errs})")

    @pytest.mark.parametrize("spec,form,noise", GBM_CASES)
    def test_error_constant_regression(self, spec, form, noise):
        errs = _gbm_errors(spec, form, noise)
        assert errs[-1] < ERROR_BOUNDS[(spec, noise)], (spec, noise, errs)

    def test_srk_error_constant_regression(self):
        errs = _srk_errors()
        assert errs[-1] < ERROR_BOUNDS[("srk", "additive")], errs
