"""Docs must execute: every ```python block in README.md / docs/*.md runs.

Each file's blocks run concatenated in a subprocess via
``tools/run_doc_examples.py`` — the same entry point as CI's docs lane.
Marked slow (full jit compiles per file); the quick CI lane calls the tool
directly as its own step.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOOL = os.path.join(REPO, "tools", "run_doc_examples.py")

sys.path.insert(0, os.path.join(REPO, "tools"))
import run_doc_examples  # noqa: E402


def test_doc_files_discovered():
    files = run_doc_examples.doc_files()
    names = {os.path.relpath(f, REPO) for f in files}
    for want in ("README.md", "docs/sdeint.md", "docs/solvers.md",
                 "docs/adjoints.md", "docs/adaptive.md"):
        assert want in names, names


def test_extractor_finds_blocks():
    src = run_doc_examples.extract(os.path.join(REPO, "README.md"))
    assert "sdeint" in src and "```" not in src


@pytest.mark.slow
@pytest.mark.parametrize(
    "relpath", ["README.md", "docs/sdeint.md", "docs/solvers.md",
                "docs/adjoints.md", "docs/adaptive.md"])
def test_doc_blocks_execute(relpath):
    proc = subprocess.run(
        [sys.executable, TOOL, os.path.join(REPO, relpath)],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
