"""Serving-core integration tests: the engine façade over scheduler +
executor — multi-tick dispatch bitwise-equality, host-round-trip accounting,
cancellation/pending, and the no-spin idle guarantees.

The mesh-sharded serving case lives in ``test_launch_distributed.py`` (it
needs a subprocess with faked devices); everything here runs on the single
real CPU device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm, sdeint, sdeint_ticks
from repro.serving import SDESampleConfig, SDESampleEngine

KEY = jax.random.PRNGKey(0)


def term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -0.5 * y,
        diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
        noise="diagonal",
    )


class TestSdeintTicks:
    def test_tick_stack_bitwise_equals_per_tick_sdeint(self):
        keys = jax.random.split(KEY, 12)
        stack = keys.reshape(3, 4, *keys.shape[1:])
        r = sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), stack,
                         dtype=jnp.float32, save_every=4)
        assert r.y_final.shape[:2] == (3, 4) and r.ys.shape[:3] == (3, 4, 2)
        for t in range(3):
            ref = sdeint(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), None,
                         batch_keys=stack[t], dtype=jnp.float32, save_every=4)
            np.testing.assert_array_equal(np.asarray(r.y_final[t]),
                                          np.asarray(ref.y_final))
            np.testing.assert_array_equal(np.asarray(r.ys[t]),
                                          np.asarray(ref.ys))

    def test_adaptive_tick_stack_bitwise(self):
        keys = jax.random.split(KEY, 4)
        stack = keys.reshape(2, 2, *keys.shape[1:])
        r = sdeint_ticks(term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3),
                         stack, dtype=jnp.float32, rtol=1e-3, bounded=False)
        ref = sdeint(term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3),
                     None, batch_keys=stack[1], dtype=jnp.float32, rtol=1e-3,
                     bounded=False)
        np.testing.assert_array_equal(np.asarray(r.y_final[1]),
                                      np.asarray(ref.y_final))
        np.testing.assert_array_equal(np.asarray(r.n_accepted[1]),
                                      np.asarray(ref.n_accepted))

    def test_flat_batch_rejected(self):
        # a single key and a flat (B, 2) single-tick batch both lack the
        # tick axis and must be pointed at sdeint, not die mid-trace
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         jax.random.split(KEY, 4)[0], dtype=jnp.float32)
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         jax.random.split(KEY, 4), dtype=jnp.float32)
        # typed (new-style) keys: (T, B) key arrays are valid, flat (B,) not
        typed = jax.random.split(jax.random.key(0), 4)
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), typed,
                         dtype=jnp.float32)
        r = sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         typed.reshape(2, 2), dtype=jnp.float32)
        assert r.y_final.shape[:2] == (2, 2)


class TestMultiTickServing:
    def serve(self, *, ticks_per_dispatch, solver="ees25", **submit_kw):
        eng = SDESampleEngine(
            term(), jnp.ones(3),
            SDESampleConfig(slots=4, ticks_per_dispatch=ticks_per_dispatch),
        )
        r1 = eng.submit(solver, t1=1.0, n_steps=16, n_paths=10, seed=5,
                        **submit_kw)
        r2 = eng.submit(solver, t1=1.0, n_steps=16, n_paths=3, seed=9,
                        **submit_kw)
        done = eng.run()
        return done[r1], done[r2], eng

    def test_multi_tick_bitwise_equals_single_tick(self):
        """The acceptance-criteria regression: multi-tick and single-tick
        serving return bit-identical SampleResults for the same requests
        (path key = fold_in(seed, i) is dispatch-grouping-independent)."""
        a1, a2, single = self.serve(ticks_per_dispatch=1)
        b1, b2, multi = self.serve(ticks_per_dispatch=4)
        np.testing.assert_array_equal(a1.y_final, b1.y_final)
        np.testing.assert_array_equal(a2.y_final, b2.y_final)
        # same 4 ticks of work, but 4 host dispatches collapse into 1
        assert single.executor.n_ticks == multi.executor.n_ticks == 4
        assert single.executor.n_dispatches == 4
        assert multi.executor.n_dispatches == 1

    def test_multi_tick_bitwise_adaptive(self):
        a1, a2, _ = self.serve(ticks_per_dispatch=1, solver="ees25:adaptive",
                               rtol=1e-3)
        b1, b2, _ = self.serve(ticks_per_dispatch=4, solver="ees25:adaptive",
                               rtol=1e-3)
        np.testing.assert_array_equal(a1.y_final, b1.y_final)
        np.testing.assert_array_equal(a2.y_final, b2.y_final)
        np.testing.assert_array_equal(a1.n_accepted, b1.n_accepted)
        np.testing.assert_array_equal(a1.t_final, b1.t_final)

    def test_results_reproducible_offline_through_multi_tick(self):
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=4, ticks_per_dispatch=3))
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=10, seed=7)
        done = eng.run()
        keys = jnp.stack(
            [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(10)]
        )
        ref = sdeint(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), None,
                     batch_keys=keys, dtype=jnp.float32)
        np.testing.assert_array_equal(done[rid].y_final,
                                      np.asarray(ref.y_final))

    def test_steady_state_uses_two_executables_per_signature(self):
        """A deep queue drains through the full-stack executable plus (at
        most) the single-tick one — not one compile per depth."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=2, ticks_per_dispatch=2))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=11)  # 6 ticks: 2+2+2
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4)   # rides along
        eng.run()
        assert eng.executor.n_ticks == 8
        assert eng.executor.n_dispatches == 4
        assert len(eng._compiled) == 1  # every dispatch was a full stack
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1)   # 1-tick tail
        eng.run()
        assert len(eng._compiled) == 2

    def test_shallow_tail_reuses_single_tick_executable(self):
        """A tail shallower than ticks_per_dispatch must not compile a new
        stack depth: it is served tick-by-tick through the single-tick
        entry (so depths in the cache stay {full, 1})."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=2, ticks_per_dispatch=4))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=12, seed=2)  # 6 ticks
        done = eng.run()
        assert eng.executor.n_ticks == 6
        assert eng.executor.n_dispatches == 3      # 4-stack + 2 single ticks
        assert {k[1] for k in eng._compiled} == {4, 1}
        # and the tail split leaves no trace in the samples
        ref = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid = ref.submit("ees25", t1=1.0, n_steps=8, n_paths=12, seed=2)
        np.testing.assert_array_equal(done[0].y_final, ref.run()[rid].y_final)

    def test_rejected_submit_burns_no_request_id(self):
        """A failed submit must not shift later default seeds (= request
        ids): the id is only allocated once validation passes."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        with pytest.raises(ValueError, match="n_steps"):
            eng.submit("ees25", t1=1.0, n_steps=0, n_paths=2)
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        assert rid == 0  # not 1: samples of seed-defaulted requests unshifted
        clean = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid2 = clean.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        np.testing.assert_array_equal(eng.run()[rid].y_final,
                                      clean.run()[rid2].y_final)

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError, match="ticks_per_dispatch"):
            SDESampleEngine(term(), jnp.ones(3),
                            SDESampleConfig(ticks_per_dispatch=0))
        # mesh_axis without an explicit mesh would defer the slots/axis
        # divisibility check to the first dispatch (ambient mesh) — rejected
        with pytest.raises(ValueError, match="mesh and mesh_axis together"):
            SDESampleEngine(term(), jnp.ones(3),
                            SDESampleConfig(mesh_axis="mc"))
        # same both-or-neither rule one layer down
        from repro.serving import TickExecutor
        with pytest.raises(ValueError, match="mesh and mesh_axis together"):
            TickExecutor(term(), jnp.ones(3), mesh_axis="mc")


class TestCancellationAndRun:
    def test_pending_tracks_queue(self):
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=4))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
        r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        assert eng.pending() == {r1: 6, r2: 2}
        eng.tick()  # serves r1[0:4]
        assert eng.pending() == {r1: 2, r2: 2}
        eng.run()
        assert eng.pending() == {}

    def test_cancel_discards_partial_results(self):
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
        r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, seed=11)
        eng.tick()                       # r1 partially served
        assert eng.cancel(r1) is True
        done = eng.run()
        assert sorted(done) == [r2]      # r1 never reaches done
        assert eng.cancel(r2) is False   # completed; result kept
        with pytest.raises(KeyError, match="unknown request id"):
            eng.cancel(999)

    def test_idle_run_with_done_and_cancelled_does_not_spin(self):
        """Regression: an idle engine — non-empty ``done`` plus queued-then-
        cancelled requests — must return immediately instead of burning
        ``max_ticks`` no-op ticks (or worse, raising)."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        done = eng.run()
        assert rid in done
        cancelled = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=10 ** 6)
        eng.cancel(cancelled)
        n_before = eng.executor.n_dispatches
        assert eng.run(max_ticks=3) == done          # no RuntimeError
        assert eng.executor.n_dispatches == n_before  # and zero dispatches
        assert eng.tick() is False

    def test_max_ticks_counts_on_device_ticks(self):
        """A multi-tick dispatch consumes its depth from the budget, so
        ``max_ticks`` bounds device work, not just host round trips."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=1, ticks_per_dispatch=4))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=8)
        with pytest.raises(RuntimeError, match="max_ticks"):
            eng.run(max_ticks=6)
        assert eng.executor.n_ticks == 6  # 4-stack + 2 single ticks
        # the capped remainder must not compile a (sig, 2) stack
        assert {k[1] for k in eng._compiled} == {4, 1}
