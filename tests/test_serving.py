"""Serving-core integration tests: the engine façade over scheduler +
executor — multi-tick dispatch bitwise-equality, host-round-trip accounting,
cancellation/pending, and the no-spin idle guarantees.

The mesh-sharded serving case lives in ``test_launch_distributed.py`` (it
needs a subprocess with faked devices); everything here runs on the single
real CPU device.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm, sdeint, sdeint_ticks
from repro.serving import (AsyncSDESampleEngine, QueueFull, SDESampleConfig,
                           SDESampleEngine)

KEY = jax.random.PRNGKey(0)


def term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -0.5 * y,
        diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
        noise="diagonal",
    )


class TestSdeintTicks:
    def test_tick_stack_bitwise_equals_per_tick_sdeint(self):
        keys = jax.random.split(KEY, 12)
        stack = keys.reshape(3, 4, *keys.shape[1:])
        r = sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), stack,
                         dtype=jnp.float32, save_every=4)
        assert r.y_final.shape[:2] == (3, 4) and r.ys.shape[:3] == (3, 4, 2)
        for t in range(3):
            ref = sdeint(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), None,
                         batch_keys=stack[t], dtype=jnp.float32, save_every=4)
            np.testing.assert_array_equal(np.asarray(r.y_final[t]),
                                          np.asarray(ref.y_final))
            np.testing.assert_array_equal(np.asarray(r.ys[t]),
                                          np.asarray(ref.ys))

    def test_adaptive_tick_stack_bitwise(self):
        keys = jax.random.split(KEY, 4)
        stack = keys.reshape(2, 2, *keys.shape[1:])
        r = sdeint_ticks(term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3),
                         stack, dtype=jnp.float32, rtol=1e-3, bounded=False)
        ref = sdeint(term(), "ees25:adaptive", 0.0, 1.0, 64, jnp.ones(3),
                     None, batch_keys=stack[1], dtype=jnp.float32, rtol=1e-3,
                     bounded=False)
        np.testing.assert_array_equal(np.asarray(r.y_final[1]),
                                      np.asarray(ref.y_final))
        np.testing.assert_array_equal(np.asarray(r.n_accepted[1]),
                                      np.asarray(ref.n_accepted))

    def test_flat_batch_rejected(self):
        # a single key and a flat (B, 2) single-tick batch both lack the
        # tick axis and must be pointed at sdeint, not die mid-trace
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         jax.random.split(KEY, 4)[0], dtype=jnp.float32)
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         jax.random.split(KEY, 4), dtype=jnp.float32)
        # typed (new-style) keys: (T, B) key arrays are valid, flat (B,) not
        typed = jax.random.split(jax.random.key(0), 4)
        with pytest.raises(ValueError, match="n_ticks, batch"):
            sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), typed,
                         dtype=jnp.float32)
        r = sdeint_ticks(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3),
                         typed.reshape(2, 2), dtype=jnp.float32)
        assert r.y_final.shape[:2] == (2, 2)


class TestMultiTickServing:
    def serve(self, *, ticks_per_dispatch, solver="ees25", **submit_kw):
        eng = SDESampleEngine(
            term(), jnp.ones(3),
            SDESampleConfig(slots=4, ticks_per_dispatch=ticks_per_dispatch),
        )
        r1 = eng.submit(solver, t1=1.0, n_steps=16, n_paths=10, seed=5,
                        **submit_kw)
        r2 = eng.submit(solver, t1=1.0, n_steps=16, n_paths=3, seed=9,
                        **submit_kw)
        done = eng.run()
        return done[r1], done[r2], eng

    def test_multi_tick_bitwise_equals_single_tick(self):
        """The acceptance-criteria regression: multi-tick and single-tick
        serving return bit-identical SampleResults for the same requests
        (path key = fold_in(seed, i) is dispatch-grouping-independent)."""
        a1, a2, single = self.serve(ticks_per_dispatch=1)
        b1, b2, multi = self.serve(ticks_per_dispatch=4)
        np.testing.assert_array_equal(a1.y_final, b1.y_final)
        np.testing.assert_array_equal(a2.y_final, b2.y_final)
        # same 4 ticks of work, but 4 host dispatches collapse into 1
        assert single.executor.n_ticks == multi.executor.n_ticks == 4
        assert single.executor.n_dispatches == 4
        assert multi.executor.n_dispatches == 1

    def test_multi_tick_bitwise_adaptive(self):
        a1, a2, _ = self.serve(ticks_per_dispatch=1, solver="ees25:adaptive",
                               rtol=1e-3)
        b1, b2, _ = self.serve(ticks_per_dispatch=4, solver="ees25:adaptive",
                               rtol=1e-3)
        np.testing.assert_array_equal(a1.y_final, b1.y_final)
        np.testing.assert_array_equal(a2.y_final, b2.y_final)
        np.testing.assert_array_equal(a1.n_accepted, b1.n_accepted)
        np.testing.assert_array_equal(a1.t_final, b1.t_final)

    def test_results_reproducible_offline_through_multi_tick(self):
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=4, ticks_per_dispatch=3))
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=10, seed=7)
        done = eng.run()
        keys = jnp.stack(
            [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(10)]
        )
        ref = sdeint(term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), None,
                     batch_keys=keys, dtype=jnp.float32)
        np.testing.assert_array_equal(done[rid].y_final,
                                      np.asarray(ref.y_final))

    def test_steady_state_uses_two_executables_per_signature(self):
        """A deep queue drains through the full-stack executable plus (at
        most) the single-tick one — not one compile per depth."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=2, ticks_per_dispatch=2))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=11)  # 6 ticks: 2+2+2
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4)   # rides along
        eng.run()
        assert eng.executor.n_ticks == 8
        assert eng.executor.n_dispatches == 4
        assert len(eng._compiled) == 1  # every dispatch was a full stack
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1)   # 1-tick tail
        eng.run()
        assert len(eng._compiled) == 2

    def test_shallow_tail_reuses_single_tick_executable(self):
        """A tail shallower than ticks_per_dispatch must not compile a new
        stack depth: it is served tick-by-tick through the single-tick
        entry (so depths in the cache stay {full, 1})."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=2, ticks_per_dispatch=4))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=12, seed=2)  # 6 ticks
        done = eng.run()
        assert eng.executor.n_ticks == 6
        assert eng.executor.n_dispatches == 3      # 4-stack + 2 single ticks
        assert {k[1] for k in eng._compiled} == {4, 1}
        # and the tail split leaves no trace in the samples
        ref = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid = ref.submit("ees25", t1=1.0, n_steps=8, n_paths=12, seed=2)
        np.testing.assert_array_equal(done[0].y_final, ref.run()[rid].y_final)

    def test_rejected_submit_burns_no_request_id(self):
        """A failed submit must not shift later default seeds (= request
        ids): the id is only allocated once validation passes."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        with pytest.raises(ValueError, match="n_steps"):
            eng.submit("ees25", t1=1.0, n_steps=0, n_paths=2)
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        assert rid == 0  # not 1: samples of seed-defaulted requests unshifted
        clean = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid2 = clean.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        np.testing.assert_array_equal(eng.run()[rid].y_final,
                                      clean.run()[rid2].y_final)

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError, match="ticks_per_dispatch"):
            SDESampleEngine(term(), jnp.ones(3),
                            SDESampleConfig(ticks_per_dispatch=0))
        # mesh_axis without an explicit mesh would defer the slots/axis
        # divisibility check to the first dispatch (ambient mesh) — rejected
        with pytest.raises(ValueError, match="mesh and mesh_axis together"):
            SDESampleEngine(term(), jnp.ones(3),
                            SDESampleConfig(mesh_axis="mc"))
        # same both-or-neither rule one layer down
        from repro.serving import TickExecutor
        with pytest.raises(ValueError, match="mesh and mesh_axis together"):
            TickExecutor(term(), jnp.ones(3), mesh_axis="mc")


class TestCancellationAndRun:
    def test_pending_tracks_queue(self):
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=4))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
        r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        assert eng.pending() == {r1: 6, r2: 2}
        eng.tick()  # serves r1[0:4]
        assert eng.pending() == {r1: 2, r2: 2}
        eng.run()
        assert eng.pending() == {}

    def test_cancel_discards_partial_results(self):
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
        r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, seed=11)
        eng.tick()                       # r1 partially served
        assert eng.cancel(r1) is True
        done = eng.run()
        assert sorted(done) == [r2]      # r1 never reaches done
        assert eng.cancel(r2) is False   # completed; result kept
        with pytest.raises(KeyError, match="unknown request id"):
            eng.cancel(999)

    def test_idle_run_with_done_and_cancelled_does_not_spin(self):
        """Regression: an idle engine — non-empty ``done`` plus queued-then-
        cancelled requests — must return immediately instead of burning
        ``max_ticks`` no-op ticks (or worse, raising)."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        done = eng.run()
        assert rid in done
        cancelled = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=10 ** 6)
        eng.cancel(cancelled)
        n_before = eng.executor.n_dispatches
        assert eng.run(max_ticks=3) == done          # no RuntimeError
        assert eng.executor.n_dispatches == n_before  # and zero dispatches
        assert eng.tick() is False

    def test_max_ticks_counts_on_device_ticks(self):
        """A multi-tick dispatch consumes its depth from the budget, so
        ``max_ticks`` bounds device work, not just host round trips."""
        eng = SDESampleEngine(term(), jnp.ones(3),
                              SDESampleConfig(slots=1, ticks_per_dispatch=4))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=8)
        with pytest.raises(RuntimeError, match="max_ticks"):
            eng.run(max_ticks=6)
        assert eng.executor.n_ticks == 6  # 4-stack + 2 single ticks
        # the capped remainder must not compile a (sig, 2) stack
        assert {k[1] for k in eng._compiled} == {4, 1}

    def test_cancelled_staged_stack_is_skipped_not_dispatched(self):
        """Regression: with double buffering the engine plans stack N+1 while
        N executes; if every owner of the staged stack is cancelled before
        its turn, the dead stack must be released — NOT dispatched as a
        no-op (``n_dispatches`` stays flat)."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, seed=3)
        r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        eng.tick()               # serves r1, stages r2's stack
        assert eng.cancel(r2) is True
        n_before = eng.executor.n_dispatches
        done = eng.run()
        assert eng.executor.n_dispatches == n_before  # dead stack skipped
        assert sorted(done) == [r1]
        # and the release returned the reservation cleanly: new same-paths
        # work plans from scratch with identical samples
        r3 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, seed=3)
        np.testing.assert_array_equal(eng.run()[r3].y_final, done[r1].y_final)

    def test_double_buffer_off_matches_on(self):
        """``double_buffer=False`` (no plan-ahead) is the PR-5 drain loop;
        staging must not change samples, dispatch counts, or compiled keys."""
        outs = []
        for db in (True, False):
            eng = SDESampleEngine(
                term(), jnp.ones(3),
                SDESampleConfig(slots=2, ticks_per_dispatch=2,
                                double_buffer=db))
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=11, seed=1)
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4, seed=2)
            done = eng.run()
            outs.append((done, eng.executor.n_dispatches,
                         set(eng._compiled)))
        (done_a, nd_a, keys_a), (done_b, nd_b, keys_b) = outs
        assert nd_a == nd_b and keys_a == keys_b
        for rid in done_a:
            np.testing.assert_array_equal(done_a[rid].y_final,
                                          done_b[rid].y_final)


class TestAdmissionAndPriority:
    def test_queue_full_raises_on_sync_submit(self):
        cfg = SDESampleConfig(slots=2, max_queue_requests=1)
        eng = SDESampleEngine(term(), jnp.ones(3), cfg)
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        with pytest.raises(QueueFull, match="max_requests=1"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        cfg = SDESampleConfig(slots=2, max_queue_paths=4)
        eng = SDESampleEngine(term(), jnp.ones(3), cfg)
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=3)
        with pytest.raises(QueueFull, match="max_paths=4"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1)  # exactly fits
        eng.run()
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4)  # drained: space

    def test_priority_changes_service_order_not_samples(self):
        """Higher priority classes retire first, but samples are pure
        functions of (seed, path) — identical to the all-default run."""
        def serve(prios):
            eng = SDESampleEngine(term(), jnp.ones(3),
                                  SDESampleConfig(slots=4))
            r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=8, seed=1,
                            priority=prios[0])
            r2 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4, seed=2,
                            priority=prios[1])
            return r1, r2, eng.run()
        r1, r2, flat = serve((0, 0))
        assert list(flat) == [r1, r2]          # FIFO retirement
        p1, p2, prio = serve((0, 5))
        assert list(prio) == [p2, p1]          # high class served first
        for a, b in ((r1, p1), (r2, p2)):
            np.testing.assert_array_equal(flat[a].y_final, prio[b].y_final)

    def test_error_paths_raise_at_submit_time(self):
        """Malformed requests die loudly at submit() — named argument, clear
        message — never at the queue head inside jit."""
        eng = SDESampleEngine(term(), jnp.ones(3), SDESampleConfig(slots=2))
        with pytest.raises(KeyError, match="unknown solver"):
            eng.submit("not-a-solver", t1=1.0, n_steps=8, n_paths=2)
        with pytest.raises(ValueError, match="n_paths must be >= 1"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=0)
        with pytest.raises(ValueError, match="save_at must be a flat"):
            eng.submit("ees25:adaptive", t1=1.0, n_steps=8, n_paths=2,
                       rtol=1e-3, save_at=[[0.5, 1.0]])
        with pytest.raises(ValueError, match="priority must be an int"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, priority=0.5)
        assert eng.pending() == {}  # nothing half-enqueued


class TestAsyncEngine:
    """The asyncio continuous-batching plane over the same scheduler/executor
    core.  Tests run the loop to completion inside ``asyncio.run`` (no
    pytest-asyncio dependency)."""

    REQS = [("ees25", dict(t1=1.0, n_steps=16, n_paths=10, seed=5)),
            ("ees25", dict(t1=1.0, n_steps=8, n_paths=3, seed=9,
                           save_every=4)),
            ("ees25", dict(t1=1.0, n_steps=16, n_paths=7, seed=2))]

    def sync_reference(self, cfg, prios):
        eng = SDESampleEngine(term(), jnp.ones(3), cfg)
        rids = [eng.submit(s, priority=p, **kw)
                for (s, kw), p in zip(self.REQS, prios)]
        done = eng.run()
        return [done[r] for r in rids]

    def async_results(self, cfg, prios):
        async def main():
            async with AsyncSDESampleEngine(term(), jnp.ones(3), cfg) as eng:
                rids = [await eng.submit(s, priority=p, **kw)
                        for (s, kw), p in zip(self.REQS, prios)]
                return [await eng.result(r, numpy=True) for r in rids]
        return asyncio.run(main())

    @pytest.mark.parametrize("ticks_per_dispatch", [1, 4])
    @pytest.mark.parametrize("prios", [(0, 0, 0), (0, 5, 1)])
    def test_async_bitwise_equals_sync_drain(self, ticks_per_dispatch, prios):
        """Acceptance criterion: the async plane returns results bitwise
        identical to the synchronous drain, across dispatch depths and with
        priorities on/off (samples are (seed, path)-pure)."""
        cfg = SDESampleConfig(slots=4, ticks_per_dispatch=ticks_per_dispatch)
        for a, b in zip(self.sync_reference(cfg, prios),
                        self.async_results(cfg, prios)):
            np.testing.assert_array_equal(np.asarray(a.y_final), b.y_final)
            if a.ys is not None:
                np.testing.assert_array_equal(np.asarray(a.ys), b.ys)

    def test_results_stay_device_resident_until_asked(self):
        async def main():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3), SDESampleConfig(slots=4)) as eng:
                rid = await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
                res = await eng.result(rid)
                assert isinstance(res.y_final, jax.Array)  # no host copy
                host = await eng.result(rid, numpy=True)
                assert isinstance(host.y_final, np.ndarray)
                np.testing.assert_array_equal(np.asarray(res.y_final),
                                              host.y_final)
        asyncio.run(main())

    def test_submit_backpressure_awaits_space(self):
        """A full bounded queue makes ``submit`` wait (not raise); capacity
        freed by retirement admits it, and the late request completes."""
        async def main():
            cfg = SDESampleConfig(slots=4, max_queue_paths=8)
            async with AsyncSDESampleEngine(term(), jnp.ones(3), cfg) as eng:
                r1 = await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=8)
                blocked = asyncio.create_task(
                    eng.submit("ees25", t1=1.0, n_steps=8, n_paths=8))
                await asyncio.sleep(0)
                assert not blocked.done()  # parked on admission, no error
                await eng.result(r1)       # retirement frees capacity
                r2 = await blocked
                res = await eng.result(r2)
                assert res.y_final.shape[0] == 8
        asyncio.run(main())

    def test_cancel_wakes_waiter_and_frees_capacity(self):
        async def main():
            cfg = SDESampleConfig(slots=2, max_queue_requests=1)
            async with AsyncSDESampleEngine(term(), jnp.ones(3), cfg) as eng:
                # Park the serve loop behind a cancelled head-of-queue: the
                # waiter gets CancelledError, the blocked submit is admitted.
                r1 = await eng.submit("ees25", t1=1.0, n_steps=8,
                                      n_paths=1000)
                blocked = asyncio.create_task(
                    eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2, seed=4))
                waiter = asyncio.create_task(eng.result(r1))
                await asyncio.sleep(0)
                assert eng.cancel(r1) is True
                with pytest.raises(asyncio.CancelledError):
                    await waiter
                r2 = await blocked
                res = await eng.result(r2, numpy=True)
                assert res.y_final.shape[0] == 2
                with pytest.raises(asyncio.CancelledError):
                    await eng.result(r1)   # stays cancelled on re-await
                with pytest.raises(KeyError, match="unknown request id"):
                    await eng.result(999)
        asyncio.run(main())

    def test_submit_validation_errors_do_not_wait(self):
        async def main():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3), SDESampleConfig(slots=2)) as eng:
                with pytest.raises(KeyError, match="unknown solver"):
                    await eng.submit("not-a-solver", t1=1.0, n_steps=8,
                                     n_paths=2)
                with pytest.raises(ValueError, match="n_paths must be >= 1"):
                    await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=0)
                with pytest.raises(ValueError, match="save_at must be a flat"):
                    await eng.submit("ees25:adaptive", t1=1.0, n_steps=8,
                                     n_paths=2, rtol=1e-3,
                                     save_at=np.ones((2, 2)))
                assert eng.pending() == {}
        asyncio.run(main())

    def test_drain_and_reuse_after_idle(self):
        """The serve loop idles when the queue empties and wakes for new
        work; ``drain`` awaits everything queued so far."""
        async def main():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3), SDESampleConfig(slots=4)) as eng:
                a = await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
                done = await eng.drain()
                assert sorted(k for k in done if k != "counters") == [a]
                b = await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
                done = await eng.drain()
                assert sorted(k for k in done if k != "counters") == [a, b]
                assert done["counters"]["retries"] == 0
        asyncio.run(main())
