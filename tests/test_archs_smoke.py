"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + (where applicable) decode consistency, on CPU."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import (
    ModelOptions,
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_train_step,
    serve_step,
)
from repro.optim import adamw

# Lazy PRNG key: creating a jax array at module scope initialises the
# XLA backend during *collection*, which the default (tier-1) lane pays
# even when this module's slow-marked cases are deselected — keep heavy
# device setup out of import time.
@functools.lru_cache(maxsize=None)
def KEY():
    return jax.random.PRNGKey(0)


ALL = list_archs()

# Compile-heavy archs run only in the slow lane; the default (tier-1) run
# keeps the cheapest member of each family (dense, ssm, moe, vlm) so those
# code paths still compile on every PR.  The hybrid (zamba2) and audio
# (hubert) archs have no cheap member and live in the slow lane only.
HEAVY_SMOKE = {
    "zamba2-7b", "hubert-xlarge", "qwen1.5-32b", "yi-9b", "olmoe-1b-7b",
}
QUICK_DECODE = {"olmo-1b"}


def _smoke_params():
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_SMOKE else a
        for a in ALL
    ]


def _decode_params():
    return [
        a if a in QUICK_DECODE else pytest.param(a, marks=pytest.mark.slow)
        for a in ALL
        if get_arch(a).supports_decode
    ]


def make_batch(cfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY(), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(KEY(), 1), (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "patch":
        batch["vision_embeds"] = jax.random.normal(
            KEY(), (B, cfg.n_vision_tokens, cfg.frontend_dim)
        )
    if cfg.frontend == "frames":
        batch = {
            "frames": jax.random.normal(KEY(), (B, S, cfg.frontend_dim)),
            "labels": batch["labels"],
        }
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_full_config_registered(arch):
    cfg = get_arch(arch)
    assert cfg.param_count() > 0
    assert cfg.name == arch


@pytest.mark.parametrize("arch", _smoke_params())
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(cfg, KEY())
    batch = make_batch(cfg)
    logits, aux = forward(cfg, params, batch)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/inf in logits"
    # one optimizer step reduces nothing in particular but must be finite
    opt = adamw(1e-3)
    ts = make_train_step(cfg, opt)
    p2, st2, m = ts(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("arch", _decode_params())
def test_decode_matches_forward(arch):
    """Token-by-token decode equals the full forward (the KV-cache/SSM-state
    correctness test).  MoE needs dropless capacity for exact equality."""
    cfg = get_arch(arch).smoke()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = init_params(cfg, KEY())
    B, S = 2, 16
    toks = jax.random.randint(KEY(), (B, S), 0, cfg.vocab)
    logits_full, _ = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = serve_step(cfg, params, cache, toks[:, i], jnp.int32(i))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(logits_full, logits_dec, atol=2e-5)


def test_encoder_only_has_no_decode():
    cfg = get_arch("hubert-xlarge")
    assert not cfg.supports_decode


def test_long_context_applicability():
    from repro.configs import cell_applicable, get_shape

    long = get_shape("long_500k")
    runnable = [a for a in ALL if cell_applicable(get_arch(a), long)[0]]
    assert sorted(runnable) == ["mamba2-130m", "zamba2-7b"]


@pytest.mark.slow
def test_remat_matches_no_remat():
    cfg = get_arch("qwen3-1.7b").smoke()
    params = init_params(cfg, KEY())
    batch = make_batch(cfg)
    l1 = loss_fn(cfg, params, batch, ModelOptions(remat=False))
    l2 = loss_fn(cfg, params, batch, ModelOptions(remat=True))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    g1 = jax.grad(lambda p: loss_fn(cfg, p, batch, ModelOptions(remat=False)))(params)
    g2 = jax.grad(lambda p: loss_fn(cfg, p, batch, ModelOptions(remat=True)))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)


def test_hybrid_shared_block_is_shared():
    """zamba2: the shared attention block appears once in params."""
    cfg = get_arch("zamba2-7b").smoke()
    params = init_params(cfg, KEY())
    assert "shared" in params
    # scanned layers contain only mamba params
    assert set(params["layers"].keys()) == {"mamba"}


def test_training_reduces_loss_tiny_lm():
    """A few hundred steps on a tiny memorisable stream reduces loss clearly."""
    cfg = get_arch("olmo-1b").smoke()
    params = init_params(cfg, KEY())
    opt = adamw(3e-3)
    ts = jax.jit(make_train_step(cfg, opt))
    st = opt.init(params)
    # fixed tiny batch -> should memorise
    batch = make_batch(cfg, B=2, S=16)
    first = None
    for i in range(60):
        params, st, m = ts(params, st, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first * 0.5, (first, last)
