"""Euclidean solver behaviour: step equivalences, reversibility orders,
adjoint gradient agreement, Brownian reconstruction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BrownianPath,
    ButcherSolver,
    MCFSolver,
    ReversibleHeun,
    SDETerm,
    brownian_path,
    ees25,
    ees25_solver,
    ees27_solver,
    euler,
    heun,
    midpoint,
    rk4,
    solve,
)

KEY = jax.random.PRNGKey(0)


def nonlinear_ode_term():
    return SDETerm(drift=lambda t, y, a: jnp.sin(y) + 0.3 * y * jnp.cos(t), noise="none")


def nonlinear_sde_term():
    return SDETerm(
        drift=lambda t, y, a: jnp.tanh(a["w"] * y + a["b"]),
        diffusion=lambda t, y, a: 0.2 + 0.1 * jnp.tanh(a["g"] * y),
        noise="diagonal",
    )


ARGS = {"w": jnp.float64(0.5), "b": jnp.float64(-0.2), "g": jnp.float64(0.3)}


class TestStepEquivalences:
    def test_butcher_equals_2n(self):
        """The Williamson 2N recurrence computes the identical RK step."""
        term = nonlinear_sde_term()
        y0 = jnp.array([0.4, -1.1, 0.8])
        dW = jnp.array([0.03, -0.05, 0.02])
        y_butcher = ButcherSolver(ees25).step(term, y0, 0.1, 0.05, dW, ARGS)
        y_2n = ees25_solver().step(term, y0, 0.1, 0.05, dW, ARGS)
        np.testing.assert_allclose(y_butcher, y_2n, rtol=1e-12)

    def test_general_noise_matches_diagonal(self):
        """A diagonal diffusion expressed as a (d, d) matrix gives the same step."""
        gvals = jnp.array([0.2, 0.3, 0.1])
        term_d = SDETerm(
            drift=lambda t, y, a: -y,
            diffusion=lambda t, y, a: gvals * jnp.ones_like(y),
            noise="diagonal",
        )
        term_g = SDETerm(
            drift=lambda t, y, a: -y,
            diffusion=lambda t, y, a: jnp.diag(gvals),
            noise="general",
        )
        y0 = jnp.array([1.0, 2.0, 3.0])
        dW = jnp.array([0.1, -0.2, 0.05])
        s = ees25_solver()
        np.testing.assert_allclose(
            s.step(term_d, y0, 0.0, 0.01, dW, None),
            s.step(term_g, y0, 0.0, 0.01, dW, None),
            rtol=1e-12,
        )


class TestReversibility:
    @pytest.mark.parametrize(
        "solver,expected_order",
        [(ees25_solver(), 6), (ees27_solver(), 8)],
    )
    def test_effective_symmetry_order(self, solver, expected_order):
        """Phi_{-h} o Phi_h = id + O(h^{m+1}): slope of log-error vs log-h."""
        term = nonlinear_ode_term()
        y0 = jnp.array([0.7, -0.4], dtype=jnp.float64)
        hs = np.array([0.1, 0.05, 0.025])
        errs = []
        for h in hs:
            y1 = solver.step(term, y0, 0.0, h, None, None)
            y0b = solver.reverse(term, y1, 0.0, h, None, None)
            errs.append(float(jnp.max(jnp.abs(y0b - y0))))
        slope = np.polyfit(np.log(hs), np.log(np.maximum(errs, 1e-300)), 1)[0]
        assert slope > expected_order - 0.5

    @pytest.mark.parametrize(
        "solver", [ReversibleHeun(), MCFSolver(euler), MCFSolver(midpoint), MCFSolver(heun)]
    )
    def test_exact_algebraic_reversibility(self, solver):
        term = nonlinear_sde_term()
        y0 = jnp.array([0.4, -1.1], dtype=jnp.float64)
        state = solver.init(term, 0.0, y0, ARGS)
        dW = jnp.array([0.07, -0.02])
        s1 = solver.step(term, state, 0.0, 0.1, dW, ARGS)
        s0 = solver.reverse(term, s1, 0.0, 0.1, dW, ARGS)
        for a, b in zip(jax.tree_util.tree_leaves(s0), jax.tree_util.tree_leaves(state)):
            np.testing.assert_allclose(a, b, atol=1e-13)

    def test_multistep_reconstruction_drift_small(self):
        """Reconstructing 256 EES steps backwards stays within tolerance."""
        term = nonlinear_sde_term()
        bm = brownian_path(KEY, 0.0, 1.0, 256, shape=(4,), dtype=jnp.float64)
        solver = ees25_solver()
        y = jnp.ones(4, dtype=jnp.float64)
        ys = [y]
        for n in range(bm.n_steps):
            y = solver.step(term, y, bm.t_of(n), bm.h, bm.increment(n), ARGS)
            ys.append(y)
        yb = y
        for n in range(bm.n_steps - 1, -1, -1):
            yb = solver.reverse(term, yb, bm.t_of(n), bm.h, bm.increment(n), ARGS)
        assert float(jnp.max(jnp.abs(yb - ys[0]))) < 1e-8


class TestAdjoints:
    def _loss(self, adjoint, solver):
        def loss(params, key):
            term = nonlinear_sde_term()
            bm = brownian_path(key, 0.0, 1.0, 128, shape=(8,), dtype=jnp.float64)
            r = solve(
                solver, term, jnp.ones(8, jnp.float64), bm, params,
                adjoint=adjoint, save_every=16,
            )
            return jnp.sum(r.y_final ** 2) + jnp.sum(r.ys ** 2)

        return loss

    def test_full_equals_recursive(self):
        s = ees25_solver()
        gf = jax.grad(self._loss("full", s))(ARGS, KEY)
        gr = jax.grad(self._loss("recursive", s))(ARGS, KEY)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-10)

    def test_reversible_close_to_full_ees(self):
        s = ees25_solver()
        gf = jax.grad(self._loss("full", s))(ARGS, KEY)
        gr = jax.grad(self._loss("reversible", s))(ARGS, KEY)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-6)

    @pytest.mark.parametrize("solver", [ReversibleHeun(), MCFSolver(midpoint)])
    def test_reversible_exact_for_algebraic_solvers(self, solver):
        gf = jax.grad(self._loss("full", solver))(ARGS, KEY)
        gr = jax.grad(self._loss("reversible", solver))(ARGS, KEY)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-9)

    def test_reversible_jits(self):
        s = ees25_solver()
        g1 = jax.grad(self._loss("reversible", s))(ARGS, KEY)
        g2 = jax.jit(jax.grad(self._loss("reversible", s)))(ARGS, KEY)
        for k in ARGS:
            np.testing.assert_allclose(g1[k], g2[k], rtol=1e-12)

    def test_grad_wrt_y0(self):
        term = nonlinear_sde_term()
        bm = brownian_path(KEY, 0.0, 1.0, 64, shape=(4,), dtype=jnp.float64)

        def loss(y0, adjoint):
            r = solve(ees25_solver(), term, y0, bm, ARGS, adjoint=adjoint)
            return jnp.sum(r.y_final ** 2)

        y0 = jnp.array([1.0, 0.5, -0.5, 2.0])
        gf = jax.grad(lambda y: loss(y, "full"))(y0)
        gr = jax.grad(lambda y: loss(y, "reversible"))(y0)
        np.testing.assert_allclose(gf, gr, rtol=1e-6)

    def test_saved_trajectory_identical_across_adjoints(self):
        term = nonlinear_sde_term()
        bm = brownian_path(KEY, 0.0, 1.0, 64, shape=(4,), dtype=jnp.float64)
        y0 = jnp.ones(4)
        outs = [
            solve(ees25_solver(), term, y0, bm, ARGS, adjoint=a, save_every=8).ys
            for a in ("full", "recursive", "reversible")
        ]
        np.testing.assert_allclose(outs[0], outs[1], atol=0)
        np.testing.assert_allclose(outs[0], outs[2], atol=0)


class TestBrownian:
    def test_increments_deterministic_and_orderfree(self):
        bm = brownian_path(KEY, 0.0, 1.0, 100, shape=(3,))
        a = bm.increment(42)
        b = bm.increment(7)
        a2 = bm.increment(42)
        np.testing.assert_array_equal(a, a2)
        assert not np.allclose(a, b)

    def test_variance_scaling(self):
        bm = brownian_path(KEY, 0.0, 2.0, 50, shape=(20000,))
        inc = bm.increment(3)
        assert float(jnp.var(inc)) == pytest.approx(2.0 / 50, rel=0.1)

    def test_pytree_shapes(self):
        bm = brownian_path(KEY, 0.0, 1.0, 10, shape=((3,), (5,)))
        dw = bm.increment(0)
        assert dw[0].shape == (3,) and dw[1].shape == (5,)

    def test_path_endpoints(self):
        bm = brownian_path(KEY, 0.0, 1.0, 16, shape=())
        w = bm.path()
        assert w.shape == (17,)
        total = sum(float(bm.increment(n)) for n in range(16))
        assert float(w[-1]) == pytest.approx(total, rel=1e-5)
