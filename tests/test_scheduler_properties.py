"""Property-based scheduler/executor-invariant harness (device-free, tier-1).

Random interleavings of submit / cancel / stage / release / tick-drain ops
drive the real :class:`~repro.serving.scheduler.Scheduler` against a trivial
**sequential oracle**: for one signature group, the next plan's flattened
ticks must equal "live same-signature requests in service order, each
contributing its next undelivered+unreserved paths, truncated to
``slots * max_ticks`` and chunked into ``slots``-wide ticks".  Everything the
serving plane relies on falls out of checking that plus delivery accounting:

* no request is ever lost or duplicated (every (request, path) pair is
  delivered exactly once; every non-cancelled request retires with its full,
  in-order path set);
* retirement respects queue order within a signature (equal priorities are
  strict FIFO);
* ``pending()`` stays consistent with delivered counts at every step;
* a cancel before dispatch never occupies a slot in any later plan;
* staged (``reserve=True``) plans — the double-buffering hook — never
  overlap the live plan's paths, survive cancels of their owners, and
  ``release`` returns their paths intact.

Runs under hypothesis when it is installed (CI) and always additionally runs
a seeded ``random.Random`` sweep sharing the same op generator, so the
default lane exercises >= 200 interleavings with no optional dependency.
"""
import random
from collections import deque

import numpy as np
import pytest

pytest.importorskip("jax")  # solver-registry parsing imports jax (host only)

from repro.serving.scheduler import Scheduler, make_request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container lane: the seeded sweep below still runs
    HAVE_HYPOTHESIS = False

# Three distinct signatures (n_steps differs), same solver kind.
N_STEPS_CHOICES = (8, 16, 32)
FALLBACK_SEEDS = range(220)  # >= 200 interleavings without hypothesis


# -- op generation (shared by the hypothesis and seeded paths) ---------------

def gen_ops(rng: random.Random, n_ops: int = 14):
    """A random op trace.  Ops reference requests positionally (k-th
    submitted) so traces are self-contained and replayable from a seed."""
    ops = []
    n_submitted = 0
    for _ in range(n_ops):
        roll = rng.random()
        if n_submitted == 0 or roll < 0.40:
            ops.append(("submit", rng.choice(N_STEPS_CHOICES),
                        rng.randint(1, 9),
                        rng.choice((0, 0, 0, 1, 5))))  # bias: default prio
            n_submitted += 1
        elif roll < 0.55:
            ops.append(("cancel", rng.randrange(n_submitted)))
        elif roll < 0.70:
            ops.append(("stage", rng.randint(1, 5), rng.randint(1, 4)))
        elif roll < 0.78:
            ops.append(("release",))
        elif roll < 0.86:
            ops.append(("deliver_staged",))
        else:
            ops.append(("drain", rng.randint(1, 5), rng.randint(1, 4)))
    return ops


# -- the oracle --------------------------------------------------------------

class OracleReq:
    def __init__(self, rid, n_steps, n_paths, priority):
        self.rid = rid
        self.n_steps = n_steps  # stands in for the full signature
        self.n_paths = n_paths
        self.priority = priority
        self.delivered = 0
        self.reserved = 0
        self.cancelled = False


class Oracle:
    """Sequential flat-fill model of the scheduler.  Deliberately trivial:
    no slot bookkeeping, just cursors over a priority-stable-sorted list."""

    def __init__(self):
        self.reqs = []

    def order(self):
        return sorted((r for r in self.reqs if not r.cancelled),
                      key=lambda r: -r.priority)

    def pick_signature(self):
        for r in self.order():
            if r.n_paths - r.delivered - r.reserved > 0:
                return r.n_steps
        return None

    def fill(self, slots, max_ticks, sig, reserve):
        """Flat fill: same-signature live requests in service order, each
        from its cursor, truncated to slots*max_ticks, chunked by slots."""
        flat = []
        for r in self.order():
            if r.n_steps != sig:
                continue
            start = r.delivered + r.reserved
            flat.extend((r, i) for i in range(start, r.n_paths))
        flat = flat[: slots * max_ticks]
        if not flat:
            return None
        if reserve:
            for r, _ in flat:
                r.reserved += 1
        return [flat[k:k + slots] for k in range(0, len(flat), slots)]


# -- trace interpreter -------------------------------------------------------

def check_pending(sched, oracle):
    want = {r.rid: r.n_paths - r.delivered
            for r in oracle.reqs if not r.cancelled and not r.done_expected}
    assert sched.pending() == want


def run_trace(ops):
    sched = Scheduler()
    oracle = Oracle()
    # staged: FIFO-delivered, LIFO-released (mirrors the engines: reserved
    # plans are delivered in planning order; only the newest is released)
    staged = deque()
    delivered_pairs = set()   # (rid, path) — each must appear exactly once
    retired_log = []

    def fake_outputs(plan):
        y = np.zeros((plan.n_ticks, plan.slots, 1))
        for t, tick in enumerate(plan.ticks):
            for s, (p, i) in enumerate(tick):
                y[t, s] = p.request.request_id * 1000 + i
        return {"y_final": y, "ys": None}

    def check_plan(plan, chunks):
        if plan is None:
            assert chunks is None
            return
        got = [[(p.request.request_id, i) for p, i in tick]
               for tick in plan.ticks]
        want = [[(r.rid, i) for r, i in chunk] for chunk in chunks]
        assert got == want, f"plan diverged from oracle: {got} != {want}"
        for tick in got:
            for rid, i in tick:
                assert rid not in cancelled_before, \
                    f"cancelled request {rid} occupies a slot"

    def deliver(plan, chunks):
        retired = sched.deliver(plan, fake_outputs(plan))
        for chunk in chunks:
            for r, i in chunk:
                assert (r.rid, i) not in delivered_pairs, \
                    f"path ({r.rid}, {i}) delivered twice"
                delivered_pairs.add((r.rid, i))
                r.delivered += 1
                if plan.reserved:
                    r.reserved -= 1
        want_retired = [r.rid for r in oracle.order()
                        if r.delivered == r.n_paths and not r.done_expected]
        for r in oracle.reqs:
            if r.delivered == r.n_paths and not r.cancelled:
                r.done_expected = True
        assert retired == want_retired
        retired_log.extend(retired)
        for rid in retired:
            res = sched.done[rid]
            r = next(r for r in oracle.reqs if r.rid == rid)
            want = np.array([rid * 1000 + i
                             for i in range(r.n_paths)])[:, None]
            assert np.array_equal(res.y_final, want), \
                f"request {rid} retired with wrong/misordered paths"

    cancelled_before = set()  # rids cancelled while still fully unplanned
    for op in ops:
        if op[0] == "submit":
            _, n_steps, n_paths, priority = op
            rid = sched.new_request_id()
            req = make_request(rid, "ees25", term_kind="euclidean", t1=1.0,
                               n_steps=n_steps, n_paths=n_paths,
                               priority=priority)
            sched.enqueue(req)
            r = OracleReq(rid, n_steps, n_paths, priority)
            r.done_expected = False
            oracle.reqs.append(r)
        elif op[0] == "cancel":
            r = oracle.reqs[op[1]]
            got = sched.cancel(r.rid)
            want = not r.cancelled and not r.done_expected
            assert got == want
            if got and r.delivered == 0 and r.reserved == 0:
                cancelled_before.add(r.rid)
            r.cancelled = r.cancelled or got
        elif op[0] == "stage":
            _, slots, max_ticks = op
            sig = oracle.pick_signature()
            plan = sched.plan(slots, max_ticks, reserve=True)
            chunks = None if sig is None else \
                oracle.fill(slots, max_ticks, sig, reserve=True)
            check_plan(plan, chunks)
            if plan is not None:
                staged.append((plan, chunks))
        elif op[0] == "release":
            if staged:
                plan, chunks = staged.pop()  # newest first: LIFO only
                sched.release(plan)
                for chunk in chunks:
                    for r, _ in chunk:
                        r.reserved -= 1
        elif op[0] == "deliver_staged":
            if staged:
                plan, chunks = staged.popleft()  # planning order
                deliver(plan, chunks)
        elif op[0] == "drain":
            _, slots, max_ticks = op
            if staged:
                continue  # unreserved plans would double-issue staged paths
            sig = oracle.pick_signature()
            plan = sched.plan(slots, max_ticks)
            chunks = None if sig is None else \
                oracle.fill(slots, max_ticks, sig, reserve=False)
            check_plan(plan, chunks)
            if plan is not None:
                deliver(plan, chunks)
        check_pending(sched, oracle)

    # Epilogue: flush staged plans in planning order, then drain to empty.
    while staged:
        plan, chunks = staged.popleft()
        deliver(plan, chunks)
    while True:
        sig = oracle.pick_signature()
        plan = sched.plan(4, 3)
        if plan is None:
            assert sig is None
            break
        deliver(plan, oracle.fill(4, 3, sig, reserve=False))
        check_pending(sched, oracle)

    # Global accounting: nothing lost, nothing duplicated.
    assert not sched.pending()
    live = [r for r in oracle.reqs if not r.cancelled]
    assert sorted(sched.done) == sorted(r.rid for r in live)
    for r in live:
        assert all((r.rid, i) in delivered_pairs for i in range(r.n_paths)), \
            f"request {r.rid} lost paths"
    # Retirement respects queue order within a signature + priority class:
    # among equal-priority same-signature requests, retirement ids ascend.
    pos = {rid: k for k, rid in enumerate(retired_log)}
    by_class = {}
    for r in live:
        by_class.setdefault((r.n_steps, r.priority), []).append(r.rid)
    for rids in by_class.values():
        order = [pos[rid] for rid in rids]
        assert order == sorted(order), \
            f"same-class requests retired out of FIFO order: {rids}"


# -- bucketed planning (PR 8): group-aware plans vs the same oracle ----------
#
# With a bucketing group_key, one plan may span several true signatures
# (horizons sharing a padded rung).  The sequential-oracle invariants must
# survive unchanged: every (request, path) pair delivered exactly once and
# in order, FIFO within a (signature, priority) class, cancelled requests
# never occupy slots — plus the new per-tick contract: a tick never mixes
# true signatures, and ``tick_sigs`` records each tick's signature.

def _rung(n_steps, m=16):
    r = m
    while r < n_steps:
        r *= 2
    return r


class _FakeBucket:
    """Minimal duck-typed bucket: hashable, carries ``n_padded`` (what the
    scheduler's introspection keys on).  Device-free stand-in for BucketKey."""

    def __init__(self, solver, n_padded):
        self.solver, self.n_padded = solver, n_padded

    def __eq__(self, other):
        return (isinstance(other, _FakeBucket)
                and (self.solver, self.n_padded)
                == (other.solver, other.n_padded))

    def __hash__(self):
        return hash((self.solver, self.n_padded))


def _bucket_group(sig):
    # solver + padded rung: 8 and 16 steps share a group, 32 is its own.
    return _FakeBucket(sig[0], _rung(sig[3]))


def run_bucketed_trace(ops):
    sched = Scheduler(group_key=_bucket_group)
    delivered_pairs = set()
    retired_log = []
    reqs = {}       # rid -> (n_steps, n_paths, priority)
    cancelled = set()

    def fake_outputs(plan):
        y = np.zeros((plan.n_ticks, plan.slots, 1))
        for t, tick in enumerate(plan.ticks):
            for s, (p, i) in enumerate(tick):
                y[t, s] = p.request.request_id * 1000 + i
        return {"y_final": y, "ys": None}

    def deliver(plan):
        assert plan.tick_sigs is not None and \
            len(plan.tick_sigs) == plan.n_ticks
        for t, tick in enumerate(plan.ticks):
            sigs = {p.request.signature for p, _ in tick}
            assert len(sigs) == 1, "tick mixes true signatures"
            assert sigs == {plan.tick_sigs[t]}
            assert all(_bucket_group(s) == plan.group for s in sigs)
            for p, i in tick:
                rid = p.request.request_id
                assert rid not in cancelled
                assert (rid, i) not in delivered_pairs, "path delivered twice"
                delivered_pairs.add((rid, i))
        retired_log.extend(sched.deliver(plan, fake_outputs(plan)))

    for op in ops:
        if op[0] == "submit":
            _, n_steps, n_paths, priority = op
            rid = sched.new_request_id()
            sched.enqueue(make_request(rid, "ees25", term_kind="euclidean",
                                       t1=1.0, n_steps=n_steps,
                                       n_paths=n_paths, priority=priority))
            reqs[rid] = (n_steps, n_paths, priority)
        elif op[0] == "cancel":
            rid = list(reqs)[op[1]]
            if sched.cancel(rid):
                cancelled.add(rid)  # must never occupy a slot from here on
        elif op[0] in ("stage", "drain", "deliver_staged", "release"):
            # Bucketed harness drains unreserved only (the reserved path is
            # covered group-agnostically by run_trace): reuse the op's sizes.
            slots, max_ticks = (op[1], op[2]) if len(op) == 3 else (4, 2)
            plan = sched.plan(slots, max_ticks)
            if plan is not None:
                deliver(plan)
        # pending() consistency at every step
        for rid, owed in sched.pending().items():
            n_steps, n_paths, _ = reqs[rid]
            got = sum((rid, i) in delivered_pairs for i in range(n_paths))
            assert owed == n_paths - got

    while True:  # drain to empty
        plan = sched.plan(4, 3)
        if plan is None:
            break
        deliver(plan)

    # Global accounting: nothing lost, nothing duplicated, FIFO per class.
    assert not sched.pending()
    live = [rid for rid in reqs
            if rid not in sched._cancelled_ids]
    assert sorted(sched.done) == sorted(live)
    for rid in live:
        n_steps, n_paths, _ = reqs[rid]
        assert all((rid, i) in delivered_pairs for i in range(n_paths)), \
            f"request {rid} lost paths"
        res = sched.done[rid]
        want = np.array([rid * 1000 + i for i in range(n_paths)])[:, None]
        assert np.array_equal(res.y_final, want)
        # introspection: bucketed requests surface the rung they coalesced
        # into and the masked padding steps per path
        assert isinstance(res.bucket, _FakeBucket)
        assert res.bucket.n_padded == _rung(n_steps)
        assert res.n_padded_steps == _rung(n_steps) - n_steps
    pos = {rid: k for k, rid in enumerate(retired_log)}
    by_class = {}
    for rid in live:
        n_steps, _, priority = reqs[rid]
        by_class.setdefault((n_steps, priority), []).append(rid)
    for rids in by_class.values():
        order = [pos[rid] for rid in rids]
        assert order == sorted(order), \
            f"same-class requests retired out of FIFO order: {rids}"


# -- entry points ------------------------------------------------------------

@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_random_interleavings_seeded(seed):
    run_trace(gen_ops(random.Random(seed)))


def test_long_traces_seeded():
    for seed in range(40):
        run_trace(gen_ops(random.Random(10_000 + seed), n_ops=40))


@pytest.mark.parametrize("seed", range(120))
def test_bucketed_random_interleavings_seeded(seed):
    run_bucketed_trace(gen_ops(random.Random(50_000 + seed)))


def test_bucketed_long_traces_seeded():
    for seed in range(25):
        run_bucketed_trace(gen_ops(random.Random(60_000 + seed), n_ops=40))


def test_identity_group_key_reproduces_legacy_plans():
    """With no group_key, the group-aware plan() must produce byte-for-byte
    the same plan sequence as before the bucketing refactor — i.e. exactly
    what the sequential oracle predicts (run_trace already asserts this);
    here: a bucketed scheduler over a SINGLE signature class also reduces to
    legacy plans (one signature per group <=> the classic filling)."""
    legacy, bucketed = Scheduler(), Scheduler(group_key=_bucket_group)
    for sched in (legacy, bucketed):
        for k, n_paths in enumerate((5, 3, 9)):
            rid = sched.new_request_id()
            sched.enqueue(make_request(rid, "ees25", term_kind="euclidean",
                                       t1=1.0, n_steps=16, n_paths=n_paths))
    while True:
        pa = legacy.plan(4, 2)
        pb = bucketed.plan(4, 2)
        if pa is None or pb is None:
            assert pa is None and pb is None
            break
        ga = [[(p.request.request_id, i) for p, i in t] for t in pa.ticks]
        gb = [[(p.request.request_id, i) for p, i in t] for t in pb.ticks]
        assert ga == gb
        for plan, sched in ((pa, legacy), (pb, bucketed)):
            y = np.zeros((plan.n_ticks, plan.slots, 1))
            sched.deliver(plan, {"y_final": y, "ys": None})


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1),
           st.integers(min_value=4, max_value=40))
    def test_random_interleavings_hypothesis(seed, n_ops):
        run_trace(gen_ops(random.Random(seed), n_ops=n_ops))
