"""Lie groups, CF-EES, geometric baselines: manifold preservation, the flat
collapse (Prop. D.1 consistency row), reversibility order, manifold adjoints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CrouchGrossman2,
    Euclidean,
    GeoEulerMaruyama,
    ManifoldSDETerm,
    Product,
    RKMK2,
    SDETerm,
    SO3,
    SOn,
    SphereAction,
    Torus,
    brownian_path,
    cfees25_solver,
    cfees27_solver,
    ees25_solver,
    solve,
)
from repro.core.lie import rodrigues, skew_from_vec, vec_from_skew

KEY = jax.random.PRNGKey(0)

ALL_GEO_SOLVERS = [
    cfees25_solver(),
    cfees27_solver(),
    GeoEulerMaruyama(),
    CrouchGrossman2(),
    RKMK2(),
]


def so3_term():
    def xi(t, y, a):
        return jnp.stack(
            [0.1 + 0.3 * y[..., 2, 0], -(0.25 + 0.2 * y[..., 1, 2]), 0.9 + 0.2 * y[..., 0, 0]],
            axis=-1,
        )

    def xig(t, y, a):
        return jnp.stack(
            [0.8 + 0.15 * y[..., 2, 2], 0.15 + 0.25 * y[..., 0, 1], 0.35 - 0.2 * y[..., 1, 1]],
            axis=-1,
        )

    return ManifoldSDETerm(group=SO3(), drift=xi, diffusion=xig, noise="diagonal")


class TestRodrigues:
    def test_matches_expm(self):
        w = jnp.array([0.3, -0.7, 0.5], dtype=jnp.float64)
        np.testing.assert_allclose(
            rodrigues(w), jax.scipy.linalg.expm(skew_from_vec(w)), atol=1e-12
        )

    def test_small_angle_stable(self):
        w = jnp.array([1e-12, -1e-13, 1e-12], dtype=jnp.float64)
        R = rodrigues(w)
        assert not np.any(np.isnan(R))
        np.testing.assert_allclose(R, np.eye(3), atol=1e-10)

    def test_grad_no_nan_at_zero(self):
        g = jax.grad(lambda w: rodrigues(w)[0, 1])(jnp.zeros(3, jnp.float64))
        assert not np.any(np.isnan(g))

    def test_skew_vec_roundtrip(self):
        w = jnp.array([1.0, 2.0, 3.0])
        np.testing.assert_allclose(vec_from_skew(skew_from_vec(w)), w)


class TestFlatCollapse:
    def test_cfees_equals_euclidean_ees(self):
        """On Euclidean space CF-EES(2,5) is *identically* EES(2,5)-2N."""
        f = lambda t, y, a: jnp.tanh(y) * a
        g = lambda t, y, a: 0.2 * jnp.cos(y)
        term_e = SDETerm(drift=f, diffusion=g, noise="diagonal")
        term_m = ManifoldSDETerm(group=Euclidean(), drift=f, diffusion=g, noise="diagonal")
        y0 = jnp.array([0.3, -1.2, 0.8])
        dW = jnp.array([0.05, -0.02, 0.01])
        ye = ees25_solver().step(term_e, y0, 0.0, 0.1, dW, jnp.float64(0.9))
        ym = cfees25_solver().step(term_m, y0, 0.0, 0.1, dW, jnp.float64(0.9))
        np.testing.assert_array_equal(ye, ym)


class TestManifoldPreservation:
    @pytest.mark.parametrize("solver", ALL_GEO_SOLVERS, ids=lambda s: s.name)
    def test_so3_stays_orthogonal(self, solver):
        term = so3_term()
        bm = brownian_path(KEY, 0.0, 1.0, 100, shape=(3,), dtype=jnp.float64)
        r = solve(solver, term, jnp.eye(3, dtype=jnp.float64), bm, None, adjoint="full")
        assert float(term.group.distance_from_manifold(r.y_final)) < 1e-12

    def test_sphere_stays_unit(self):
        n = 4
        m = n * (n - 1) // 2
        iu = jnp.triu_indices(n, 1)

        def skew_flat(v):
            S = jnp.zeros(v.shape[:-1] + (n, n), v.dtype)
            S = S.at[..., iu[0], iu[1]].set(v)
            return S - jnp.swapaxes(S, -1, -2)

        term = ManifoldSDETerm(
            group=SphereAction(n),
            drift=lambda t, y, a: skew_flat(jnp.tanh(a @ y)),
            diffusion=lambda t, y, a: 0.2,
            noise="general",
            noise_apply=lambda sig, dw: skew_flat(sig * dw),
        )
        W = 0.3 * jax.random.normal(KEY, (m, n), jnp.float64)
        y0 = jnp.zeros(n, jnp.float64).at[0].set(1.0)
        bm = brownian_path(KEY, 0.0, 1.0, 50, shape=(m,), dtype=jnp.float64)
        r = solve(cfees25_solver(), term, y0, bm, W, adjoint="full")
        assert abs(float(jnp.linalg.norm(r.y_final)) - 1.0) < 1e-12

    def test_torus_stays_wrapped(self):
        grp = Torus()
        term = ManifoldSDETerm(
            group=grp,
            drift=lambda t, y, a: 5.0 * jnp.ones_like(y),
            diffusion=lambda t, y, a: jnp.ones_like(y),
            noise="diagonal",
        )
        bm = brownian_path(KEY, 0.0, 5.0, 100, shape=(6,), dtype=jnp.float64)
        r = solve(cfees25_solver(), term, jnp.zeros(6), bm, None, adjoint="full")
        assert float(jnp.max(jnp.abs(r.y_final))) <= np.pi + 1e-9

    def test_son_general(self):
        n = 5
        grp = SOn(n)
        key1, key2 = jax.random.split(KEY)
        M = jax.random.normal(key1, (n, n), jnp.float64)

        def xi(t, y, a):
            S = M @ y
            return 0.3 * (S - S.T)

        term = ManifoldSDETerm(group=grp, drift=xi, noise="none")
        bm = brownian_path(key2, 0.0, 1.0, 20, shape=(), dtype=jnp.float64)
        r = solve(cfees25_solver(), term, jnp.eye(n, dtype=jnp.float64), bm, None)
        assert float(grp.distance_from_manifold(r.y_final)) < 1e-12


class TestCFEESReversibility:
    def test_reverse_order_on_so3(self):
        """Theorem 3.2: CF-EES(2,5) recovers the initial condition to order 5
        (error O(h^6) per step)."""
        term = so3_term()
        solver = cfees25_solver()
        Y0 = jnp.eye(3, dtype=jnp.float64)
        hs = np.array([0.1, 0.05, 0.025])
        errs = []
        for h in hs:
            y1 = solver.step(term, Y0, 0.0, h, jnp.zeros(3), None)
            y0b = solver.reverse(term, y1, 0.0, h, jnp.zeros(3), None)
            errs.append(float(jnp.max(jnp.abs(y0b - Y0))))
        slope = np.polyfit(np.log(hs), np.log(errs), 1)[0]
        assert slope > 5.5

    def test_geo_em_not_effectively_symmetric(self):
        term = so3_term()
        solver = GeoEulerMaruyama()
        Y0 = jnp.eye(3, dtype=jnp.float64)
        hs = np.array([0.1, 0.05, 0.025])
        errs = []
        for h in hs:
            y1 = solver.step(term, Y0, 0.0, h, jnp.zeros(3), None)
            y0b = solver.reverse(term, y1, 0.0, h, jnp.zeros(3), None)
            errs.append(float(jnp.max(jnp.abs(y0b - Y0))))
        slope = np.polyfit(np.log(hs), np.log(errs), 1)[0]
        assert slope < 3.5  # order ~2 reverse error: *not* near-reversible


class TestManifoldAdjoint:
    def test_kuramoto_product_gradients(self):
        N = 5
        grp = Product([Torus(), Euclidean()])

        def drift(t, y, p):
            th, om = y
            return (om, p["K"] * jnp.mean(jnp.sin(th[None, :] - th[:, None]), axis=1) - om)

        def diff(t, y, p):
            th, om = y
            return (jnp.zeros_like(th), p["D"] * jnp.ones_like(om))

        term = ManifoldSDETerm(group=grp, drift=drift, diffusion=diff, noise="diagonal")
        y0 = (jnp.linspace(-1.0, 1.0, N), jnp.zeros(N))

        def loss(p, adjoint):
            bm = brownian_path(KEY, 0.0, 2.0, 200, shape=((N,), (N,)), dtype=jnp.float64)
            r = solve(cfees25_solver(), term, y0, bm, p, adjoint=adjoint, save_every=50)
            th, om = r.y_final
            ths, oms = r.ys
            return jnp.sum(jnp.cos(th)) + 0.1 * jnp.sum(om ** 2) + 0.01 * jnp.sum(ths ** 2)

        p = {"K": jnp.float64(2.0), "D": jnp.float64(0.05)}
        gf = jax.grad(lambda q: loss(q, "full"))(p)
        gr = jax.grad(lambda q: loss(q, "reversible"))(p)
        for k in p:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-6)
