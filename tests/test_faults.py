"""Fault-injection tests for the divergence-aware serving plane (PR 9).

What must hold under injected faults (``repro.serving.faults``):

* **Isolation** — a NaN'd slot never poisons co-batched clean requests:
  their retired samples are bitwise-identical to a fault-free engine's.
* **Retry ladder** — diverged requests re-enter the queue degraded (halved
  ``h``, then the canonical fallback solver), capped by
  ``RetryPolicy.max_retries``; the final result lands under the ORIGINAL
  request id with ``retries`` set.
* **Crash recovery** — a dispatch-time crash releases exactly the
  undelivered reservations (sync), or triggers a supervised serve-loop
  restart (async); every queued request is then served exactly once,
  bitwise what an uninterrupted run would have produced.
* **Deadlines** — an expired request cancels in place: the sync engine
  surfaces ``timed_out=True``, the async engine raises ``TimeoutError`` to
  the waiter and frees its admission capacity.
* **Accounting** — engine counters (``retries`` / ``timeouts`` /
  ``diverged_requests`` / ``diverged_paths`` / ``restarts``) surface through
  ``pending(detail=True)`` and async ``drain()``.

Randomized sweeps at the bottom drive seeded fault schedules against a
fault-free reference engine: no request lost, duplicated, or stuck, and
every un-faulted result bitwise-unchanged.
"""
import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm
from repro.serving import (
    AsyncSDESampleEngine,
    FakeClock,
    FaultConfig,
    InjectedCrash,
    RetryPolicy,
    SDESampleConfig,
    SDESampleEngine,
    inject_faults,
)

KEY = jax.random.PRNGKey(0)


def term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -0.5 * y,
        diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
        noise="diagonal",
    )


def stiff_term() -> SDETerm:
    # Blows up deterministically on the coarse grids requests below use, and
    # stabilizes once the retry ladder halves h far enough.
    return SDETerm(
        drift=lambda t, y, a: -40.0 * y,
        diffusion=lambda t, y, a: 0.05 * jnp.ones_like(y),
        noise="diagonal",
    )


def make_engine(t=None, slots=4, **cfg_kw):
    return SDESampleEngine(t if t is not None else term(),
                           jnp.ones(3, jnp.float32),
                           SDESampleConfig(slots=slots, **cfg_kw))


def leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestNaNIsolation:
    def test_victim_retries_cobatched_request_bitwise_clean(self):
        # Two 2-path requests share one 4-slot tick: slots 0-1 belong to the
        # victim, 2-3 to the bystander.  Corrupt slot 0 of dispatch 0.
        def serve(faults):
            eng = make_engine(slots=4)
            inj = (inject_faults(eng, FaultConfig(nan_slots=((0, 0, 0),)))
                   if faults else None)
            a = eng.submit("ees25", t1=1.0, n_steps=16, n_paths=2, seed=1)
            b = eng.submit("ees25", t1=1.0, n_steps=16, n_paths=2, seed=2)
            done = eng.run()
            return eng, inj, a, b, done

        eng, inj, a, b, done = serve(True)
        _, _, ra, rb, ref = serve(False)
        assert inj.n_nans == 1
        assert set(done) == {a, b}
        # The bystander never saw the fault: bitwise equal to the clean run.
        np.testing.assert_array_equal(np.asarray(done[b].y_final),
                                      np.asarray(ref[rb].y_final))
        assert done[b].retries == 0
        # The victim retried once (degraded) and completed clean.
        assert done[a].retries == 1
        assert bool(jnp.isfinite(done[a].y_final).all())
        assert eng.counters["retries"] == 1
        assert eng.counters["diverged_requests"] == 1
        assert eng.counters["diverged_paths"] == 1

    def test_counters_surface_via_pending_detail(self):
        eng = make_engine(slots=4)
        inject_faults(eng, FaultConfig(nan_slots=((0, 0, 0),)))
        eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=1)
        eng.run()
        detail = eng.pending(detail=True)
        assert detail["counters"]["retries"] == 1
        assert detail["counters"]["diverged_requests"] == 1
        assert detail["counters"]["timeouts"] == 0


class TestRetryLadder:
    def test_degrade_halves_then_falls_back(self):
        from repro.serving.scheduler import make_request

        pol = RetryPolicy()
        r0 = make_request(1, "heun", term_kind="euclidean", t1=1.0,
                          n_steps=64, n_paths=2)
        r1 = pol.degrade(r0, 0)  # halve h: same solver, doubled steps
        assert r1["n_steps"] == 128 and r1["solver"] == r0.solver
        r_fb = pol.degrade(r0, pol.max_h_halvings)  # then fall back
        assert r_fb["solver"].startswith("ees27")
        assert r_fb["n_steps"] == 64

    def test_stiff_request_walks_ladder_to_completion(self):
        eng = make_engine(stiff_term(), slots=4)
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4, seed=0)
        done = eng.run()
        assert set(done) == {rid}
        res = done[rid]
        assert res.retries >= 1
        assert bool(jnp.isfinite(res.y_final).all())
        assert eng.counters["retries"] == res.retries
        assert eng.counters["diverged_requests"] >= 1

    def test_retries_capped_result_surfaces_diverged(self):
        pol = RetryPolicy(max_retries=1, max_h_halvings=0)
        eng = SDESampleEngine(
            stiff_term(), jnp.ones(3, jnp.float32),
            SDESampleConfig(slots=4, retry_policy=pol))
        rid = eng.submit("ees25", t1=1.0, n_steps=4, n_paths=4, seed=0)
        done = eng.run()
        res = done[rid]
        assert res.retries == 1  # burned the cap, still diverged
        assert bool(np.asarray(res.diverged).any())

    def test_async_retry_lands_under_root_id(self):
        async def go():
            async with AsyncSDESampleEngine(
                    stiff_term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4)) as eng:
                rid = await eng.submit("ees25", t1=1.0, n_steps=8, n_paths=4,
                                       seed=0)
                res = await eng.result(rid)
                out = await eng.drain()
                return rid, res, out

        rid, res, out = asyncio.run(go())
        assert res.retries >= 1 and bool(jnp.isfinite(res.y_final).all())
        assert out["counters"]["retries"] == res.retries
        assert rid in out


class TestCrashRecovery:
    def test_sync_crash_releases_reservations_rerun_bitwise(self):
        ref_eng = make_engine(slots=4)
        for i in range(4):
            ref_eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=i)
        ref = ref_eng.run()

        eng = make_engine(slots=4)
        inj = inject_faults(eng, FaultConfig(crash_dispatches=(1,)))
        rids = [eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=i)
                for i in range(4)]
        with pytest.raises(InjectedCrash):
            eng.run()
        assert inj.n_crashes == 1
        # Crashed work went back on the queue; a rerun serves it exactly
        # once — run() returns the cumulative done map.
        done = eng.run()
        assert set(done) == set(rids)
        for rid in rids:
            np.testing.assert_array_equal(np.asarray(done[rid].y_final),
                                          np.asarray(ref[rid].y_final))

    def test_async_supervised_restart_serves_all_bitwise(self):
        async def go(fault_cfg):
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4)) as eng:
                if fault_cfg is not None:
                    inj = inject_faults(eng, fault_cfg)
                rids = [await eng.submit("ees25", t1=1.0, n_steps=16,
                                         n_paths=4, seed=i)
                        for i in range(4)]
                results = [await eng.result(r) for r in rids]
                counters = dict(eng._eng.counters)
                n_crashes = inj.n_crashes if fault_cfg is not None else 0
            return results, counters, n_crashes

        ref, _, _ = asyncio.run(go(None))
        got, counters, n_crashes = asyncio.run(
            go(FaultConfig(crash_dispatches=(0,))))
        assert n_crashes == 1 and counters["restarts"] == 1
        assert len(got) == len(ref) == 4
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g.y_final),
                                          np.asarray(r.y_final))

    def test_async_restart_budget_exhausted_fails_waiters(self):
        async def go():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4, max_restarts=1)) as eng:
                inject_faults(eng, FaultConfig(crash_rate=1.0))
                rid = await eng.submit("ees25", t1=1.0, n_steps=16,
                                       n_paths=4, seed=0)
                with pytest.raises(InjectedCrash):
                    await eng.result(rid)

        asyncio.run(go())

    def test_non_transient_error_is_not_restarted(self):
        class Boom(RuntimeError):
            pass

        async def go():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4)) as eng:
                real = eng.executor.dispatch

                def bad(*a, **kw):
                    raise Boom("hard failure")

                eng._eng.executor.dispatch = bad
                rid = await eng.submit("ees25", t1=1.0, n_steps=16,
                                       n_paths=4, seed=0)
                with pytest.raises(Boom):
                    await eng.result(rid)
                assert eng._eng.counters["restarts"] == 0
                eng._eng.executor.dispatch = real

        asyncio.run(go())


class TestDeadlines:
    def test_sync_deadline_times_out_in_queue(self):
        clk = FakeClock()
        eng = SDESampleEngine(term(), jnp.ones(3, jnp.float32),
                              SDESampleConfig(slots=4), clock=clk)
        rid = eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=0,
                         deadline_ms=50.0)
        clk.advance(0.2)
        done = eng.run()
        res = done[rid]
        assert res.timed_out and res.y_final is None
        assert eng.counters["timeouts"] == 1

    def test_sync_deadline_not_hit_serves_normally(self):
        clk = FakeClock()
        eng = SDESampleEngine(term(), jnp.ones(3, jnp.float32),
                              SDESampleConfig(slots=4), clock=clk)
        rid = eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=0,
                         deadline_ms=1e6)
        done = eng.run()
        assert not done[rid].timed_out
        assert bool(jnp.isfinite(done[rid].y_final).all())
        assert eng.counters["timeouts"] == 0

    def test_deadline_remaining_visible_in_pending_detail(self):
        clk = FakeClock()
        eng = SDESampleEngine(term(), jnp.ones(3, jnp.float32),
                              SDESampleConfig(slots=4), clock=clk)
        rid = eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=0,
                         deadline_ms=1000.0)
        detail = eng.pending(detail=True)
        assert detail[rid]["deadline_remaining_s"] == pytest.approx(1.0)

    def test_async_deadline_raises_and_frees_capacity(self):
        async def go():
            clk = FakeClock()
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4), clock=clk) as eng:
                # Block the serve loop from ever planning this request by
                # advancing the clock past its deadline before first service.
                rid = await eng.submit("ees25", t1=1.0, n_steps=16,
                                       n_paths=4, seed=0, deadline_ms=1.0)
                clk.advance(10.0)
                with pytest.raises(TimeoutError):
                    await eng.result(rid)
                assert eng._eng.counters["timeouts"] == 1
                # Capacity freed: the engine still serves new work, bitwise.
                rid2 = await eng.submit("ees25", t1=1.0, n_steps=16,
                                        n_paths=4, seed=7)
                res = await eng.result(rid2)
            ref = make_engine(slots=4)
            ref_id = ref.submit("ees25", t1=1.0, n_steps=16, n_paths=4,
                                seed=7)
            ref_res = ref.run()[ref_id]
            np.testing.assert_array_equal(np.asarray(res.y_final),
                                          np.asarray(ref_res.y_final))

        asyncio.run(go())


class TestRandomizedFaultSweeps:
    """Seeded random fault interleavings vs a fault-free reference: every
    request retires exactly once (no loss, no duplication, no stuck
    waiters), and whatever the schedule did not touch is bitwise-unchanged."""

    N_REQ = 6

    def _submit_all(self, eng):
        return [eng.submit("ees25", t1=1.0 + (i % 2), n_steps=16, n_paths=2,
                           seed=i) for i in range(self.N_REQ)]

    def _reference(self):
        eng = make_engine(slots=4)
        rids = self._submit_all(eng)
        done = eng.run()
        return {i: done[r] for i, r in enumerate(rids)}

    @pytest.mark.parametrize("seed", range(4))
    def test_sync_nan_schedule(self, seed):
        ref = self._reference()
        eng = make_engine(slots=4)
        inj = inject_faults(eng, FaultConfig(seed=seed, nan_rate=0.4))
        rids = self._submit_all(eng)
        done = eng.run()
        assert set(done) == set(rids)  # exactly once, nothing stuck
        for i, rid in enumerate(rids):
            res = done[rid]
            assert bool(jnp.isfinite(res.y_final).all())
            if res.retries == 0:
                np.testing.assert_array_equal(np.asarray(res.y_final),
                                              np.asarray(ref[i].y_final))
        assert eng.counters["retries"] >= (1 if inj.n_nans else 0)

    @pytest.mark.parametrize("seed", range(4))
    def test_async_crash_and_nan_interleaving(self, seed):
        ref = self._reference()

        async def go():
            async with AsyncSDESampleEngine(
                    term(), jnp.ones(3, jnp.float32),
                    SDESampleConfig(slots=4, max_restarts=100)) as eng:
                inj = inject_faults(eng, FaultConfig(
                    seed=seed, nan_rate=0.3, crash_rate=0.2))
                rids = [await eng.submit("ees25", t1=1.0 + (i % 2),
                                         n_steps=16, n_paths=2, seed=i)
                        for i in range(self.N_REQ)]
                results = [await eng.result(r) for r in rids]
                out = await eng.drain()
                return results, out, inj.n_crashes, dict(eng._eng.counters)

        results, out, n_crashes, counters = asyncio.run(go())
        assert len(results) == self.N_REQ
        assert counters["restarts"] == n_crashes
        for i, res in enumerate(results):
            assert bool(jnp.isfinite(res.y_final).all())
            if res.retries == 0:
                np.testing.assert_array_equal(np.asarray(res.y_final),
                                              np.asarray(ref[i].y_final))

    def test_faulty_executor_delegates_and_counts(self):
        eng = make_engine(slots=4)
        inj = inject_faults(eng, FaultConfig(seed=0, delay_rate=1.0,
                                             delay_s=0.001))
        eng.submit("ees25", t1=1.0, n_steps=16, n_paths=4, seed=0)
        eng.run()
        assert inj.n_delays >= 1 and inj.n_dispatch_calls >= 1
        assert inj.n_crashes == 0 and inj.n_nans == 0
        # Delegation: the injector exposes the inner executor's counters.
        assert inj.n_dispatches == eng.executor.inner.n_dispatches
