"""PR 10 — fused multi-step training pipeline.

Invariants under test:

* ``make_scanned_step`` with ``steps_per_call=K`` is **bitwise-identical**
  to K sequential un-scanned steps — all three adjoints, fixed and adaptive
  grids (the scan is a pure dispatch amortization, never a numerics change).
* The fused guard select (one ``tree_map`` over the joined
  ``(params, opt_state)`` tree) is bitwise-identical to the PR-9 two-pass
  implementation, on finite and on guard-skipped steps.
* The mesh-sharded data-parallel step matches the single-device step
  bitwise (single-device mesh here; the multi-device case runs in
  ``test_launch_distributed.py`` under 8 fake devices).
* ``microbatches`` gradient accumulation reproduces the full-batch step for
  path-decomposable losses.
* ``train_loop`` / ``resilient_train_loop`` chunked modes: dispatch counts,
  batched metric fetches, chunk-boundary checkpointing, exact mid-chunk
  resume via ``batch_at`` replay, and chunk-granular skip/rollback.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SDETerm
from repro.optim import adamw, cosine_schedule
from repro.train.checkpoint import checkpoint_meta, latest_step
from repro.train.trainer import (
    ResilienceConfig,
    TrainLoopConfig,
    init_scan_counters,
    make_scanned_step,
    make_sde_train_step,
    resilient_train_loop,
    train_loop,
)

TERM = SDETerm(
    drift=lambda t, y, p: p["nu"] * (p["mu"] - y),
    diffusion=lambda t, y, p: p["sigma"] * jnp.ones_like(y),
    noise="diagonal",
)
PARAMS = {"nu": jnp.float64(0.5), "mu": jnp.float64(0.0),
          "sigma": jnp.float64(0.5)}
KEY = jax.random.PRNGKey(0)
COMMON = dict(t0=0.0, t1=1.0, n_steps=16, n_paths=8)
Y0 = lambda p: jnp.zeros(4, jnp.float64)  # noqa: E731
LOSS = lambda p, r: (jnp.mean(r.y_final ** 2)  # noqa: E731
                     + 0.1 * jnp.mean(jnp.mean(r.y_final, 0) ** 2))


def _opt(steps=64):
    return adamw(cosine_schedule(1e-3, 2, steps))


def _fresh(tree):
    return jax.tree_util.tree_map(lambda x: jnp.array(x), tree)


def _leaves_eq(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


class TestScannedStep:
    @pytest.mark.parametrize("adjoint", ["full", "recursive", "reversible"])
    def test_scan_matches_sequential(self, adjoint):
        opt = _opt()
        step = make_sde_train_step("ees25", TERM, opt, Y0, LOSS,
                                   adjoint=adjoint, **COMMON)
        jstep = jax.jit(step)
        p, s = PARAMS, opt.init(PARAMS)
        losses = []
        for i in range(4):
            p, s, m = jstep(p, s, jax.random.fold_in(KEY, i))
            losses.append(np.asarray(m["loss"]))
        scanned = make_scanned_step(step, 4)
        p2, s2, c2, hist = scanned(_fresh(PARAMS), opt.init(PARAMS),
                                   init_scan_counters(), KEY, jnp.asarray(0))
        assert _leaves_eq((p, s), (p2, s2))
        assert np.array_equal(np.asarray(hist["loss"]), np.stack(losses))

    def test_scan_matches_sequential_adaptive(self):
        opt = _opt()
        kw = dict(rtol=1e-3, atol=1e-5, save_at=jnp.linspace(0.0, 1.0, 5))
        loss = lambda p, r: jnp.mean(r.ys ** 2)  # noqa: E731
        step = make_sde_train_step("ees25:adaptive", TERM, opt, Y0, loss,
                                   **kw, **COMMON)
        jstep = jax.jit(step)
        p, s = PARAMS, opt.init(PARAMS)
        for i in range(3):
            p, s, _ = jstep(p, s, jax.random.fold_in(KEY, i))
        scanned = make_scanned_step(step, 3)
        p2, s2, _, _ = scanned(_fresh(PARAMS), opt.init(PARAMS),
                               init_scan_counters(), KEY, jnp.asarray(0))
        assert _leaves_eq((p, s), (p2, s2))

    def test_counters_and_step0_offset(self):
        opt = _opt()
        step = make_sde_train_step("ees25", TERM, opt, Y0, LOSS, **COMMON)
        scanned = make_scanned_step(step, 3)
        # two chunks, offset step0 — same trajectory as one 6-step sequence
        p, s, c, _ = scanned(_fresh(PARAMS), opt.init(PARAMS),
                             init_scan_counters(), KEY, jnp.asarray(0))
        p, s, c, _ = scanned(p, s, c, KEY, jnp.asarray(3))
        jstep = jax.jit(step)
        pr, sr = PARAMS, opt.init(PARAMS)
        for i in range(6):
            pr, sr, _ = jstep(pr, sr, jax.random.fold_in(KEY, i))
        assert _leaves_eq((p, s), (pr, sr))
        got = jax.device_get(c)
        assert int(got["steps"]) == 6 and int(got["skipped"]) == 0

    def test_four_arg_step_records_injected_faults(self):
        opt = _opt()
        base = make_sde_train_step("ees25", TERM, opt, Y0, LOSS, **COMMON)
        faults = jnp.asarray([1, 4])

        def faulty(p, o, k, s):
            p2, o2, m = base(p, o, k)
            hit = jnp.isin(s, faults)
            keep = lambda new, old: jnp.where(hit, old, new)  # noqa: E731
            p2, o2 = jax.tree_util.tree_map(keep, (p2, o2), (p, o))
            return p2, o2, dict(m, skipped=m["skipped"] | hit)

        scanned = make_scanned_step(faulty, 6)
        _, _, c, hist = scanned(_fresh(PARAMS), opt.init(PARAMS),
                                init_scan_counters(), KEY, jnp.asarray(0))
        sk = np.asarray(jax.device_get(hist["skipped"])).astype(bool)
        assert sk.tolist() == [False, True, False, False, True, False]
        assert int(jax.device_get(c)["skipped"]) == 2

    def test_bad_steps_per_call_raises(self):
        with pytest.raises(ValueError, match="steps_per_call"):
            make_scanned_step(lambda p, o, k: (p, o, {}), 0)


class TestGuardFuse:
    """The fused single-traversal guard select vs the PR-9 two-pass code."""

    def _reference_step(self, opt, loss):
        # verbatim shape of the pre-PR-10 guard: update, then TWO separate
        # tree_map(keep, ...) passes over params and opt_state
        from repro.core import sdeint
        from repro.core.pytree import tree_blowup
        from repro.core.sdeint import path_keys

        def step(params, opt_state, key):
            def lfn(p):
                r = sdeint(TERM, "ees25", COMMON["t0"], COMMON["t1"],
                           COMMON["n_steps"], Y0(p), None, args=p,
                           adjoint="reversible", batch_keys=path_keys(
                               key, COMMON["n_paths"]), bulk_increments=True)
                return loss(p, r)

            l, g = jax.value_and_grad(lfn)(params)
            bad = tree_blowup(g) | ~jnp.isfinite(l)
            new_p, new_s, gnorm = opt.update(g, opt_state, params)
            keep = lambda new, old: jnp.where(bad, old, new)  # noqa: E731
            params = jax.tree_util.tree_map(keep, new_p, params)
            opt_state = jax.tree_util.tree_map(keep, new_s, opt_state)
            return params, opt_state, {"loss": l, "grad_norm": gnorm,
                                       "skipped": bad}

        return step

    def test_finite_steps_bitwise(self):
        opt = _opt()
        fused = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, LOSS,
                                            **COMMON))
        ref = jax.jit(self._reference_step(opt, LOSS))
        pf, sf = PARAMS, opt.init(PARAMS)
        pr, sr = PARAMS, opt.init(PARAMS)
        for i in range(3):
            k = jax.random.fold_in(KEY, i)
            pf, sf, mf = fused(pf, sf, k)
            pr, sr, mr = ref(pr, sr, k)
            assert not bool(np.asarray(mf["skipped"]))
        assert _leaves_eq((pf, sf), (pr, sr))

    def test_skipped_step_bitwise_and_inert(self):
        opt = _opt()
        blown = lambda p, r: LOSS(p, r) + jnp.nan  # noqa: E731
        fused = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, blown,
                                            **COMMON))
        ref = jax.jit(self._reference_step(opt, blown))
        s0 = opt.init(PARAMS)
        pf, sf, mf = fused(PARAMS, s0, KEY)
        pr, sr, mr = ref(PARAMS, s0, KEY)
        assert bool(np.asarray(mf["skipped"])) and bool(np.asarray(mr["skipped"]))
        assert _leaves_eq((pf, sf), (pr, sr))
        assert _leaves_eq(pf, PARAMS)  # guard held the params


class TestMicrobatch:
    def test_decomposable_loss_matches_full_batch(self):
        opt = _opt()
        loss = lambda p, r: jnp.mean(r.y_final ** 2)  # noqa: E731
        full = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, loss,
                                           **COMMON))
        mb = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, loss,
                                         microbatches=4, **COMMON))
        p1, s1, m1 = full(PARAMS, opt.init(PARAMS), KEY)
        p2, s2, m2 = mb(PARAMS, opt.init(PARAMS), KEY)
        # mean-of-slice-means == full mean for equal slices; the grads are
        # reduced in a different association order, so ulp-tight, not bitwise
        assert np.allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-12)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-9, atol=1e-12)

    def test_non_dividing_microbatches_raises(self):
        with pytest.raises(ValueError, match="microbatches"):
            make_sde_train_step("ees25", TERM, _opt(), Y0, LOSS,
                                microbatches=3, **COMMON)


class TestMeshDataParallel:
    def test_single_device_mesh_bitwise(self):
        from repro.launch.mesh import make_train_mesh

        opt = _opt()
        mesh = make_train_mesh(1)
        plain = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, LOSS,
                                            **COMMON))
        dp = jax.jit(make_sde_train_step("ees25", TERM, opt, Y0, LOSS,
                                         mesh=mesh, mesh_axis="dp", **COMMON))
        pa, sa, ma = plain(PARAMS, opt.init(PARAMS), KEY)
        pb, sb, mb = dp(PARAMS, opt.init(PARAMS), KEY)
        assert _leaves_eq((pa, sa), (pb, sb))
        assert np.array_equal(np.asarray(ma["loss"]), np.asarray(mb["loss"]))

    def test_single_device_mesh_adaptive_bitwise(self):
        from repro.launch.mesh import make_train_mesh

        opt = _opt()
        mesh = make_train_mesh(1)
        kw = dict(rtol=1e-3, atol=1e-5, save_at=jnp.linspace(0.0, 1.0, 5))
        loss = lambda p, r: jnp.mean(r.ys ** 2)  # noqa: E731
        plain = jax.jit(make_sde_train_step("ees25:adaptive", TERM, opt, Y0,
                                            loss, **kw, **COMMON))
        dp = jax.jit(make_sde_train_step("ees25:adaptive", TERM, opt, Y0,
                                         loss, mesh=mesh, mesh_axis="dp",
                                         **kw, **COMMON))
        pa, sa, _ = plain(PARAMS, opt.init(PARAMS), KEY)
        pb, sb, _ = dp(PARAMS, opt.init(PARAMS), KEY)
        assert _leaves_eq((pa, sa), (pb, sb))

    def test_mesh_validation(self):
        from repro.launch.mesh import make_train_mesh

        with pytest.raises(ValueError, match="mesh_axis"):
            make_sde_train_step("ees25", TERM, _opt(), Y0, LOSS,
                                mesh_axis="dp", **COMMON)
        with pytest.raises(ValueError, match="mesh"):
            make_sde_train_step("ees25", TERM, _opt(), Y0, LOSS,
                                mesh=make_train_mesh(1), **COMMON)


# --------------------------------------------------------------------------
# train_loop: chunked dispatch, batched fetch, mid-chunk resume.
# --------------------------------------------------------------------------

class _ToyData:
    """Step-pure data source: batch_at(step) is a pure function of step."""

    def __init__(self, dim=3, batch=4):
        self.dim, self.batch = dim, batch

    def batch_at(self, step):
        rng = np.random.default_rng(1000 + step)
        return rng.standard_normal((self.batch, self.dim))


def _toy_setup(steps):
    opt = adamw(cosine_schedule(1e-2, 2, steps))
    params = {"w": jnp.asarray(np.linspace(0.3, 0.9, 3))}

    def step_fn(p, o, b):
        l, g = jax.value_and_grad(
            lambda pp: jnp.mean((b @ pp["w"]) ** 2))(p)
        p2, o2, gn = opt.update(g, o, p)
        return p2, o2, {"loss": l, "grad_norm": gn}

    return opt, params, step_fn


class TestTrainLoopChunked:
    def test_chunked_bitwise_and_dispatch_count(self):
        steps = 10
        opt, params, step_fn = _toy_setup(steps)
        o1 = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                        step_fn=step_fn,
                        loop=TrainLoopConfig(steps=steps, log_every=2))
        o4 = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                        step_fn=step_fn,
                        loop=TrainLoopConfig(steps=steps, log_every=2,
                                             steps_per_call=4))
        # the dispatch-count regression: one jit call per step vs per chunk
        assert o1["n_dispatches"] == steps
        assert o4["n_dispatches"] == 3  # ceil(10 / 4)
        assert _leaves_eq(o1["params"], o4["params"])
        assert o1["losses"] == o4["losses"]

    def test_resume_from_chunk_boundary_bitwise(self, tmp_path):
        steps = 12
        opt, params, step_fn = _toy_setup(steps)
        loop = lambda n, d=None: TrainLoopConfig(  # noqa: E731
            steps=n, ckpt_every=4, ckpt_dir=d, log_every=100, steps_per_call=4)
        d = str(tmp_path / "ck")
        train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                   step_fn=step_fn, loop=loop(8, d))
        assert latest_step(d) == 8  # chunk-boundary save
        resumed = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                             step_fn=step_fn, loop=loop(steps, d))
        unbroken = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                              step_fn=step_fn, loop=loop(steps))
        assert _leaves_eq(resumed["params"], unbroken["params"])
        assert resumed["n_dispatches"] == 1  # 12 - 8 = one 4-step chunk

    def test_resume_mid_chunk_bitwise(self, tmp_path):
        # checkpoint written at step 5 by a K=1 run, resumed by a K=4 run:
        # step 5 is mid-chunk for the resumer — still bitwise, because
        # scanned chunks == sequential steps and batch_at replay is exact
        steps = 11
        opt, params, step_fn = _toy_setup(steps)
        d = str(tmp_path / "ck")
        train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                   step_fn=step_fn,
                   loop=TrainLoopConfig(steps=5, ckpt_every=5, ckpt_dir=d,
                                        log_every=100))
        assert latest_step(d) == 5
        resumed = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                             step_fn=step_fn,
                             loop=TrainLoopConfig(steps=steps, ckpt_every=100,
                                                  ckpt_dir=d, log_every=100,
                                                  steps_per_call=4))
        unbroken = train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                              step_fn=step_fn,
                              loop=TrainLoopConfig(steps=steps, log_every=100,
                                                   steps_per_call=4))
        assert _leaves_eq(resumed["params"], unbroken["params"])
        # 6 remaining steps from step 5: chunks of 4 + 2
        assert resumed["n_dispatches"] == 2

    def test_checkpoint_meta_records_chunking(self, tmp_path):
        opt, params, step_fn = _toy_setup(8)
        d = str(tmp_path / "ck")
        train_loop(None, _fresh(params), _ToyData(), optimizer=opt,
                   step_fn=step_fn,
                   loop=TrainLoopConfig(steps=8, ckpt_every=4, ckpt_dir=d,
                                        log_every=100, steps_per_call=4))
        assert checkpoint_meta(d, latest_step(d))["steps_per_call"] == 4


# --------------------------------------------------------------------------
# resilient_train_loop: chunked guard/rollback.
# --------------------------------------------------------------------------

class TestResilientChunked:
    def test_fault_free_chunked_matches_stepwise(self):
        opt = _opt()
        step = make_sde_train_step("ees25", TERM, opt, Y0, LOSS, **COMMON)
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            r1 = resilient_train_loop(
                jax.jit(step), _fresh(PARAMS), opt.init(PARAMS), KEY,
                res=ResilienceConfig(steps=10, ckpt_every=4, ckpt_dir=d1))
            r2 = resilient_train_loop(
                step, _fresh(PARAMS), opt.init(PARAMS), KEY,
                res=ResilienceConfig(steps=10, ckpt_every=4, ckpt_dir=d2,
                                     steps_per_call=4))
        assert _leaves_eq(r1["params"], r2["params"])
        assert r1["losses"] == r2["losses"]
        assert r1["skipped"] == r2["skipped"]
        assert r1["goodput"] == r2["goodput"] == 1.0

    def test_chunked_rollback_on_skip_streak(self):
        opt = _opt()
        base = make_sde_train_step("ees25", TERM, opt, Y0, LOSS, **COMMON)
        faults = jnp.asarray([2, 3, 4, 9])  # streak of 3 -> rollback at 4

        def faulty(p, o, k, s):
            p2, o2, m = base(p, o, k)
            hit = jnp.isin(s, faults)
            keep = lambda new, old: jnp.where(hit, old, new)  # noqa: E731
            p2, o2 = jax.tree_util.tree_map(keep, (p2, o2), (p, o))
            return p2, o2, dict(m, skipped=m["skipped"] | hit)

        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            r1 = resilient_train_loop(
                faulty, _fresh(PARAMS), opt.init(PARAMS), KEY,
                res=ResilienceConfig(steps=12, ckpt_every=2, ckpt_dir=d1,
                                     skip_patience=3))
            r2 = resilient_train_loop(
                faulty, _fresh(PARAMS), opt.init(PARAMS), KEY,
                res=ResilienceConfig(steps=12, ckpt_every=2, ckpt_dir=d2,
                                     skip_patience=3, steps_per_call=5))
        # same policy at both granularities: identical skip pattern, one
        # rollback, identical goodput (restored *states* may differ — the
        # chunked mode's checkpoints live on chunk boundaries)
        assert r1["skipped"] == r2["skipped"]
        assert r1["rollbacks"] == r2["rollbacks"] == 1
        assert r1["goodput"] == r2["goodput"]
        assert len(r2["losses"]) == 12

    def test_record_chunk_averages_per_step(self):
        from repro.train.fault_tolerance import StragglerTracker

        tr = StragglerTracker([0])
        tr.record_chunk(0, 8.0, 16)
        assert tr._times[0] == [0.5]
        with pytest.raises(ValueError, match="n_steps"):
            tr.record_chunk(0, 1.0, 0)
