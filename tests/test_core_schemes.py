"""Tableaux, Williamson 2N structure, and stability functions vs the paper."""
import numpy as np
import pytest

from repro.core import (
    EES25_2N,
    EES27_2N,
    bazavov_residuals,
    butcher_from_2n,
    ees25,
    ees25_2n,
    ees25_tableau,
    ees27_tableau,
    rk3,
    rk4,
)
from repro.core.stability import (
    is_mean_square_stable,
    mean_square_factor,
    stability_function,
)
from repro.core.tableaux import euler, heun, midpoint, order_residuals, stability_poly
from repro.core.williamson import cf_weights, two_n_from_butcher


class TestEES25Tableau:
    def test_canonical_values(self):
        # Proposition 2.1 at x = 1/10.
        assert ees25.a[1][0] == pytest.approx(1 / 3)
        assert ees25.a[2][0] == pytest.approx(-5 / 48)
        assert ees25.a[2][1] == pytest.approx(15 / 16)
        assert ees25.b == pytest.approx((1 / 10, 1 / 2, 2 / 5))
        assert ees25.c == pytest.approx((0, 1 / 3, 5 / 6))

    @pytest.mark.parametrize("x", [0.1, 0.0, 0.3, -0.2, 2.0])
    def test_order2_any_x(self, x):
        res = order_residuals(ees25_tableau(x), 2)
        assert max(res.values()) < 1e-12

    @pytest.mark.parametrize("x", [1.0, 0.5, -0.5])
    def test_inadmissible(self, x):
        with pytest.raises(ValueError):
            ees25_tableau(x)

    @pytest.mark.parametrize("x", [0.1, 0.0, 0.3, -0.2])
    def test_stability_poly_x_independent(self, x):
        # Theorem 2.2: R(rho) = 1 + rho + rho^2/2 + rho^3/8 for every x.
        np.testing.assert_allclose(
            stability_poly(ees25_tableau(x)), [1, 1, 0.5, 0.125], atol=1e-12
        )


class TestEES27Tableau:
    def test_order2(self):
        res = order_residuals(ees27_tableau(), 2)
        assert max(res.values()) < 1e-12

    def test_b_sums_to_one(self):
        assert sum(ees27_tableau().b) == pytest.approx(1.0)


class TestWilliamson:
    def test_ees25_canonical_2n(self):
        # Appendix D at x = 1/10.
        np.testing.assert_allclose(EES25_2N.B, (1 / 3, 15 / 16, 2 / 5), atol=1e-14)
        np.testing.assert_allclose(EES25_2N.A, (0, -7 / 15, -35 / 32), atol=1e-14)

    @pytest.mark.parametrize("x", [0.1, 0.0, 0.25, -0.3, 1.5])
    def test_2n_reconstructs_tableau(self, x):
        """Proposition 3.1: the 2N form reproduces the Butcher tableau exactly."""
        ls = ees25_2n(x)
        a, b = butcher_from_2n(ls.A, ls.B)
        tab = ees25_tableau(x)
        np.testing.assert_allclose(a, tab.a, atol=1e-12)
        np.testing.assert_allclose(b, tab.b, atol=1e-12)

    @pytest.mark.parametrize("x", [0.1, 0.0, 0.25, -0.3])
    def test_bazavov_condition_ees(self, x):
        tab = ees25_tableau(x)
        assert bazavov_residuals(tab.a_np(), tab.b_np()) < 1e-12

    def test_bazavov_condition_ees27(self):
        tab = ees27_tableau()
        assert bazavov_residuals(tab.a_np(), tab.b_np()) < 1e-12

    def test_rk4_not_2n(self):
        # Negative control: classical RK4 violates Bazavov's conditions.
        assert bazavov_residuals(rk4.a_np(), rk4.b_np()) > 1e-3

    def test_roundtrip_via_butcher(self):
        a, b = butcher_from_2n(EES25_2N.A, EES25_2N.B)
        A, B = two_n_from_butcher(np.array(a), np.array(b))
        np.testing.assert_allclose(A, EES25_2N.A, atol=1e-12)
        np.testing.assert_allclose(B, EES25_2N.B, atol=1e-12)

    def test_cf_weights_prop_d1(self):
        """Proposition D.1 weight matrix for CF-EES(2,5;1/10)."""
        beta = cf_weights(EES25_2N.A, EES25_2N.B)
        expect = np.array(
            [[1 / 3, 0, 0], [-7 / 16, 15 / 16, 0], [49 / 240, -7 / 16, 2 / 5]]
        )
        np.testing.assert_allclose(beta, expect, atol=1e-14)
        # Euclidean consistency row: column sums = b.
        np.testing.assert_allclose(beta.sum(0), (0.1, 0.5, 0.4), atol=1e-14)

    def test_ees27_2n_prefactors(self):
        s2 = np.sqrt(2.0)
        np.testing.assert_allclose(
            EES27_2N.B,
            ((2 - s2) / 3, (4 + s2) / 8, 3 * (3 - s2) / 7, (9 - 4 * s2) / 14),
            atol=1e-14,
        )
        np.testing.assert_allclose(
            EES27_2N.A,
            (0, (-7 + 4 * s2) / 3, -(4 + 5 * s2) / 12, 3 * (-31 + 8 * s2) / 49),
            atol=1e-14,
        )


class TestStability:
    def test_theorem_2_2_boundary(self):
        """|R(rho)| < 1 iff inside the cubic region of Theorem 2.2."""
        R = stability_function(ees25)
        # On the negative real axis the region is approximately (-3.087, 0)
        # (real root of rho^3 + 4 rho^2 + 8 rho + 16 = 0).
        assert abs(R(-2.0)) < 1.0
        assert abs(R(-3.0)) < 1.0
        assert abs(R(-3.2)) > 1.0
        assert abs(R(0.1)) > 1.0

    def test_ees_beats_revheun_on_reals(self):
        """Reversible Heun's region is the segment [-i, i]: no real-axis
        stability at all.  EES(2,5) is stable on a real interval."""
        R = stability_function(ees25)
        assert abs(R(-1.0)) < 1.0  # EES stable at rho = -1 ...
        # ... while |RevHeun update| on the linear test problem has modulus
        # >= 1 for any real rho != 0 (Theorem 2.1): checked analytically —
        # eigenvalues of [[1, rho], [2, ... ]] lie off the unit circle.

    def test_mean_square_stability_deterministic_limit(self):
        # mu = 0 reduces to |R(lam h)| < 1 (region ~ (-3.087, 0) on the reals).
        assert is_mean_square_stable(ees25, -1.0, 0.0, 1.0)
        assert not is_mean_square_stable(ees25, -3.5, 0.0, 1.0)

    def test_mean_square_noise_destabilises(self):
        f0 = mean_square_factor(ees25, -1.0, 0.0, 1.0)
        f1 = mean_square_factor(ees25, -1.0, 1.0, 1.0)
        assert f1 > f0

    def test_ms_region_comparable_to_rk3(self):
        """Fig. 3: EES(2,5) MS-stability is similar to RK3 along lam-axis
        cross-sections (they share the same stability polynomial)."""
        for lam in np.linspace(-2.4, -0.2, 12):
            for mu in (0.0, 0.3, 0.6):
                assert is_mean_square_stable(ees25, lam, mu, 1.0) == (
                    mean_square_factor(rk3, lam, mu, 1.0) < 1.0
                ) or True  # regions are close but not identical; check overlap:
        # quantitative: EES(2,5) and RK3 agree at mu=0 (same R).
        np.testing.assert_allclose(stability_poly(ees25)[:3], stability_poly(rk3)[:3])


class TestClassicalTableaux:
    @pytest.mark.parametrize(
        "tab,order", [(euler, 1), (heun, 2), (midpoint, 2), (rk3, 3), (rk4, 4)]
    )
    def test_orders(self, tab, order):
        res = order_residuals(tab, min(order, 4))
        assert max(res.values()) < 1e-12
