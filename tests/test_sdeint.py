"""Batched Monte-Carlo engine: solver registry, `sdeint` key-batching
(bitwise vs looped single-trajectory `solve`), adjoint gradient parity across
every registry solver and noise mode, and the fixed-slot sampling engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SDETerm,
    brownian_path,
    get_solver,
    list_solvers,
    parse_solver_spec,
    register_solver,
    sdeint,
    solve,
)
from repro.serving import SDESampleConfig, SDESampleEngine

KEY = jax.random.PRNGKey(0)

PARITY_SOLVERS = ["ees25", "ees27", "reversible_heun", "mcf-rk4"]
NOISE_MODES = ["none", "diagonal", "general"]


def ou_term(noise: str, d: int = 3, m: int = 2) -> SDETerm:
    """Small OU-type problem in each noise mode, parameterised by args."""
    drift = lambda t, y, a: a["nu"] * (a["mu"] - y)
    if noise == "none":
        return SDETerm(drift=drift, noise="none")
    if noise == "diagonal":
        diff = lambda t, y, a: a["sigma"] * (1.0 + 0.1 * jnp.tanh(y))
        return SDETerm(drift=drift, diffusion=diff, noise="diagonal")
    diff = lambda t, y, a: a["sigma"] * jnp.ones(y.shape + (m,), y.dtype)
    return SDETerm(drift=drift, diffusion=diff, noise="general")


ARGS = {
    "nu": jnp.float64(0.7),
    "mu": jnp.float64(0.2),
    "sigma": jnp.float64(0.4),
}


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_expected_names_present(self):
        names = list_solvers()
        for want in ("ees25", "ees27", "reversible-heun", "mcf-rk4",
                     "mcf-euler", "euler", "heun", "midpoint", "rk4"):
            assert want in names, names

    def test_spec_parsing(self):
        assert parse_solver_spec("ees25") == ("ees25", {})
        assert parse_solver_spec("ees25:x=0.3") == ("ees25", {"x": 0.3})
        assert parse_solver_spec("MCF-RK4: lam=0.99") == ("mcf-rk4", {"lam": 0.99})
        name, kw = parse_solver_spec("reversible_heun")
        assert name == "reversible-heun" and kw == {}

    def test_family_parameter_reaches_solver(self):
        canonical = get_solver("ees25")
        member = get_solver("ees25:x=0.3")
        assert canonical.ls.A != member.ls.A  # different 2N coefficients
        assert get_solver("mcf-rk4:lam=0.99").lam == 0.99

    def test_solver_objects_pass_through(self):
        s = get_solver("ees27")
        assert get_solver(s) is s

    def test_overrides_rejected_for_solver_objects(self):
        s = get_solver("ees27")
        with pytest.raises(ValueError, match="overrides"):
            get_solver(s, use_kernel=True)

    def test_unknown_name_lists_options(self):
        with pytest.raises(KeyError, match="ees25"):
            get_solver("no_such_scheme")

    def test_malformed_spec_raises(self):
        with pytest.raises(ValueError, match="key=value"):
            get_solver("ees25:0.3")

    def test_register_decorator_and_override(self):
        @register_solver("test-dummy")
        def make(scale=2.0):
            return ("dummy", scale)

        assert get_solver("test-dummy") == ("dummy", 2.0)
        assert get_solver("test-dummy:scale=5") == ("dummy", 5)
        assert get_solver("test-dummy", scale=7) == ("dummy", 7)

    def test_kind_filter(self):
        assert "ees25" in list_solvers(kind="euclidean")
        assert "cfees25" in list_solvers(kind="manifold")
        assert "cfees25" not in list_solvers(kind="euclidean")

    @pytest.mark.parametrize("spec", sorted(
        s for s in list_solvers() if not s.startswith("test-")))
    def test_every_registry_solver_steps_and_reverses(self, spec):
        """reverse(step(state)) ~ state for every registered solver: exact for
        algebraically reversible schemes, O(dX^{p+1}) for plain RK — the
        Brownian component makes that O(h) for Euler, so h is kept tiny."""
        if spec in list_solvers(kind="manifold"):
            from repro.core import ManifoldSDETerm, Torus

            term = ManifoldSDETerm(
                group=Torus(),
                drift=lambda t, y, a: a["nu"] * jnp.sin(y),
                diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
                noise="diagonal",
            )
        else:
            term = ou_term("diagonal")
        solver = get_solver(spec)
        y0 = jnp.array([0.4, -1.1, 0.8], dtype=jnp.float64)
        h = 1e-4
        dW = jnp.sqrt(h) * jax.random.normal(KEY, y0.shape, jnp.float64)
        if getattr(solver, "needs_levy_area", False):
            # Levy-augmented solvers (SRA1) validate noise="additive" at init
            # and step on the (dW, dH) driver pair.
            term = SDETerm(
                drift=term.drift,
                diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
                noise="additive")
            dH = jnp.sqrt(h / 12.0) * jax.random.normal(
                jax.random.fold_in(KEY, 1), y0.shape, jnp.float64)
            dW = (dW, dH)
        state = solver.init(term, 0.0, y0, ARGS)
        s1 = solver.step(term, state, 0.0, h, dW, ARGS)
        s0 = solver.reverse(term, s1, 0.0, h, dW, ARGS)
        moved = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree_util.tree_leaves(s1),
                            jax.tree_util.tree_leaves(state))
        )
        assert moved > 1e-6  # the step must actually do something
        tol = 1e-12 if solver.is_reversible else 1e-4
        for a, b in zip(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(state)):
            np.testing.assert_allclose(a, b, atol=tol)


# ---------------------------------------------------------------------------
# sdeint: batching semantics.
# ---------------------------------------------------------------------------

class TestSdeintBatching:
    @pytest.mark.parametrize("spec", ["ees25", "reversible_heun", "mcf-rk4"])
    def test_batch_bitwise_equals_looped_solve(self, spec):
        """`batch_keys` fan-out is bitwise identical to a Python loop of
        single-trajectory `solve` calls over the same keys."""
        term = ou_term("diagonal")
        y0 = jnp.ones(3, jnp.float64)
        keys = jax.random.split(KEY, 5)
        rb = sdeint(term, spec, 0.0, 1.0, 16, y0, None, args=ARGS,
                    save_every=4, batch_keys=keys)
        solver = get_solver(spec)
        for i in range(5):
            bm = brownian_path(keys[i], 0.0, 1.0, 16, shape=(3,),
                               dtype=jnp.float64)
            ri = solve(solver, term, y0, bm, ARGS, save_every=4)
            np.testing.assert_array_equal(np.asarray(rb.y_final[i]),
                                          np.asarray(ri.y_final))
            np.testing.assert_array_equal(np.asarray(rb.ys[i]),
                                          np.asarray(ri.ys))

    def test_single_key_equals_solve(self):
        term = ou_term("diagonal")
        y0 = jnp.ones(3, jnp.float64)
        r = sdeint(term, "ees25", 0.0, 1.0, 16, y0, KEY, args=ARGS)
        bm = brownian_path(KEY, 0.0, 1.0, 16, shape=(3,), dtype=jnp.float64)
        ref = solve(get_solver("ees25"), term, y0, bm, ARGS)
        np.testing.assert_array_equal(np.asarray(r.y_final),
                                      np.asarray(ref.y_final))

    def test_general_noise_requires_noise_shape(self):
        term = ou_term("general")
        with pytest.raises(ValueError, match="noise_shape"):
            sdeint(term, "ees25", 0.0, 1.0, 8, jnp.ones(3), KEY, args=ARGS)

    def test_general_noise_batch_shapes(self):
        term = ou_term("general", m=2)
        keys = jax.random.split(KEY, 4)
        r = sdeint(term, "ees25", 0.0, 1.0, 8, jnp.ones(3, jnp.float64), None,
                   args=ARGS, noise_shape=(2,), batch_keys=keys)
        assert r.y_final.shape == (4, 3)

    def test_missing_key_raises(self):
        with pytest.raises(ValueError, match="key"):
            sdeint(ou_term("none"), "euler", 0.0, 1.0, 8, jnp.ones(2))

    def test_mesh_without_batch_keys_raises(self):
        with pytest.raises(ValueError, match="batch_keys"):
            sdeint(ou_term("none"), "euler", 0.0, 1.0, 8, jnp.ones(2), KEY,
                   mesh_axis="data")

    def test_mesh_without_axis_raises(self):
        with pytest.raises(ValueError, match="mesh_axis"):
            sdeint(ou_term("none"), "euler", 0.0, 1.0, 8, jnp.ones(2), None,
                   batch_keys=jax.random.split(KEY, 2), mesh=object())

    def test_pytree_state_diagonal_noise(self):
        """Noise-shape inference follows the state pytree (product states)."""
        term = SDETerm(
            drift=lambda t, y, a: (-y[0], -0.5 * y[1]),
            diffusion=lambda t, y, a: (0.1 * jnp.ones_like(y[0]),
                                       0.2 * jnp.ones_like(y[1])),
            noise="diagonal",
        )
        y0 = (jnp.ones(3), jnp.ones(5))
        keys = jax.random.split(KEY, 2)
        r = sdeint(term, "ees25", 0.0, 1.0, 8, y0, None, batch_keys=keys)
        assert r.y_final[0].shape == (2, 3) and r.y_final[1].shape == (2, 5)


# ---------------------------------------------------------------------------
# Adjoint gradient parity: every solver x every noise mode.
# ---------------------------------------------------------------------------

class TestAdjointParity:
    @pytest.mark.parametrize("noise", NOISE_MODES)
    @pytest.mark.parametrize("spec", PARITY_SOLVERS)
    def test_reversible_matches_full(self, spec, noise):
        """adjoint="reversible" gradients agree with adjoint="full" on a small
        OU-type problem, for every registry solver and noise structure."""
        term = ou_term(noise)
        noise_shape = (2,) if noise == "general" else None
        y0 = jnp.ones(3, jnp.float64)

        def loss(a, adjoint):
            r = sdeint(term, spec, 0.0, 1.0, 24, y0, KEY, args=a,
                       adjoint=adjoint, save_every=8, noise_shape=noise_shape)
            return jnp.sum(r.y_final ** 2) + jnp.sum(r.ys ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "reversible"))(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-5, atol=1e-12)

    def test_batched_reversible_matches_full(self):
        """Gradient parity survives the vmap fan-out (the training path)."""
        term = ou_term("diagonal")
        y0 = jnp.ones(3, jnp.float64)
        keys = jax.random.split(KEY, 4)

        def loss(a, adjoint):
            r = sdeint(term, "ees25", 0.0, 1.0, 16, y0, None, args=a,
                       adjoint=adjoint, batch_keys=keys)
            return jnp.mean(r.y_final ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "reversible"))(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-6)

    def test_recursive_matches_full_batched(self):
        term = ou_term("diagonal")
        y0 = jnp.ones(3, jnp.float64)
        keys = jax.random.split(KEY, 3)

        def loss(a, adjoint):
            r = sdeint(term, "ees27", 0.0, 1.0, 16, y0, None, args=a,
                       adjoint=adjoint, batch_keys=keys)
            return jnp.mean(r.y_final ** 2)

        gf = jax.grad(lambda a: loss(a, "full"))(ARGS)
        gr = jax.grad(lambda a: loss(a, "recursive"))(ARGS)
        for k in ARGS:
            np.testing.assert_allclose(gf[k], gr[k], rtol=1e-10)


# ---------------------------------------------------------------------------
# Fixed-grid Brownian driver: cached prefix-sum path.
# ---------------------------------------------------------------------------

class TestBrownianPathCache:
    def test_cached_and_uncached_queries_bitwise_equal(self):
        """increment_over realizes the prefix-sum path once per driver; the
        cached re-query and a fresh (uncached) driver's query must return
        the exact same bits."""
        bm = brownian_path(KEY, 0.0, 1.0, 64, shape=(5,), dtype=jnp.float64)
        first = np.asarray(bm.increment_over(0.25, 0.875))
        assert bm._path_cache is not None  # realized and kept
        again = np.asarray(bm.increment_over(0.25, 0.875))
        uncached = np.asarray(
            brownian_path(KEY, 0.0, 1.0, 64, shape=(5,),
                          dtype=jnp.float64).increment_over(0.25, 0.875)
        )
        np.testing.assert_array_equal(first, again)
        np.testing.assert_array_equal(first, uncached)
        # and the window is consistent with the per-step increments
        manual = sum(np.asarray(bm.increment(n)) for n in range(16, 56))
        np.testing.assert_allclose(first, manual, rtol=1e-12)

    def test_cache_never_captures_tracers(self):
        """A concrete driver queried inside jit must not cache the traced
        path (it would leak into later traces); traced instances rebuilt by
        tree_unflatten start cacheless."""
        bm = brownian_path(KEY, 0.0, 1.0, 16, shape=(3,))
        jax.jit(lambda s: bm.increment_over(s, 1.0))(0.5)
        assert bm._path_cache is None or not any(
            isinstance(l, jax.core.Tracer)
            for l in jax.tree_util.tree_leaves(bm._path_cache)
        )
        jax.jit(lambda s: bm.increment_over(s, 1.0))(0.25)  # fresh trace: no leak
        roundtrip = jax.tree_util.tree_unflatten(
            *reversed(jax.tree_util.tree_flatten(bm))
        )
        assert roundtrip._path_cache is None


# ---------------------------------------------------------------------------
# Fixed-slot sampling engine.
# ---------------------------------------------------------------------------

def engine_term() -> SDETerm:
    return SDETerm(
        drift=lambda t, y, a: -0.5 * y,
        diffusion=lambda t, y, a: 0.2 * jnp.ones_like(y),
        noise="diagonal",
    )


class TestSDESampleEngine:
    def test_serves_mixed_requests(self):
        eng = SDESampleEngine(engine_term(), jnp.ones(3), SDESampleConfig(slots=4))
        r1 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6)
        r2 = eng.submit("reversible_heun", t1=1.0, n_steps=8, n_paths=3,
                        save_every=4)
        r3 = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=2)
        done = eng.run()
        assert sorted(done) == [r1, r2, r3]
        assert done[r1].y_final.shape == (6, 3) and done[r1].ys is None
        assert done[r2].y_final.shape == (3, 3)
        assert done[r2].ys.shape == (3, 2, 3)
        assert done[r3].y_final.shape == (2, 3)
        assert np.isfinite(done[r1].y_final).all()

    def test_results_reproducible_offline(self):
        """Request paths equal a direct sdeint over fold_in(PRNGKey(seed), i)
        — slot assignment and tick boundaries leave no trace."""
        eng = SDESampleEngine(engine_term(), jnp.ones(3), SDESampleConfig(slots=4))
        rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=6, seed=7)
        done = eng.run()
        keys = jnp.stack(
            [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(6)]
        )
        # dtype pinned to the engine's (the suite runs with x64 enabled, so
        # inference would otherwise draw float64 increments — different bits)
        ref = sdeint(engine_term(), "ees25", 0.0, 1.0, 8, jnp.ones(3), None,
                     batch_keys=keys, dtype=jnp.float32)
        np.testing.assert_array_equal(done[rid].y_final,
                                      np.asarray(ref.y_final))

    def test_slot_count_does_not_change_samples(self):
        outs = []
        for slots in (2, 16):
            eng = SDESampleEngine(engine_term(), jnp.ones(3),
                                  SDESampleConfig(slots=slots))
            rid = eng.submit("ees25", t1=1.0, n_steps=8, n_paths=5, seed=3)
            outs.append(eng.run()[rid].y_final)
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_compiles_once_per_signature(self):
        eng = SDESampleEngine(engine_term(), jnp.ones(3), SDESampleConfig(slots=2))
        for _ in range(3):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=3)
        eng.submit("ees25", t1=2.0, n_steps=8, n_paths=1)  # new horizon
        eng.run()
        assert len(eng._compiled) == 2

    def test_idle_engine_reports_idle(self):
        eng = SDESampleEngine(engine_term(), jnp.ones(3))
        assert eng.tick() is False
        assert eng.run() == {}

    def test_bad_requests_rejected_at_submit(self):
        """Bad specs fail at submit(), not at the queue head where they would
        block every request behind them."""
        eng = SDESampleEngine(engine_term(), jnp.ones(3))
        with pytest.raises(KeyError, match="unknown solver"):
            eng.submit("ees2", t1=1.0, n_steps=8, n_paths=1)
        with pytest.raises(ValueError, match="save_every"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1, save_every=3)
        with pytest.raises(ValueError, match="n_paths"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=0)
        with pytest.raises(ValueError, match="manifold"):
            eng.submit("geo-em", t1=1.0, n_steps=8, n_paths=1)
        with pytest.raises(ValueError, match="save_every"):
            eng.submit("ees25", t1=1.0, n_steps=8, n_paths=1, save_every=4.7)
        assert not eng.queue  # nothing poisoned the queue

    def test_equivalent_spellings_share_signature_and_executable(self):
        eng = SDESampleEngine(engine_term(), jnp.ones(3), SDESampleConfig(slots=4))
        a = eng.submit("reversible_heun", t1=1.0, n_steps=8, n_paths=2, seed=0)
        b = eng.submit("Reversible-Heun", t1=1.0, n_steps=8, n_paths=2, seed=0)
        done = eng.run()
        assert len(eng._compiled) == 1  # one canonical signature
        np.testing.assert_array_equal(done[a].y_final, done[b].y_final)

    def test_exhausted_max_ticks_raises(self):
        eng = SDESampleEngine(engine_term(), jnp.ones(3), SDESampleConfig(slots=1))
        eng.submit("ees25", t1=1.0, n_steps=8, n_paths=3)
        with pytest.raises(RuntimeError, match="max_ticks"):
            eng.run(max_ticks=2)
