"""Adaptive EES (embedded estimator, Appendix D) + launch-layer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EES25_2N, SDETerm
from repro.core.adaptive import integrate_adaptive, step_with_error
from repro.launch.roofline import (
    _shape_bytes,
    collective_bytes,
    memory_summary,
)


class TestEmbeddedEstimator:
    def test_error_estimate_tracks_true_error(self):
        """The embedded (2,1) pair's estimate correlates with the true local
        error across step sizes (order gap of 1)."""
        term = SDETerm(drift=lambda t, y, a: jnp.sin(y) + 0.2 * y, noise="none")
        y0 = jnp.array([0.7, -0.3], dtype=jnp.float64)

        def true_err(h):
            fine = y0
            for i in range(64):
                fine, _ = step_with_error(EES25_2N, term, fine, i * h / 64, h / 64, None, None)
            coarse, est = step_with_error(EES25_2N, term, y0, 0.0, h, None, None)
            return (
                float(jnp.max(jnp.abs(coarse - fine))),
                float(jnp.max(jnp.abs(est))),
            )

        for h in (0.2, 0.1, 0.05):
            true, est = true_err(h)
            # estimate is first-order-gap: bounds the true error within ~20x
            assert est > true * 0.05, (h, true, est)
            assert est < max(true * 200, 1e-8), (h, true, est)

    def test_estimate_scales_quadratically(self):
        """Embedded estimate ~ O(h^2) (difference of order-2 and order-1)."""
        term = SDETerm(drift=lambda t, y, a: jnp.cos(y), noise="none")
        y0 = jnp.array([0.3], dtype=jnp.float64)
        ests = []
        hs = [0.2, 0.1, 0.05]
        for h in hs:
            _, est = step_with_error(EES25_2N, term, y0, 0.0, h, None, None)
            ests.append(float(jnp.abs(est[0])))
        slope = np.polyfit(np.log(hs), np.log(ests), 1)[0]
        assert 1.5 < slope < 3.0, (slope, ests)

    def test_adaptive_integration_accuracy(self):
        """Adaptive EES on y' = -5y hits the analytic solution."""
        term = SDETerm(drift=lambda t, y, a: -5.0 * y, noise="none")
        y0 = jnp.array([1.0], dtype=jnp.float64)
        out = integrate_adaptive(EES25_2N, term, y0, None, t0=0.0, t1=1.0,
                                 rtol=1e-6, atol=1e-9, max_steps=4096,
                                 bounded=False)
        assert float(out.t_final) == pytest.approx(1.0)
        np.testing.assert_allclose(float(out.y_final[0]), np.exp(-5.0), rtol=1e-4)
        assert int(out.n_accepted) > 5

    def test_adaptive_rejects_on_stiffness(self):
        """A stiff segment must trigger rejections / smaller steps."""
        term = SDETerm(
            drift=lambda t, y, a: jnp.where(t > 0.5, -200.0, -1.0) * y, noise="none"
        )
        y0 = jnp.array([1.0], dtype=jnp.float64)
        out = integrate_adaptive(EES25_2N, term, y0, None, t0=0.0, t1=1.0,
                                 h0=0.2, rtol=1e-5, max_steps=4096,
                                 bounded=False)
        assert int(out.n_rejected) >= 1
        assert float(out.h_final) < 0.05  # controller shrank into stability


class TestRooflineParsers:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
        assert _shape_bytes("f32[8]") == 32
        assert _shape_bytes("(f32[4,4]{1,0}, bf16[2,2]{1,0})") == 64 + 8
        assert _shape_bytes("pred[]") == 1

    def test_collective_bytes_counts_kinds(self):
        hlo = """
ENTRY %main.1 (p: f32[4]) -> f32[4] {
  %ar = f32[4]{0} all-reduce(%p), replica_groups={}
  %ag = bf16[8,2]{1,0} all-gather(%x), dimensions={0}
  %t = (s32[], f32[4]) tuple(%c, %ar)
  ROOT %r = f32[4]{0} add(%ar, %ar)
}
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 16
        assert out["all-gather"] == 32
        assert out["count"] == 2

    def test_structured_respects_trip_count(self):
        from repro.launch.roofline import collective_bytes_structured

        hlo = """
HloModule m

%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%gte), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main.2 (p: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[2]{0} all-gather(%x), dimensions={0}
  ROOT %r = f32[4]{0} get-tuple-element(%w), index=1
}
"""
        total = collective_bytes_structured(hlo)
        assert total == 7 * 16 + 8, total


class TestLaunchHelpers:
    def test_input_specs_all_cells(self):
        from repro.configs import ALL_SHAPES, cell_applicable, get_arch, list_archs
        from repro.launch.dryrun import input_specs

        for arch in list_archs():
            for shape in ALL_SHAPES:
                ok, _ = cell_applicable(get_arch(arch), shape)
                if not ok:
                    continue
                specs = input_specs(arch, shape.name)
                assert specs, (arch, shape.name)
                for leaf in jax.tree_util.tree_leaves(specs):
                    assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_microbatches_divide_local_batch(self):
        from repro.configs import ALL_SHAPES, get_arch, list_archs
        from repro.launch.dryrun import microbatches_for

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        for arch in list_archs():
            cfg = get_arch(arch)
            for shape in ALL_SHAPES:
                if shape.kind != "train":
                    continue
                mb = microbatches_for(cfg, shape, FakeMesh())
                b_loc = shape.global_batch // 16
                assert b_loc % mb == 0, (arch, mb, b_loc)

    def test_model_flops(self):
        from repro.configs import get_arch, get_shape
        from repro.launch.dryrun import model_flops_for

        cfg = get_arch("yi-9b")
        train = model_flops_for(cfg, get_shape("train_4k"))
        # 6 N D with N=8.83B, D=256*4096 tokens
        assert train == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
        decode = model_flops_for(cfg, get_shape("decode_32k"))
        assert decode == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
