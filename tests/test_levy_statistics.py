"""Space-time Levy-area statistics for both Brownian drivers, tier-1.

The Levy-augmented queries added for the SRK solvers must (a) have the right
law — ``DH ~ N(0, h/12)``, independent of the matching ``DW`` — (b) be pure
functions of their inputs (bitwise re-query determinism, bulk == per-step
row-for-row, consistency between a direct interval query and any grid that
contains that interval as a step), and (c) be *additions*: drawing areas from
the salted key family (``_LEVY_SALT``) must leave the ``W`` stream untouched
to the bit.

Moment checks are seeded Monte-Carlo over a few thousand keys with 4-sigma
acceptance bands, so they are deterministic in CI.  The determinism
properties additionally run under hypothesis when it is installed (random
query intervals/seeds), with a seeded fallback sweep sharing the same case
generator so the default lane needs no optional dependency — the same idiom
as ``test_scheduler_properties.py``.
"""
import random

import numpy as np
import pytest

pytest.importorskip("jax")

import jax
import jax.numpy as jnp

from repro.core.brownian import brownian_path, virtual_brownian_tree
from repro.core.grid import TimeGrid

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container lane: the seeded sweep below still runs
    HAVE_HYPOTHESIS = False

FALLBACK_SEEDS = range(60)
N_KEYS = 4096  # moment-check sample size: sigma(sample var) ~ 2%


def _keys(n=N_KEYS, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _case(rng: random.Random):
    """One random determinism case: an interval inside [0, 1] and a seed."""
    s = rng.uniform(0.0, 0.9)
    t = s + rng.uniform(0.01, 1.0 - s)
    return s, t, rng.randrange(2 ** 16)


# ---------------------------------------------------------------------------
# Law: moments, variance scaling, (dW, dH) independence.
# ---------------------------------------------------------------------------


class TestLaw:
    @pytest.mark.parametrize("h", [0.25, 1.0 / 64.0])
    def test_path_levy_moments(self, h):
        n_steps = int(round(1.0 / h))
        dh = jax.vmap(lambda k: brownian_path(
            k, 0.0, 1.0, n_steps, (), jnp.float64).levy_area_step(0))(_keys())
        dh = np.asarray(dh)
        band = 4.0 * np.sqrt(h / 12.0) / np.sqrt(N_KEYS)
        assert abs(dh.mean()) < band, (dh.mean(), band)
        np.testing.assert_allclose(dh.var(), h / 12.0, rtol=0.1)

    def test_tree_levy_moments(self):
        s, t = 0.25, 0.75
        h = t - s
        dh = jax.vmap(lambda k: virtual_brownian_tree(
            k, 0.0, 1.0, (), jnp.float64).levy_area(s, t))(_keys(seed=1))
        dh = np.asarray(dh)
        band = 4.0 * np.sqrt(h / 12.0) / np.sqrt(N_KEYS)
        assert abs(dh.mean()) < band, (dh.mean(), band)
        np.testing.assert_allclose(dh.var(), h / 12.0, rtol=0.1)

    def test_path_levy_independent_of_increment(self):
        """corr(dW, dH) over one step ~ 0 (they come from disjoint key
        families); 4/sqrt(N) acceptance band on the sample correlation."""
        def one(k):
            bm = brownian_path(k, 0.0, 1.0, 4, (), jnp.float64)
            return bm.increment(2), bm.levy_area_step(2)
        dw, dh = jax.vmap(one)(_keys(seed=2))
        dw, dh = np.asarray(dw), np.asarray(dh)
        rho = np.corrcoef(dw, dh)[0, 1]
        assert abs(rho) < 4.0 / np.sqrt(N_KEYS), rho

    def test_tree_levy_independent_of_increment(self):
        def one(k):
            bm = virtual_brownian_tree(k, 0.0, 1.0, (), jnp.float64)
            return bm.increment_over(0.5, 0.75), bm.levy_area(0.5, 0.75)
        dw, dh = jax.vmap(one)(_keys(seed=3))
        dw, dh = np.asarray(dw), np.asarray(dh)
        rho = np.corrcoef(dw, dh)[0, 1]
        assert abs(rho) < 4.0 / np.sqrt(N_KEYS), rho

    def test_steps_are_mutually_independent(self):
        """Areas of different steps come from different fold_in counters."""
        def one(k):
            bm = brownian_path(k, 0.0, 1.0, 4, (), jnp.float64)
            return bm.levy_area_step(0), bm.levy_area_step(3)
        a, b = jax.vmap(one)(_keys(seed=4))
        rho = np.corrcoef(np.asarray(a), np.asarray(b))[0, 1]
        assert abs(rho) < 4.0 / np.sqrt(N_KEYS), rho


# ---------------------------------------------------------------------------
# Purity: re-query determinism, bulk == per-step, grid/interval consistency,
# and the W stream staying untouched.
# ---------------------------------------------------------------------------


class TestDeterminism:
    def _check_case(self, s, t, seed):
        bm = virtual_brownian_tree(jax.random.PRNGKey(seed), 0.0, 1.0, (),
                                   jnp.float64)
        a = np.asarray(bm.levy_area(s, t))
        b = np.asarray(bm.levy_area(s, t))
        np.testing.assert_array_equal(a, b)
        dw, dh = bm.levy_increment_over(s, t)
        np.testing.assert_array_equal(np.asarray(dw),
                                      np.asarray(bm.increment_over(s, t)))
        np.testing.assert_array_equal(np.asarray(dh), a)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=40, deadline=None)
        @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
        def test_requery_bitwise_hypothesis(self, case_seed):
            self._check_case(*_case(random.Random(case_seed)))

    def test_requery_bitwise_seeded_sweep(self):
        for seed in FALLBACK_SEEDS:
            self._check_case(*_case(random.Random(seed)))

    def test_path_bulk_matches_per_step(self):
        bm = brownian_path(jax.random.PRNGKey(5), 0.0, 2.0, 16, (3,),
                           jnp.float64)
        grid = TimeGrid.uniform(0.0, 2.0, 16, driver=bm)
        # Bit-stability is a *compiled-computation* property (the bulk pass
        # runs under its own jit so its bits cannot depend on the calling
        # context) — compare against the jitted per-step query, which is what
        # every solve's scan body actually runs (same precedent as
        # test_fused_step.TestBulkIncrements).
        dWs, dHs = bm.grid_levy_increments(grid.ts)
        per_step = jax.jit(lambda n: bm.grid_levy_increment(grid.ts, n))
        for n in range(16):
            dw, dh = per_step(n)
            np.testing.assert_array_equal(np.asarray(dWs[n]), np.asarray(dw))
            np.testing.assert_array_equal(np.asarray(dHs[n]), np.asarray(dh))

    def test_tree_bulk_matches_per_step(self):
        bm = virtual_brownian_tree(jax.random.PRNGKey(6), 0.0, 1.0, (2,),
                                   jnp.float64)
        grid = TimeGrid.uniform(0.0, 1.0, 8, driver=bm)
        dWs, dHs = bm.grid_levy_increments(grid.ts)
        per_step = jax.jit(lambda n: bm.grid_levy_increment(grid.ts, n))
        for n in range(8):
            dw, dh = per_step(n)
            np.testing.assert_array_equal(np.asarray(dWs[n]), np.asarray(dw))
            np.testing.assert_array_equal(np.asarray(dHs[n]), np.asarray(dh))

    def test_grid_levy_matches_timegrid_accessors(self):
        """TimeGrid.levy_increment(s) — what the solve loop consumes — are
        the driver queries, bit for bit."""
        bm = brownian_path(jax.random.PRNGKey(7), 0.0, 1.0, 8, (2,),
                           jnp.float64)
        grid = TimeGrid.uniform(0.0, 1.0, 8, driver=bm)
        dWs, dHs = grid.levy_increments()
        per_step = jax.jit(lambda n: grid.levy_increment(n))
        for n in range(8):
            dw, dh = per_step(n)
            np.testing.assert_array_equal(np.asarray(dWs[n]), np.asarray(dw))
            np.testing.assert_array_equal(np.asarray(dHs[n]), np.asarray(dh))

    def test_interval_query_matches_grid_step(self):
        """A direct levy_area(s, t) equals the same interval queried as a
        step of ANY grid (the draw is keyed on quantized endpoints)."""
        bm = virtual_brownian_tree(jax.random.PRNGKey(8), 0.0, 1.0, (),
                                   jnp.float64)
        ts = jnp.linspace(0.0, 1.0, 17)
        for n in (0, 5, 15):
            direct = bm.levy_area(ts[n], ts[n + 1])
            via_grid = bm.grid_levy_increment(ts, n)[1]
            np.testing.assert_array_equal(np.asarray(direct),
                                          np.asarray(via_grid))

    def test_levy_queries_leave_w_stream_untouched(self):
        """The salted key family must not perturb a single W bit: the dWs
        component of the Levy-augmented bulk realization equals the plain
        bulk realization, and per-step increments are unchanged after area
        queries."""
        bm = brownian_path(jax.random.PRNGKey(9), 0.0, 1.0, 12, (4,),
                           jnp.float64)
        ts = jnp.linspace(0.0, 1.0, 13)
        plain = np.asarray(bm.grid_increments(ts))
        dWs, _ = bm.grid_levy_increments(ts)
        np.testing.assert_array_equal(np.asarray(dWs), plain)
        _ = bm.levy_area_step(3)
        np.testing.assert_array_equal(np.asarray(jax.jit(bm.increment)(3)),
                                      plain[3])

        vbt = virtual_brownian_tree(jax.random.PRNGKey(10), 0.0, 1.0, (2,),
                                    jnp.float64)
        w_before = np.asarray(vbt.weval(0.625))
        _ = vbt.levy_area(0.5, 0.625)
        np.testing.assert_array_equal(np.asarray(vbt.weval(0.625)), w_before)
        dWs_t, _ = vbt.grid_levy_increments(ts)
        np.testing.assert_array_equal(np.asarray(dWs_t),
                                      np.asarray(vbt.grid_increments(ts)))
