"""Benchmark-JSON gate checker: one entrypoint for every BENCH artifact.

CI used to carry an inline ``python -c`` snippet per benchmark; those gates
now live here, unchanged, keyed by file basename.  Each checker raises
``AssertionError`` (with the offending payload) on a regression, so the CI
step fails exactly as the inline snippets did.

Run:  python tools/check_bench.py --file bench.json --file bench_serving.json
      python tools/check_bench.py --file BENCH_training.json

Dispatch (substring of the basename, first match wins):
  bench.json / *throughput*  batched-sampling speedup records present
  *serving*                  drain sweep + (when present) load/bucketing gates
  *kernels*                  fused step-kernel record count
  *stability*                EES25 frontier finite and >= reversible-heun
  *rev(ersible)_adaptive*    adjoint zoo presence, grad parity, memory win
  *adaptive*                 adaptive & fixed record groups present
  *resilience*               delegated to benchmarks.bench_resilience.check
  *training*                 scanned-step speedup + DP bitwise parity (PR 10)
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (REPO, os.path.join(REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def check_throughput(d: dict) -> None:
    r = d["records"]
    assert len(r) >= 6, r
    assert all("speedup_bulk" in x for x in r), r


def check_serving(d: dict) -> None:
    r = d["records"]
    assert len(r) >= 6, r
    depths = {x["queue_depth"] for x in r}
    assert len(depths) >= 3 and all("requests_per_sec" in x for x in r), r
    multi = [x for x in r if x["ticks_per_dispatch"] > 1]
    assert multi and all(x["host_dispatches"] < x["n_ticks"] for x in multi), r
    single = [x for x in r if x["ticks_per_dispatch"] == 1]
    assert all(x["host_dispatches"] == x["n_ticks"] for x in single), r
    # bench_load merges its sections into the same JSON; gate them when there.
    if "load" in d:
        load = d["load"]
        for k in ("p50_ms", "p99_ms", "saturation_rps"):
            assert k in load and math.isfinite(load[k]) and load[k] > 0, load
        assert load["p50_ms"] <= load["p99_ms"], load
        assert load["dispatches_per_tick"] <= 1.0, load
        assert d["records"], d  # load section merged, drain sweep intact
    if "bucketing" in d:
        b = d["bucketing"]
        assert b["n_executables_bucketed"] <= b["n_buckets"] < b["n_signatures"], b
        assert b["n_executables_unbucketed"] == b["n_signatures"], b
        assert b["saturation_rps_bucketed"] > 0 and b["saturation_rps_unbucketed"] > 0, b
        assert b["warm_compile_s"] < b["cold_compile_s"], b


def check_kernels(d: dict) -> None:
    r = d["records"]
    assert len(r) >= 12, r


def check_stability(d: dict) -> None:
    fr = d["frontiers"]
    assert d["records"], d
    for lam in (f"{s:g}" for s in d["stiffness"]):
        ees = fr["ees25"][lam]["max_stable_h"]
        rh = fr["reversible-heun"][lam]["max_stable_h"]
        assert math.isfinite(ees) and ees > 0, (lam, ees)
        assert ees >= rh, (lam, ees, rh)


def check_rev_adaptive(d: dict) -> None:
    r = {x["adjoint"]: x for x in d["records"]}
    assert {"full", "recursive", "reversible", "reversible-bulk"} <= set(r), r
    assert r["reversible"]["grad_rel_err_vs_full"] < 1e-3, r
    assert r["reversible"]["temp_bytes"] < r["full"]["temp_bytes"], r


def check_adaptive(d: dict) -> None:
    r = d["records"]
    assert r["adaptive"] and r["fixed"], r


def check_resilience(d: dict) -> None:
    from benchmarks.bench_resilience import check

    check(d)


def check_training(d: dict) -> None:
    r = d["records"]
    assert r, d
    num_keys = ("us_per_step_sequential", "us_per_step_scanned",
                "steps_per_sec_sequential", "steps_per_sec_scanned",
                "speedup_scan")
    for x in r:
        for k in num_keys:
            assert k in x and math.isfinite(x[k]) and x[k] > 0, (k, x)
    # On CPU the scanned chunk must beat K host-threaded dispatches at the
    # largest K (the tentpole claim); tiny configs can be compute-bound at
    # low K, so the gate is on the best K-max record, not every record.
    k_max = max(x["steps_per_call"] for x in r)
    assert math.isfinite(d["speedup_scan_k8"]), d["speedup_scan_k8"]
    if d.get("device") == "cpu":
        assert d["speedup_scan_k8"] > 1, [
            x for x in r if x["steps_per_call"] == k_max]
    # Sharded DP must match the single-device trajectory bitwise whenever the
    # ladder ran (devices > 1; empty on single-device CI).
    for m in d.get("mesh_records", []):
        assert m["grads_bitwise_vs_single"], m


CHECKS = (
    ("throughput", check_throughput),
    ("serving", check_serving),
    ("kernels", check_kernels),
    ("stability", check_stability),
    ("rev_adaptive", check_rev_adaptive),
    ("reversible_adaptive", check_rev_adaptive),
    ("adaptive", check_adaptive),
    ("resilience", check_resilience),
    ("training", check_training),
)


def checker_for(path: str):
    base = os.path.basename(path).lower()
    if base == "bench.json":
        return check_throughput
    for key, fn in CHECKS:
        if key in base:
            return fn
    raise SystemExit(f"no gate registered for {path!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", action="append", required=True, dest="files",
                    help="benchmark JSON to gate (repeatable)")
    args = ap.parse_args(argv)
    for path in args.files:
        with open(path) as f:
            data = json.load(f)
        fn = checker_for(path)
        fn(data)
        print(f"OK {path} [{fn.__name__}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
