"""Execute every ```python block in README.md and docs/*.md.

The docs lane of CI runs this so quickstarts can never rot: each markdown
file's blocks are concatenated (in order, so later blocks may use earlier
definitions) into one script and run in a fresh subprocess with
``PYTHONPATH=src`` and 8 faked XLA host devices (the multi-device fan-out
examples need a mesh; everything else ignores it).

Run:  python tools/run_doc_examples.py [files...]
Exit status is non-zero if any file's blocks fail.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BLOCK = re.compile(r"^```python\s*$(.*?)^```\s*$", re.MULTILINE | re.DOTALL)


def doc_files():
    docs = sorted(
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md")
    )
    return [os.path.join(REPO, "README.md")] + docs


def extract(path: str) -> str:
    with open(path) as f:
        text = f.read()
    blocks = [m.group(1) for m in _BLOCK.finditer(text)]
    return "\n\n".join(blocks)


def run_file(path: str) -> bool:
    source = extract(path)
    rel = os.path.relpath(path, REPO)
    if not source.strip():
        print(f"-- {rel}: no python blocks")
        return True
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    # The mesh examples want >1 device; faking host devices is safe here
    # because each file runs in its own subprocess (unlike the test suite,
    # which must see the real device).
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as tmp:
        tmp.write(source)
        script = tmp.name
    try:
        proc = subprocess.run(
            [sys.executable, script], env=env, cwd=REPO,
            capture_output=True, text=True, timeout=900,
        )
    finally:
        os.unlink(script)
    if proc.returncode != 0:
        print(f"FAIL {rel}\n--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
        return False
    print(f"ok   {rel} ({source.count(chr(10)) + 1} lines)")
    return True


def main(argv):
    files = [os.path.abspath(a) for a in argv] or doc_files()
    failed = [f for f in files if not run_file(f)]
    if failed:
        print(f"\n{len(failed)} doc file(s) failed: "
              + ", ".join(os.path.relpath(f, REPO) for f in failed))
        return 1
    print(f"\nall {len(files)} doc file(s) green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
