"""Quickstart: solve an SDE with EES(2,5) and take O(1)-memory gradients.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import SDETerm, brownian_path, ees25_solver, solve

# dy = tanh(w y) dt + 0.1 dW on R^4, 1000 steps.
term = SDETerm(
    drift=lambda t, y, args: jnp.tanh(args["w"] * y),
    diffusion=lambda t, y, args: 0.1 * jnp.ones_like(y),
    noise="diagonal",
)
params = {"w": jnp.float32(0.5)}
bm = brownian_path(jax.random.PRNGKey(0), t0=0.0, t1=1.0, n_steps=1000, shape=(4,))


def loss(p):
    # reversible adjoint: backward pass RECONSTRUCTS the trajectory with the
    # effectively-symmetric reverse step — no O(n_steps) activation storage.
    out = solve(ees25_solver(), term, jnp.ones(4), bm, p, adjoint="reversible")
    return jnp.sum(out.y_final ** 2)


value, grads = jax.jit(jax.value_and_grad(loss))(params)
print(f"loss = {value:.6f}")
print(f"dloss/dw = {grads['w']:.6f}")

# cross-check against full backprop (discretise-then-optimise):
g_full = jax.grad(
    lambda p: jnp.sum(
        solve(ees25_solver(), term, jnp.ones(4), bm, p, adjoint="full").y_final ** 2
    )
)(params)
print(f"full-adjoint dloss/dw = {g_full['w']:.6f}  (should match to ~1e-5)")
