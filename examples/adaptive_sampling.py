"""Adaptive sampling on a Virtual Brownian Tree: tolerance in, trajectory out.

A mean-reverting process gets a sharp stiff transient around t = 1 (the drift
rate spikes 40x inside a narrow window).  A fixed grid must resolve the spike
everywhere; the adaptive EES stepper shrinks steps only inside the window —
same Brownian path, tolerance-controlled error, dense output on an arbitrary
grid.

Run:  PYTHONPATH=src python examples/adaptive_sampling.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import (SDETerm, TimeGrid, get_solver, sdeint, solve,
                        virtual_brownian_tree)

T1 = 2.0


def rate(t, a):
    return a["nu"] * (1.0 + 40.0 * jnp.exp(-(((t - 1.0) / 0.08) ** 2)))


term = SDETerm(
    drift=lambda t, y, a: rate(t, a) * (a["mu"] - y),
    diffusion=lambda t, y, a: a["sigma"] * jnp.ones_like(y),
    noise="diagonal",
)
args = {"nu": jnp.float64(0.7), "mu": jnp.float64(0.2), "sigma": jnp.float64(0.3)}
y0 = jnp.ones(4, jnp.float64)
keys = jax.random.split(jax.random.PRNGKey(0), 256)

# Dense output on a grid nobody integrated on: 33 arbitrary times.
ts = jnp.linspace(0.0, T1, 33)
out = sdeint(term, "ees25:adaptive", 0.0, T1, 512, y0, None, args=args,
             rtol=1e-3, atol=1e-5, save_at=ts, batch_keys=keys)
print(f"batch of {out.ys.shape[0]} paths, dense output {out.ys.shape[1:]} "
      f"on save_at grid")
print(f"mean accepted steps {float(jnp.mean(out.n_accepted)):.1f}, "
      f"rejected {float(jnp.mean(out.n_rejected)):.1f}, "
      f"all reached t1: {bool((out.t_final == T1).all())}")

# Strong error vs a fine fixed grid on the SAME driver (matched paths).
def tree(k):
    return virtual_brownian_tree(k, 0.0, T1, shape=(4,), dtype=jnp.float64,
                                 tol=T1 * 2.0 ** -14)

ref = jax.jit(jax.vmap(lambda k: solve(
    get_solver("ees25"), term, y0,
    TimeGrid.uniform(0.0, T1, 4096, tree(k)), args).y_final))(keys)
err = float(jnp.sqrt(jnp.mean(jnp.sum((out.y_final - ref) ** 2, axis=-1))))
budget = float(jnp.mean(out.n_accepted + out.n_rejected))
print(f"strong error vs matched 4096-step reference: {err:.2e} "
      f"using ~{budget:.0f} steps/path")

# The same tolerance through the serving engine:
from repro.serving import SDESampleConfig, SDESampleEngine

eng = SDESampleEngine(term, y0, SDESampleConfig(slots=64), args=args)
rid = eng.submit("ees25:adaptive", t1=T1, n_steps=512, n_paths=100,
                 rtol=1e-3, save_at=[0.5, 1.0, 1.5, 2.0], seed=7)
res = eng.run()[rid]
print(f"engine served {res.y_final.shape[0]} paths, ys {res.ys.shape} "
      f"(reproducible offline from seed 7)")
