"""End-to-end driver: train a Neural Langevin SDE on high-volatility OU
dynamics with the EES(2,5) reversible adjoint (paper Section 4, Table 1).

Run:  PYTHONPATH=src python examples/train_ou_nsde.py [--epochs 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brownian_path, ees25_solver, solve
from repro.nsde import init_lsde, lsde_readout, lsde_term, moment_mse
from repro.nsde.data import ou_paths
from repro.optim import adamw, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    T, n_saves = 2.0, 4
    rng = np.random.default_rng(0)
    target = jnp.asarray(ou_paths(rng, 8192, n_saves, T=T)[:, 1:], jnp.float32)

    key = jax.random.PRNGKey(0)
    params = init_lsde(key, d_obs=1, d_z=32, width=32)
    term = lsde_term()
    solver = ees25_solver()
    opt = adamw(cosine_schedule(1e-2, 10, args.epochs))
    state = opt.init(params)

    def loss_fn(p, k):
        bm = brownian_path(k, 0.0, T, args.steps, shape=(args.batch, 32))
        z0 = jnp.zeros((args.batch, 32)) + p["encoder"]["b"]
        r = solve(solver, term, z0, bm, p, adjoint="reversible",
                  save_every=args.steps // n_saves)
        ys = lsde_readout(p, r.ys)[..., 0]
        return moment_mse(ys.T, target)

    @jax.jit
    def step(p, s, k):
        l, g = jax.value_and_grad(loss_fn)(p, k)
        p, s, gn = opt.update(g, s, p)
        return l, p, s

    t0 = time.time()
    for e in range(args.epochs):
        key, sub = jax.random.split(key)
        l, params, state = step(params, state, sub)
        if (e + 1) % 25 == 0:
            print(f"epoch {e+1:4d}  moment-mse {float(l):.5f}  "
                  f"({time.time()-t0:.1f}s)", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
