"""End-to-end driver: train a Neural Langevin SDE on high-volatility OU
dynamics with the EES(2,5) reversible adjoint (paper Section 4, Table 1).

The whole integration stack goes through the batched engine: the solver is a
registry spec string (try ``--solver ees25:x=0.3`` or ``mcf-rk4``), and the
Monte-Carlo batch is ``sdeint``'s per-key vmap fan-out via
``make_sde_train_step``.

Run:  PYTHONPATH=src python examples/train_ou_nsde.py [--epochs 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nsde import init_lsde, lsde_readout, lsde_term, moment_mse
from repro.nsde.data import ou_paths
from repro.optim import adamw, cosine_schedule
from repro.train.trainer import make_sde_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--solver", default="ees25",
                    help="registry spec, e.g. ees25, ees25:x=0.3, mcf-rk4")
    args = ap.parse_args()

    T, n_saves = 2.0, 4
    rng = np.random.default_rng(0)
    target = jnp.asarray(ou_paths(rng, 8192, n_saves, T=T)[:, 1:], jnp.float32)

    key = jax.random.PRNGKey(0)
    params = init_lsde(key, d_obs=1, d_z=32, width=32)
    opt = adamw(cosine_schedule(1e-2, 10, args.epochs))
    state = opt.init(params)

    def loss_of_result(p, r):
        ys = lsde_readout(p, r.ys)[..., 0]  # (n_paths, n_saves)
        return moment_mse(ys, target)

    step = jax.jit(make_sde_train_step(
        args.solver, lsde_term(), opt,
        y0_fn=lambda p: jnp.zeros(32) + p["encoder"]["b"],
        loss_fn_result=loss_of_result,
        t0=0.0, t1=T, n_steps=args.steps, n_paths=args.batch,
        adjoint="reversible", save_every=args.steps // n_saves,
    ))

    t0 = time.time()
    for e in range(args.epochs):
        key, sub = jax.random.split(key)
        params, state, m = step(params, state, sub)
        if (e + 1) % 25 == 0:
            print(f"epoch {e+1:4d}  moment-mse {float(m['loss']):.5f}  "
                  f"({time.time()-t0:.1f}s)", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
