"""Beyond-paper integration: train a small LM whose residual stream is
integrated with EES(2,5) and backpropagated with the O(1)-depth-memory
reversible adjoint (DESIGN.md section 5).

Run:  PYTHONPATH=src python examples/train_lm_ees_residual.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import ModelOptions, init_params
from repro.models.layers import apply_norm, attn_block, mlp_block
from repro.models.reversible import ees_depth_solve
from repro.models.transformer import _mask_pad_vocab
from repro.optim import adamw

cfg = get_arch("olmo-1b").smoke()
opts = ModelOptions()


def block_fn(lp, h):
    """Depth-ODE vector field: the standard layer's residual increment."""
    a = attn_block(cfg, lp["attn"], h, opts)
    return a + mlp_block(cfg, lp["mlp"], h + a, opts)


def forward(params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    h = ees_depth_solve(block_fn, params["layers"], h, step=1.0,
                        adjoint="reversible")
    h = apply_norm(cfg.norm, None, h)
    logits = _mask_pad_vocab(cfg, h @ params["embed"].T)
    return logits.astype(jnp.float32)


def loss_fn(params, tokens, labels):
    logp = jax.nn.log_softmax(forward(params, tokens))
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))


def main():
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw(3e-3)
    state = opt.init(params)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab)

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(loss_fn)(p, toks[:, :-1], toks[:, 1:])
        p, s, _ = opt.update(g, s, p)
        return l, p, s

    t0 = time.time()
    for e in range(50):
        l, params, state = step(params, state)
        if (e + 1) % 10 == 0:
            print(f"step {e+1:3d}  ce {float(l):.4f}  ({time.time()-t0:.1f}s)")
    print("done — activations never stored across depth (reversible).")


if __name__ == "__main__":
    main()
