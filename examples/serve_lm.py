"""Serve a (smoke-sized) LM with the continuous-batching engine.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b
"""
import argparse

import jax

from repro.configs import get_arch
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(slots=4, max_len=args.max_len))
    for i in range(args.requests):
        eng.submit([2 + i, 7, 11])
    done = eng.run()
    for rid, toks in sorted(done.items()):
        print(f"request {rid}: {len(toks)} tokens -> {toks[:12]}...")


if __name__ == "__main__":
    main()
