"""Manifold NSDE: train a stochastic Kuramoto model on T*T^N with CF-EES(2,5)
and the reversible adjoint (paper Section 4, Table 3).

Run:  PYTHONPATH=src python examples/kuramoto_torus.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brownian_path, cfees25_solver, solve
from repro.nsde import init_kuramoto_nsde, kuramoto_nsde_term, wrapped_energy_score
from repro.nsde.data import kuramoto_paths
from repro.optim import adamw

N, BATCH, T, STEPS, EPOCHS = 16, 32, 2.0, 24, 40


def main():
    rng = np.random.default_rng(0)
    ths, oms = kuramoto_paths(rng, N, BATCH, 400, T=T, subsample=400)
    th0, om0 = jnp.asarray(ths[:, 0]), jnp.asarray(oms[:, 0])
    tgt_th, tgt_om = jnp.asarray(ths[:, -1]), jnp.asarray(oms[:, -1])

    key = jax.random.PRNGKey(0)
    params = init_kuramoto_nsde(key, N, width=64)
    term = kuramoto_nsde_term()
    solver = cfees25_solver()
    opt = adamw(2e-3)
    state = opt.init(params)

    def loss(p, k):
        def one(kk):
            bm = brownian_path(kk, 0.0, T, STEPS, shape=((BATCH, N), (BATCH, N)))
            return solve(solver, term, (th0, om0), bm, p, adjoint="reversible").y_final

        ths_s, oms_s = jax.vmap(one)(jax.random.split(k, 4))
        es = jax.vmap(lambda i: wrapped_energy_score(
            ths_s[:, i], oms_s[:, i], tgt_th[i], tgt_om[i]))(jnp.arange(BATCH))
        return jnp.mean(es)

    @jax.jit
    def step(p, s, k):
        l, g = jax.value_and_grad(loss)(p, k)
        p, s, _ = opt.update(g, s, p)
        return l, p, s

    t0 = time.time()
    for e in range(EPOCHS):
        key, sub = jax.random.split(key)
        l, params, state = step(params, state, sub)
        if (e + 1) % 10 == 0:
            print(f"epoch {e+1:3d}  energy-score {float(l):.3f}  "
                  f"({time.time()-t0:.1f}s)", flush=True)
    print("done — state stayed on T*T^N throughout (wrapped angles).")


if __name__ == "__main__":
    main()
