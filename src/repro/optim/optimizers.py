"""Optimizers in pure JAX (no optax dependency): Adam/AdamW with mixed
precision (bf16 params, f32 moments), global-norm clipping, schedules.

State layout mirrors the params pytree so the same PartitionSpecs shard both
(optionally extended with a data-axis shard for ZeRO-style partitioning).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["adamw", "sgd", "cosine_schedule", "clip_by_global_norm", "Optimizer"]


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (params, state)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state: OptState, params):
        gnorm = jnp.zeros((), jnp.float32)
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g32
            v2 = b2 * v + (1 - b2) * g32 * g32
            upd32 = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            if weight_decay:
                upd32 = upd32 + weight_decay * p.astype(jnp.float32)
            p2 = (p.astype(jnp.float32) - lr_t * upd32).astype(p.dtype)
            return p2, m2, v2

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        params2 = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mu2 = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        nu2 = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return params2, OptState(step=step, mu=mu2, nu=nu2), gnorm

    return Optimizer(init=init, update=update)


def sgd(lr: Callable | float, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum:
            return OptState(
                step=jnp.zeros((), jnp.int32),
                mu=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                nu=None,
            )
        return OptState(step=jnp.zeros((), jnp.int32), mu=None, nu=None)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        if momentum:
            mu2 = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
            )
            params2 = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
                params,
                mu2,
            )
            return params2, OptState(step=step, mu=mu2, nu=None), jnp.zeros(())
        params2 = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return params2, OptState(step=step, mu=None, nu=None), jnp.zeros(())

    return Optimizer(init=init, update=update)
