"""Gradient compression: int8-quantised all-reduce with error feedback.

The distributed-optimisation trick for bandwidth-bound data parallelism:
gradients are quantised to int8 with a per-tensor scale before crossing the
wire (4x fewer bytes than f32, 2x fewer than bf16) and the quantisation
residual is carried to the next step (error feedback), which keeps SGD/Adam
convergence unaffected to first order (Karimireddy et al., 2019).

``compressed_psum_with_feedback`` is the shard_map building block; the wire
format note: on TPU the int8 payload rides an all-to-all + all-gather pair
(reduce-scatter cannot sum int8 without overflow); this module's reference
implementation psums the dequantised values — same numerics, and the byte
accounting for the roofline uses the int8 payload size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _old

    def shard_map(f, mesh, in_specs, out_specs):
        return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(mesh, axis: str, x_stacked, err_stacked):
    """Test/reference harness: leading axis of ``x_stacked`` is sharded over
    ``axis``; returns (summed values broadcast back, new error residuals)."""

    def body(v, e):
        val = v + e  # error feedback
        q, scale = quantize_int8(val)
        deq = dequantize_int8(q, scale)
        new_err = val - deq
        out = jax.lax.psum(deq, axis)  # int8 payload on the wire (see module doc)
        return out, new_err

    f = shard_map(body, mesh, in_specs=(P(axis), P(axis)), out_specs=(P(axis), P(axis)))
    return f(x_stacked, err_stacked)


def compress_grads_tree(grads, err_tree, mesh=None, axis: str = "data"):
    """Per-leaf int8 quantise-with-feedback for a gradient pytree (to be used
    inside an existing shard_map'd step; psum is implicit under SPMD)."""

    def one(g, e):
        val = g.astype(jnp.float32) + e
        q, scale = quantize_int8(val)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), val - deq

    pairs = jax.tree_util.tree_map(one, grads, err_tree)
    g2 = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2
