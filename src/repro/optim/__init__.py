from .optimizers import Optimizer, adamw, clip_by_global_norm, cosine_schedule, sgd

__all__ = ["Optimizer", "adamw", "sgd", "cosine_schedule", "clip_by_global_norm"]
