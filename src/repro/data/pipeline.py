"""Deterministic sharded synthetic data pipeline.

Framework-grade properties the trainer depends on:

* **Deterministic by (step, host)** — batch content is a pure function of the
  global step and the host's shard, so restart-from-checkpoint replays the
  exact stream (no data-loader state in checkpoints) and elastic re-sharding
  re-partitions the same global stream.
* **Host-sharded** — each process materialises only its ``1/num_hosts`` slice
  of the global batch; `form_global_array` assembles the jax.Array.
* **Prefetch** — a small lookahead queue overlaps host-side generation with
  device compute.

The token stream is synthetic (hash-based), standing in for a tokenised
corpus reader; the interface (``__iter__`` of per-step batches) is what a real
loader would implement.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "prefetch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class SyntheticTokens:
    """Deterministic pseudo-corpus: batch(step) is pure in (cfg, step)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base = np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
        for i in range(self.local_batch):
            gseq = step * cfg.global_batch + cfg.host_id * self.local_batch + i
            rng = np.random.default_rng(np.uint64(gseq) ^ base)
            rows.append(rng.integers(0, cfg.vocab, size=cfg.seq_len + 1, dtype=np.int32))
        arr = np.stack(rows)
        return {
            "tokens": arr[:, :-1],
            "labels": arr[:, 1:],
            "loss_mask": np.ones((self.local_batch, cfg.seq_len), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Host-side lookahead buffer (overlaps generation with device steps)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
