"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]

Faithfulness notes (DESIGN.md §Arch-applicability): the real Zamba2 uses two
alternating shared attention blocks whose input is concat(hidden, embedding);
we model ONE shared attention+MLP block applied every 6 Mamba2 layers on the
hidden stream alone — same parameter-sharing structure and FLOP profile.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
    source="arXiv:2411.15242; unverified",
))
