"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. [arXiv:2409.12191; hf]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_vision_tokens x frontend_dim) projected into
the first positions of the sequence.  M-RoPE degrades to 1-D RoPE for the
stubbed (pre-pooled) patch stream — noted in DESIGN.md.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    frontend="patch",
    frontend_dim=1280,
    n_vision_tokens=256,
    source="arXiv:2409.12191; hf",
))
