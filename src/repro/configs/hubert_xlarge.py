"""hubert-xlarge [audio]: encoder-only transformer over frame embeddings.

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504. [arXiv:2106.07447;
unverified]

The CNN waveform frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (seq x frontend_dim=512) which a linear
projection maps to d_model.  Encoder-only: no decode shapes (DESIGN.md).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    mlp="gelu",
    norm="rmsnorm",
    frontend="frames",
    frontend_dim=512,
    source="arXiv:2106.07447; unverified",
))
