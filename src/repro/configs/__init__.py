"""Architecture & shape registry (one module per assigned arch)."""
from .base import ArchConfig, get_arch, list_archs, register
from .shapes import ALL_SHAPES, ShapeSpec, cell_applicable, get_shape

__all__ = [
    "ArchConfig",
    "get_arch",
    "list_archs",
    "register",
    "ALL_SHAPES",
    "ShapeSpec",
    "cell_applicable",
    "get_shape",
]
