"""olmo-1b [dense]: non-parametric LayerNorm.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304. [arXiv:2402.00838; hf]
OLMo uses a plain (gateless) MLP with d_ff=8192 and non-parametric LN.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    norm="nonparam_ln",
    mlp="gelu",
    tie_embeddings=True,
    source="arXiv:2402.00838; hf",
))
