"""Architecture config schema + registry.

Every assigned architecture is a frozen :class:`ArchConfig`; reduced smoke
variants are derived with :meth:`ArchConfig.smoke`.  The model substrate
(`repro.models.transformer`) consumes only this schema — adding an arch is a
new config file, not new model code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    causal: bool = True

    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | nonparam_ln
    mlp: str = "swiglu"  # swiglu | gelu

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # hybrid (zamba2-style): a *shared* attention block every k layers
    shared_attn_every: int = 0

    # modality frontend stubs
    frontend: str = "none"  # none | patch | frames
    frontend_dim: int = 0
    n_vision_tokens: int = 0

    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/logits shard
        cleanly on any reasonable model axis (standard TPU practice).  Pad
        logits are masked to -1e9; pad rows receive no gradient signal."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def block_kind(self) -> Tuple[str, ...]:
        if self.family in ("ssm",):
            return ("mamba",) * self.n_layers
        if self.family == "hybrid":
            return ("mamba",) * self.n_layers
        return ("attn",) * self.n_layers

    @property
    def supports_decode(self) -> bool:
        return self.causal  # encoder-only archs have no autoregressive decode

    @property
    def subquadratic(self) -> bool:
        """Whether long-context decode (500k) is feasible: SSM/hybrid only."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6 N D)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            ds = self.ssm_state
            heads = self.ssm_heads
            conv_dim = di + 2 * ds
            # in_proj -> (z, x, B, C, dt), conv, A/D/dt_bias, norm, out_proj
            per_layer += d * (2 * di + 2 * ds + heads)
            per_layer += conv_dim * self.ssm_conv
            per_layer += 3 * heads + di
            per_layer += di * d
            per_layer += d  # pre-norm
        if self.family in ("dense", "moe", "vlm", "audio"):
            qkvo = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
            if self.qkv_bias:
                qkvo += (self.n_heads + 2 * self.n_kv_heads) * hd
            per_layer += qkvo
            if self.norm == "rmsnorm":
                per_layer += 2 * d
            if self.family == "moe":
                per_layer += d * self.n_experts  # router
                per_layer += self.n_experts * (3 * d * self.moe_d_ff)
            else:
                ff = 3 * d * self.d_ff if self.mlp == "swiglu" else 2 * d * self.d_ff
                per_layer += ff
        n += per_layer * self.n_layers
        if self.family == "hybrid" and self.shared_attn_every:
            qkvo = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
            n += qkvo + 3 * d * self.d_ff + 2 * d  # one shared block
        if self.frontend == "patch":
            n += self.frontend_dim * d
        if self.frontend == "frames":
            n += self.frontend_dim * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        inactive = (
            self.n_layers
            * (self.n_experts - self.moe_top_k)
            * 3
            * self.d_model
            * self.moe_d_ff
        )
        return full - inactive

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=32 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_dim=32 if self.frontend != "none" else 0,
            n_vision_tokens=4 if self.frontend == "patch" else 0,
            dtype="float32",
        )


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (  # noqa: F401
        hubert_xlarge,
        mamba2_130m,
        olmo_1b,
        olmoe_1b_7b,
        qwen1p5_32b,
        qwen2_vl_2b,
        qwen3_1p7b,
        qwen3_moe_30b_a3b,
        yi_9b,
        zamba2_7b,
    )
