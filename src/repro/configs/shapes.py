"""The four assigned input-shape cells for every LM architecture.

``train_*`` lowers ``train_step``; ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV/state cache of ``seq_len``).
``long_500k`` requires sub-quadratic sequence mixing and is only run for
SSM/hybrid archs; encoder-only archs have no decode at all (see
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) cell."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only: no autoregressive decode"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "needs sub-quadratic sequence mixing (SSM/hybrid only)"
    return True, ""
