import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 pods x 256 v5e chips.

Two artifacts per cell:

1. **Memory module** — the production step (scanned layers, remat, chunked
   attention, gradient accumulation) jitted with production shardings;
   ``.lower().compile()`` success proves shardability and
   ``memory_analysis()`` proves the cell fits the 16 GiB v5e HBM.

2. **Cost modules** — XLA's ``cost_analysis()`` counts a ``while`` body
   *once*, ignoring trip count (verified against a hand-counted sharded
   matmul), so the scanned-layer module under-reports FLOPs by ~L x.  We
   therefore compile the per-layer body (forward, and vjp for training) as a
   standalone module with identical shardings and assemble

       total = outside + L * body (+ n_shared * shared_body)   [x microbatches]

   for the §Roofline terms.  Collective bytes are parsed from each module's
   partitioned HLO and assembled the same way.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --variant v2
Results land in launch_results/<mesh>/<arch>__<shape>__<variant>.json.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_SHAPES, cell_applicable, get_arch, get_shape, list_archs
from repro.launch.mesh import (
    batch_axes,
    batch_pspecs,
    cache_pspecs,
    make_production_mesh,
    opt_state_pspecs,
    param_pspecs,
    shardings_for,
)
from repro.launch.roofline import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    collective_bytes,
    collective_bytes_structured,
    memory_summary,
)
from repro.models import (
    ModelOptions,
    ShardingPolicy,
    forward,
    init_cache,
    init_params,
    make_serve_step,
    serve_step,
)
from repro.models.transformer import _init_layer, _layer_apply, loss_fn
from repro.models.layers import attn_block, init_attn_block, init_mlp, mlp_block
from repro.models.ssm import init_mamba_cache, mamba_block_decode
from repro.models.layers import attn_block_decode
from repro.optim import adamw, cosine_schedule
from repro.train.trainer import make_accum_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "launch_results")
HBM_BUDGET = 16 * 2**30  # v5e

_COST_KEYS = ("flops", "bytes", "coll", "transcendentals")


# ---------------------------------------------------------------------------
# Cell configuration heuristics.
# ---------------------------------------------------------------------------

def microbatches_for(cfg, shape, mesh) -> int:
    """Gradient-accumulation factor so layer-boundary activations fit HBM."""
    if shape.kind != "train":
        return 1
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    b_loc = max(shape.global_batch // dp, 1)
    bound = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2  # bf16 carriers
    if cfg.family == "moe":
        # dispatch/scatter working set (xe + gate/up/down + bwd copies) is a
        # multiple of the token volume through the experts
        bound *= 4
    budget = 4 * 2**30
    k = max(1, (bound + budget - 1) // budget)
    while b_loc % k != 0:  # must divide the local batch
        k += 1
    return min(k, b_loc)


def input_specs(arch: str, shape_name: str, *, microbatches: int = 1):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32

    def mb(shp):
        if microbatches > 1:
            return (microbatches, shp[0] // microbatches) + shp[1:]
        return shp

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "frames":
            batch = {"frames": jax.ShapeDtypeStruct(mb((B, S, cfg.frontend_dim)), jnp.bfloat16)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct(mb((B, S)), i32)}
            if cfg.frontend == "patch":
                batch["vision_embeds"] = jax.ShapeDtypeStruct(
                    mb((B, cfg.n_vision_tokens, cfg.frontend_dim)), jnp.bfloat16
                )
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct(mb((B, S)), i32)
            batch["loss_mask"] = jax.ShapeDtypeStruct(mb((B, S)), f32)
        return batch
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


_BF16_BARRIER = os.environ.get("REPRO_BF16_BARRIER", "0") == "1"


def _opts(mesh, *, seq_shard: bool = False, cache_constraints=None,
          attn_chunk: int = 512) -> ModelOptions:
    return ModelOptions(
        remat=True,
        use_flash="never",  # CPU host cannot lower Pallas; kernel used on real TPU
        attn_chunk=attn_chunk,
        shard=ShardingPolicy(
            batch_axes=None if seq_shard else batch_axes(mesh),
            model_axis="model",
            seq_axes=batch_axes(mesh) if seq_shard else None,
        ),
        cache_constraints=cache_constraints,
        bf16_ar_barrier=_BF16_BARRIER,
    )


# ---------------------------------------------------------------------------
# Cost-module compilation.
# ---------------------------------------------------------------------------

def _cost_of(compiled, *, structured_coll: bool = False) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    if structured_coll:
        coll = collective_bytes_structured(text)
    else:
        coll = float(sum(v for k, v in collective_bytes(text).items() if k != "count"))
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "coll": coll,
    }


def _compile_cost(fn, args, in_shardings, mesh):
    with mesh:
        c = jax.jit(fn, in_shardings=in_shardings).lower(*args).compile()
    return _cost_of(c)


def _acc(total: Dict[str, float], part: Dict[str, float], factor: float = 1.0):
    for k in _COST_KEYS:
        total[k] = total.get(k, 0.0) + factor * part[k]
    return total


def _abstract_layer(cfg):
    return jax.eval_shape(
        lambda: _init_layer(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    )


def _layer_param_shardings(cfg, mesh, fsdp):
    from repro.launch.mesh import _leaf_spec  # internal rule fn

    al = _abstract_layer(cfg)

    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        return _leaf_spec(mesh, keys[-1], leaf.shape, fsdp="data" if fsdp else None,
                          stacked=False)

    specs = jax.tree_util.tree_map_with_path(assign, al)
    return al, shardings_for(mesh, specs)


def build_cost_terms(cfg, shape, mesh, *, fsdp: bool, microbatches: int,
                     full_cost: Dict[str, float]) -> Dict[str, float]:
    """Assemble trip-count-corrected totals from per-layer cost modules."""
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    ba = batch_axes(mesh)
    b_mb = max(B // microbatches, 1)
    # unroll attention chunks inside the cost module (no inner while loop)
    opts = _opts(mesh, attn_chunk=max(S, 1))
    al, l_sh = _layer_param_shardings(cfg, mesh, fsdp)
    h_sds = jax.ShapeDtypeStruct((b_mb, S, cfg.d_model), jnp.bfloat16)
    h_sh = NamedSharding(mesh, P(ba, None, None))
    shared = None
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        shared = jax.eval_shape(lambda: {
            "attn": init_attn_block(cfg, jax.random.PRNGKey(0), jnp.bfloat16),
            "mlp": init_mlp(cfg, jax.random.PRNGKey(0), jnp.bfloat16),
        })
        from repro.launch.mesh import _leaf_spec

        def assign(path, leaf):
            keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
            return _leaf_spec(mesh, keys[-1], leaf.shape, fsdp="data" if fsdp else None,
                              stacked=False)

        sh_specs = jax.tree_util.tree_map_with_path(assign, shared)
        shared_sh = shardings_for(mesh, sh_specs)
    n_inv = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every if shared else 0

    def body_fwd(h, lp):
        # idx=1: skip the shared-attn cond branch; it is costed separately.
        return _layer_apply(cfg, opts, None, h, lp, jnp.int32(1))[0]

    def shared_fwd(h, sp):
        h = h + attn_block(cfg, sp["attn"], h, opts)
        return h + mlp_block(cfg, sp["mlp"], h, opts)

    total = dict.fromkeys(_COST_KEYS, 0.0)

    # stub variants isolate HBM traffic the fused Pallas kernels eliminate
    # (materialised attention scores / SSD segment matrices); the kernel-path
    # memory term = stub bytes + analytic kernel I/O (q/k/v/o or x/B/C/y tiles
    # stream once).  FLOPs are identical between paths.
    stub_opts = _opts(mesh, attn_chunk=max(S, 1))
    stub_opts = ModelOptions(**{**stub_opts.__dict__, "attn_impl": "stub"})

    def body_fwd_stub(h, lp):
        return _layer_apply(cfg, stub_opts, None, h, lp, jnp.int32(1))[0]

    def shared_fwd_stub(h, sp):
        h = h + attn_block(cfg, sp["attn"], h, stub_opts)
        return h + mlp_block(cfg, sp["mlp"], h, stub_opts)

    kio = _seq_mix_io_bytes(cfg, b_mb, S, mesh.size)
    kernel_bytes = 0.0

    if shape.kind == "train":
        def body_vjp(h, ct, lp):
            y, vjp = jax.vjp(body_fwd, h, lp)
            return vjp(ct)

        def body_vjp_stub(h, ct, lp):
            y, vjp = jax.vjp(body_fwd_stub, h, lp)
            return vjp(ct)

        c_fwd = _compile_cost(body_fwd, (h_sds, al), (h_sh, l_sh), mesh)
        c_vjp = _compile_cost(body_vjp, (h_sds, h_sds, al), (h_sh, h_sh, l_sh), mesh)
        st_fwd = _compile_cost(body_fwd_stub, (h_sds, al), (h_sh, l_sh), mesh)
        st_vjp = _compile_cost(body_vjp_stub, (h_sds, h_sds, al), (h_sh, h_sh, l_sh), mesh)
        # remat: forward once + (recompute fwd + bwd) = fwd + vjp-module
        _acc(total, c_fwd, L * microbatches)
        _acc(total, c_vjp, L * microbatches)
        kernel_bytes += (st_fwd["bytes"] + st_vjp["bytes"] + 4.5 * kio) * L * microbatches
        if shared:
            def shared_vjp(h, ct, sp):
                y, vjp = jax.vjp(shared_fwd, h, sp)
                return vjp(ct)

            def shared_vjp_stub(h, ct, sp):
                y, vjp = jax.vjp(shared_fwd_stub, h, sp)
                return vjp(ct)

            s_fwd = _compile_cost(shared_fwd, (h_sds, shared), (h_sh, shared_sh), mesh)
            s_vjp = _compile_cost(shared_vjp, (h_sds, h_sds, shared),
                                  (h_sh, h_sh, shared_sh), mesh)
            ss_fwd = _compile_cost(shared_fwd_stub, (h_sds, shared), (h_sh, shared_sh), mesh)
            ss_vjp = _compile_cost(shared_vjp_stub, (h_sds, h_sds, shared),
                                   (h_sh, h_sh, shared_sh), mesh)
            _acc(total, s_fwd, n_inv * microbatches)
            _acc(total, s_vjp, n_inv * microbatches)
            akio = _attn_io_bytes(cfg, b_mb, S, mesh.size)
            kernel_bytes += (ss_fwd["bytes"] + ss_vjp["bytes"] + 4.5 * akio) * n_inv * microbatches
        # outside (embed/head/loss/optimizer): the full module counted the
        # scan body once; subtract one measured body to avoid double count.
        _acc(total, full_cost, 1.0)
        _acc(total, c_vjp, -1.0)
        _acc(total, c_fwd, -1.0)
        kernel_bytes += max(full_cost["bytes"] - c_vjp["bytes"] - c_fwd["bytes"], 0.0)
        total["bytes_kernel"] = kernel_bytes
        return total

    if shape.kind == "prefill":
        # At 32k the unrolled score tensor (b, H, S, S) exceeds practical HLO
        # sizes; cost the layer with stub mixing + exact analytic attention
        # flops (4 b H S^2 hd; the jnp fallback computes the full square).
        analytic_attention = S >= 16384 and cfg.family not in ("ssm",)
        st_fwd = _compile_cost(body_fwd_stub, (h_sds, al), (h_sh, l_sh), mesh)
        if analytic_attention and cfg.family != "hybrid":
            c_fwd = dict(st_fwd)
            c_fwd["flops"] += _attn_flops(cfg, b_mb, S, mesh.size)
            c_fwd["bytes"] += _attn_score_bytes(cfg, b_mb, S, mesh.size)
        elif cfg.family == "hybrid":
            c_fwd = _compile_cost(body_fwd, (h_sds, al), (h_sh, l_sh), mesh)
        else:
            c_fwd = _compile_cost(body_fwd, (h_sds, al), (h_sh, l_sh), mesh)
        _acc(total, c_fwd, L)
        kernel_bytes += (st_fwd["bytes"] + 1.0 * kio) * L
        if shared:
            ss_fwd = _compile_cost(shared_fwd_stub, (h_sds, shared), (h_sh, shared_sh), mesh)
            if analytic_attention:
                s_fwd = dict(ss_fwd)
                s_fwd["flops"] += _attn_flops(cfg, b_mb, S, mesh.size)
                s_fwd["bytes"] += _attn_score_bytes(cfg, b_mb, S, mesh.size)
            else:
                s_fwd = _compile_cost(shared_fwd, (h_sds, shared), (h_sh, shared_sh), mesh)
            _acc(total, s_fwd, n_inv)
            akio = _attn_io_bytes(cfg, b_mb, S, mesh.size)
            kernel_bytes += (ss_fwd["bytes"] + 1.0 * akio) * n_inv
        _acc(total, full_cost, 1.0)
        _acc(total, c_fwd, -1.0)
        kernel_bytes += max(full_cost["bytes"] - c_fwd["bytes"], 0.0)
        total["bytes_kernel"] = kernel_bytes
        return total

    # decode: per-layer decode body with the production cache layout.
    cc = _decode_cache_constraints(cfg, mesh, B, S)
    d_opts = _opts(mesh, cache_constraints=cc)
    h1 = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    h1_sh = NamedSharding(mesh, P(ba if B % _dp(mesh) == 0 and B > 1 else None, None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.family in ("ssm", "hybrid"):
        mc = jax.eval_shape(lambda: init_mamba_cache(cfg, B, jnp.bfloat16))
        mc_specs = {k: cc[k] for k in mc}
        mc_sh = {k: NamedSharding(mesh, v) for k, v in mc_specs.items()}

        def dec_body(h, lc, lp):
            out, nc = mamba_block_decode(cfg, lp["mamba"], h, lc)
            return h + out, nc

        c_dec = _compile_cost(dec_body, (h1, mc, al), (h1_sh, mc_sh, l_sh), mesh)
        _acc(total, c_dec, L)
        if shared:
            hd = cfg.resolved_head_dim
            kc = jax.ShapeDtypeStruct((B, cfg.n_kv_heads, S, hd), jnp.bfloat16)
            kc_sh = NamedSharding(mesh, cc["k"])

            def sh_dec(h, kca, vca, sp):
                o, kcb, vcb = attn_block_decode(cfg, sp["attn"], h, kca, vca, jnp.int32(0))
                h = h + o
                return h + mlp_block(cfg, sp["mlp"], h, d_opts), kcb, vcb

            s_dec = _compile_cost(sh_dec, (h1, kc, kc, shared),
                                  (h1_sh, kc_sh, kc_sh, shared_sh), mesh)
            _acc(total, s_dec, n_inv)
    else:
        hd = cfg.resolved_head_dim
        kc = jax.ShapeDtypeStruct((B, cfg.n_kv_heads, S, hd), jnp.bfloat16)
        kc_sh = NamedSharding(mesh, cc["k"])

        def dec_body(h, kca, vca, lp):
            o, kcb, vcb = attn_block_decode(cfg, lp["attn"], h, kca, vca, jnp.int32(0))
            h = h + o
            if cfg.family == "moe":
                from repro.models.moe import moe_block

                out, _ = moe_block(cfg, lp["moe"], h, d_opts)
                h = h + out
            else:
                h = h + mlp_block(cfg, lp["mlp"], h, d_opts)
            return h, kcb, vcb

        c_dec = _compile_cost(dec_body, (h1, kc, kc, al), (h1_sh, kc_sh, kc_sh, l_sh), mesh)
        _acc(total, c_dec, L)
    _acc(total, full_cost, 1.0)
    _acc(total, c_dec, -1.0)
    total["bytes_kernel"] = total["bytes"]
    return total




def _attn_flops(cfg, b, S, n_devices) -> float:
    """Full (non-causal-skip) attention flops per device: qk + pv."""
    return 4.0 * b * cfg.n_heads * S * S * cfg.resolved_head_dim / n_devices


def _attn_score_bytes(cfg, b, S, n_devices) -> float:
    """Fallback-path score-matrix traffic per device (s write + softmax r/w +
    p read: ~4 passes of the f32 (b, H, S, S) tensor)."""
    return 4.0 * b * cfg.n_heads * S * S * 4.0 / n_devices


def _attn_io_bytes(cfg, b, S, n_devices) -> float:
    """Per-(layer, microbatch, device) flash-attention HBM I/O: q/k/v/o stream
    once in bf16; running stats negligible."""
    hd = cfg.resolved_head_dim
    elems = b * S * hd * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
    return 2.0 * elems / n_devices


def _ssd_io_bytes(cfg, b, S, n_devices) -> float:
    di, ds = cfg.d_inner, cfg.ssm_state
    elems = b * S * (2 * di + 2 * ds + cfg.ssm_heads)
    return 4.0 * elems / n_devices  # f32 path of the SSD kernel


def _seq_mix_io_bytes(cfg, b, S, n_devices) -> float:
    if cfg.family in ("ssm", "hybrid"):
        return _ssd_io_bytes(cfg, b, S, n_devices)
    return _attn_io_bytes(cfg, b, S, n_devices)



def _local_bytes(tree, specs, mesh) -> float:
    """Per-device bytes of a sharded pytree (leaf size / sharded axis sizes)."""
    from repro.launch.mesh import axis_size

    total = 0.0
    for leaf, spec in zip(
        jax.tree_util.tree_leaves(tree),
        jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        n = 1
        for d in leaf.shape:
            n *= d
        shard = 1
        for entry in tuple(spec):
            shard *= axis_size(mesh, entry)
        total += n * jnp.dtype(leaf.dtype).itemsize / max(shard, 1)
    return total


def _analytic_memory_bytes(cfg, shape, mesh, *, microbatches, al, l_specs) -> float:
    """Fusion-aware HBM-traffic model (the post-fusion TPU estimate):

        per layer/microbatch: 4x weight-shard (fwd read, remat read, bwd read,
        grad write) + C x activation-boundary tensors (C ~= 45 train / 12
        prefill, counting q/k/v/o, mlp gate/up/down, norms, residuals across
        fwd + bwd + remat-fwd) + fused-kernel I/O;
        outside: logits traffic (~6 passes) + embedding + optimizer sweep.

    XLA's cost_analysis 'bytes accessed' is pre-fusion (every HLO op's
    operands counted), a ~10x overestimate for fused pipelines; this model is
    what the §Roofline dominance classification uses, with both measured
    variants reported alongside.
    """
    B, S = shape.global_batch, shape.seq_len
    dp = _dp(mesh)
    L = cfg.n_layers
    mb = microbatches
    b_loc = max(B // mb // dp, 1)
    w_loc = _local_bytes(al, l_specs, mesh)
    act = b_loc * S * cfg.d_model * 2.0
    train = shape.kind == "train"
    c_act = 45.0 if train else 12.0
    c_w = 4.0 if train else 1.0
    kio = _seq_mix_io_bytes(cfg, max(B // mb, 1), S, mesh.size) * (4.5 if train else 1.0)
    per_layer = c_w * w_loc + c_act * act + kio
    total = per_layer * L * mb
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        n_inv = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        total += per_layer * n_inv * mb  # same order as a dense layer
    # outside: logits + embed + optimizer sweep (per device)
    from repro.launch.mesh import axis_size

    v_shard = cfg.padded_vocab // max(
        1, axis_size(mesh, "model") if cfg.padded_vocab % axis_size(mesh, "model") == 0 else 1
    )
    logits = b_loc * S * v_shard * 4.0 * (6.0 if train else 1.0) * mb
    embed = cfg.padded_vocab * cfg.d_model * 2.0 / mesh.size * (3.0 if train else 1.0)
    opt = 0.0
    if train:
        n_params_loc = cfg.param_count() * 2.0 / axis_size(mesh, "model")
        opt = 7.0 * n_params_loc  # p r/w, m r/w, v r/w (f32~2x bf16), grads read
    return total + logits + embed + opt

def _dp(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _decode_cache_constraints(cfg, mesh, B, S):
    """Per-layer cache PartitionSpecs (leading layer axis stripped)."""
    abstract_cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    full = cache_pspecs(mesh, abstract_cache, batch=B)
    out = {}

    def strip(path, spec):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1]
        if name in ("shared_k", "shared_v"):
            out["k" if name == "shared_k" else "v"] = P(*tuple(spec)[1:])
        elif name in ("k", "v"):
            out[name] = P(*tuple(spec)[1:])
        elif name in ("state", "conv_x", "conv_B", "conv_C"):
            # mamba leaves live under cache["mamba"][...] with leading L
            out[name] = P(*tuple(spec)[1:])
        return spec

    jax.tree_util.tree_map_with_path(strip, full,
                                     is_leaf=lambda x: isinstance(x, P))
    return out


# ---------------------------------------------------------------------------
# Memory-module build (the shardability + HBM proof).
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               zero1: bool = False):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    B, S = shape.global_batch, shape.seq_len
    mb = microbatches_for(cfg, shape, mesh)
    seq_shard = shape.kind != "decode" and (B // mb) % _dp(mesh) != 0
    opts = _opts(mesh, seq_shard=seq_shard)

    abstract_params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_specs = param_pspecs(mesh, abstract_params, fsdp=fsdp)
    p_sh = shardings_for(mesh, p_specs)
    batch_sds = input_specs(arch, shape_name, microbatches=mb)

    if shape.kind == "train":
        optimizer = adamw(cosine_schedule(3e-4, 2000, 100_000))
        abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
        o_sh = shardings_for(
            mesh, opt_state_pspecs(mesh, abstract_opt, p_specs, zero1=zero1)
        )
        # microbatched batch leaves: (mb, B/mb, S...) -> batch axis is dim 1
        def bspec(path, leaf):
            dims = len(leaf.shape)
            ba = batch_axes(mesh)
            if mb > 1:
                entries = (None, ba) + (None,) * (dims - 2)
            elif seq_shard and dims >= 2:
                entries = (None, ba) + (None,) * (dims - 2)
            else:
                entries = (ba,) + (None,) * (dims - 1)
            from repro.launch.mesh import _safe

            return _safe(mesh, leaf.shape, entries)

        b_specs = jax.tree_util.tree_map_with_path(bspec, batch_sds)
        b_sh = shardings_for(mesh, b_specs)
        # bf16 grad accumulation for >16B-param models (buffer halving; §Perf)
        adt = jnp.bfloat16 if cfg.param_count() > 16e9 else jnp.float32
        grad_constraint = None
        if zero1:
            # ZeRO-2: reduce-scatter grads into a data-sharded accumulator.
            gspecs = opt_state_pspecs(
                mesh, jax.eval_shape(optimizer.init, abstract_params),
                p_specs, zero1=True,
            ).mu

            def grad_constraint(tree):
                return jax.tree_util.tree_map(
                    lambda x, spec: jax.lax.with_sharding_constraint(x, spec),
                    tree, gspecs,
                    is_leaf=lambda x: x is None,
                )
        step = make_accum_train_step(cfg, optimizer, opts, microbatches=mb,
                                     accum_dtype=adt, grad_constraint=grad_constraint)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return jitted, (abstract_params, abstract_opt, batch_sds), mb

    if shape.kind == "prefill":
        b_specs = batch_pspecs(mesh, batch_sds, seq_shard=seq_shard)
        b_sh = shardings_for(mesh, b_specs)

        def prefill(params, batch):
            logits, _ = forward(cfg, params, batch, opts, head_positions="last")
            return logits

        jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        return jitted, (abstract_params, batch_sds), mb

    # decode
    abstract_cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    c_specs = cache_pspecs(mesh, abstract_cache, batch=B)
    c_sh = shardings_for(mesh, c_specs)
    cc = _decode_cache_constraints(cfg, mesh, B, S)
    d_opts = _opts(mesh, cache_constraints=cc)
    if B % _dp(mesh) != 0 or B == 1:
        d_opts = ModelOptions(
            remat=d_opts.remat, use_flash=d_opts.use_flash,
            attn_chunk=d_opts.attn_chunk,
            shard=ShardingPolicy(batch_axes=None, model_axis="model"),
            cache_constraints=cc,
        )
    tok_spec = P(batch_axes(mesh)) if B % _dp(mesh) == 0 and B > 1 else P()
    tok_sh = NamedSharding(mesh, tok_spec)

    def step(params, cache, tokens, pos):
        return serve_step(cfg, params, cache, tokens, pos, d_opts)

    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, tok_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    sds = input_specs(arch, shape_name)
    return jitted, (abstract_params, abstract_cache, sds["tokens"], sds["pos"]), mb


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N_active D for train; 2 N_active D for inference."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


# ---------------------------------------------------------------------------
# Cell runner.
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, *, fsdp: bool = True,
             variant: str = "v2", cost: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "variant": variant, "status": "skipped", "reason": reason}
    zero1 = variant.startswith("v3")
    if zero1:
        fsdp = False  # ZeRO-1: TP-only params, data-sharded moments
    t0 = time.time()
    with mesh:
        jitted, args, mb = build_cell(arch, shape_name, mesh, fsdp=fsdp,
                                      zero1=zero1)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = memory_summary(compiled)
        full_cost = _cost_of(compiled, structured_coll=True)
    t_mem = time.time() - t0
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "status": "ok",
        "n_devices": mesh.size,
        "microbatches": mb,
        "fsdp": fsdp,
        "compile_s": round(t_mem, 1),
        "memory": mem,
        "fits_hbm": mem["total_hbm_bytes"] <= HBM_BUDGET,
        "full_module_cost": full_cost,
    }
    if cost:
        terms = build_cost_terms(cfg, shape, mesh, fsdp=fsdp, microbatches=mb,
                                 full_cost=full_cost)
        # collectives: trust the structured full-module count (captures XLA's
        # all-reduce hoisting out of the accumulation loop); flops/bytes come
        # from the per-layer assembly (real per-iteration execution).
        terms["coll"] = full_cost["coll"]
        mf = model_flops_for(cfg, shape)
        compute_s = terms["flops"] / PEAK_FLOPS
        memory_s = terms["bytes"] / HBM_BW
        memory_s_kernel = terms.get("bytes_kernel", terms["bytes"]) / HBM_BW
        al = _abstract_layer(cfg)
        _, l_sh_tmp = _layer_param_shardings(cfg, mesh, False)
        l_specs_tmp = jax.tree_util.tree_map(lambda sh: sh.spec, l_sh_tmp)
        analytic_bytes = _analytic_memory_bytes(
            cfg, shape, mesh, microbatches=mb, al=al, l_specs=l_specs_tmp
        )
        memory_s_analytic = (
            analytic_bytes / HBM_BW if shape.kind != "decode" else memory_s_kernel
        )
        collective_s = terms["coll"] / LINK_BW
        tdict = {"compute": compute_s, "memory": memory_s_analytic,
                 "collective": collective_s}
        dominant = max(tdict, key=tdict.get)
        rec["roofline"] = {
            "flops": terms["flops"],
            "bytes_accessed": terms["bytes"],
            "bytes_accessed_kernel": terms.get("bytes_kernel", terms["bytes"]),
            "coll_bytes": terms["coll"],
            "transcendentals": terms["transcendentals"],
            "compute_s": compute_s,
            "memory_s_hlo_prefusion": memory_s,
            "memory_s_kernel_prefusion": memory_s_kernel,
            "memory_s": memory_s_analytic,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": mf,
            "useful_ratio": mf / (terms["flops"] * mesh.size) if terms["flops"] else None,
            "peak_fraction": compute_s / max(max(tdict.values()), 1e-30),
        }
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def save(rec: dict):
    d = os.path.join(os.path.abspath(RESULTS_DIR), rec["mesh"])
    os.makedirs(d, exist_ok=True)
    v = rec.get("variant", "baseline")
    suffix = "" if v in ("baseline", "") else f"__{v}"
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--variant", default="v2")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in list_archs():
            for s in ALL_SHAPES:
                cells.append((a, s.name))
    else:
        cells.append((args.arch, args.shape))

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch, shape_name in cells:
            suffix = "" if args.variant in ("baseline", "") else f"__{args.variant}"
            out = os.path.join(os.path.abspath(RESULTS_DIR), mesh_kind,
                               f"{arch}__{shape_name}{suffix}.json")
            if args.skip_existing and os.path.exists(out):
                print(f"[skip-existing] {mesh_kind} {arch} {shape_name}", flush=True)
                continue
            try:
                rec = run_cell(arch, shape_name, mesh_kind, fsdp=not args.no_fsdp,
                               variant=args.variant, cost=not args.no_cost)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                       "variant": args.variant, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            save(rec)
            tag = rec["status"]
            n_ok += tag == "ok"
            n_skip += tag == "skipped"
            n_fail += tag == "error"
            extra = ""
            if tag == "ok":
                extra = (f" hbm={rec['memory']['total_hbm_bytes']/2**30:.2f}GiB"
                         f" fits={rec['fits_hbm']} mb={rec['microbatches']}")
                if "roofline" in rec:
                    r = rec["roofline"]
                    extra += (f" dom={r['dominant']} comp={r['compute_s']*1e3:.1f}ms"
                              f" mem={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms"
                              f" useful={r['useful_ratio']:.2f}" if r.get("useful_ratio") else "")
            elif tag == "error":
                extra = " " + rec["error"][:160]
            print(f"[{tag}] {mesh_kind:6s} {arch:20s} {shape_name:12s}{extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
