"""Render the §Dry-run / §Roofline tables from launch_results/ JSON records.

    python -m repro.launch.report [--mesh single] [--variant final] [--md]
"""
import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "launch_results")


def load(mesh: str, variant: str):
    recs = []
    pat = os.path.join(os.path.abspath(RESULTS_DIR), mesh, f"*__{variant}.json")
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    if r["status"] == "skipped":
        return (r["arch"], r["shape"], "skip", r.get("reason", ""), "", "", "", "", "", "")
    if r["status"] != "ok":
        return (r["arch"], r["shape"], "ERR", r.get("error", "")[:40], "", "", "", "", "", "")
    roof = r.get("roofline", {})
    mem = r["memory"]
    return (
        r["arch"],
        r["shape"],
        "ok",
        f"{mem['total_hbm_bytes']/2**30:.1f}",
        "Y" if r.get("fits_hbm") else "N",
        f"{roof.get('compute_s', 0)*1e3:.1f}",
        f"{roof.get('memory_s', 0)*1e3:.1f}",
        f"{roof.get('collective_s', 0)*1e3:.1f}",
        roof.get("dominant", "?")[:4],
        f"{roof.get('useful_ratio') or 0:.2f}",
    )


HDR = ("arch", "shape", "st", "HBM(GiB)", "fit", "comp(ms)", "mem(ms)", "coll(ms)",
       "dom", "useful")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="final")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh, args.variant)
    rows = [fmt_row(r) for r in recs]
    if args.md:
        print("| " + " | ".join(HDR) + " |")
        print("|" + "---|" * len(HDR))
        for row in rows:
            print("| " + " | ".join(str(x) for x in row) + " |")
    else:
        w = [max(len(str(r[i])) for r in rows + [HDR]) for i in range(len(HDR))]
        print("  ".join(h.ljust(w[i]) for i, h in enumerate(HDR)))
        for row in rows:
            print("  ".join(str(x).ljust(w[i]) for i, x in enumerate(row)))
    ok = [r for r in recs if r["status"] == "ok"]
    fits = [r for r in ok if r.get("fits_hbm")]
    print(f"\n{args.mesh}/{args.variant}: {len(ok)} ok, "
          f"{sum(1 for r in recs if r['status']=='skipped')} skipped (documented), "
          f"{len(ok)-len(fits)} over HBM budget")


if __name__ == "__main__":
    main()
