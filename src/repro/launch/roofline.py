"""Roofline-term extraction from compiled XLA artifacts.

Per (arch x shape x mesh) cell we derive the three-term roofline from the
SPMD-partitioned module (all quantities per device):

    compute_s    = HLO_FLOPs        / PEAK_FLOPS      (197 TFLOP/s bf16, v5e)
    memory_s     = HLO_bytes        / HBM_BW          (819 GB/s)
    collective_s = collective_bytes / LINK_BW         (~50 GB/s/link ICI)

``cost_analysis`` provides flops & bytes; collective bytes are parsed from
the post-optimisation HLO text (result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"conditional\(.*?\), (?:true_computation=%?([\w.\-]+), false_computation=%?([\w.\-]+)|branch_computations=\{([^}]*)\})")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """Map computation-name -> body lines.  Computation headers sit at indent
    0 and end with '{'; the name is the first %-token (or the token after
    ENTRY).  Handles nested parens in parameter tuple types."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            name = None
            for tok in line.split():
                if tok.startswith("%"):
                    name = tok.lstrip("%").split("(")[0]
                    break
            if name is None:
                first = line.split()[0]
                if first not in ("ENTRY", "HloModule"):
                    name = first.split("(")[0]
            cur = name
            if cur is not None:
                comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
            if line.strip() == "}":
                cur = None
    return comps


def _line_collective_bytes(line: str) -> int:
    s = line.strip()
    if " = " not in s:
        return 0
    rhs = s.split(" = ", 1)[1]
    for kind in _COLLECTIVES:
        idx = rhs.find(f" {kind}(")
        if idx < 0:
            idx = rhs.find(f" {kind}-start(")
        if idx >= 0:
            return _shape_bytes(rhs[:idx])
    return 0


def collective_bytes_structured(hlo_text: str) -> float:
    """Collective result-bytes with while-loop trip counts applied.

    XLA cost/byte analyses count a loop body once; collectives inside a
    scanned-layer loop really fire once *per iteration* — except when XLA's
    all-reduce code motion hoists them out, which this structural count
    respects because it reads the *post-optimisation* module.  Trip counts
    are read from each loop condition's ``constant(N) / compare(LT)``
    (exact for lax.scan-generated loops).  ``conditional`` branches are
    counted at full weight (upper bound).
    """
    comps = _split_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = [int(m.group(1)) for l in lines for m in _CONST_RE.finditer(l)]
        return max(consts) if consts else 1

    memo: Dict[str, float] = {}

    def eff(name: str, stack=()) -> float:
        if name in memo:
            return memo[name]
        if name in stack:
            return 0.0
        total = 0.0
        for line in comps.get(name, []):
            total += _line_collective_bytes(line)
            wm = _WHILE_RE.search(line)
            if wm:
                total += trip_count(wm.group(1)) * eff(wm.group(2), stack + (name,))
            cm = _COND_BRANCH_RE.search(line)
            if cm:
                branches = [b for b in (cm.group(1), cm.group(2)) if b]
                if cm.group(3):
                    branches = [b.strip().lstrip("%") for b in cm.group(3).split(",")]
                for b in branches:
                    total += eff(b, stack + (name,))
        memo[name] = total
        return total

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            for tok in line.split():
                if tok.startswith("%"):
                    entry = tok.lstrip("%").split("(")[0]
                    break
            break
    if entry is None:
        return float(sum(v for k, v in collective_bytes(hlo_text).items() if k != "count"))
    return eff(entry)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes, summed over the module (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs_rhs = s.split(" = ", 1)[1]
        for kind in _COLLECTIVES:
            # match '<type> <kind>(' — `kind-start`/`kind-done` pairs count once
            idx = lhs_rhs.find(f" {kind}(")
            if idx < 0:
                idx = lhs_rhs.find(f" {kind}-start(")
                if idx < 0:
                    continue
            type_str = lhs_rhs[:idx]
            out[kind] += _shape_bytes(type_str)
            out["count"] += 1
            break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: float  # per device
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None  # 6 N D (global, useful-work estimate)
    useful_ratio: Optional[float] = None
    peak_fraction: Optional[float] = None  # compute_s / max(all terms)

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_devices: int, model_flops: Optional[float] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cbytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / (flops * n_devices) if flops else None
    peak_fraction = compute_s / max(max(terms.values()), 1e-30)
    return Roofline(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=cbytes,
        coll_breakdown=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        peak_fraction=peak_fraction,
    )


def memory_summary(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        out[attr] = float(getattr(m, attr, 0) or 0)
    out["total_hbm_bytes"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out.get("alias_size_in_bytes", 0.0)
    )
    return out
