"""Serving launcher: batched continuous decoding.

    python -m repro.launch.serve --arch qwen3-1.7b --requests 8
"""
import argparse
import time

import jax

from repro.configs import get_arch
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(slots=args.slots, max_len=args.max_len))
    t0 = time.time()
    for i in range(args.requests):
        eng.submit([2 + i % 50, 7, 11])
    done = eng.run()
    n_tok = sum(len(v) for v in done.values())
    dt = time.time() - t0
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s on CPU smoke config)")


if __name__ == "__main__":
    main()
