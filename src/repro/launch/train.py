"""Production training launcher.

    python -m repro.launch.train --arch olmo-1b --steps 100 [--smoke]
    python -m repro.launch.train --arch qwen3-1.7b --mesh single  # on a pod

On real hardware the mesh axes map onto the pod topology and the same code
runs under ``jax.distributed.initialize()`` (multi-host); on this CPU host use
``--smoke`` (reduced config, 1 device) — the full configs are exercised by
``repro.launch.dryrun`` (ShapeDtypeStruct only, no allocation).
"""
import argparse

import jax

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import ModelOptions, ShardingPolicy, init_params
from repro.optim import adamw, cosine_schedule
from repro.train.trainer import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--steps-per-call", type=int, default=1,
                    help="optimizer steps fused into one jit dispatch "
                         "(lax.scan over stacked batches; bitwise-equal to "
                         "sequential steps, fewer host round trips)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={jax.device_count()}")

    data = SyntheticTokens(DataConfig(args.batch, args.seq, cfg.vocab))
    out = train_loop(
        cfg,
        params,
        data,
        optimizer=adamw(cosine_schedule(args.lr, 10, args.steps)),
        opts=ModelOptions(remat=True),
        loop=TrainLoopConfig(
            steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=max(args.steps // 2, 1),
            microbatches=args.microbatches,
            log_every=max(args.steps // 10, 1),
            steps_per_call=args.steps_per_call,
        ),
    )
    for step, loss in out["losses"]:
        print(f"step {step:5d}  loss {loss:.4f}")
    print(f"wall: {out['wall_s']:.1f}s  dispatches: {out['n_dispatches']}")


if __name__ == "__main__":
    main()
