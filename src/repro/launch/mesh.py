"""Production mesh + sharding rules (DP x TP x EP x SP over (pod, data, model)).

``make_production_mesh`` is a *function* so importing this module never
touches jax device state.  Sharding is rule-based over parameter paths:

  embeddings       vocab on "model"            (vocab-parallel head + loss)
  attention q/k/v  columns on "model"          (head-parallel)
  attention o      rows on "model"
  mlp up/gate      columns on "model"          (megatron TP)
  mlp down         rows on "model"
  MoE experts      expert axis on "model"      (EP; all-to-all at dispatch)
  mamba z/x/B/C    columns on "model"          (d_inner / d_state parallel)
  mamba out        rows on "model"
  per-head vectors "model" when divisible else replicated
  norms / biases   replicated
  (+ optional FSDP: remaining big axis on "data", ZeRO-3 style)

Every rule is divisibility-guarded: a dim that does not divide the mesh axis
falls back to replication for that dim (e.g. mamba2-130m's vocab=50280 on a
16-way axis).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "make_production_mesh",
    "make_sample_mesh",
    "make_train_mesh",
    "axis_size",
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "shardings_for",
    "opt_state_pspecs",
]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sample_mesh(n_devices: Optional[int] = None, axis: str = "mc") -> Mesh:
    """1-D Monte-Carlo sampling mesh: ``n_devices`` devices on one axis.

    Trajectory fan-out is embarrassingly parallel, so sampling workloads
    (``sdeint(..., mesh_axis=...)``, the serving engine's sharded slots, the
    throughput bench's multi-device ladder) shard a single batch axis — no
    model axis needed.  Defaults to every visible device.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"n_devices={n_devices} not in [1, {len(devices)}] visible devices"
        )
    return Mesh(np.array(devices[:n]), (axis,))


def make_train_mesh(n_devices: Optional[int] = None, axis: str = "dp") -> Mesh:
    """1-D data-parallel training mesh for the scanned SDE train step.

    Same embarrassingly-parallel shape as :func:`make_sample_mesh` — the
    trainer shards the Monte-Carlo *path* axis, not the model — but named
    ``"dp"`` by convention so launch configs read as data parallelism.
    Feed it to ``make_sde_train_step(..., mesh=make_train_mesh(),
    mesh_axis="dp")``; gradients come back bitwise-equal to the
    single-device step (see ``docs/performance.md``).
    """
    return make_sample_mesh(n_devices, axis=axis)


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _safe(mesh: Mesh, shape, spec_entries):
    """Drop shardings whose axis size does not divide the dim."""
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
        elif dim % axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter sharding.
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wg", "wu", "wz", "wx", "wB", "wC", "wdt", "conv_x",
        "conv_B", "conv_C"}
_ROW = {"wo", "wd", "out_proj"}
_HEADVEC = {"bq", "bk", "bv", "conv_bx", "conv_bB", "conv_bC", "A_log", "D",
            "dt_bias", "gate_norm"}


def _leaf_spec(mesh, name: str, shape, *, fsdp: Optional[str], stacked: bool):
    eff = shape[1:] if stacked else shape
    model = "model"

    def done(entries):
        if stacked:
            entries = (None,) + tuple(entries)
        return _safe(mesh, shape, entries)

    if name == "embed":
        return _safe(mesh, shape, (model, fsdp))
    if name == "lm_head":
        return _safe(mesh, shape, (fsdp, model))
    if name in ("vision_proj", "frame_proj"):
        return _safe(mesh, shape, (None, model))
    if name == "router":
        return done((None,) * len(eff))
    if name in _COL:
        if len(eff) == 3:  # MoE expert-stacked (E, d, f): EP on experts
            return done((model, fsdp, None))
        return done((fsdp, model))
    if name in _ROW:
        if len(eff) == 3:  # (E, f, d)
            return done((model, None, fsdp))
        return done((model, fsdp))
    if name in _HEADVEC:
        return done((model,) * 1 + (None,) * (len(eff) - 1))
    # norms and anything unrecognised: replicate
    return done((None,) * len(eff))


def param_pspecs(mesh: Mesh, abstract_params, *, fsdp: bool = False):
    """Pytree of PartitionSpec matching an (abstract) params tree."""
    fsdp_axis = "data" if fsdp else None

    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        stacked = "layers" in keys
        return _leaf_spec(mesh, keys[-1], leaf.shape, fsdp=fsdp_axis, stacked=stacked)

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def opt_state_pspecs(mesh: Mesh, abstract_opt_state, pspecs_params, *, zero1: bool = False):
    """OptState(step, mu, nu) sharded like the params.

    ``zero1``: additionally shard the moments over the "data" axis (ZeRO-1) —
    params stay TP-only (replicated across data) so no per-microbatch weight
    all-gather; only the updated params are gathered once per step.
    """
    from repro.optim.optimizers import OptState

    if not zero1:
        return OptState(step=P(), mu=pspecs_params, nu=pspecs_params)

    def extend(spec, leaf):
        entries = list(tuple(spec)) + [None] * (len(leaf.shape) - len(tuple(spec)))
        for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
            if e is None and dim % axis_size(mesh, "data") == 0 and dim > 1:
                entries[i] = "data"
                break
        return _safe(mesh, leaf.shape, entries)

    mu = jax.tree_util.tree_map(
        extend, pspecs_params, abstract_opt_state.mu,
        is_leaf=lambda x: isinstance(x, P),
    )
    return OptState(step=P(), mu=mu, nu=mu)


# ---------------------------------------------------------------------------
# Batch / cache sharding.
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pspecs(mesh: Mesh, abstract_batch, *, seq_shard: bool = False):
    """tokens/labels (B, S, ...): batch on (pod, data); optionally SP on seq."""
    ba = batch_axes(mesh)

    def assign(path, leaf):
        dims = len(leaf.shape)
        if seq_shard and dims >= 2:
            entries = (None, ba) + (None,) * (dims - 2)
        else:
            entries = (ba,) + (None,) * (dims - 1)
        return _safe(mesh, leaf.shape, entries)

    return jax.tree_util.tree_map_with_path(assign, abstract_batch)


def cache_pspecs(mesh: Mesh, abstract_cache, *, batch: int):
    """Decode caches: batch-shard when divisible, else shard heads/state on
    "model" and sequence on data (the long_500k layout)."""
    ba = batch_axes(mesh)
    batch_ok = batch % axis_size(mesh, ba) == 0 and batch > 1

    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        d = len(shape)
        if name in ("k", "v", "shared_k", "shared_v"):
            # (L|inv, B, KV, S, hd): batch on data; heads on model when they
            # divide, otherwise sequence on model (split-k decode attention,
            # softmax partial-sums psum over "model").
            if batch_ok:
                spec = _safe(mesh, shape, (None, ba, "model", None, None))
                if spec[2] is None:
                    spec = _safe(mesh, shape, (None, ba, None, "model", None))
                return spec
            spec = _safe(mesh, shape, (None, None, "model", ba, None))
            if spec[2] is None:
                return _safe(mesh, shape, (None, None, None, (ba + ("model",)) if isinstance(ba, tuple) else (ba, "model"), None))
            return spec
        if name == "state":  # (L, B, nh, dh, ds)
            if batch_ok:
                return _safe(mesh, shape, (None, ba, "model", None, None))
            spec = _safe(mesh, shape, (None, None, "model", None, None))
            if spec[2] is None:  # nh not divisible: shard the state dim
                spec = _safe(mesh, shape, (None, None, None, None, "model"))
            return spec
        if name.startswith("conv_"):  # (L, B, K-1, di|ds)
            if batch_ok:
                return _safe(mesh, shape, (None, ba, None, "model"))
            return _safe(mesh, shape, (None, None, None, "model"))
        return P(*([None] * d))

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)


def shardings_for(mesh: Mesh, pspecs):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
