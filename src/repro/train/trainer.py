"""Training loop: jit'd step, gradient accumulation, checkpoint/restart.

The step function is built once (``make_train_step``) and jit'd with donated
(params, opt_state) buffers; microbatch gradient accumulation runs as a
``lax.scan`` over the leading microbatch axis *inside* the jit so accumulation
never round-trips to host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelOptions, loss_fn, make_train_step
from repro.optim import adamw, cosine_schedule
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import recovery_plan

__all__ = ["TrainLoopConfig", "train_loop", "make_accum_train_step",
           "make_sde_train_step", "ResilienceConfig", "resilient_train_loop"]


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    microbatches: int = 1  # gradient-accumulation factor
    log_every: int = 10


def make_accum_train_step(cfg, optimizer, opts: ModelOptions, microbatches: int = 1,
                          accum_dtype=None, grad_constraint=None):
    """train_step with in-jit gradient accumulation over ``microbatches``.

    ``accum_dtype``: dtype of the gradient-accumulation buffer (default f32;
    bf16 halves the buffer for >16B-param models at ~8-bit mantissa cost over
    <=32 microbatches — noted in EXPERIMENTS.md §Perf).

    ``grad_constraint``: optional fn applied to the accumulation carry each
    microbatch.  Passing a data-axis sharding constraint turns the
    per-microbatch gradient all-reduce into a reduce-scatter onto a sharded
    buffer (ZeRO-2): 1/dp the buffer memory and ~half the bytes on the wire;
    the optimizer then updates shard-locally and params all-gather once."""
    if microbatches <= 1:
        return make_train_step(cfg, optimizer, opts)
    import jax.numpy as _jnp

    adt = accum_dtype or _jnp.float32
    constrain = grad_constraint or (lambda t: t)

    def step(params, opt_state, batch):
        # batch leaves: (microbatches, local_batch/mb, ...)
        def acc(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, mb, opts))(params)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(adt), gsum, g
            )
            gsum = constrain(gsum)
            return (gsum, lsum + loss), None

        zeros = constrain(
            jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, adt), params)
        )
        (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros(())), batch)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": lsum / microbatches, "grad_norm": gnorm}

    return step


def make_sde_train_step(
    solver,
    term,
    optimizer,
    y0_fn: Callable,
    loss_fn_result: Callable,
    *,
    t0: float,
    t1: float,
    n_steps: int,
    n_paths: int,
    adjoint: str = "reversible",
    save_every: Optional[int] = None,
    save_at=None,
    rtol: Optional[float] = None,
    atol: Optional[float] = None,
    remat_chunk: Optional[int] = None,
    bulk_increments: bool = True,
    noise_shape=None,
    guard: bool = True,
):
    """Neural-SDE analogue of ``make_train_step``: one Monte-Carlo batch of
    ``n_paths`` trajectories through ``sdeint``, a loss on the result, one
    optimizer update.

    ``solver`` is a registry spec string (``"ees25"``, ``"mcf-rk4"``,
    ``"ees25:adaptive"``, ...) or a solver object; ``y0_fn(params)`` produces
    the (shared) initial state; ``loss_fn_result(params, result)`` maps the
    batched result (leading axis ``n_paths``) to a scalar.  The returned step
    is ``(params, opt_state, key) -> (params, opt_state, metrics)`` and is
    jit-compatible; each path derives its key by ``fold_in``, matching the
    serving engine's convention.

    Adaptive solves (an ``:adaptive`` spec) take ``rtol``/``atol`` and a
    ``save_at`` output grid, with ``n_steps`` as the trial-step budget.  Every
    adjoint works on them — each path realizes its accepted-step grid
    (gradient-stopped controller) and the backward pass runs over that
    realized grid, so the default O(1)-memory ``"reversible"`` adjoint now
    trains on adaptive grids too (tolerance-driven step placement *and*
    constant trajectory memory in one step function).

    ``bulk_increments`` (default ``True``) is the PR-4 throughput
    configuration: all Brownian increments realized in one batched pass and
    streamed through the solve — see ``docs/performance.md``.  Set it
    ``False`` for the strict memory-lean configuration (per-step noise
    recompute, no O(n_steps x noise) buffer in the backward residuals).

    ``guard`` (default ``True``) is the trainer half of the PR-9 divergence
    guard (``docs/robustness.md``): when the loss or any gradient leaf comes
    back non-finite, the optimizer update is **skipped** — params and
    opt_state pass through unchanged — and ``metrics["skipped"]`` is 1.
    One blown Monte-Carlo batch then costs one wasted step instead of
    poisoning the parameters (every later step would be NaN).  The guard is
    in-jit (a ``where`` select, no host sync) and bitwise-inert on finite
    steps: ``where(True, new, old)`` is ``new``.  Pair it with
    :func:`resilient_train_loop` for checkpoint rollback when skips persist.
    """
    from repro.core import get_solver, sdeint
    from repro.core.pytree import tree_blowup

    solver = get_solver(solver)
    extra = {}
    if rtol is not None:
        extra["rtol"] = rtol
    if atol is not None:
        extra["atol"] = atol
    if save_at is not None:
        extra["save_at"] = jnp.asarray(save_at)
    if remat_chunk is not None:
        extra["remat_chunk"] = remat_chunk
    extra["bulk_increments"] = bulk_increments

    def step(params, opt_state, key):
        def loss(p):
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                jnp.arange(n_paths)
            )
            r = sdeint(
                term, solver, t0, t1, n_steps, y0_fn(p), None, args=p,
                adjoint=adjoint, save_every=save_every,
                noise_shape=noise_shape, batch_keys=keys, **extra,
            )
            return loss_fn_result(p, r)

        l, g = jax.value_and_grad(loss)(params)
        if not guard:
            params, opt_state, gnorm = optimizer.update(g, opt_state, params)
            return params, opt_state, {"loss": l, "grad_norm": gnorm}
        bad = tree_blowup(g) | ~jnp.isfinite(l)
        new_p, new_s, gnorm = optimizer.update(g, opt_state, params)
        keep = lambda new, old: jnp.where(bad, old, new)  # noqa: E731
        params = jax.tree_util.tree_map(keep, new_p, params)
        opt_state = jax.tree_util.tree_map(keep, new_s, opt_state)
        return params, opt_state, {"loss": l, "grad_norm": gnorm,
                                   "skipped": bad}

    return step


def train_loop(
    cfg,
    params,
    data_iter,
    *,
    optimizer=None,
    opts: ModelOptions = ModelOptions(),
    loop: TrainLoopConfig = TrainLoopConfig(),
    step_fn: Optional[Callable] = None,
    to_device: Callable = lambda b: b,
) -> Dict[str, Any]:
    optimizer = optimizer or adamw(cosine_schedule(3e-4, 10, loop.steps))
    opt_state = optimizer.init(params)
    start = 0
    if loop.ckpt_dir:
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            params, opt_state = restore_checkpoint(
                loop.ckpt_dir, last, (params, opt_state)
            )
            start = last
    step_fn = step_fn or make_accum_train_step(cfg, optimizer, opts, loop.microbatches)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    # Step-pure sources (batch_at) give exact replay after restart; plain
    # iterators are only correct for fresh runs.
    step_pure = hasattr(data_iter, "batch_at")
    it = None if step_pure else iter(data_iter)
    for step in range(start, loop.steps):
        batch = to_device(data_iter.batch_at(step) if step_pure else next(it))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % loop.log_every == 0 or step == loop.steps - 1:
            losses.append((step + 1, float(metrics["loss"])))
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            save_checkpoint(loop.ckpt_dir, step + 1, (params, opt_state))
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "wall_s": time.time() - t0,
    }


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for :func:`resilient_train_loop` (see ``docs/robustness.md``).

    ``skip_patience`` consecutive guard-skipped steps trigger a rollback to
    the latest checkpoint (the blow-up evidently was not a one-off batch);
    checkpoints are written every ``ckpt_every`` *productive* boundaries so a
    rollback never restores a state reached through skipped steps.
    ``mesh_shape`` / ``hosts_per_pod`` feed :func:`recovery_plan` when the
    heartbeat monitor reports dead hosts."""

    steps: int = 100
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    skip_patience: int = 3
    mesh_shape: tuple = (1, 1, 1)
    hosts_per_pod: int = 1


def resilient_train_loop(
    step_fn: Callable,
    params,
    opt_state,
    key,
    *,
    res: ResilienceConfig = ResilienceConfig(),
    monitor=None,
    tracker=None,
    host: int = 0,
) -> Dict[str, Any]:
    """Drive a guarded SDE train step with skip-streak rollback and fleet
    health bookkeeping — the trainer-side divergence story (PR 9).

    ``step_fn`` is a (possibly jit'd) ``make_sde_train_step`` product:
    ``(params, opt_state, key) -> (params, opt_state, metrics)``.  Step
    ``i`` uses ``fold_in(key, i)``, so the trajectory is reproducible and a
    rollback replays the identical keys it first saw.

    Per step, the loop records the step time into ``tracker``
    (:class:`~repro.train.fault_tolerance.StragglerTracker`) and beats
    ``monitor`` (:class:`~repro.train.fault_tolerance.HeartbeatMonitor`);
    when the monitor reports dead hosts, a
    :func:`~repro.train.fault_tolerance.recovery_plan` is computed against
    ``res.mesh_shape`` and appended to the history (the launcher acts on
    it; this in-process loop keeps training its own shard).

    The guard's ``metrics["skipped"]`` drives the rollback policy: after
    ``res.skip_patience`` consecutive skips the loop restores the latest
    checkpoint under ``res.ckpt_dir`` (written every ``res.ckpt_every``
    productive steps, plus one at step 0 so rollback is always possible)
    and continues.  Returns params/opt_state plus a history dict — per-step
    ``losses`` and ``skipped`` flags, ``rollbacks``, ``recovery_plans``,
    and ``goodput`` (productive steps / total steps: the resilience metric
    ``benchmarks/bench_resilience.py`` sweeps against fault rate)."""
    history: Dict[str, Any] = {"losses": [], "skipped": [], "rollbacks": 0,
                               "recovery_plans": []}
    if res.ckpt_dir:
        save_checkpoint(res.ckpt_dir, 0, (params, opt_state))
    streak = 0
    productive = 0
    for step in range(res.steps):
        k = jax.random.fold_in(key, step)
        t_step = time.monotonic()
        params, opt_state, metrics = step_fn(params, opt_state, k)
        skipped = bool(np.asarray(metrics.get("skipped", False)))
        dt = time.monotonic() - t_step
        if tracker is not None:
            tracker.record(host, dt)
        if monitor is not None:
            monitor.beat(host)
            dead = monitor.dead_hosts()
            if dead:
                history["recovery_plans"].append(recovery_plan(
                    res.mesh_shape, res.hosts_per_pod, dead,
                    (latest_step(res.ckpt_dir) or 0) if res.ckpt_dir else 0))
        history["losses"].append(float(metrics["loss"]))
        history["skipped"].append(skipped)
        if skipped:
            streak += 1
            if streak >= res.skip_patience and res.ckpt_dir:
                last = latest_step(res.ckpt_dir)
                if last is not None:
                    params, opt_state = restore_checkpoint(
                        res.ckpt_dir, last, (params, opt_state))
                    history["rollbacks"] += 1
                    streak = 0
        else:
            streak = 0
            productive += 1
            if res.ckpt_dir and (step + 1) % res.ckpt_every == 0:
                save_checkpoint(res.ckpt_dir, step + 1, (params, opt_state))
    history["goodput"] = productive / max(res.steps, 1)
    return {"params": params, "opt_state": opt_state, **history}
