"""Training loop: jit'd step, step scanning, grad accumulation, checkpointing.

Three layers, each optional, all composable (PR 10 — the training-side twin
of the serving plane's multi-tick dispatch):

* ``make_sde_train_step`` — ONE optimizer update from one Monte-Carlo batch,
  with in-jit gradient accumulation over ``microbatches`` of the path axis
  (remat'd, so ``n_paths`` beyond memory still trains) and an optional
  mesh-sharded data-parallel variant (``mesh``/``mesh_axis``) that shards the
  path axis over devices with **bitwise-identical** loss and gradients to the
  single-device step (per-path gradients are gathered and reduced in the same
  order a single device reduces them — no ``psum`` reassociation).
* ``make_scanned_step`` — ``steps_per_call=K`` optimizer updates inside one
  jit'd ``lax.scan`` with a donated ``(params, opt_state, counters)`` carry:
  one host round trip per K steps instead of per step.  Metrics histories
  (loss / grad-norm / skipped) accumulate on device and are fetched once per
  chunk.  Scanned chunks are bitwise-equal to sequential steps (tested for
  all three adjoints, fixed and adaptive grids), so ``K`` is a pure
  throughput knob — it never changes the trajectory.
* ``train_loop`` / ``resilient_train_loop`` — host-side driving, chunked
  when ``steps_per_call > 1``: checkpoint cadence moves to chunk boundaries,
  the PR-9 skip guard's rollback/streak logic runs at chunk granularity from
  the per-chunk ``skipped`` history, and metric fetches are batched (no
  per-step blocking ``float(...)`` sync; ``n_dispatches`` in the result is
  the regression-tested dispatch count).
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelOptions, loss_fn, make_train_step
from repro.optim import adamw, cosine_schedule
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import recovery_plan

__all__ = ["TrainLoopConfig", "train_loop", "make_accum_train_step",
           "make_sde_train_step", "make_scanned_step", "init_scan_counters",
           "ResilienceConfig", "resilient_train_loop"]


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    microbatches: int = 1  # gradient-accumulation factor
    log_every: int = 10
    steps_per_call: int = 1  # optimizer steps fused into one jit dispatch


def make_accum_train_step(cfg, optimizer, opts: ModelOptions, microbatches: int = 1,
                          accum_dtype=None, grad_constraint=None):
    """train_step with in-jit gradient accumulation over ``microbatches``.

    ``accum_dtype``: dtype of the gradient-accumulation buffer (default f32;
    bf16 halves the buffer for >16B-param models at ~8-bit mantissa cost over
    <=32 microbatches — noted in EXPERIMENTS.md §Perf).

    ``grad_constraint``: optional fn applied to the accumulation carry each
    microbatch.  Passing a data-axis sharding constraint turns the
    per-microbatch gradient all-reduce into a reduce-scatter onto a sharded
    buffer (ZeRO-2): 1/dp the buffer memory and ~half the bytes on the wire;
    the optimizer then updates shard-locally and params all-gather once."""
    if microbatches <= 1:
        return make_train_step(cfg, optimizer, opts)
    import jax.numpy as _jnp

    adt = accum_dtype or _jnp.float32
    constrain = grad_constraint or (lambda t: t)

    def step(params, opt_state, batch):
        # batch leaves: (microbatches, local_batch/mb, ...)
        def acc(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, mb, opts))(params)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(adt), gsum, g
            )
            gsum = constrain(gsum)
            return (gsum, lsum + loss), None

        zeros = constrain(
            jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, adt), params)
        )
        (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros(())), batch)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": lsum / microbatches, "grad_norm": gnorm}

    return step


def make_sde_train_step(
    solver,
    term,
    optimizer,
    y0_fn: Callable,
    loss_fn_result: Callable,
    *,
    t0: float,
    t1: float,
    n_steps: int,
    n_paths: int,
    adjoint: str = "reversible",
    save_every: Optional[int] = None,
    save_at=None,
    rtol: Optional[float] = None,
    atol: Optional[float] = None,
    remat_chunk: Optional[int] = None,
    bulk_increments: bool = True,
    noise_shape=None,
    guard: bool = True,
    microbatches: int = 1,
    mesh=None,
    mesh_axis: Optional[str] = None,
):
    """Neural-SDE analogue of ``make_train_step``: one Monte-Carlo batch of
    ``n_paths`` trajectories through ``sdeint``, a loss on the result, one
    optimizer update.

    ``solver`` is a registry spec string (``"ees25"``, ``"mcf-rk4"``,
    ``"ees25:adaptive"``, ...) or a solver object; ``y0_fn(params)`` produces
    the (shared) initial state; ``loss_fn_result(params, result)`` maps the
    batched result (leading axis ``n_paths``) to a scalar.  The returned step
    is ``(params, opt_state, key) -> (params, opt_state, metrics)`` and is
    jit- and scan-compatible (``key`` may be a traced value — see
    :func:`make_scanned_step`); path ``i`` derives its key as
    ``path_keys(key, n_paths)[i]``, matching the serving engine's convention.

    Adaptive solves (an ``:adaptive`` spec) take ``rtol``/``atol`` and a
    ``save_at`` output grid, with ``n_steps`` as the trial-step budget.  Every
    adjoint works on them — each path realizes its accepted-step grid
    (gradient-stopped controller) and the backward pass runs over that
    realized grid, so the default O(1)-memory ``"reversible"`` adjoint now
    trains on adaptive grids too (tolerance-driven step placement *and*
    constant trajectory memory in one step function).

    ``bulk_increments`` (default ``True``) is the PR-4 throughput
    configuration: all Brownian increments realized in one batched pass and
    streamed through the solve — see ``docs/performance.md``.  Set it
    ``False`` for the strict memory-lean configuration (per-step noise
    recompute, no O(n_steps x noise) buffer in the backward residuals).

    ``guard`` (default ``True``) is the trainer half of the PR-9 divergence
    guard (``docs/robustness.md``): when the loss or any gradient leaf comes
    back non-finite, the optimizer update is **skipped** — params and
    opt_state pass through unchanged — and ``metrics["skipped"]`` is 1.
    One blown Monte-Carlo batch then costs one wasted step instead of
    poisoning the parameters (every later step would be NaN).  The guard is
    in-jit (one fused ``where``-select traversal over the joined
    ``(params, opt_state)`` tree, no host sync) and bitwise-inert on finite
    steps: ``where(True, new, old)`` is ``new``.  Pair it with
    :func:`resilient_train_loop` for checkpoint rollback when skips persist.

    ``microbatches`` > 1 accumulates gradients over that many equal slices of
    the path axis inside the jit (a remat'd ``lax.scan`` over per-slice
    ``value_and_grad``), trading compute scheduling for peak memory so
    ``n_paths`` beyond a device's capacity still trains.  The reported loss
    and gradient are the *mean over slices* — identical to the full batch in
    exact arithmetic for path-decomposable (mean-type) losses; cross-path
    moment losses see per-slice estimates (document the loss you train).

    ``mesh``/``mesh_axis`` shard the Monte-Carlo path axis over a device mesh
    (:func:`repro.launch.mesh.make_sample_mesh` /
    :func:`~repro.launch.mesh.make_train_mesh`) with ``shard_map``.  Loss and
    gradients are **bitwise-identical** to the single-device step: parameters
    are tiled per path, the sharded ``vjp`` yields *per-path* gradients (no
    in-``shard_map`` cross-path reduction, hence no ``psum`` reassociation),
    which are gathered to replicated and summed in the same order the
    single-device vmap transpose sums them.  Cross-path losses are supported
    — the loss runs on the gathered (replicated) result.
    """
    from repro.core import get_solver, sdeint
    from repro.core.pytree import tree_blowup
    from repro.core.sdeint import path_keys

    solver = get_solver(solver)
    extra = {}
    if rtol is not None:
        extra["rtol"] = rtol
    if atol is not None:
        extra["atol"] = atol
    if save_at is not None:
        extra["save_at"] = jnp.asarray(save_at)
    if remat_chunk is not None:
        extra["remat_chunk"] = remat_chunk
    extra["bulk_increments"] = bulk_increments

    microbatches = max(int(microbatches), 1)
    if n_paths % microbatches != 0:
        raise ValueError(
            f"microbatches={microbatches} does not divide n_paths={n_paths}"
        )
    chunk_paths = n_paths // microbatches

    if mesh_axis is not None:
        if mesh is None:
            raise ValueError(
                "mesh_axis given without mesh: pass mesh="
                "make_sample_mesh()/make_train_mesh() explicitly"
            )
        n_dev = mesh.shape[mesh_axis]
        if chunk_paths % n_dev != 0:
            raise ValueError(
                f"mesh axis {mesh_axis!r} of size {n_dev} does not divide "
                f"the per-microbatch path count {chunk_paths}"
            )
    elif mesh is not None:
        raise ValueError("mesh given without mesh_axis; name the axis to shard over")

    def batch_loss(p, keys):
        r = sdeint(
            term, solver, t0, t1, n_steps, y0_fn(p), None, args=p,
            adjoint=adjoint, save_every=save_every,
            noise_shape=noise_shape, batch_keys=keys, **extra,
        )
        return loss_fn_result(p, r)

    if mesh_axis is None:
        lg_fn = batch_loss if microbatches == 1 else jax.checkpoint(batch_loss)

        def value_and_grad_batch(params, keys):
            return jax.value_and_grad(lg_fn)(params, keys)
    else:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        try:  # jax <= 0.5
            from jax.experimental.shard_map import shard_map
        except ImportError:  # pragma: no cover — jax >= 0.6
            from jax import shard_map

        rep = NamedSharding(mesh, P())

        def one_path(p, k):
            return sdeint(
                term, solver, t0, t1, n_steps, y0_fn(p), k, args=p,
                adjoint=adjoint, save_every=save_every,
                noise_shape=noise_shape, **extra,
            )

        solve_tiled = shard_map(
            lambda pt, ks: jax.vmap(one_path)(pt, ks),
            mesh=mesh, in_specs=(P(mesh_axis), P(mesh_axis)),
            out_specs=P(mesh_axis), check_rep=False,
        )

        def value_and_grad_batch(params, keys):
            nb = jax.tree_util.tree_leaves(keys)[0].shape[0]
            p_t = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (nb,) + jnp.shape(x)), params
            )
            # vjp wrt the *tiled* params: the pullback returns per-path
            # gradients — the cross-path sum happens below, replicated, in
            # vmap-transpose order, which is what makes the sharded step
            # bitwise-equal to the single-device one.  Integer result leaves
            # (adaptive controller counts) ride along as vjp aux.
            cell = {}

            def fwd(pt):
                r = solve_tiled(pt, keys)
                leaves, treedef = jax.tree_util.tree_flatten(r)
                is_f = [jnp.issubdtype(l.dtype, jnp.inexact) for l in leaves]
                cell["treedef"], cell["is_f"] = treedef, is_f
                floats = [l for l, f in zip(leaves, is_f) if f]
                aux = [l for l, f in zip(leaves, is_f) if not f]
                return floats, aux

            floats, pull, aux = jax.vjp(fwd, p_t, has_aux=True)
            gather = lambda xs: [  # noqa: E731
                jax.lax.with_sharding_constraint(x, rep) for x in xs
            ]
            floats, aux = gather(floats), gather(aux)
            treedef, is_f = cell["treedef"], cell["is_f"]

            def merged_loss(pp, fls):
                fit, ait = iter(fls), iter(aux)
                leaves = [next(fit) if f else next(ait) for f in is_f]
                return loss_fn_result(pp, jax.tree_util.tree_unflatten(treedef, leaves))

            l, (g_direct, f_bar) = jax.value_and_grad(
                merged_loss, argnums=(0, 1))(params, floats)
            (g_t,) = pull(f_bar)
            g_t = jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(x, rep), g_t
            )
            g_paths = jax.tree_util.tree_map(lambda x: jnp.sum(x, 0), g_t)
            g = jax.tree_util.tree_map(lambda a, b: a + b, g_direct, g_paths)
            return l, g

    def step(params, opt_state, key):
        keys = path_keys(key, n_paths)
        if microbatches == 1:
            l, g = value_and_grad_batch(params, keys)
        else:
            kchunks = keys.reshape((microbatches, chunk_paths) + keys.shape[1:])

            def acc(gsum, kc):
                l, g = value_and_grad_batch(params, kc)
                gsum = jax.tree_util.tree_map(lambda a, b: a + b, gsum, g)
                return gsum, l

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(jnp.shape(p), jnp.result_type(p)), params
            )
            gsum, ls = jax.lax.scan(acc, zeros, kchunks)
            l = jnp.mean(ls)
            g = jax.tree_util.tree_map(lambda x: x / microbatches, gsum)

        if not guard:
            params, opt_state, gnorm = optimizer.update(g, opt_state, params)
            return params, opt_state, {"loss": l, "grad_norm": gnorm}
        bad = tree_blowup(g) | ~jnp.isfinite(l)
        new_p, new_s, gnorm = optimizer.update(g, opt_state, params)
        keep = lambda new, old: jnp.where(bad, old, new)  # noqa: E731
        params, opt_state = jax.tree_util.tree_map(
            keep, (new_p, new_s), (params, opt_state)
        )
        return params, opt_state, {"loss": l, "grad_norm": gnorm,
                                   "skipped": bad}

    return step


def init_scan_counters():
    """Device-resident counters threaded through a scanned step's carry:
    ``steps`` dispatched and guard-``skipped`` totals (int32 scalars)."""
    return {"steps": jnp.zeros((), jnp.int32),
            "skipped": jnp.zeros((), jnp.int32)}


def make_scanned_step(step_fn: Callable, steps_per_call: int, *,
                      jit: bool = True, donate: bool = True) -> Callable:
    """Fuse ``steps_per_call`` optimizer updates into ONE jit dispatch.

    ``step_fn`` is a *traceable* ``(params, opt_state, key) ->
    (params, opt_state, metrics)`` step (a :func:`make_sde_train_step`
    product; a fn taking an extra trailing ``step`` argument —
    ``(params, opt_state, key, step)`` — is also accepted, which is how
    tests inject step-indexed faults in-graph).  The returned callable is

        ``scanned(params, opt_state, counters, key, step0)
            -> (params, opt_state, counters, metrics_hist)``

    running global steps ``step0 .. step0 + K - 1`` inside one ``lax.scan``
    with a donated ``(params, opt_state, counters)`` carry; step ``s`` uses
    ``fold_in(key, s)``, exactly the sequential loops' convention, so the
    result is **bitwise-identical** to K un-scanned steps (tested across all
    three adjoints on fixed and adaptive grids).  Each leaf of ``metrics``
    comes back as a ``(K,)`` history — fetch it once per chunk, not per step.
    ``counters`` (:func:`init_scan_counters`) accumulate dispatched/skipped
    step totals on device.  ``step0`` may vary per call without retracing
    (pass it as an int array); chunks of different length need different
    scanned fns (the loops keep a per-length cache).
    """
    K = int(steps_per_call)
    if K < 1:
        raise ValueError(f"steps_per_call must be >= 1, got {steps_per_call}")
    try:
        takes_step = len(inspect.signature(step_fn).parameters) >= 4
    except (TypeError, ValueError):  # jitted/wrapped fn with opaque signature
        takes_step = False

    def scanned(params, opt_state, counters, key, step0):
        def body(carry, s):
            p, o, c = carry
            k = jax.random.fold_in(key, s)
            p, o, m = (step_fn(p, o, k, s) if takes_step
                       else step_fn(p, o, k))
            sk = m.get("skipped", False) if isinstance(m, dict) else False
            c = {"steps": c["steps"] + 1,
                 "skipped": c["skipped"] + jnp.asarray(sk).astype(jnp.int32)}
            return (p, o, c), m

        (params, opt_state, counters), hist = jax.lax.scan(
            body, (params, opt_state, counters),
            step0 + jnp.arange(K, dtype=jnp.asarray(step0).dtype))
        return params, opt_state, counters, hist

    if jit:
        scanned = jax.jit(scanned, donate_argnums=(0, 1, 2) if donate else ())
    return scanned


def train_loop(
    cfg,
    params,
    data_iter,
    *,
    optimizer=None,
    opts: ModelOptions = ModelOptions(),
    loop: TrainLoopConfig = TrainLoopConfig(),
    step_fn: Optional[Callable] = None,
    to_device: Callable = lambda b: b,
) -> Dict[str, Any]:
    """Drive a batch-consuming step.  With ``loop.steps_per_call = K > 1``
    the loop stacks K batches and runs them through one jit'd ``lax.scan``
    per dispatch (``step_fn`` must then be traceable); metric fetches are
    batched into ONE device→host transfer at the end either way, and the
    result carries ``n_dispatches`` — the number of jit calls issued — for
    the dispatch-count regression test."""
    optimizer = optimizer or adamw(cosine_schedule(3e-4, 10, loop.steps))
    opt_state = optimizer.init(params)
    start = 0
    if loop.ckpt_dir:
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            params, opt_state = restore_checkpoint(
                loop.ckpt_dir, last, (params, opt_state)
            )
            start = last
    raw_step = step_fn or make_accum_train_step(cfg, optimizer, opts, loop.microbatches)
    K = max(int(loop.steps_per_call), 1)

    t0 = time.time()
    n_dispatches = 0
    pending = []  # (first_logged_step_info, device arrays) — fetched once at end
    # Step-pure sources (batch_at) give exact replay after restart; plain
    # iterators are only correct for fresh runs.
    step_pure = hasattr(data_iter, "batch_at")
    it = None if step_pure else iter(data_iter)
    get_batch = (lambda s: data_iter.batch_at(s)) if step_pure else (lambda s: next(it))

    if K == 1:
        jstep = jax.jit(raw_step, donate_argnums=(0, 1))
        for step in range(start, loop.steps):
            batch = to_device(get_batch(step))
            params, opt_state, metrics = jstep(params, opt_state, batch)
            n_dispatches += 1
            if (step + 1) % loop.log_every == 0 or step == loop.steps - 1:
                pending.append(((step + 1,), metrics["loss"]))
            if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
                save_checkpoint(loop.ckpt_dir, step + 1, (params, opt_state),
                                extra={"steps_per_call": K})
    else:
        chunk_cache: Dict[int, Callable] = {}

        def chunk_fn(length):
            if length not in chunk_cache:
                def scanned(p, o, batches):
                    def body(c, b):
                        pp, oo = c
                        pp, oo, m = raw_step(pp, oo, b)
                        return (pp, oo), m

                    (p, o), hist = jax.lax.scan(body, (p, o), batches)
                    return p, o, hist

                chunk_cache[length] = jax.jit(scanned, donate_argnums=(0, 1))
            return chunk_cache[length]

        step = start
        last_ckpt = start
        while step < loop.steps:
            length = min(K, loop.steps - step)
            batches = [get_batch(s) for s in range(step, step + length)]
            stacked = to_device(jax.tree_util.tree_map(
                lambda *xs: np.stack([np.asarray(x) for x in xs]), *batches))
            params, opt_state, hist = chunk_fn(length)(params, opt_state, stacked)
            n_dispatches += 1
            pending.append(((step, length), hist["loss"]))
            step += length
            if loop.ckpt_dir and step - last_ckpt >= loop.ckpt_every:
                save_checkpoint(loop.ckpt_dir, step, (params, opt_state),
                                extra={"steps_per_call": K})
                last_ckpt = step

    fetched = jax.device_get([d for _, d in pending])  # the ONE metrics sync
    losses = []
    for (info, _), vals in zip(pending, fetched):
        if K == 1:
            losses.append((info[0], float(vals)))
        else:
            s0, length = info
            for j in range(length):
                s1 = s0 + j + 1
                if s1 % loop.log_every == 0 or s1 == loop.steps:
                    losses.append((s1, float(vals[j])))
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "wall_s": time.time() - t0,
        "n_dispatches": n_dispatches,
    }


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for :func:`resilient_train_loop` (see ``docs/robustness.md``).

    ``skip_patience`` consecutive guard-skipped steps trigger a rollback to
    the latest checkpoint (the blow-up evidently was not a one-off batch);
    checkpoints are written every ``ckpt_every`` *productive* boundaries so a
    rollback never restores a state reached through skipped steps.
    ``mesh_shape`` / ``hosts_per_pod`` feed :func:`recovery_plan` when the
    heartbeat monitor reports dead hosts.

    ``steps_per_call = K > 1`` runs the loop in chunked mode: K steps per
    jit dispatch via :func:`make_scanned_step` (``step_fn`` must be
    traceable), ONE metrics fetch per chunk, and the skip/rollback policy
    evaluated from the chunk's ``skipped`` history at chunk granularity —
    a rollback triggered at in-chunk position ``j`` restores the latest
    checkpoint and re-dispatches the remaining steps from it.  On a
    fault-free run the trajectory is bitwise-identical to stepwise mode;
    after a rollback it can differ, because checkpoints land on chunk
    boundaries (the first boundary with ``ckpt_every`` productive steps
    since the last save), so the restored state may be older than the one
    per-step cadence would have kept.  Same policy, chunk-granular
    cadence — the price of never syncing more than once per K steps."""

    steps: int = 100
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    skip_patience: int = 3
    mesh_shape: tuple = (1, 1, 1)
    hosts_per_pod: int = 1
    steps_per_call: int = 1


def resilient_train_loop(
    step_fn: Callable,
    params,
    opt_state,
    key,
    *,
    res: ResilienceConfig = ResilienceConfig(),
    monitor=None,
    tracker=None,
    host: int = 0,
) -> Dict[str, Any]:
    """Drive a guarded SDE train step with skip-streak rollback and fleet
    health bookkeeping — the trainer-side divergence story (PR 9 + PR 10).

    ``step_fn`` is a (possibly jit'd) ``make_sde_train_step`` product:
    ``(params, opt_state, key) -> (params, opt_state, metrics)``.  Step
    ``i`` uses ``fold_in(key, i)``, so the trajectory is reproducible and a
    rollback replays the identical keys it first saw.

    With ``res.steps_per_call = 1`` (default) the loop dispatches per step
    and may use any Python-level ``step_fn`` (fault-injection dispatchers
    included); losses are kept on device and fetched in ONE transfer at the
    end.  With ``K > 1`` it dispatches :func:`make_scanned_step` chunks —
    ``step_fn`` must be traceable — and fetches each chunk's metric
    histories once; the skip streak carries across chunk boundaries and the
    rollback policy replays from the rollback point (see
    :class:`ResilienceConfig`).

    Per dispatch, the loop records step time into ``tracker``
    (:class:`~repro.train.fault_tolerance.StragglerTracker` — per-step in
    stepwise mode, amortized via ``record_chunk`` in chunked mode) and beats
    ``monitor`` (:class:`~repro.train.fault_tolerance.HeartbeatMonitor`);
    when the monitor reports dead hosts, a
    :func:`~repro.train.fault_tolerance.recovery_plan` is computed against
    ``res.mesh_shape`` and appended to the history (the launcher acts on
    it; this in-process loop keeps training its own shard).

    The guard's ``metrics["skipped"]`` drives the rollback policy: after
    ``res.skip_patience`` consecutive skips the loop restores the latest
    checkpoint under ``res.ckpt_dir`` (written every ``res.ckpt_every``
    productive steps — chunk-boundary-aligned when chunked — plus one at
    step 0 so rollback is always possible) and continues.  Returns
    params/opt_state plus a history dict — per-step ``losses`` and
    ``skipped`` flags, ``rollbacks``, ``recovery_plans``, and ``goodput``
    (productive steps / total steps: the resilience metric
    ``benchmarks/bench_resilience.py`` sweeps against fault rate)."""
    K = max(int(res.steps_per_call), 1)
    history: Dict[str, Any] = {"losses": [], "skipped": [], "rollbacks": 0,
                               "recovery_plans": []}
    if res.ckpt_dir:
        save_checkpoint(res.ckpt_dir, 0, (params, opt_state),
                        extra={"steps_per_call": K})
    streak = 0
    productive = 0

    def fleet_beat(dt, n_steps_done):
        if tracker is not None:
            if n_steps_done == 1:
                tracker.record(host, dt)
            else:
                tracker.record_chunk(host, dt, n_steps_done)
        if monitor is not None:
            monitor.beat(host)
            dead = monitor.dead_hosts()
            if dead:
                history["recovery_plans"].append(recovery_plan(
                    res.mesh_shape, res.hosts_per_pod, dead,
                    (latest_step(res.ckpt_dir) or 0) if res.ckpt_dir else 0))

    try:
        takes_step = len(inspect.signature(step_fn).parameters) >= 4
    except (TypeError, ValueError):
        takes_step = False

    if K == 1:
        dev_losses = []
        for step in range(res.steps):
            k = jax.random.fold_in(key, step)
            t_step = time.monotonic()
            params, opt_state, metrics = (
                step_fn(params, opt_state, k, jnp.asarray(step)) if takes_step
                else step_fn(params, opt_state, k))
            skipped = bool(np.asarray(metrics.get("skipped", False)))
            fleet_beat(time.monotonic() - t_step, 1)
            dev_losses.append(metrics["loss"])
            history["skipped"].append(skipped)
            if skipped:
                streak += 1
                if streak >= res.skip_patience and res.ckpt_dir:
                    last = latest_step(res.ckpt_dir)
                    if last is not None:
                        params, opt_state = restore_checkpoint(
                            res.ckpt_dir, last, (params, opt_state))
                        history["rollbacks"] += 1
                        streak = 0
            else:
                streak = 0
                productive += 1
                if res.ckpt_dir and (step + 1) % res.ckpt_every == 0:
                    save_checkpoint(res.ckpt_dir, step + 1,
                                    (params, opt_state),
                                    extra={"steps_per_call": K})
        history["losses"] = [float(x) for x in jax.device_get(dev_losses)]
    else:
        scan_cache: Dict[int, Callable] = {}

        def scanned_for(length):
            if length not in scan_cache:
                scan_cache[length] = make_scanned_step(step_fn, length)
            return scan_cache[length]

        counters = init_scan_counters()
        step = 0
        since_ckpt = 0
        while step < res.steps:
            length = min(K, res.steps - step)
            t_chunk = time.monotonic()
            p2, o2, counters, hist = scanned_for(length)(
                params, opt_state, counters, key, jnp.asarray(step))
            # the chunk's ONE device->host sync: loss + skipped histories
            m = jax.device_get({
                "loss": hist["loss"],
                "skipped": hist.get("skipped", np.zeros(length, bool)),
            })
            fleet_beat(time.monotonic() - t_chunk, length)
            sk = np.asarray(m["skipped"]).astype(bool)
            commit = length
            rolled = False
            for j in range(length):
                history["losses"].append(float(m["loss"][j]))
                history["skipped"].append(bool(sk[j]))
                if sk[j]:
                    streak += 1
                    if streak >= res.skip_patience and res.ckpt_dir:
                        last = latest_step(res.ckpt_dir)
                        if last is not None:
                            params, opt_state = restore_checkpoint(
                                res.ckpt_dir, last, (params, opt_state))
                            history["rollbacks"] += 1
                            streak = 0
                            since_ckpt = 0
                            commit = j + 1
                            rolled = True
                            break
                else:
                    streak = 0
                    productive += 1
                    since_ckpt += 1
            if not rolled:
                params, opt_state = p2, o2
            step += commit
            if res.ckpt_dir and not rolled and since_ckpt >= res.ckpt_every:
                save_checkpoint(res.ckpt_dir, step, (params, opt_state),
                                extra={"steps_per_call": K})
                since_ckpt = 0
    history["goodput"] = productive / max(res.steps, 1)
    return {"params": params, "opt_state": opt_state, **history}
