"""Sharded, atomic, reshardable checkpoints (no orbax dependency).

Layout::

    <dir>/step_000123/
        manifest.json          # treedef, leaf shapes/dtypes, mesh shape
        host_<k>.npz           # this host's shard of every leaf

* **Atomic**: written to ``step_X.tmp`` then ``os.rename``d — a crash never
  leaves a half-checkpoint that restore would pick up.
* **Step-exact resume**: optimizer state (incl. step counter) is part of the
  pytree; combined with the deterministic data pipeline, restart reproduces
  the exact training trajectory (tested).
* **Elastic re-shard**: leaves are saved *unsharded per host slice* with their
  global shapes in the manifest; `restore` device_puts onto whatever sharding
  the new mesh prescribes, so a checkpoint written on mesh (4,) restores onto
  (8,) or (2, 4) — node-failure recovery with a different pod count.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "checkpoint_meta"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Write ``tree`` atomically under ``directory/step_<step>``.

    ``extra``: optional JSON-serialisable metadata stored in the manifest —
    the chunked train loops record their ``steps_per_call`` here so a resume
    can report how the checkpointed trajectory was dispatched (the *params*
    are chunking-independent: scanned chunks are bitwise-equal to sequential
    steps, so any ``steps_per_call`` may resume any checkpoint, including
    from a mid-chunk step of a differently-chunked run).
    """
    host = jax.process_index()
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{host}"
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    meta = {"step": step, "leaves": []}
    if extra:
        meta["extra"] = dict(extra)
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npz has no native bf16: store raw bits
            arr = arr.view(np.uint16)
        arrays[name] = arr
        meta["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": dtype_name}
        )
    np.savez(os.path.join(tmp, f"host_{host}.npz"), **arrays)
    if host == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    return final


def checkpoint_meta(directory: str, step: int) -> dict:
    """The ``extra`` metadata recorded with a checkpoint ({} when none was
    given) — e.g. the ``steps_per_call`` a chunked loop trained with."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("extra", {})


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp0")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, shardings=None) -> Any:
    """Restore into the structure of ``like``; optionally re-shard.

    ``shardings``: matching pytree of jax.sharding.Sharding (or None leaves)
    for elastic restore onto a new mesh.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, f"host_{jax.process_index()}.npz")) as z:
        data = {k: z[k] for k in z.files}

    names, leaves, treedef = _flatten_with_paths(like)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "device_set")
        )
    else:
        shard_leaves = [None] * len(leaves)
    out = []
    for name, leaf, shard in zip(names, leaves, shard_leaves):
        arr = data[name]
        want = getattr(leaf, "dtype", None)
        if want is not None and str(want) == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(jax.numpy.bfloat16.dtype)
        j = jax.numpy.asarray(arr)
        if want is not None and j.dtype != want:
            j = j.astype(want)
        out.append(jax.device_put(j, shard) if shard is not None else j)
    return jax.tree_util.tree_unflatten(treedef, out)
