"""Fault-tolerance & straggler machinery for multi-pod runs.

What is *mechanised* here (and unit-tested):

* :class:`HeartbeatMonitor` — per-host liveness with a deadline; the launcher
  polls ``dead_hosts()`` and triggers elastic restart when non-empty.
* :class:`StragglerTracker` — rolling per-step latency stats; flags hosts
  whose step time exceeds ``k`` MADs above the fleet median.  On TPU pods the
  mitigation is *restart-into-smaller-mesh* (synchronous SPMD cannot drop a
  participant mid-step), which composes with the elastic checkpoint restore
  in :mod:`repro.train.checkpoint`.
* :func:`recovery_plan` — given a dead-host set and the mesh shape, computes
  the largest valid (pod, data, model) mesh on the survivors and the
  checkpoint step to resume from.

Design notes for 1000+ nodes (implemented policy, not aspiration):
the data pipeline is pure in (step, host) so recovery needs *no* data-state
handoff; checkpoints re-shard onto the shrunken mesh; the step counter lives
in the optimizer state so the resumed trajectory is exact on the surviving
fleet.  Backup-worker ("hot spare") slots are expressed by launching with a
mesh smaller than the physical fleet and keeping spares in the same slice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HeartbeatMonitor", "StragglerTracker", "recovery_plan"]


class HeartbeatMonitor:
    """Per-host liveness.  Hosts named at construction start the deadline
    clock immediately; unknown hosts register lazily on their first ``beat``
    (an elastic fleet adds hosts mid-run — a monitor must never throw on a
    heartbeat from one)."""

    def __init__(self, hosts: Sequence[int], deadline_s: float = 60.0):
        self.deadline_s = deadline_s
        self._last: Dict[int, float] = {h: time.monotonic() for h in hosts}

    def beat(self, host: int, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self._last.items() if now - t > self.deadline_s]


class StragglerTracker:
    """Flags hosts persistently slower than the fleet (k MADs over median)."""

    def __init__(self, hosts: Sequence[int], window: int = 32, k: float = 4.0):
        self.window = window
        self.k = k
        self._times: Dict[int, List[float]] = {h: [] for h in hosts}

    def record(self, host: int, step_time_s: float):
        # Lazy registration: a host joining the fleet mid-run (or one the
        # caller forgot to pre-declare) must not KeyError its first sample.
        buf = self._times.setdefault(host, [])
        buf.append(step_time_s)
        if len(buf) > self.window:
            buf.pop(0)

    def record_chunk(self, host: int, chunk_time_s: float, n_steps: int):
        """Record a fused multi-step dispatch (``steps_per_call`` chunk) as
        ONE per-step-average sample, so straggler medians stay comparable
        between hosts running different chunk sizes.  Note the window then
        fills ``n_steps``x slower in wall-clock steps — size ``window`` to
        chunks, not steps, on chunked fleets."""
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        self.record(host, chunk_time_s / n_steps)

    def stragglers(self) -> List[int]:
        med_per_host = {
            h: float(np.median(v)) for h, v in self._times.items() if len(v) >= 8
        }
        if len(med_per_host) < 2:
            return []
        vals = np.array(list(med_per_host.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        return [h for h, v in med_per_host.items() if v > med + self.k * mad]


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    new_mesh_shape: Tuple[int, ...]
    resume_step: int
    dropped_hosts: Tuple[int, ...]


def recovery_plan(
    mesh_shape: Tuple[int, ...],
    hosts_per_pod: int,
    dead_hosts: Sequence[int],
    latest_ckpt_step: int,
) -> RecoveryPlan:
    """Shrink the leading (pod) axis to exclude pods containing dead hosts.

    Synchronous SPMD requires whole-pod granularity: a pod with any dead host
    is dropped; the survivors form a (pods', data, model) mesh and training
    resumes from the latest checkpoint re-sharded onto it.

    Every dead host id must belong to the fleet the mesh describes
    (``0 <= host < pods * hosts_per_pod``): a bogus id means the failure
    report and the mesh disagree, and silently ignoring it would produce a
    recovery plan that keeps a genuinely dead pod — raise loudly instead.
    """
    if len(mesh_shape) == 2:
        mesh_shape = (1,) + tuple(mesh_shape)
    pods, data, model = mesh_shape
    fleet = pods * hosts_per_pod
    for h in dead_hosts:
        if not 0 <= int(h) < fleet:
            raise ValueError(
                f"dead host {h} is outside the fleet: mesh {tuple(mesh_shape)}"
                f" with hosts_per_pod={hosts_per_pod} has host ids 0.."
                f"{fleet - 1}"
            )
    dead_pods = sorted({h // hosts_per_pod for h in dead_hosts})
    surviving = pods - len(dead_pods)
    if surviving < 1:
        raise RuntimeError("no surviving pods")
    return RecoveryPlan(
        new_mesh_shape=(surviving, data, model),
        resume_step=latest_ckpt_step,
        dropped_hosts=tuple(dead_hosts),
    )
