"""Batched Monte-Carlo sampling engine: fixed-slot batching over ``sdeint``.

The SDE analogue of the LM :class:`~repro.serving.engine.Engine`: requests
(solver name, horizon, number of paths) join a FIFO queue; every engine tick
integrates one *fixed-size* batch of trajectories — ``slots`` paths — in a
single jit'd ``sdeint`` call, filling the batch with paths from as many
compatible queued requests as fit (continuous batching).  A request larger
than ``slots`` is served across several ticks.

Two properties make slicing safe:

* path ``i`` of request ``r`` always uses ``fold_in(base_key_r, i)``, so the
  sample a request receives is independent of slot assignment, tick
  boundaries, and whatever else shares its batch;
* ``sdeint``'s batch is bitwise equal to single-trajectory solves, so a
  request's paths are reproducible offline from its seed alone.

Compiled executables are cached per request *signature* (solver spec,
horizon, step count, save cadence, adaptive tolerances / output grid) —
ticks re-use them, so steady-state serving never recompiles, exactly like
the LM engine's single jit'd step (built once from
:func:`repro.models.make_serve_step`).  Each cached entry donates its input
key buffer (``donate_argnums``) on backends that support donation, so the
per-tick key stack is reused in place instead of allocating a fresh device
buffer every tick.  Adaptive requests (an ``"ees25:adaptive"``-style spec)
run the single forward-only controller pass (``bounded=False`` — sampling
needs no second sweep; bitwise-identical to realize-then-solve) on a Virtual
Brownian Tree — paths in one batch each walk their own accept/reject step
sequence under vmap — and remain reproducible offline from the seed: the
result surfaces each path's realized-grid stats (``n_accepted`` /
``n_rejected`` / ``t_final``), and a client can realize the identical grid
offline with :func:`repro.core.adaptive.realize_grid` + ``solve`` under any
adjoint, including the O(1)-memory reversible one, for gradient work on
served samples.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import canonical_spec, parse_solver_spec, sdeint, solver_kind

__all__ = ["SDESampleConfig", "SampleRequest", "SampleResult", "SDESampleEngine"]


@dataclasses.dataclass(frozen=True)
class SDESampleConfig:
    slots: int = 64          # trajectories integrated per tick
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    request_id: int
    solver: str
    t0: float
    t1: float
    n_steps: int
    n_paths: int
    save_every: Optional[int]
    seed: int
    # Adaptive-solve options (solver spec carries an "adaptive" flag):
    # tolerances for the PI controller and an arbitrary-time output grid.
    rtol: Optional[float] = None
    atol: Optional[float] = None
    save_at: Optional[Tuple[float, ...]] = None

    @property
    def signature(self) -> Tuple:
        """Requests with equal signatures can share one compiled batch."""
        return (self.solver, self.t0, self.t1, self.n_steps, self.save_every,
                self.rtol, self.atol, self.save_at)


@dataclasses.dataclass
class SampleResult:
    """Stacked per-path outputs: ``y_final`` is (n_paths, ...); ``ys`` is
    (n_paths, n_saves, ...) when the request asked for a saved trajectory.

    ``t_final`` (adaptive requests only) is the (n_paths,) time each path
    actually reached — equal to the request's ``t1`` unless the trial-step
    budget ``n_steps`` was exhausted first, in which case the path stopped
    short and its ``y_final`` is NOT a sample at ``t1``.  Check it (or just
    ``(t_final == t1).all()``) before trusting adaptive results from
    aggressive tolerance/budget combinations.

    ``n_accepted`` / ``n_rejected`` (adaptive requests only) are the
    per-path realized-grid statistics: how many steps each path's controller
    accepted/rejected — the realized grid a client would replay offline (via
    ``realize_grid`` with the same seed-derived key) for gradient work."""

    y_final: Any
    ys: Optional[Any]
    t_final: Optional[np.ndarray] = None
    n_accepted: Optional[np.ndarray] = None
    n_rejected: Optional[np.ndarray] = None


@dataclasses.dataclass(eq=False)  # identity hash: instances are queue entries
class _Pending:
    request: SampleRequest
    delivered: int = 0
    y_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    ys: List[np.ndarray] = dataclasses.field(default_factory=list)
    t_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_accepted: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_rejected: List[np.ndarray] = dataclasses.field(default_factory=list)


class SDESampleEngine:
    """Serve Monte-Carlo sampling requests against one SDE term.

    ``term``/``y0``/``args`` define the process; each request picks a solver
    from the registry by name and a horizon.  Results come back as stacked
    numpy arrays per request id (like ``Engine.done``).
    """

    def __init__(self, term, y0, cfg: SDESampleConfig = SDESampleConfig(),
                 args: Any = None, noise_shape=None):
        self.term = term
        self.y0 = y0
        self.cfg = cfg
        self.args = args
        self.noise_shape = noise_shape
        self.queue: deque = deque()
        self.done: Dict[int, SampleResult] = {}
        self._next_id = 0
        self._compiled: Dict[Tuple, Any] = {}

    def submit(self, solver: str, *, t1: float, n_steps: int, n_paths: int,
               t0: float = 0.0, save_every: Optional[int] = None,
               seed: Optional[int] = None, rtol: Optional[float] = None,
               atol: Optional[float] = None, save_at=None) -> int:
        """Queue a sampling request; returns its request id.

        Parameters
        ----------
        solver:
            Registry spec string — ``"ees25"``, ``"mcf-rk4:lam=0.99"``,
            ``"ees25:adaptive"``, ...  An ``adaptive`` flag switches the
            request to tolerance-driven stepping on a Virtual Brownian Tree;
            ``n_steps`` then bounds trial steps instead of fixing a grid.
        t0, t1:
            Integration window (``t1 > t0``).
        n_steps:
            Grid size (fixed) or trial-step budget (adaptive).
        n_paths:
            Trajectories to sample; large requests are served across ticks.
        save_every:
            Fixed grid only: save the state every that many steps (must
            divide ``n_steps``); results gain a ``(n_paths, n_saves, ...)``
            ``ys``.
        seed:
            Base seed; path ``i`` uses ``fold_in(PRNGKey(seed), i)``, so
            results are reproducible offline regardless of batching.
            Defaults to the request id.
        rtol, atol:
            Adaptive only: controller tolerances (defaults 1e-4 / 1e-6).
        save_at:
            Adaptive only: sequence of output times in ``[t0, t1]`` — dense
            output interpolated between accepted steps.

        Example
        -------
        >>> rid = eng.submit("ees25:adaptive", t1=2.0, n_steps=256,
        ...                  n_paths=1000, rtol=1e-3, save_at=[0.5, 1.0, 2.0])
        >>> eng.run()[rid].ys.shape
        (1000, 3, ...)
        """
        # Reject bad requests here, not at the queue head where a crash
        # would starve everything queued behind them.
        if n_paths < 1:
            raise ValueError(f"n_paths must be >= 1, got {n_paths}")
        n_steps = int(n_steps)
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        if not float(t1) > float(t0):
            raise ValueError(f"need t1 > t0, got t0={t0}, t1={t1}")
        solver = canonical_spec(solver)  # raises on unknown names; one
        # normal form per solver so equivalent spellings share a signature
        want = "manifold" if hasattr(self.term, "algebra_increment") else "euclidean"
        if solver_kind(solver) != want:
            raise ValueError(
                f"solver {solver!r} is {solver_kind(solver)}-kind but this "
                f"engine's term needs a {want} solver"
            )
        adaptive = parse_solver_spec(solver)[1].get("adaptive", False)
        if not adaptive:
            for name, val in (("rtol", rtol), ("atol", atol), ("save_at", save_at)):
                if val is not None:
                    raise ValueError(
                        f"{name} only applies to adaptive solves; request an "
                        f"':adaptive' solver spec (got {solver!r})"
                    )
        if adaptive and save_every is not None:
            raise ValueError(
                "save_every indexes a fixed grid; adaptive requests take "
                "save_at=<sequence of times> instead"
            )
        if save_at is not None:
            save_at = tuple(float(t) for t in save_at)
            if not save_at:
                raise ValueError("save_at must be a non-empty sequence of times")
            if not all(float(t0) <= t <= float(t1) for t in save_at):
                raise ValueError(f"save_at times must lie in [{t0}, {t1}]")
        if save_every is not None:
            if int(save_every) != save_every or int(save_every) < 1:
                raise ValueError(f"save_every must be a positive int, got {save_every}")
            save_every = int(save_every)
            if n_steps % save_every != 0:
                raise ValueError(
                    f"save_every={save_every} does not divide n_steps={n_steps}"
                )
        rid = self._next_id
        self._next_id += 1
        req = SampleRequest(
            request_id=rid, solver=solver, t0=float(t0), t1=float(t1),
            n_steps=n_steps, n_paths=int(n_paths),
            save_every=save_every, seed=rid if seed is None else int(seed),
            rtol=None if rtol is None else float(rtol),
            atol=None if atol is None else float(atol),
            save_at=save_at,
        )
        self.queue.append(_Pending(req))
        return rid

    # -- internals -----------------------------------------------------------

    def _batch_fn(self, sig: Tuple):
        """The cached jit'd batch for ``sig`` — compiled once per signature.

        Steady-state serving re-enters the same executable every tick (no
        per-tick re-jit: the cache key is the full signature, and
        :meth:`submit` canonicalises specs so equivalent spellings share an
        entry).  The key-stack argument is donated where the backend
        implements donation, letting XLA reuse the previous tick's buffer
        for each resample instead of allocating a new one.
        """
        if sig not in self._compiled:
            solver, t0, t1, n_steps, save_every, rtol, atol, save_at = sig
            extra = {}
            if rtol is not None:
                extra["rtol"] = rtol
            if atol is not None:
                extra["atol"] = atol
            if save_at is not None:
                extra["save_at"] = jnp.asarray(save_at)

            if parse_solver_spec(solver)[1].get("adaptive", False):
                # Serving is forward-only: the while-loop stepper stops when
                # every path reaches t1 instead of padding to the n_steps
                # budget (bitwise-identical results).
                extra["bounded"] = False

            def batch(keys):
                return sdeint(
                    self.term, solver, t0, t1, n_steps, self.y0, None,
                    args=self.args, save_every=save_every,
                    noise_shape=self.noise_shape, dtype=self.cfg.dtype,
                    batch_keys=keys, **extra,
                )

            # Donate the per-tick key stack so its device buffer is reused
            # across ticks.  CPU does not implement donation (jax would warn
            # once per tick), so donate only where it takes effect.
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._compiled[sig] = jax.jit(batch, donate_argnums=donate)
        return self._compiled[sig]

    def _path_key(self, req: SampleRequest, i: int):
        return jax.random.fold_in(jax.random.PRNGKey(req.seed), i)

    def tick(self) -> bool:
        """Integrate one fixed-slot batch; return False when idle."""
        if not self.queue:
            return False
        head = self.queue[0]
        sig = head.request.signature
        # Fill the slot budget with paths from queued requests sharing the
        # head's signature (FIFO over requests, contiguous over paths).
        plan: List[Tuple[_Pending, int]] = []  # (pending, path index)
        budget = self.cfg.slots
        for pending in self.queue:
            if budget == 0:
                break
            if pending.request.signature != sig:
                continue
            take = min(budget, pending.request.n_paths - pending.delivered)
            plan.extend((pending, pending.delivered + j) for j in range(take))
            budget -= take
        # Fixed batch shape: pad unused slots with a dummy key so every tick
        # of this signature hits the same compiled executable.
        keys = [self._path_key(p.request, i) for p, i in plan]
        keys += [jax.random.PRNGKey(0)] * (self.cfg.slots - len(keys))
        result = self._batch_fn(sig)(jnp.stack(keys))
        y_final = np.asarray(result.y_final)
        ys = None if result.ys is None else np.asarray(result.ys)
        # Adaptive results carry where each path actually stopped plus its
        # realized-grid stats; surface them so budget-exhausted (truncated)
        # paths are detectable and step counts are observable per path.
        stats = {
            name: (None if getattr(result, name, None) is None
                   else np.asarray(getattr(result, name)))
            for name in ("t_final", "n_accepted", "n_rejected")
        }
        for slot, (pending, _) in enumerate(plan):
            pending.y_final.append(y_final[slot])
            if ys is not None:
                pending.ys.append(ys[slot])
            for name, arr in stats.items():
                if arr is not None:
                    getattr(pending, name).append(arr[slot])
            pending.delivered += 1
        # Retire fully-served requests, preserving queue order.
        for pending in dict.fromkeys(p for p, _ in plan):
            if pending.delivered == pending.request.n_paths:
                self.queue.remove(pending)
                self.done[pending.request.request_id] = SampleResult(
                    y_final=np.stack(pending.y_final),
                    ys=np.stack(pending.ys) if pending.ys else None,
                    **{name: (np.stack(getattr(pending, name))
                              if getattr(pending, name) else None)
                       for name in ("t_final", "n_accepted", "n_rejected")},
                )
        return True

    def run(self, max_ticks: int = 10_000) -> Dict[int, SampleResult]:
        for _ in range(max_ticks):
            if not self.tick():
                break
        else:
            if self.queue:
                raise RuntimeError(
                    f"max_ticks={max_ticks} exhausted with {len(self.queue)} "
                    "request(s) still queued; raise max_ticks or slots"
                )
        return self.done
