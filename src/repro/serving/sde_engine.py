"""Batched Monte-Carlo sampling engine: a façade over scheduler + executor.

The SDE analogue of the LM :class:`~repro.serving.engine.Engine`: requests
(solver name, horizon, number of paths) join a FIFO queue; the engine serves
them in *fixed-size* ticks of ``slots`` trajectories, filling each tick with
paths from as many compatible queued requests as fit (continuous batching).
A request larger than ``slots`` is served across several ticks.

Since PR 5 the engine is a thin façade over two layers (see
``docs/serving.md``):

* :class:`repro.serving.scheduler.Scheduler` — host-side: FIFO queue,
  signature grouping, slot-plan construction, result scatter/retirement,
  cancellation, ``pending()`` introspection.  Pure Python, unit-testable
  without a device.
* :class:`repro.serving.executor.TickExecutor` — device-side: runs a
  same-signature *stack* of tick key-buffers through one jit'd, donated
  on-device multi-tick loop (:func:`repro.core.sdeint_ticks`), so
  ``ticks_per_dispatch`` ticks cost ONE host round trip instead of one
  each; with ``mesh_axis`` set, each tick's slot axis additionally shards
  over a device mesh (``slots = devices x per_device_slots``).

Three properties make the slicing and the dispatch grouping safe:

* path ``i`` of request ``r`` always uses ``fold_in(base_key_r, i)``, so the
  sample a request receives is independent of slot assignment, tick
  boundaries, dispatch depth, and device placement;
* ``sdeint``'s batch is bitwise equal to single-trajectory solves, and
  ``sdeint_ticks``'s on-device tick loop is bitwise equal to per-tick
  dispatch — so multi-tick, single-tick, and mesh-sharded serving all
  return identical bits (regression-tested);
* compiled executables are cached per request *signature* (solver spec,
  horizon, step count, save cadence, adaptive tolerances / output grid) and
  stack depth — steady-state serving never recompiles, and each cached
  entry donates its key buffer on backends that support donation.

Since PR 6 the engine **double-buffers** by default: jax dispatch is
asynchronous, so right after a stack is handed to the device the engine
plans and key-packs the *next* stack (``Scheduler.plan(reserve=True)``)
while the device is still integrating — reservations keep the cursor
arithmetic identical to plan-after-deliver, so the plan sequence and all
samples are bitwise-unchanged (``double_buffer=False`` restores the strict
sequential loop).  ``submit`` takes a ``priority`` class and is bounded by
``max_queue_requests`` / ``max_queue_paths`` admission control
(:class:`QueueFull`); :class:`repro.serving.AsyncSDESampleEngine` builds the
fully asynchronous, cross-signature-interleaving serving plane on the same
two layers (see ``docs/serving.md``).

Adaptive requests (an ``"ees25:adaptive"``-style spec) run the single
forward-only controller pass (``bounded=False`` — sampling needs no second
sweep; bitwise-identical to realize-then-solve) on a Virtual Brownian Tree —
paths in one batch each walk their own accept/reject step sequence under
vmap — and remain reproducible offline from the seed: the result surfaces
each path's realized-grid stats (``n_accepted`` / ``n_rejected`` /
``t_final``), and a client can realize the identical grid offline with
:func:`repro.core.adaptive.realize_grid` + ``solve`` under any adjoint,
including the O(1)-memory reversible one, for gradient work on served
samples.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import parse_solver_spec, select_solver
from .bucketing import BucketKey, BucketingConfig, bucket_key, group_key
from .executor import TickExecutor, enable_persistent_compile_cache
from .scheduler import (
    STAT_FIELDS,
    QueueFull,
    RetryPolicy,
    SampleRequest,
    SampleResult,
    Scheduler,
    SlotPlan,
    make_request,
)

__all__ = ["SDESampleConfig", "SampleRequest", "SampleResult",
           "SDESampleEngine", "QueueFull", "RetryPolicy"]


@dataclasses.dataclass(frozen=True)
class SDESampleConfig:
    slots: int = 64            # trajectories integrated per tick
    dtype: Any = jnp.float32
    ticks_per_dispatch: int = 1  # ticks per host round trip (on-device loop)
    mesh: Any = None             # device mesh to shard the slot axis over
    mesh_axis: Optional[str] = None  # mesh axis name (slots % axis size == 0)
    # Host-side double buffering: build + key-pack slot plan N+1 while the
    # device still runs stack N (jax dispatch is asynchronous, so the host
    # work overlaps device compute).  Plan sequence and samples are
    # bitwise-unchanged; False restores strict plan-after-deliver.
    double_buffer: bool = True
    # Admission control: bound the live queue (requests / owed paths); a
    # submit over either limit raises QueueFull instead of growing the
    # queue without bound.  None = unbounded (the PR-5 behaviour).
    max_queue_requests: Optional[int] = None
    max_queue_paths: Optional[int] = None
    # Signature coalescing (PR 8): pad eligible fixed-grid requests up a
    # powers-of-two step ladder so signatures that differ only in horizon
    # length share one executable and stack into the same dispatch —
    # bitwise-identical to exact dispatch (see repro.serving.bucketing).
    # False is the exact opt-out: one executable per signature.
    bucketing: bool = True
    bucket_min_steps: int = 8
    # Directory for jax's persistent compilation cache: compiled serving
    # executables are written to disk and reloaded by later processes, so a
    # restarted engine warm-starts instead of re-paying XLA compilation.
    compile_cache_dir: Optional[str] = None
    # Divergence guard (PR 9): every solve carries the in-loop blow-up check
    # (non-finite state, or |y| > guard_threshold) and delivers a per-path
    # ``diverged`` flag — a pure observer, so guarded samples are
    # bitwise-identical to unguarded ones.  None disables the guard (and
    # with it retry-on-divergence).  float('inf') checks non-finiteness only.
    guard_threshold: Optional[float] = 1e6
    # Degradation ladder for requests whose delivered paths diverged: halve
    # the step, then fall back to the wide-stability ees27 scheme, at most
    # max_retries resubmits per request (seeded — retries are reproducible).
    # None turns retries off (diverged results retire flagged, unretried).
    retry_policy: Optional[RetryPolicy] = RetryPolicy()
    # Supervised async serve loop: how many times an injected/transient
    # executor crash may restart the loop before it fails the engine.
    max_restarts: int = 2


class SDESampleEngine:
    """Serve Monte-Carlo sampling requests against one SDE term.

    ``term``/``y0``/``args`` define the process; each request picks a solver
    from the registry by name and a horizon.  Results come back as stacked
    numpy arrays per request id (like ``Engine.done``).  The engine itself
    only wires the host-side :class:`~repro.serving.scheduler.Scheduler` to
    the device-side :class:`~repro.serving.executor.TickExecutor` and turns
    slot plans into key buffers.
    """

    def __init__(self, term, y0, cfg: SDESampleConfig = SDESampleConfig(),
                 args: Any = None, noise_shape=None, clock=None):
        if cfg.ticks_per_dispatch < 1:
            raise ValueError(
                f"ticks_per_dispatch must be >= 1, got {cfg.ticks_per_dispatch}"
            )
        if (cfg.mesh is None) != (cfg.mesh_axis is None):
            # A long-lived engine must not depend on whatever mesh context
            # happens to be ambient at dispatch time — and slots/axis
            # divisibility has to be checkable here, not at the queue head.
            raise ValueError(
                "sharded serving needs mesh and mesh_axis together; pass "
                "both in SDESampleConfig (e.g. make_sample_mesh() + 'mc')"
            )
        if cfg.mesh is not None:
            axis = cfg.mesh.shape[cfg.mesh_axis]
            if cfg.slots % axis != 0:
                raise ValueError(
                    f"slots={cfg.slots} must be a multiple of mesh axis "
                    f"{cfg.mesh_axis!r} (size {axis}) to shard the slot axis"
                )
        self.term = term
        self.y0 = y0
        self.cfg = cfg
        self.args = args
        self.noise_shape = noise_shape
        if cfg.compile_cache_dir is not None:
            enable_persistent_compile_cache(cfg.compile_cache_dir)
        self._bucket_cfg = BucketingConfig(enabled=cfg.bucketing,
                                           min_steps=cfg.bucket_min_steps)
        self.scheduler = Scheduler(
            max_requests=cfg.max_queue_requests,
            max_paths=cfg.max_queue_paths,
            group_key=lambda sig: group_key(sig, self._bucket_cfg),
            clock=clock,
        )
        self.executor = TickExecutor(
            term, y0, args=args, noise_shape=noise_shape, dtype=cfg.dtype,
            mesh=cfg.mesh, mesh_axis=cfg.mesh_axis,
            guard=cfg.guard_threshold,
        )
        self._key_cache: Dict[int, np.ndarray] = {}
        self._pad_key = np.asarray(jax.random.PRNGKey(0))
        # Double buffering: the (reserved plan, packed key stack) staged
        # while the device ran the previous dispatch.
        self._staged: Optional[Tuple[SlotPlan, jax.Array]] = None
        # Robustness bookkeeping (PR 9).  Retry children run under NEGATIVE
        # internal ids (never colliding with user ids, never shifting the
        # default-seed id counter) and keep the ROOT request's seed, so a
        # retried sample is exactly what submitting the degraded spec
        # directly would produce.  Counters are cumulative over the engine's
        # lifetime — see pending(detail=True) / AsyncSDESampleEngine.drain.
        self._retry_ids = itertools.count(1)
        self._retry_parent: Dict[int, int] = {}   # child rid -> root rid
        self._retry_attempt: Dict[int, int] = {}  # root rid -> retries spent
        self._req_by_id: Dict[int, SampleRequest] = {}
        self._deadline: Dict[int, float] = {}     # root rid -> absolute s
        self.counters: Dict[str, int] = {
            "retries": 0, "timeouts": 0, "diverged_requests": 0,
            "diverged_paths": 0, "restarts": 0,
        }

    # The queue, result store, and compiled-executable cache live on the two
    # layers; these views keep the engine's original surface (and tests).
    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def done(self) -> Dict[int, SampleResult]:
        return self.scheduler.done

    @property
    def _compiled(self):
        return self.executor._compiled

    def submit(self, solver: str, *, t1: float, n_steps: int, n_paths: int,
               t0: float = 0.0, save_every: Optional[int] = None,
               seed: Optional[int] = None, rtol: Optional[float] = None,
               atol: Optional[float] = None, save_at=None,
               priority: int = 0,
               deadline_ms: Optional[float] = None) -> int:
        """Queue a sampling request; returns its request id.

        Parameters
        ----------
        solver:
            Registry spec string — ``"ees25"``, ``"mcf-rk4:lam=0.99"``,
            ``"ees25:adaptive"``, ...  An ``adaptive`` flag switches the
            request to tolerance-driven stepping on a Virtual Brownian Tree;
            ``n_steps`` then bounds trial steps instead of fixing a grid.
            ``"auto"`` (or ``"auto:stiffness=<lam>"``) defers the choice to
            :func:`repro.core.registry.select_solver`, fed with the engine
            term's declared noise mode and the request's step size — the
            resolved spec is what gets compiled and cached, so two requests
            that auto-select the same solver share an executable.
        t0, t1:
            Integration window (``t1 > t0``).
        n_steps:
            Grid size (fixed) or trial-step budget (adaptive).
        n_paths:
            Trajectories to sample; large requests are served across ticks.
        save_every:
            Fixed grid only: save the state every that many steps (must
            divide ``n_steps``); results gain a ``(n_paths, n_saves, ...)``
            ``ys``.
        seed:
            Base seed; path ``i`` uses ``fold_in(PRNGKey(seed), i)``, so
            results are reproducible offline regardless of batching.
            Defaults to the request id.
        rtol, atol:
            Adaptive only: controller tolerances (defaults 1e-4 / 1e-6).
        save_at:
            Adaptive only: sequence of output times in ``[t0, t1]`` — dense
            output interpolated between accepted steps.
        priority:
            Service class (default 0): higher priorities are planned sooner;
            equal priorities keep strict FIFO.  Priority reorders *when* a
            request is served, never its samples (pure function of
            ``(seed, path)``).
        deadline_ms:
            Wall-clock budget in milliseconds.  A request not fully
            delivered when it expires retires into ``done`` with
            ``timed_out=True`` and no arrays (the async engine instead wakes
            the waiter with ``TimeoutError``).  The sync engine checks
            deadlines once per dispatch cycle, so expiry resolution is one
            dispatch.  Retries inherit the remaining budget.

        Raises
        ------
        ValueError / KeyError on any malformed option — always here at
        submit time, never inside jit at the queue head.
        :class:`~repro.serving.scheduler.QueueFull` when admission control
        (``max_queue_requests`` / ``max_queue_paths``) rejects the request.

        Example
        -------
        >>> rid = eng.submit("ees25:adaptive", t1=2.0, n_steps=256,
        ...                  n_paths=1000, rtol=1e-3, save_at=[0.5, 1.0, 2.0])
        >>> eng.run()[rid].ys.shape
        (1000, 3, ...)
        """
        if isinstance(solver, str):
            name, auto_kw = parse_solver_spec(solver)
            if name == "auto":
                unknown = set(auto_kw) - {"stiffness", "noise"}
                if unknown:
                    raise ValueError(
                        f"unknown option {sorted(unknown)[0]!r} for solver "
                        "'auto'; valid keys: noise, stiffness"
                    )
                auto_kw.setdefault(
                    "noise", getattr(self.term, "noise", "diagonal"))
                solver = select_solver(
                    dt=(t1 - t0) / max(int(n_steps), 1), **auto_kw)
        term_kind = ("manifold" if hasattr(self.term, "algebra_increment")
                     else "euclidean")
        # Validate against the *peeked* id: a rejected submit must not burn
        # an id (default seeds equal the request id, so a burned id would
        # shift every later request's samples).
        req = make_request(
            self.scheduler.next_request_id, solver, term_kind=term_kind,
            t0=t0, t1=t1, n_steps=n_steps, n_paths=n_paths,
            save_every=save_every, seed=seed, rtol=rtol, atol=atol,
            save_at=save_at, priority=priority, deadline_ms=deadline_ms,
        )
        rid = self.scheduler.enqueue(req)
        self._req_by_id[rid] = req
        if deadline_ms is not None:
            self._deadline[rid] = self.scheduler.clock() + deadline_ms / 1e3
        return rid

    def pending(self, detail: bool = False) -> Dict[int, Any]:
        """Paths still owed per queued request id — poll this between ticks
        (cancelled requests drop out; completed ones move to ``done``).

        ``detail=True`` returns per-request dicts instead of bare counts:
        ``remaining`` plus the coalescing introspection — ``bucket`` (the
        :class:`~repro.serving.bucketing.BucketKey` the request was planned
        into, None before planning or for exact dispatch),
        ``n_padded_steps`` (masked padding steps per path),
        ``n_padded_paths`` (dead slots delivered alongside it so far),
        ``n_diverged`` (delivered paths the blow-up guard flagged) and
        ``deadline_remaining_s``.  The detail dict additionally carries one
        non-request entry, ``"counters"``: the engine-lifetime robustness
        counters (``retries`` / ``timeouts`` / ``diverged_requests`` /
        ``diverged_paths`` / ``restarts``)."""
        out = self.scheduler.pending(detail=detail)
        if detail:
            out["counters"] = dict(self.counters)
        return out

    def warmup(self, signatures) -> int:
        """Ahead-of-time compile the executables a list of requests needs.

        ``signatures`` is a list of submit-style dicts — ``{"solver": ...,
        "t1": ..., "n_steps": ...}`` plus any of ``t0`` / ``save_every`` /
        ``rtol`` / ``atol`` / ``save_at`` — describing expected traffic
        (``n_paths`` / ``seed`` / ``priority`` are ignored: executables
        depend only on the signature).  Each is resolved to its bucket (or
        exact signature) and AOT-compiled at the configured ``slots`` for
        both dispatch depths the engine uses (``ticks_per_dispatch`` and the
        single-tick tail).  With ``compile_cache_dir`` set this also
        populates the on-disk cache, so later processes warm-start.  Returns
        the number of executables actually compiled by this call (already
        cached entries — in memory or on disk — are cheap no-ops and do not
        count)."""
        fresh = 0
        for spec in signatures:
            spec = dict(spec)
            for drop in ("n_paths", "seed", "priority"):
                spec.pop(drop, None)
            solver = spec.pop("solver")
            term_kind = ("manifold" if hasattr(self.term, "algebra_increment")
                         else "euclidean")
            req = make_request(0, solver, term_kind=term_kind,
                               n_paths=1, seed=0, **spec)
            key = bucket_key(req.signature, self._bucket_cfg)
            if key is None:
                key = req.signature
            for depth in {1, self.cfg.ticks_per_dispatch}:
                fresh += self.executor.warmup(key, depth, self.cfg.slots)
        return fresh

    def cancel(self, request_id: int) -> bool:
        """Cancel a queued request (partial results discarded).  True if this
        call cancelled it; False if already cancelled or already completed;
        ``KeyError`` on unknown ids.  A request mid-retry is cancellable by
        its ROOT id — the queued degraded child (internal negative id) is
        what actually gets cancelled."""
        target = request_id
        if (request_id in self._retry_attempt
                and request_id not in self.scheduler.done):
            for child, root in self._retry_parent.items():
                if root == request_id:
                    target = child
                    break
        cancelled = self.scheduler.cancel(target)
        if cancelled:
            self._key_cache.pop(target, None)
            self._req_by_id.pop(target, None)
            self._deadline.pop(request_id, None)
            self._retry_attempt.pop(request_id, None)
            if target != request_id:
                self._retry_parent.pop(target, None)
                # The root id is what clients hold — record its cancellation
                # so re-cancels return False and async result() raises
                # CancelledError instead of KeyError.
                self.scheduler._cancelled_ids.add(request_id)
        return cancelled

    # -- robustness internals (PR 9) ----------------------------------------

    def _expire(self) -> list:
        """Retire queued requests whose deadline passed; book the timeouts.

        A timed-out retry child resolves to its ROOT id — the child never
        surfaces (its negative id is internal), the root lands in ``done``
        with ``timed_out=True``.  Returns the expired ROOT ids (what the
        async plane wakes waiters on)."""
        roots = []
        for rid in self.scheduler.expire_deadlines():
            self.counters["timeouts"] += 1
            self._key_cache.pop(rid, None)
            self._req_by_id.pop(rid, None)
            root = self._retry_parent.pop(rid, rid)
            attempt = self._retry_attempt.pop(root, 0)
            self._deadline.pop(root, None)
            res = self.scheduler.done.pop(rid)
            self.scheduler.done[root] = dataclasses.replace(
                res, retries=attempt)
            roots.append(root)
        return roots

    def _make_retry(self, root: int, req: SampleRequest,
                    attempt: int) -> Optional[int]:
        """Enqueue the degraded resubmit of ``req`` (retry ``attempt``);
        None when no retry is possible (deadline spent, or the degraded spec
        does not validate — e.g. a manifold term with a euclidean fallback)."""
        policy = self.cfg.retry_policy
        deadline_ms = None
        dl = self._deadline.get(root)
        if dl is not None:
            remaining = dl - self.scheduler.clock()
            if remaining <= 0:
                return None
            deadline_ms = remaining * 1e3
        overrides = policy.degrade(req, attempt)
        n_steps = overrides.get("n_steps", req.n_steps)
        save_every = req.save_every
        if save_every is not None and n_steps != req.n_steps:
            # Halved h doubles the grid; scale the cadence so the retried
            # result saves the same times (and the same number of frames).
            save_every = save_every * (n_steps // req.n_steps)
        term_kind = ("manifold" if hasattr(self.term, "algebra_increment")
                     else "euclidean")
        child_id = -next(self._retry_ids)
        try:
            child = make_request(
                child_id, overrides.get("solver", req.solver),
                term_kind=term_kind, t0=req.t0, t1=req.t1, n_steps=n_steps,
                n_paths=req.n_paths, save_every=save_every, seed=req.seed,
                rtol=req.rtol, atol=req.atol, save_at=req.save_at,
                priority=req.priority, deadline_ms=deadline_ms)
        except ValueError:
            return None
        # force: a retry replaces capacity an earlier admit already granted;
        # refusing it would strand the request (and any async waiter).
        self.scheduler.enqueue(child, force=True)
        self._req_by_id[child_id] = child
        self._retry_parent[child_id] = root
        self._retry_attempt[root] = attempt + 1
        self.counters["retries"] += 1
        return child_id

    def _finalize_retired(self, rid: int) -> Optional[int]:
        """Post-retirement hook: book divergence, retry or surface.

        Called with an id just retired into ``done``.  Returns the ROOT id
        now terminally complete (results of retry children move under their
        root), or None when the request went back on the queue as a
        degraded retry.  Forces a host read of the per-path ``diverged``
        flags — one tiny bool array per retired request, NOT per tick."""
        res = self.scheduler.done[rid]
        root = self._retry_parent.get(rid, rid)
        attempt = self._retry_attempt.get(root, 0)
        n_div = 0
        if res.diverged is not None:
            n_div = int(np.asarray(jax.device_get(res.diverged)).sum())
        if n_div:
            self.counters["diverged_requests"] += 1
            self.counters["diverged_paths"] += n_div
        req = self._req_by_id.get(rid)
        if (n_div and self.cfg.retry_policy is not None and req is not None
                and attempt < self.cfg.retry_policy.max_retries
                and self._make_retry(root, req, attempt) is not None):
            del self.scheduler.done[rid]
            self._req_by_id.pop(rid, None)
            if rid != root:
                self._retry_parent.pop(rid, None)
            return None
        self._req_by_id.pop(rid, None)
        self._retry_attempt.pop(root, None)
        self._deadline.pop(root, None)
        if rid != root:
            self._retry_parent.pop(rid, None)
            res = self.scheduler.done.pop(rid)
            self.scheduler.done[root] = res
        if attempt:
            self.scheduler.done[root] = dataclasses.replace(
                self.scheduler.done[root], retries=attempt)
        return root

    # -- internals -----------------------------------------------------------

    def _request_keys(self, req: SampleRequest) -> np.ndarray:
        """All of a request's path keys, built once: one vmapped
        ``fold_in(PRNGKey(seed), i)`` over the path indices (integer ops —
        bitwise-identical to per-path host calls)."""
        keys = self._key_cache.get(req.request_id)
        if keys is None:
            from repro.core.sdeint import path_keys

            keys = np.asarray(
                path_keys(jax.random.PRNGKey(req.seed), req.n_paths))
            self._key_cache[req.request_id] = keys
        return keys

    def _plan_keys(self, plan: SlotPlan) -> jax.Array:
        """Assemble the (n_ticks, slots, ...) key stack for one dispatch;
        unassigned slots get a dummy key (their outputs are never read), so
        every dispatch of a (signature, depth) pair reuses one executable."""
        buf = np.empty((plan.n_ticks, plan.slots) + self._pad_key.shape,
                       self._pad_key.dtype)
        buf[:] = self._pad_key
        for t, tick in enumerate(plan.ticks):
            s = 0
            while s < len(tick):  # contiguous (pending, path) runs -> slices
                p, i0 = tick[s]
                e = s + 1
                while e < len(tick) and tick[e][0] is p:
                    e += 1
                buf[t, s:e] = self._request_keys(p.request)[i0:i0 + (e - s)]
                s = e
        return jnp.asarray(buf)

    def _split_subplans(self, plan: SlotPlan) -> list:
        """Split a plan into dispatch units that only ever touch the full
        ``ticks_per_dispatch`` stack executable or the single-tick one.

        A plan shallower than the configured depth (the queue tail, or a
        ``max_ticks``-capped budget) is served tick-by-tick through the
        single-tick executable rather than as a fresh variable-depth stack —
        otherwise every distinct tail depth would trigger a full XLA
        recompile of the solve, and a drain would touch up to
        ``ticks_per_dispatch`` executables per signature instead of two."""
        if plan.n_ticks in (1, self.cfg.ticks_per_dispatch):
            return [plan]
        return [SlotPlan(plan.tick_sigs[t] if plan.tick_sigs else
                         plan.signature, plan.slots, [tick],
                         reserved=plan.reserved, group=plan.group,
                         tick_sigs=(plan.tick_sigs[t],)
                         if plan.tick_sigs else None)
                for t, tick in enumerate(plan.ticks)]

    def _exec_key(self, plan: SlotPlan):
        """What the executor caches/dispatches on for this plan: its bucket
        when the scheduler grouped it into one, else its exact signature."""
        if isinstance(plan.group, BucketKey):
            return plan.group
        return plan.signature

    def _active_steps(self, plan: SlotPlan):
        """The bucket executable's per-tick true-step-count operand (None for
        exact dispatch).  Each tick is signature-homogeneous by planner
        contract, so its entry is that tick's signature's ``n_steps``."""
        if not isinstance(plan.group, BucketKey):
            return None
        return jnp.asarray([sig[3] for sig in plan.tick_sigs], jnp.int32)

    def _dispatch(self, plan: SlotPlan, keys):
        """Route one subplan to the executor — bucketed or exact."""
        return self.executor.dispatch(self._exec_key(plan), keys,
                                      self._active_steps(plan))

    def _take_plan(self, depth: int):
        """The next (plan, key stack) to dispatch: the staged pair when it is
        still live and fits the tick budget, else a fresh reserved plan.

        A staged stack whose every request was cancelled since staging is
        *released*, never dispatched — a fully-cancelled stack must not burn
        a no-op device dispatch (regression-tested: ``n_dispatches`` stays
        flat when a cancel empties the queue mid-run)."""
        while self._staged is not None:
            plan, keys = self._staged
            self._staged = None
            if not plan.live:
                self.scheduler.release(plan)   # skip, don't dispatch no-ops
                continue
            if plan.n_ticks > depth:
                # The budget shrank since staging (run(max_ticks=...) tail):
                # unwind the reservation — staged is always the newest plan,
                # so LIFO release is safe — and replan at the allowed depth.
                self.scheduler.release(plan)
                continue
            return plan, keys
        plan = self.scheduler.plan(self.cfg.slots, depth, reserve=True)
        if plan is None:
            return None, None
        return plan, self._plan_keys(plan)

    def _stage_next(self) -> None:
        """Plan and key-pack the next dispatch while the device is still
        running the current one (host-side double buffering): reservations
        make the cursor arithmetic identical to planning after delivery, so
        the plan sequence — and therefore every sample — is unchanged."""
        if self._staged is None:
            plan = self.scheduler.plan(self.cfg.slots,
                                       self.cfg.ticks_per_dispatch,
                                       reserve=True)
            if plan is not None:
                self._staged = (plan, self._plan_keys(plan))

    def _dispatch_next(self, tick_limit: int) -> int:
        """Plan (or unstage), dispatch, and deliver one tick stack; returns
        the number of ticks served (0 when idle — nothing live queued).

        Crash safety: if a dispatch raises (an injected executor fault, an
        XLA error), the reservations of every not-yet-delivered tick are
        released before the exception propagates — the queue keeps owning
        exactly the undelivered work, so a caller that catches the error and
        calls ``run()`` again serves every path exactly once (no loss, no
        duplication; samples are key-determined, so the rerun is bitwise
        what an uninterrupted run would have delivered)."""
        self._expire()
        depth = min(tick_limit, self.cfg.ticks_per_dispatch)
        plan, keys = self._take_plan(depth)
        if plan is None:
            return 0
        subplans = self._split_subplans(plan)
        offset = 0
        delivered = 0
        try:
            for i, sp in enumerate(subplans):
                sp_keys = keys if len(subplans) == 1 else \
                    keys[offset:offset + sp.n_ticks]
                offset += sp.n_ticks
                result = self._dispatch(sp, sp_keys)
                if i == len(subplans) - 1 and self.cfg.double_buffer:
                    # Device is (asynchronously) chewing on the stack we just
                    # dispatched; overlap the next plan's host work with it.
                    self._stage_next()
                outputs = {"y_final": np.asarray(result.y_final),
                           "ys": (None if result.ys is None
                                  else np.asarray(result.ys))}
                # Adaptive results carry where each path actually stopped
                # plus its realized-grid stats; the guard adds the per-path
                # diverged flag — surface them all so truncated paths are
                # detectable, step counts observable, and blow-ups
                # retryable.
                for name in STAT_FIELDS:
                    val = getattr(result, name, None)
                    outputs[name] = None if val is None else np.asarray(val)
                for rid in self.scheduler.deliver(sp, outputs):
                    self._key_cache.pop(rid, None)
                    self._finalize_retired(rid)
                delivered += 1
        except BaseException:
            # LIFO unwind: the staged (newest) reservation first, then the
            # undelivered remainder of the crashed plan.
            if self._staged is not None:
                staged_plan, _ = self._staged
                self._staged = None
                self.scheduler.release(staged_plan)
            residual = [tick for sp in subplans[delivered:]
                        for tick in sp.ticks]
            if residual:
                self.scheduler.release(SlotPlan(
                    plan.signature, plan.slots, residual, reserved=True,
                    group=plan.group))
            raise
        return plan.n_ticks

    def tick(self) -> bool:
        """Serve one dispatch (up to ``ticks_per_dispatch`` ticks in one host
        round trip); return False when idle."""
        return self._dispatch_next(self.cfg.ticks_per_dispatch) > 0

    def run(self, max_ticks: int = 10_000) -> Dict[int, SampleResult]:
        """Serve until the queue drains (or ``max_ticks`` ticks ran).

        Idle states — an empty queue, or one holding only cancelled
        requests — return immediately with whatever ``done`` already holds;
        they can never spin the tick budget."""
        served = 0
        while served < max_ticks:
            n = self._dispatch_next(max_ticks - served)
            if n == 0:
                return self.done
            served += n
        if self.pending():
            raise RuntimeError(
                f"max_ticks={max_ticks} exhausted with {len(self.pending())} "
                "request(s) still queued; raise max_ticks or slots"
            )
        return self.done
