"""repro.serving — batched engines.

  engine       — LM continuous-batching decode engine (fixed-slot serve_step)
  scheduler    — host-side SDE serving core: priority/FIFO queue, signature
                 grouping, slot plans, admission control, result
                 scatter/retirement (device-free)
  executor     — device-side SDE serving core: jit'd on-device multi-tick
                 dispatch, optional mesh-sharded slot axis
  bucketing    — signature coalescing: padded bucketed dispatch (ladder
                 rungs + BucketKey planning groups, bitwise-identical)
  sde_engine   — Monte-Carlo SDE sampling engine (façade over the two layers)
  async_engine — asyncio continuous-batching serving plane: awaitable
                 submit/result with backpressure, cross-signature
                 interleaving, host-side double buffering, device-resident
                 results
  faults       — deterministic fault injection (NaN trajectories, transient
                 executor crashes, delays) + FakeClock, for exercising the
                 robustness layer (guards, retries, deadlines, restarts)
"""
from .async_engine import AsyncSDESampleEngine
from .bucketing import BucketingConfig, BucketKey, bucket_key, group_key, ladder_rung
from .engine import Engine, ServeConfig
from .executor import TickExecutor, enable_persistent_compile_cache
from .faults import FakeClock, FaultConfig, FaultyExecutor, InjectedCrash, inject_faults
from .scheduler import QueueFull, RetryPolicy, Scheduler, SlotPlan
from .sde_engine import SampleRequest, SampleResult, SDESampleConfig, SDESampleEngine

__all__ = [
    "Engine",
    "ServeConfig",
    "QueueFull",
    "Scheduler",
    "SlotPlan",
    "TickExecutor",
    "enable_persistent_compile_cache",
    "BucketingConfig",
    "BucketKey",
    "bucket_key",
    "group_key",
    "ladder_rung",
    "AsyncSDESampleEngine",
    "SDESampleEngine",
    "SDESampleConfig",
    "SampleRequest",
    "SampleResult",
    "RetryPolicy",
    "FaultConfig",
    "FaultyExecutor",
    "FakeClock",
    "InjectedCrash",
    "inject_faults",
]
