"""repro.serving — batched engines.

  engine     — LM continuous-batching decode engine (fixed-slot serve_step)
  scheduler  — host-side SDE serving core: FIFO queue, signature grouping,
               slot plans, result scatter/retirement (device-free)
  executor   — device-side SDE serving core: jit'd on-device multi-tick
               dispatch, optional mesh-sharded slot axis
  sde_engine — Monte-Carlo SDE sampling engine (façade over the two layers)
"""
from .engine import Engine, ServeConfig
from .executor import TickExecutor
from .scheduler import Scheduler, SlotPlan
from .sde_engine import SampleRequest, SampleResult, SDESampleConfig, SDESampleEngine

__all__ = [
    "Engine",
    "ServeConfig",
    "Scheduler",
    "SlotPlan",
    "TickExecutor",
    "SDESampleEngine",
    "SDESampleConfig",
    "SampleRequest",
    "SampleResult",
]
