"""repro.serving — batched engines.

  engine     — LM continuous-batching decode engine (fixed-slot serve_step)
  sde_engine — Monte-Carlo SDE sampling engine (fixed-slot batched sdeint)
"""
from .engine import Engine, ServeConfig
from .sde_engine import SampleRequest, SampleResult, SDESampleConfig, SDESampleEngine

__all__ = [
    "Engine",
    "ServeConfig",
    "SDESampleEngine",
    "SDESampleConfig",
    "SampleRequest",
    "SampleResult",
]
