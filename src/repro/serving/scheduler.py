"""Host-side serving scheduler: FIFO queue, signature grouping, slot plans.

This is the pure-Python half of the SDE serving core (the device half is
:mod:`repro.serving.executor`; :class:`repro.serving.SDESampleEngine` is the
façade over both).  The scheduler owns everything that does NOT need a
device — and is therefore unit-testable without one:

* the FIFO request queue and the ``done`` result store;
* request validation at submit time (:func:`make_request`), so a bad spec
  can never crash at the queue head and starve the requests behind it;
* **slot-plan construction** (:meth:`Scheduler.plan`): fill up to
  ``max_ticks`` fixed-size ticks of ``slots`` paths each with paths from
  queued requests sharing the head request's *signature* — FIFO over
  requests, contiguous over each request's path indices.  Within that
  signature group, planning ``T`` ticks at once is allocation-for-allocation
  identical to planning one tick ``T`` times (the cursor arithmetic is the
  same), which is what lets the executor run the whole stack in one
  on-device loop without changing which path lands in which slot.  Across
  signatures the stack widens the continuous-batching window: a deeper
  dispatch may finish a later same-signature request before an earlier
  different-signature one gets its first tick — the same
  group-by-signature policy the single-tick engine already applied within
  one tick, extended over ``ticks_per_dispatch`` ticks.  Service *order*
  (and latency) across signatures therefore depends on the dispatch depth;
  the delivered samples never do;
* **result scatter and retirement** (:meth:`Scheduler.deliver`): route each
  slot of each tick back to its request, retire fully-served requests into
  ``done`` in queue order;
* cancellation (lazy — a cancelled entry is skipped by the planner and
  pruned from the queue on the next plan, so ``cancel`` is O(1)) and
  :meth:`Scheduler.pending` introspection for polling clients.

The scheduler never touches a PRNG key: a plan names ``(request, path
index)`` pairs, and sampling reproducibility comes from the engine mapping
pair ``(r, i)`` to ``fold_in(PRNGKey(seed_r), i)`` — independent of slot
assignment, tick boundaries, dispatch grouping, and device placement.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import canonical_spec, parse_solver_spec, solver_kind

__all__ = [
    "SampleRequest",
    "SampleResult",
    "PendingRequest",
    "SlotPlan",
    "Scheduler",
    "make_request",
]

# Per-path adaptive statistics riding along with every delivery.
STAT_FIELDS = ("t_final", "n_accepted", "n_rejected")


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    request_id: int
    solver: str
    t0: float
    t1: float
    n_steps: int
    n_paths: int
    save_every: Optional[int]
    seed: int
    # Adaptive-solve options (solver spec carries an "adaptive" flag):
    # tolerances for the PI controller and an arbitrary-time output grid.
    rtol: Optional[float] = None
    atol: Optional[float] = None
    save_at: Optional[Tuple[float, ...]] = None

    @property
    def signature(self) -> Tuple:
        """Requests with equal signatures can share one compiled batch."""
        return (self.solver, self.t0, self.t1, self.n_steps, self.save_every,
                self.rtol, self.atol, self.save_at)


@dataclasses.dataclass
class SampleResult:
    """Stacked per-path outputs: ``y_final`` is (n_paths, ...); ``ys`` is
    (n_paths, n_saves, ...) when the request asked for a saved trajectory.

    ``t_final`` (adaptive requests only) is the (n_paths,) time each path
    actually reached — equal to the request's ``t1`` unless the trial-step
    budget ``n_steps`` was exhausted first, in which case the path stopped
    short and its ``y_final`` is NOT a sample at ``t1``.  Check it (or just
    ``(t_final == t1).all()``) before trusting adaptive results from
    aggressive tolerance/budget combinations.

    ``n_accepted`` / ``n_rejected`` (adaptive requests only) are the
    per-path realized-grid statistics: how many steps each path's controller
    accepted/rejected — the realized grid a client would replay offline (via
    ``realize_grid`` with the same seed-derived key) for gradient work."""

    y_final: Any
    ys: Optional[Any]
    t_final: Optional[np.ndarray] = None
    n_accepted: Optional[np.ndarray] = None
    n_rejected: Optional[np.ndarray] = None


@dataclasses.dataclass(eq=False)  # identity hash: instances are queue entries
class PendingRequest:
    request: SampleRequest
    delivered: int = 0
    cancelled: bool = False
    y_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    ys: List[np.ndarray] = dataclasses.field(default_factory=list)
    t_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_accepted: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_rejected: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.request.n_paths - self.delivered


@dataclasses.dataclass
class SlotPlan:
    """One dispatch: up to ``max_ticks`` same-signature ticks of ``slots``
    paths each.  ``ticks[t][s]`` names the (pending, path-index) pair that
    owns slot ``s`` of tick ``t``; trailing slots of a tick may be unassigned
    (the engine pads them with dummy keys and the planner never references
    their outputs)."""

    signature: Tuple
    slots: int
    ticks: List[List[Tuple[PendingRequest, int]]]

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)

    @property
    def n_paths(self) -> int:
        return sum(len(t) for t in self.ticks)


def make_request(request_id: int, solver: str, *, term_kind: str, t1: float,
                 n_steps: int, n_paths: int, t0: float = 0.0,
                 save_every: Optional[int] = None, seed: Optional[int] = None,
                 rtol: Optional[float] = None, atol: Optional[float] = None,
                 save_at=None) -> SampleRequest:
    """Validate request options and build a :class:`SampleRequest`.

    Raises on anything malformed — this runs at submit time, not at the
    queue head where a crash would starve everything queued behind it.
    ``term_kind`` is the solver kind the serving term needs (``"euclidean"``
    or ``"manifold"``); the solver spec must match.
    """
    if n_paths < 1:
        raise ValueError(f"n_paths must be >= 1, got {n_paths}")
    n_steps = int(n_steps)
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    if not float(t1) > float(t0):
        raise ValueError(f"need t1 > t0, got t0={t0}, t1={t1}")
    solver = canonical_spec(solver)  # raises on unknown names; one
    # normal form per solver so equivalent spellings share a signature
    if solver_kind(solver) != term_kind:
        raise ValueError(
            f"solver {solver!r} is {solver_kind(solver)}-kind but this "
            f"engine's term needs a {term_kind} solver"
        )
    adaptive = parse_solver_spec(solver)[1].get("adaptive", False)
    if not adaptive:
        for name, val in (("rtol", rtol), ("atol", atol), ("save_at", save_at)):
            if val is not None:
                raise ValueError(
                    f"{name} only applies to adaptive solves; request an "
                    f"':adaptive' solver spec (got {solver!r})"
                )
    if adaptive and save_every is not None:
        raise ValueError(
            "save_every indexes a fixed grid; adaptive requests take "
            "save_at=<sequence of times> instead"
        )
    if save_at is not None:
        save_at = tuple(float(t) for t in save_at)
        if not save_at:
            raise ValueError("save_at must be a non-empty sequence of times")
        if not all(float(t0) <= t <= float(t1) for t in save_at):
            raise ValueError(f"save_at times must lie in [{t0}, {t1}]")
    if save_every is not None:
        if int(save_every) != save_every or int(save_every) < 1:
            raise ValueError(f"save_every must be a positive int, got {save_every}")
        save_every = int(save_every)
        if n_steps % save_every != 0:
            raise ValueError(
                f"save_every={save_every} does not divide n_steps={n_steps}"
            )
    return SampleRequest(
        request_id=request_id, solver=solver, t0=float(t0), t1=float(t1),
        n_steps=n_steps, n_paths=int(n_paths), save_every=save_every,
        seed=request_id if seed is None else int(seed),
        rtol=None if rtol is None else float(rtol),
        atol=None if atol is None else float(atol),
        save_at=save_at,
    )


class Scheduler:
    """FIFO scheduler over :class:`PendingRequest` entries (host-side only)."""

    def __init__(self):
        self.queue: Deque[PendingRequest] = deque()
        self.done: Dict[int, SampleResult] = {}
        self._next_id = 0
        self._cancelled_ids: set = set()

    @property
    def next_request_id(self) -> int:
        """The id the next enqueued request will get.  Reading it does not
        allocate: build (and validate) the request against this id first, so
        a rejected submit burns no id and leaves default seeds (= request
        id) of later requests unshifted."""
        return self._next_id

    def new_request_id(self) -> int:
        rid = self._next_id
        self._next_id += 1
        return rid

    def enqueue(self, request: SampleRequest) -> int:
        self._next_id = max(self._next_id, request.request_id + 1)
        self.queue.append(PendingRequest(request))
        return request.request_id

    # -- introspection / cancellation ---------------------------------------

    def pending(self) -> Dict[int, int]:
        """Paths still owed per queued request id (FIFO order, cancelled
        entries excluded) — what a polling client checks between ``run``s."""
        return {p.request.request_id: p.remaining
                for p in self.queue if not p.cancelled}

    def cancel(self, request_id: int) -> bool:
        """Cancel a queued request; partial results are discarded.

        Returns True if this call cancelled it, False if it was already
        cancelled or already completed (``done`` keeps completed results —
        cancellation never un-delivers).  Unknown ids raise ``KeyError``.
        O(1) effect: the entry is only marked here and pruned by the next
        :meth:`plan`, so an idle engine never spins over cancelled husks.
        """
        if request_id in self.done:
            return False
        if request_id in self._cancelled_ids:
            return False  # repeat cancel, incl. after plan() pruned the entry
        for p in self.queue:
            if p.request.request_id == request_id:
                p.cancelled = True
                self._cancelled_ids.add(request_id)
                return True
        raise KeyError(f"unknown request id {request_id}")

    # -- planning -----------------------------------------------------------

    def plan(self, slots: int, max_ticks: int = 1) -> Optional[SlotPlan]:
        """Build the next dispatch: up to ``max_ticks`` ticks of the head
        signature, or None when no work is queued.

        Prunes cancelled entries first (their partial results are dropped),
        then fills tick after tick over the head-signature group exactly as
        successive single-tick plans over that group would — multi-tick
        dispatch never changes *which* path runs in which slot.  It can
        change cross-signature service order: the stack keeps draining the
        head signature, so an other-signature request queued in between
        waits for the next dispatch (see the module docstring).
        """
        if any(p.cancelled for p in self.queue):
            live = [p for p in self.queue if not p.cancelled]
            # prune in place: the queue object is a stable view (the engine
            # façade exposes it), so rebinding would strand held references
            self.queue.clear()
            self.queue.extend(live)
        if not self.queue:
            return None
        sig = self.queue[0].request.signature
        taken: Dict[PendingRequest, int] = {}
        ticks: List[List[Tuple[PendingRequest, int]]] = []
        for _ in range(max_ticks):
            tick: List[Tuple[PendingRequest, int]] = []
            budget = slots
            for p in self.queue:
                if budget == 0:
                    break
                if p.request.signature != sig:
                    continue
                start = p.delivered + taken.get(p, 0)
                take = min(budget, p.request.n_paths - start)
                tick.extend((p, start + j) for j in range(take))
                if take:
                    taken[p] = taken.get(p, 0) + take
                    budget -= take
            if not tick:
                break  # signature group exhausted before max_ticks
            ticks.append(tick)
        if not ticks:
            return None
        return SlotPlan(signature=sig, slots=slots, ticks=ticks)

    # -- delivery -----------------------------------------------------------

    def deliver(self, plan: SlotPlan,
                outputs: Dict[str, Optional[np.ndarray]]) -> List[int]:
        """Scatter dispatch outputs back to their requests and retire.

        ``outputs`` maps field name (``y_final`` / ``ys`` / the adaptive
        stats) to a stacked host array with leading ``(n_ticks, slots)``
        axes, or None for fields this signature does not produce.  Returns
        the ids retired into ``done``, in queue order.
        """
        for t, tick in enumerate(plan.ticks):
            for s, (p, i) in enumerate(tick):
                if i != p.delivered:  # pragma: no cover — planner invariant
                    raise RuntimeError(
                        f"plan slot (tick {t}, slot {s}) delivers path {i} of "
                        f"request {p.request.request_id} but {p.delivered} "
                        "paths were delivered so far — out-of-order delivery"
                    )
                p.y_final.append(outputs["y_final"][t, s])
                if outputs.get("ys") is not None:
                    p.ys.append(outputs["ys"][t, s])
                for name in STAT_FIELDS:
                    if outputs.get(name) is not None:
                        getattr(p, name).append(outputs[name][t, s])
                p.delivered += 1
        retired = []
        for p in dict.fromkeys(p for tick in plan.ticks for p, _ in tick):
            if p.delivered == p.request.n_paths and not p.cancelled:
                self.queue.remove(p)
                rid = p.request.request_id
                self.done[rid] = SampleResult(
                    y_final=np.stack(p.y_final),
                    ys=np.stack(p.ys) if p.ys else None,
                    **{name: (np.stack(getattr(p, name))
                              if getattr(p, name) else None)
                       for name in STAT_FIELDS},
                )
                retired.append(rid)
        return retired
