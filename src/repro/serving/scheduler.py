"""Host-side serving scheduler: FIFO queue, signature grouping, slot plans.

This is the pure-Python half of the SDE serving core (the device half is
:mod:`repro.serving.executor`; :class:`repro.serving.SDESampleEngine` is the
façade over both).  The scheduler owns everything that does NOT need a
device — and is therefore unit-testable without one:

* the FIFO request queue and the ``done`` result store;
* request validation at submit time (:func:`make_request`), so a bad spec
  can never crash at the queue head and starve the requests behind it;
* **slot-plan construction** (:meth:`Scheduler.plan`): fill up to
  ``max_ticks`` fixed-size ticks of ``slots`` paths each with paths from
  queued requests sharing the head request's *signature* — FIFO over
  requests, contiguous over each request's path indices.  Within that
  signature group, planning ``T`` ticks at once is allocation-for-allocation
  identical to planning one tick ``T`` times (the cursor arithmetic is the
  same), which is what lets the executor run the whole stack in one
  on-device loop without changing which path lands in which slot.  Across
  signatures the stack widens the continuous-batching window: a deeper
  dispatch may finish a later same-signature request before an earlier
  different-signature one gets its first tick — the same
  group-by-signature policy the single-tick engine already applied within
  one tick, extended over ``ticks_per_dispatch`` ticks.  Service *order*
  (and latency) across signatures therefore depends on the dispatch depth;
  the delivered samples never do;
* **result scatter and retirement** (:meth:`Scheduler.deliver`): route each
  slot of each tick back to its request, retire fully-served requests into
  ``done`` in queue order;
* cancellation (lazy — a cancelled entry is skipped by the planner and
  pruned from the queue on the next plan, so ``cancel`` is O(1)) and
  :meth:`Scheduler.pending` introspection for polling clients;
* **priority classes**: every request carries a ``priority`` (higher is
  served sooner); planning walks the queue in *service order* — a stable
  sort by descending priority, so equal priorities keep strict FIFO and the
  default ``priority=0`` workload behaves exactly as before.  Priority only
  reorders *when* a request is served, never *what* it receives (samples are
  a pure function of ``(seed, path index)``);
* **admission control**: optional ``max_requests`` / ``max_paths`` bounds
  turn :meth:`Scheduler.enqueue` into a bounded queue that raises
  :class:`QueueFull` instead of growing without limit — the hook the async
  engine's backpressure (``await submit``) and a sync caller's load shedding
  both build on;
* **plan-ahead reservations** (:meth:`Scheduler.plan` with
  ``reserve=True``): a reserved plan marks its paths in flight so the *next*
  plan starts beyond them — this is what lets an engine build and stage
  stack N+1 while the device still runs stack N (host-side double
  buffering).  Reserved plans must be delivered in the order they were
  planned; an undispatched reserved plan can be returned via
  :meth:`Scheduler.release` (LIFO — newest first), e.g. when every request
  in a staged stack was cancelled before its dispatch.

The scheduler never touches a PRNG key: a plan names ``(request, path
index)`` pairs, and sampling reproducibility comes from the engine mapping
pair ``(r, i)`` to ``fold_in(PRNGKey(seed_r), i)`` — independent of slot
assignment, tick boundaries, dispatch grouping, device placement, priority
ordering, and double buffering.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import canonical_spec, parse_solver_spec, solver_kind

__all__ = [
    "QueueFull",
    "RetryPolicy",
    "SampleRequest",
    "SampleResult",
    "PendingRequest",
    "SlotPlan",
    "Scheduler",
    "make_request",
]


class QueueFull(RuntimeError):
    """Admission control refused a submit: the bounded queue is at capacity.

    Sync callers should shed load (or retry later); the async engine's
    ``await submit`` catches this and waits for space instead."""

# Per-path statistics riding along with every delivery: the adaptive
# controller stats plus the per-path blow-up flag from the in-loop guard
# (``diverged`` — produced whenever the engine's guard is enabled, for
# fixed-grid and adaptive requests alike; None when the guard is off).
STAT_FIELDS = ("t_final", "n_accepted", "n_rejected", "diverged")


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    request_id: int
    solver: str
    t0: float
    t1: float
    n_steps: int
    n_paths: int
    save_every: Optional[int]
    seed: int
    # Adaptive-solve options (solver spec carries an "adaptive" flag):
    # tolerances for the PI controller and an arbitrary-time output grid.
    rtol: Optional[float] = None
    atol: Optional[float] = None
    save_at: Optional[Tuple[float, ...]] = None
    # Service-order class: higher priorities are planned sooner; equal
    # priorities keep strict FIFO.  Never part of the signature — priority
    # says when a request runs, not what executable runs it.
    priority: int = 0
    # Wall-clock budget: paths not delivered within deadline_ms of submit
    # retire with a timeout result (sync) / a TimeoutError (async).  Never
    # part of the signature — a deadline says how long a request may wait,
    # not what executable runs it.
    deadline_ms: Optional[float] = None

    @property
    def signature(self) -> Tuple:
        """Requests with equal signatures can share one compiled batch."""
        return (self.solver, self.t0, self.t1, self.n_steps, self.save_every,
                self.rtol, self.atol, self.save_at)


@dataclasses.dataclass
class SampleResult:
    """Stacked per-path outputs: ``y_final`` is (n_paths, ...); ``ys`` is
    (n_paths, n_saves, ...) when the request asked for a saved trajectory.

    ``t_final`` (adaptive requests only) is the (n_paths,) time each path
    actually reached — equal to the request's ``t1`` unless the trial-step
    budget ``n_steps`` was exhausted first, in which case the path stopped
    short and its ``y_final`` is NOT a sample at ``t1``.  Check it (or just
    ``(t_final == t1).all()``) before trusting adaptive results from
    aggressive tolerance/budget combinations.

    ``n_accepted`` / ``n_rejected`` (adaptive requests only) are the
    per-path realized-grid statistics: how many steps each path's controller
    accepted/rejected — the realized grid a client would replay offline (via
    ``realize_grid`` with the same seed-derived key) for gradient work.

    ``diverged`` (guard-enabled engines) is the (n_paths,) per-path blow-up
    flag from the in-loop divergence guard: True where a path's state went
    non-finite or exceeded the guard threshold at any step.  The samples are
    whatever the solver computed (the guard is a pure observer); treat
    flagged paths as unusable.  None when the guard is off.

    ``timed_out`` marks a request whose ``deadline_ms`` elapsed before
    delivery: its arrays are None and it retired with a timeout state
    instead of samples.  ``retries`` counts degradation-ladder resubmits the
    engine spent on this request (0 for a first-attempt completion; see
    :class:`RetryPolicy`).

    ``bucket`` / ``n_padded_steps`` / ``n_padded_paths`` surface bucketed
    dispatch (PR 8) for operators watching padding waste: ``bucket`` is the
    :class:`~repro.serving.bucketing.BucketKey` this request was coalesced
    into (None when it dispatched exact), ``n_padded_steps`` how many masked
    padding steps its executable carried beyond the request's true
    ``n_steps``, and ``n_padded_paths`` how many dead (dummy-key) slots rode
    along in the ticks that served it.  Padding never changes the samples —
    padding steps are skipped conditionals and dead slots are dropped before
    scatter — these fields only quantify the compute the coalescing spent to
    share an executable."""

    y_final: Any
    ys: Optional[Any]
    t_final: Optional[np.ndarray] = None
    n_accepted: Optional[np.ndarray] = None
    n_rejected: Optional[np.ndarray] = None
    diverged: Optional[np.ndarray] = None
    bucket: Any = None
    n_padded_steps: int = 0
    n_padded_paths: int = 0
    timed_out: bool = False
    retries: int = 0


@dataclasses.dataclass(eq=False)  # identity hash: instances are queue entries
class PendingRequest:
    request: SampleRequest
    delivered: int = 0
    # Paths named by a not-yet-delivered *reserved* plan (see Scheduler.plan
    # with reserve=True): planning starts beyond delivered + reserved, so a
    # staged stack and the live one never overlap.
    reserved: int = 0
    cancelled: bool = False
    # Bucketing introspection (set when the request is first planned /
    # delivered; see SampleResult for the field semantics).
    bucket: Any = None
    n_padded_steps: int = 0
    n_padded_paths: int = 0
    # Absolute wall-clock deadline (scheduler-clock seconds) when the
    # request carries deadline_ms; set at enqueue time.
    deadline: Optional[float] = None
    y_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    ys: List[np.ndarray] = dataclasses.field(default_factory=list)
    t_final: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_accepted: List[np.ndarray] = dataclasses.field(default_factory=list)
    n_rejected: List[np.ndarray] = dataclasses.field(default_factory=list)
    diverged: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.request.n_paths - self.delivered

    def n_diverged(self) -> int:
        """Delivered paths flagged by the blow-up guard so far.  Each entry
        is one path's scalar flag; async deliveries keep them device-resident
        until materialised, so this forces a transfer of tiny bools only."""
        return int(sum(bool(np.asarray(d)) for d in self.diverged))


@dataclasses.dataclass
class SlotPlan:
    """One dispatch: up to ``max_ticks`` same-*group* ticks of ``slots``
    paths each.  ``ticks[t][s]`` names the (pending, path-index) pair that
    owns slot ``s`` of tick ``t``; trailing slots of a tick may be unassigned
    (the engine pads them with dummy keys and the planner never references
    their outputs).  ``reserved`` plans hold their paths in flight until
    delivered (or released) — see :meth:`Scheduler.plan`.

    Without bucketing a group IS one signature and every tick shares it.
    Under a bucketed group several *true* signatures (same bucket, different
    horizons) may stack into one plan: each **tick** stays homogeneous in
    true signature — ``tick_sigs[t]`` names tick ``t``'s — because the
    executor's per-tick ``active_steps`` operand is one scalar per tick.
    ``group`` carries the planning-group key (a
    :class:`~repro.serving.bucketing.BucketKey` for bucketed plans);
    ``signature`` remains the first tick's true signature for single-
    signature consumers."""

    signature: Tuple
    slots: int
    ticks: List[List[Tuple[PendingRequest, int]]]
    reserved: bool = False
    group: Any = None
    tick_sigs: Optional[Tuple[Tuple, ...]] = None

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)

    @property
    def n_paths(self) -> int:
        return sum(len(t) for t in self.ticks)

    @property
    def live(self) -> bool:
        """False once every owning request was cancelled — a dead stack an
        engine should skip (releasing it) instead of dispatching no-ops."""
        return any(not p.cancelled for tick in self.ticks for p, _ in tick)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Degradation ladder for diverged requests (see ``docs/robustness.md``).

    A request whose delivered paths carry any guard ``diverged`` flag is
    resubmitted by the engine down a two-stage ladder, at most
    ``max_retries`` times total:

    1. the first ``max_h_halvings`` retries **halve the step size** — same
       solver, ``n_steps`` doubled over the same window (for adaptive
       requests this doubles the trial-step budget);
    2. further retries **fall back** to ``fallback_solver`` (``ees27`` — the
       paper's widest-stability-region explicit scheme), preserving the
       request's adaptive flag; if the request already runs the fallback
       family, the ladder keeps halving instead.

    Retries reuse the root request's seed, so a retried sample is exactly
    what submitting the degraded spec directly would have produced —
    reproducible, and bitwise-independent of when the retry happened."""

    max_retries: int = 2
    max_h_halvings: int = 1
    fallback_solver: str = "ees27"

    def degrade(self, request: "SampleRequest", attempt: int) -> Dict[str, Any]:
        """Spec overrides for retry number ``attempt`` (0-based): a dict of
        ``make_request`` keyword overrides (``solver`` / ``n_steps``)."""
        base, opts = parse_solver_spec(request.solver)
        fb = canonical_spec(self.fallback_solver)
        fb_base, _ = parse_solver_spec(fb)
        if attempt < self.max_h_halvings or base == fb_base:
            return {"solver": request.solver, "n_steps": request.n_steps * 2}
        solver = self.fallback_solver
        if opts.get("adaptive", False):
            solver = f"{solver}:adaptive"
        return {"solver": canonical_spec(solver), "n_steps": request.n_steps}


def make_request(request_id: int, solver: str, *, term_kind: str, t1: float,
                 n_steps: int, n_paths: int, t0: float = 0.0,
                 save_every: Optional[int] = None, seed: Optional[int] = None,
                 rtol: Optional[float] = None, atol: Optional[float] = None,
                 save_at=None, priority: int = 0,
                 deadline_ms: Optional[float] = None) -> SampleRequest:
    """Validate request options and build a :class:`SampleRequest`.

    Raises on anything malformed — this runs at submit time, not at the
    queue head where a crash would starve everything queued behind it.
    ``term_kind`` is the solver kind the serving term needs (``"euclidean"``
    or ``"manifold"``); the solver spec must match.
    """
    if n_paths < 1:
        raise ValueError(f"n_paths must be >= 1, got {n_paths}")
    n_steps = int(n_steps)
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    if not float(t1) > float(t0):
        raise ValueError(f"need t1 > t0, got t0={t0}, t1={t1}")
    solver = canonical_spec(solver)  # raises on unknown names; one
    # normal form per solver so equivalent spellings share a signature
    if solver_kind(solver) != term_kind:
        raise ValueError(
            f"solver {solver!r} is {solver_kind(solver)}-kind but this "
            f"engine's term needs a {term_kind} solver"
        )
    adaptive = parse_solver_spec(solver)[1].get("adaptive", False)
    if not adaptive:
        for name, val in (("rtol", rtol), ("atol", atol), ("save_at", save_at)):
            if val is not None:
                raise ValueError(
                    f"{name} only applies to adaptive solves; request an "
                    f"':adaptive' solver spec (got {solver!r})"
                )
    if adaptive and save_every is not None:
        raise ValueError(
            "save_every indexes a fixed grid; adaptive requests take "
            "save_at=<sequence of times> instead"
        )
    if save_at is not None:
        try:
            save_at = tuple(float(t) for t in save_at)
        except (TypeError, ValueError):
            # A 2-D array, complex dtype, strings, ... must die HERE with the
            # argument named, not as a dtype error inside jit at the queue
            # head.
            raise ValueError(
                "save_at must be a flat sequence of real (float-convertible) "
                f"times, got {save_at!r}"
            ) from None
        if not save_at:
            raise ValueError("save_at must be a non-empty sequence of times")
        if not all(float(t0) <= t <= float(t1) for t in save_at):
            raise ValueError(f"save_at times must lie in [{t0}, {t1}]")
    if save_every is not None:
        if int(save_every) != save_every or int(save_every) < 1:
            raise ValueError(f"save_every must be a positive int, got {save_every}")
        save_every = int(save_every)
        if n_steps % save_every != 0:
            raise ValueError(
                f"save_every={save_every} does not divide n_steps={n_steps}"
            )
    if int(priority) != priority:
        raise ValueError(f"priority must be an int, got {priority!r}")
    if deadline_ms is not None:
        deadline_ms = float(deadline_ms)
        if not deadline_ms > 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
    return SampleRequest(
        request_id=request_id, solver=solver, t0=float(t0), t1=float(t1),
        n_steps=n_steps, n_paths=int(n_paths), save_every=save_every,
        seed=request_id if seed is None else int(seed),
        rtol=None if rtol is None else float(rtol),
        atol=None if atol is None else float(atol),
        save_at=save_at,
        priority=int(priority),
        deadline_ms=deadline_ms,
    )


class Scheduler:
    """Priority-FIFO scheduler over :class:`PendingRequest` entries (host-side
    only).  ``max_requests`` / ``max_paths`` bound the live queue (admission
    control): an :meth:`enqueue` that would exceed either raises
    :class:`QueueFull` without enqueueing.

    ``group_key`` maps a request signature to its *planning group* — the
    unit :meth:`plan` fills a dispatch from.  The default (identity) keeps
    the classic one-signature-per-plan behaviour; the bucketing layer passes
    :func:`repro.serving.bucketing.group_key` so signatures sharing a padded
    bucket plan together (see :class:`SlotPlan` for the per-tick homogeneity
    contract)."""

    def __init__(self, max_requests: Optional[int] = None,
                 max_paths: Optional[int] = None, group_key=None, clock=None):
        self.queue: Deque[PendingRequest] = deque()
        self.done: Dict[int, SampleResult] = {}
        self.max_requests = max_requests
        self.max_paths = max_paths
        self.group_key = group_key if group_key is not None else (lambda sig: sig)
        # Deadline clock: monotonic seconds.  Injectable (fault-injection
        # tests pass a FakeClock) so deadline behaviour is deterministic.
        self.clock = clock if clock is not None else time.monotonic
        self._next_id = 0
        self._cancelled_ids: set = set()

    @property
    def next_request_id(self) -> int:
        """The id the next enqueued request will get.  Reading it does not
        allocate: build (and validate) the request against this id first, so
        a rejected submit burns no id and leaves default seeds (= request
        id) of later requests unshifted."""
        return self._next_id

    def new_request_id(self) -> int:
        rid = self._next_id
        self._next_id += 1
        return rid

    def enqueue(self, request: SampleRequest, *, force: bool = False) -> int:
        """Admit ``request`` into the queue (raising :class:`QueueFull` at
        capacity).  ``force=True`` bypasses admission control — reserved for
        the engine's internal retry resubmits, which replace capacity an
        earlier admit already granted and must never be refused (a refused
        retry would strand its waiter)."""
        live = [p for p in self.queue if not p.cancelled]
        if (not force and self.max_requests is not None
                and len(live) + 1 > self.max_requests):
            raise QueueFull(
                f"queue holds {len(live)} live request(s); admission limit is "
                f"max_requests={self.max_requests} — drain, cancel, or raise "
                "the limit (the async engine awaits space instead)"
            )
        if not force and self.max_paths is not None:
            owed = sum(p.remaining for p in live)
            if owed + request.n_paths > self.max_paths:
                raise QueueFull(
                    f"queue owes {owed} path(s) and this request adds "
                    f"{request.n_paths}; admission limit is max_paths="
                    f"{self.max_paths}"
                )
        self._next_id = max(self._next_id, request.request_id + 1)
        entry = PendingRequest(request)
        if request.deadline_ms is not None:
            entry.deadline = self.clock() + request.deadline_ms / 1e3
        self.queue.append(entry)
        return request.request_id

    # -- introspection / cancellation ---------------------------------------

    def pending(self, detail: bool = False) -> Dict[int, Any]:
        """Paths still owed per queued request id (FIFO order, cancelled
        entries excluded) — what a polling client checks between ``run``s.

        ``detail=True`` returns a dict per request instead of a bare count:
        ``remaining`` plus the bucketing introspection — ``bucket`` (the
        :class:`~repro.serving.bucketing.BucketKey` the request coalesced
        into once planned; None before planning or for exact dispatch),
        ``n_padded_steps`` (masked padding steps its bucket executable
        carries beyond the true ``n_steps``) and ``n_padded_paths`` (dead
        slots that rode along in its delivered ticks so far) — plus the
        robustness fields: ``n_diverged`` (delivered paths flagged by the
        blow-up guard so far) and ``deadline_remaining_s`` (seconds until
        this request's deadline expires; None without a deadline)."""
        if not detail:
            return {p.request.request_id: p.remaining
                    for p in self.queue if not p.cancelled}
        now = self.clock()
        return {p.request.request_id: {
                    "remaining": p.remaining,
                    "bucket": p.bucket,
                    "n_padded_steps": p.n_padded_steps,
                    "n_padded_paths": p.n_padded_paths,
                    "n_diverged": p.n_diverged(),
                    "deadline_remaining_s": (
                        None if p.deadline is None
                        else max(0.0, p.deadline - now)),
                }
                for p in self.queue if not p.cancelled}

    def cancel(self, request_id: int) -> bool:
        """Cancel a queued request; partial results are discarded.

        Returns True if this call cancelled it, False if it was already
        cancelled or already completed (``done`` keeps completed results —
        cancellation never un-delivers).  Unknown ids raise ``KeyError``.
        O(1) effect: the entry is only marked here and pruned by the next
        :meth:`plan`, so an idle engine never spins over cancelled husks.
        """
        if request_id in self.done:
            return False
        if request_id in self._cancelled_ids:
            return False  # repeat cancel, incl. after plan() pruned the entry
        for p in self.queue:
            if p.request.request_id == request_id:
                p.cancelled = True
                self._cancelled_ids.add(request_id)
                return True
        raise KeyError(f"unknown request id {request_id}")

    def expire_deadlines(self, now: Optional[float] = None) -> List[int]:
        """Retire every queued request whose deadline has passed.

        Each expired request is cancelled in place (same lazy mechanism as
        :meth:`cancel` — partial results drop, the planner prunes the husk)
        and a timeout :class:`SampleResult` (``timed_out=True``, no arrays)
        lands in ``done`` so pollers and waiters observe a terminal state
        instead of a vanished id.  Returns the expired ids, FIFO order.
        Engines call this once per dispatch cycle; ``now`` overrides the
        scheduler clock (tests)."""
        now = self.clock() if now is None else now
        expired: List[int] = []
        for p in self.queue:
            if p.cancelled or p.deadline is None or now < p.deadline:
                continue
            rid = p.request.request_id
            p.cancelled = True
            self._cancelled_ids.add(rid)
            self.done[rid] = SampleResult(y_final=None, ys=None,
                                          timed_out=True)
            expired.append(rid)
        return expired

    # -- planning -----------------------------------------------------------

    def _service_order(self) -> List[PendingRequest]:
        """Live queue entries in service order: a *stable* sort by descending
        priority, so equal priorities (incl. the default 0) keep strict FIFO
        and the all-default workload plans exactly as the plain FIFO did."""
        return sorted((p for p in self.queue if not p.cancelled),
                      key=lambda p: -p.request.priority)

    @staticmethod
    def _unplanned(p: PendingRequest) -> int:
        return p.request.n_paths - p.delivered - p.reserved

    def signatures(self) -> List[Tuple[Tuple, int]]:
        """Unique signatures with plannable (live, unreserved) work, in
        service order, each with the best priority among its requests."""
        out: List[Tuple[Tuple, int]] = []
        seen = set()
        for p in self._service_order():
            if self._unplanned(p) <= 0:
                continue
            sig = p.request.signature
            if sig not in seen:
                seen.add(sig)
                out.append((sig, p.request.priority))
        return out

    def groups(self) -> List[Tuple[Any, int]]:
        """Unique *planning groups* with plannable work, in service order,
        each with the best priority among its requests — what an
        interleaving serve loop round-robins over.  With the identity
        ``group_key`` this is exactly :meth:`signatures`; with bucketing the
        list is shorter (bucketed signatures merge)."""
        out: List[Tuple[Any, int]] = []
        seen = set()
        for p in self._service_order():
            if self._unplanned(p) <= 0:
                continue
            g = self.group_key(p.request.signature)
            if g not in seen:
                seen.add(g)
                out.append((g, p.request.priority))
        return out

    def plan(self, slots: int, max_ticks: int = 1, *,
             signature: Optional[Tuple] = None,
             group: Any = None,
             reserve: bool = False) -> Optional[SlotPlan]:
        """Build the next dispatch: up to ``max_ticks`` ticks of one
        planning group, or None when no plannable work is queued.

        Prunes cancelled entries first (their partial results are dropped),
        then fills tick after tick over the chosen group exactly as
        successive single-tick plans over that group would — multi-tick
        dispatch never changes *which* path runs in which slot.  It can
        change cross-group service order: the stack keeps draining one
        group, so an other-group request queued in between waits for the
        next dispatch (see the module docstring).

        Within a group, ticks fill **one true signature at a time** in
        service order of each signature's first plannable request, FIFO over
        requests within a signature, contiguous over each request's path
        indices; a tick never mixes signatures (the bucket executable takes
        one ``active_steps`` scalar per tick), so switching signature closes
        the current tick even if slots remain.  With the identity
        ``group_key`` a group holds exactly one signature and this reduces
        verbatim to the classic filling.

        ``group`` pins the planning group (an interleaving serve loop
        round-robins :meth:`groups`); ``signature`` pins the group *through*
        a signature (kept for single-signature callers — it resolves to
        ``group_key(signature)``).  By default the group of the first
        plannable request in service order — highest priority, then FIFO —
        is drained.

        ``reserve=True`` marks the planned paths in flight, so a later
        ``plan`` call (before this one is delivered) starts beyond them —
        the double-buffering hook.  Reserved plans must be **delivered in
        planning order** (path scatter is ordered per request); an
        undispatched reserved plan is returned via :meth:`release`, newest
        first.
        """
        if any(p.cancelled for p in self.queue):
            live = [p for p in self.queue if not p.cancelled]
            # prune in place: the queue object is a stable view (the engine
            # façade exposes it), so rebinding would strand held references
            self.queue.clear()
            self.queue.extend(live)
        if signature is not None and group is not None:
            raise ValueError("pass signature= or group=, not both")
        order = self._service_order()
        if signature is not None:
            group = self.group_key(signature)
        if group is None:
            for p in order:
                if self._unplanned(p) > 0:
                    group = self.group_key(p.request.signature)
                    break
        if group is None:
            return None
        # Members of the group, bucketed by true signature in service order
        # of first appearance (each tick must stay signature-homogeneous).
        by_sig: Dict[Tuple, List[PendingRequest]] = {}
        sig_order: List[Tuple] = []
        for p in order:
            sig = p.request.signature
            if self.group_key(sig) != group:
                continue
            if sig not in by_sig:
                by_sig[sig] = []
                sig_order.append(sig)
            by_sig[sig].append(p)
        taken: Dict[PendingRequest, int] = {}
        ticks: List[List[Tuple[PendingRequest, int]]] = []
        tick_sigs: List[Tuple] = []
        for sig in sig_order:
            while len(ticks) < max_ticks:
                tick: List[Tuple[PendingRequest, int]] = []
                budget = slots
                for p in by_sig[sig]:
                    if budget == 0:
                        break
                    start = p.delivered + p.reserved + taken.get(p, 0)
                    take = min(budget, p.request.n_paths - start)
                    tick.extend((p, start + j) for j in range(take))
                    if take:
                        taken[p] = taken.get(p, 0) + take
                        budget -= take
                if not tick:
                    break  # this signature exhausted; move to the next
                ticks.append(tick)
                tick_sigs.append(sig)
            if len(ticks) >= max_ticks:
                break
        if not ticks:
            return None
        if reserve:
            for p, n in taken.items():
                p.reserved += n
        # Introspection: record the bucket (duck-typed — only bucket groups
        # carry an n_padded rung) on every request the plan touches.
        n_padded = getattr(group, "n_padded", None)
        if n_padded is not None:
            for p in taken:
                p.bucket = group
                p.n_padded_steps = n_padded - p.request.n_steps
        return SlotPlan(signature=tick_sigs[0], slots=slots, ticks=ticks,
                        reserved=reserve, group=group,
                        tick_sigs=tuple(tick_sigs))

    def release(self, plan: SlotPlan) -> None:
        """Return an undispatched *reserved* plan's paths to the queue.

        Only valid LIFO — release the most recently planned outstanding
        reservation first — because planning cursors grow past every live
        reservation: releasing an older plan while a newer one still holds
        later paths would let the next plan re-issue the newer plan's work.
        The engine only ever stages (and therefore releases) the newest plan.
        """
        if not plan.reserved:
            raise ValueError("release() takes a plan built with reserve=True")
        counts: Dict[PendingRequest, int] = {}
        for tick in plan.ticks:
            for p, _ in tick:
                counts[p] = counts.get(p, 0) + 1
        for p, n in counts.items():
            p.reserved -= n  # cancelled husks unwind too; harmless

    # -- delivery -----------------------------------------------------------

    def deliver(self, plan: SlotPlan,
                outputs: Dict[str, Optional[np.ndarray]],
                *, stack=np.stack) -> List[int]:
        """Scatter dispatch outputs back to their requests and retire.

        ``outputs`` maps field name (``y_final`` / ``ys`` / the adaptive
        stats) to a stacked array with leading ``(n_ticks, slots)`` axes, or
        None for fields this signature does not produce.  Returns the ids
        retired into ``done``, in service order.  ``stack`` builds each
        retired result's per-request arrays — ``np.stack`` (default) lands
        results on the host; the async engine passes ``jnp.stack`` so
        results stay device-resident until the caller materialises them.
        """
        for t, tick in enumerate(plan.ticks):
            dead = plan.slots - len(tick)
            for p in dict.fromkeys(p for p, _ in tick):
                p.n_padded_paths += dead
            for s, (p, i) in enumerate(tick):
                if i != p.delivered:  # pragma: no cover — planner invariant
                    raise RuntimeError(
                        f"plan slot (tick {t}, slot {s}) delivers path {i} of "
                        f"request {p.request.request_id} but {p.delivered} "
                        "paths were delivered so far — out-of-order delivery"
                    )
                p.y_final.append(outputs["y_final"][t, s])
                if outputs.get("ys") is not None:
                    p.ys.append(outputs["ys"][t, s])
                for name in STAT_FIELDS:
                    if outputs.get(name) is not None:
                        getattr(p, name).append(outputs[name][t, s])
                p.delivered += 1
                if plan.reserved:
                    p.reserved -= 1
        retired = []
        for p in dict.fromkeys(p for tick in plan.ticks for p, _ in tick):
            if p.delivered == p.request.n_paths and not p.cancelled:
                self.queue.remove(p)
                rid = p.request.request_id
                self.done[rid] = SampleResult(
                    y_final=stack(p.y_final),
                    ys=stack(p.ys) if p.ys else None,
                    bucket=p.bucket,
                    n_padded_steps=p.n_padded_steps,
                    n_padded_paths=p.n_padded_paths,
                    **{name: (stack(getattr(p, name))
                              if getattr(p, name) else None)
                       for name in STAT_FIELDS},
                )
                retired.append(rid)
        return retired
