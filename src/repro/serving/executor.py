"""Device-side serving executor: one jit'd multi-tick dispatch per signature.

The executor is the device half of the SDE serving core (the host half is
:mod:`repro.serving.scheduler`).  It knows nothing about requests or queues:
its unit of work is a **tick stack** — a ``(n_ticks, slots)`` buffer of
per-path PRNG keys, all ticks sharing one request signature — which it runs
through :func:`repro.core.sdeint_ticks`: an on-device ``lax.map`` over the
tick axis inside ONE jit'd, input-donating dispatch.  A deep queue therefore
costs one host round trip per signature *stack* instead of one per tick;
``n_dispatches`` / ``n_ticks`` counters expose the ratio (the
``bench_serving`` metric).

Executables are cached per ``(signature, n_ticks)`` — the engine dispatches
only full ``ticks_per_dispatch`` stacks plus single ticks (shallow queue
tails are served tick-by-tick rather than as fresh depths), so a serving
loop that drains a deep queue touches at most two entries per signature
and never recompiles on a varying tail.  Each entry donates its key-stack argument on backends that
implement donation, so the per-dispatch key upload reuses the previous
buffer instead of allocating a fresh one.

When the executor is built with a ``mesh_axis``, every tick's ``slots`` axis
is sharded over that device-mesh axis through ``sdeint``'s existing
``shard_map`` fan-out — ``slots = devices x per_device_slots`` becomes the
serving unit — while the tick axis stays sequential (ticks are serving time,
not parallel work).  Path keys are placement-independent
(``fold_in(seed, i)``), so sharded, multi-tick, and single-tick dispatch all
produce bitwise-identical samples.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import parse_solver_spec, sdeint_ticks

from .bucketing import BucketKey

__all__ = ["TickExecutor", "enable_persistent_compile_cache"]


def enable_persistent_compile_cache(path: str) -> None:
    """Point jax's persistent compilation cache at ``path``.

    Compiled executables are written to (and reloaded from) the directory, so
    a fresh process warm-starts: the first dispatch of a known
    ``(bucket, depth)`` pays deserialization instead of XLA compilation.
    The size/time floors are dropped so even the small CPU-smoke executables
    persist — serving executables are few (that is the point of bucketing)
    and re-compiling any of them stalls a tick.
    """
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    # jax latches "no cache" at the first compile it ever runs (imports
    # compile little helpers long before an engine exists), and config
    # updates alone do not re-initialize it — reset so the new dir takes
    # effect for every compile from here on.
    from jax.experimental.compilation_cache import compilation_cache
    compilation_cache.reset_cache()


class TickExecutor:
    """Run same-signature tick stacks for one SDE term on one (set of)
    device(s).  ``term``/``y0``/``args`` define the process; ``mesh`` +
    ``mesh_axis`` optionally shard each tick's slot axis."""

    def __init__(self, term, y0, *, args: Any = None, noise_shape=None,
                 dtype: Any = jnp.float32, mesh=None,
                 mesh_axis: Optional[str] = None,
                 guard: Optional[float] = None):
        if (mesh is None) != (mesh_axis is None):
            # Both or neither: a long-lived executor must not resolve the
            # mesh from whatever `with mesh:` context is ambient at dispatch
            # time (and mesh-without-axis has no defined sharding).
            raise ValueError(
                "sharded dispatch needs mesh and mesh_axis together; got "
                f"mesh={'set' if mesh is not None else 'None'}, "
                f"mesh_axis={mesh_axis!r}"
            )
        self.term = term
        self.y0 = y0
        self.args = args
        self.noise_shape = noise_shape
        self.dtype = dtype
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        # In-loop blow-up guard threshold: every executable carries the
        # per-path divergence check (see repro.core.adjoint.solve) and its
        # results gain a (n_ticks, slots) bool ``diverged`` leaf.  The flag
        # stays on device until the scheduler retires the request — no per-
        # dispatch host sync.  None compiles guard-free executables.
        self.guard = guard
        self._compiled: Dict[Tuple, Any] = {}
        # Host-round-trip accounting: n_dispatches counts jit re-entries
        # (host -> device round trips), n_ticks the engine ticks they served.
        self.n_dispatches = 0
        self.n_ticks = 0

    def _stack_fn(self, key: Union[Tuple, BucketKey], n_ticks: int):
        """The cached jit'd dispatch for ``(key, n_ticks)``.

        ``key`` is either an exact request signature (the classic path) or a
        :class:`~repro.serving.bucketing.BucketKey`, whose executable
        integrates the padded grid and takes a per-tick ``active_steps``
        operand as its second argument.

        Steady-state serving re-enters the same executable every dispatch
        (no per-tick re-jit: the cache key is the signature-or-bucket plus
        the stack depth, and the scheduler canonicalises specs at submit so
        equivalent spellings share an entry).  The key-stack argument is
        donated where the backend implements donation, letting XLA reuse
        the previous dispatch's buffer for each upload.
        """
        cache_key = (key, n_ticks)
        if cache_key not in self._compiled:
            if isinstance(key, BucketKey):
                bk = key

                def stack(tick_keys, active_steps):
                    return sdeint_ticks(
                        self.term, bk.solver, bk.t0,
                        bk.t0 + bk.n_padded * bk.h, bk.n_padded, self.y0,
                        tick_keys, active_steps=active_steps,
                        step_size=bk.h, args=self.args,
                        noise_shape=self.noise_shape, dtype=self.dtype,
                        mesh=self.mesh, mesh_axis=self.mesh_axis,
                        guard=self.guard,
                    )
            else:
                solver, t0, t1, n_steps, save_every, rtol, atol, save_at = key
                extra = {}
                if rtol is not None:
                    extra["rtol"] = rtol
                if atol is not None:
                    extra["atol"] = atol
                if save_at is not None:
                    extra["save_at"] = jnp.asarray(save_at)

                if parse_solver_spec(solver)[1].get("adaptive", False):
                    # Serving is forward-only: the while-loop stepper stops
                    # when every path reaches t1 instead of padding to the
                    # n_steps budget (bitwise-identical results).
                    extra["bounded"] = False

                def stack(tick_keys):
                    return sdeint_ticks(
                        self.term, solver, t0, t1, n_steps, self.y0,
                        tick_keys, args=self.args, save_every=save_every,
                        noise_shape=self.noise_shape, dtype=self.dtype,
                        mesh=self.mesh, mesh_axis=self.mesh_axis,
                        guard=self.guard, **extra,
                    )

            # Donate the key stack so its device buffer is reused across
            # dispatches.  CPU does not implement donation (jax would warn
            # once per dispatch), so donate only where it takes effect.
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._compiled[cache_key] = jax.jit(stack, donate_argnums=donate)
        return self._compiled[cache_key]

    def has_compiled(self, key: Union[Tuple, BucketKey],
                     n_ticks: int) -> bool:
        """Whether a ``dispatch(key, <n_ticks-deep stack>)`` will re-enter a
        cached executable.  False means the call pays tracing + XLA compile —
        the async engine runs such first dispatches in a worker thread so
        the event loop (other submitters/awaiters) stays responsive."""
        return (key, n_ticks) in self._compiled

    def warmup(self, key: Union[Tuple, BucketKey], n_ticks: int,
               slots: int) -> bool:
        """Ahead-of-time compile the ``(key, n_ticks)`` executable.

        Uses jit's ``lower(...).compile()`` AOT path on shape/dtype structs,
        so no device work runs and no keys are materialised; the compiled
        object is stored back in the cache (its call syntax matches the jit
        wrapper's).  With a persistent compile cache enabled this both
        populates and reads the on-disk cache.  Returns True when this call
        actually lowered+compiled (False: the entry was already compiled).
        """
        fn = self._stack_fn(key, n_ticks)
        if not hasattr(fn, "lower"):  # already AOT-compiled earlier
            return False
        keys_t = jax.ShapeDtypeStruct((n_ticks, slots, 2), jnp.uint32)
        if isinstance(key, BucketKey):
            active_t = jax.ShapeDtypeStruct((n_ticks,), jnp.int32)
            compiled = fn.lower(keys_t, active_t).compile()
        else:
            compiled = fn.lower(keys_t).compile()
        self._compiled[(key, n_ticks)] = compiled
        return True

    def dispatch(self, key: Union[Tuple, BucketKey], tick_keys,
                 active_steps=None):
        """Run a ``(n_ticks, slots, ...)`` key stack; one host round trip.

        For a :class:`BucketKey`, ``active_steps`` (shape ``(n_ticks,)``
        int32 — each tick's true step count) is forwarded as the bucket
        executable's second operand; exact signatures take keys only.

        Returns the solve result pytree with leading ``(n_ticks, slots)``
        axes on every leaf; tick ``t`` is bitwise equal to a single-tick
        dispatch of ``tick_keys[t]`` (see :func:`repro.core.sdeint_ticks`).
        """
        n_ticks = tick_keys.shape[0]
        fn = self._stack_fn(key, n_ticks)
        if isinstance(key, BucketKey):
            if active_steps is None:
                raise ValueError("bucketed dispatch needs active_steps")
            out = fn(tick_keys, jnp.asarray(active_steps, jnp.int32))
        else:
            out = fn(tick_keys)
        self.n_dispatches += 1
        self.n_ticks += n_ticks
        return out
