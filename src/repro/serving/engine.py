"""Batched decode engine: fixed-slot continuous batching over `serve_step`.

Requests join free slots; every engine tick decodes one token for all live
slots in a single jit'd ``serve_step`` call (the decode cells of the dry-run
lower exactly this step).  Finished sequences (EOS or max length) free their
slot for the next queued request — continuous batching without re-compiling.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache, make_serve_step, ModelOptions

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8
    max_len: int = 256
    eos_id: int = 1
    greedy: bool = True
    temperature: float = 1.0


@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    pos: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig = ServeConfig(), opts=ModelOptions()):
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode")
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.cache = init_cache(cfg, serve_cfg.slots, serve_cfg.max_len)
        self._step = jax.jit(make_serve_step(cfg, opts))
        self.slots = [_Slot() for _ in range(serve_cfg.slots)]
        self.queue: deque = deque()
        self.done: Dict[int, List[int]] = {}
        self._next_id = 0
        self.key = jax.random.PRNGKey(0)

    def submit(self, prompt_tokens: List[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, list(prompt_tokens)))
        return rid

    def _admit(self):
        for slot in self.slots:
            if slot.request_id is None and self.queue:
                rid, prompt = self.queue.popleft()
                slot.request_id = rid
                slot.pos = 0
                slot.tokens = list(prompt)

    def tick(self):
        """Advance every live slot by one token (prefill token-by-token too;
        a production engine would chunk-prefill — same serve_step shape)."""
        self._admit()
        live = [s for s in self.slots if s.request_id is not None]
        if not live:
            return False
        # All slots share one position counter per tick in this simplified
        # engine: we advance the *maximum* needed slot; idle slots decode into
        # scratch position and are ignored.
        cur = np.zeros(self.sc.slots, np.int32)
        pos = 0
        for i, s in enumerate(self.slots):
            if s.request_id is not None:
                idx = min(s.pos, len(s.tokens) - 1)
                cur[i] = s.tokens[idx]
                pos = max(pos, s.pos)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(cur), jnp.int32(pos)
        )
        logits = np.asarray(logits)
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                continue
            if s.pos < len(s.tokens) - 1:
                s.pos += 1  # still prefilling
                continue
            if self.sc.greedy:
                nxt = int(np.argmax(logits[i]))
            else:
                self.key, sub = jax.random.split(self.key)
                nxt = int(
                    jax.random.categorical(sub, jnp.asarray(logits[i]) / self.sc.temperature)
                )
            s.tokens.append(nxt)
            s.pos += 1
            if nxt == self.sc.eos_id or len(s.tokens) >= self.sc.max_len:
                self.done[s.request_id] = s.tokens
                slot_reset = _Slot()
                self.slots[i] = slot_reset
        return True

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
        return self.done
