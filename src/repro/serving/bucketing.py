"""Signature coalescing for the serving plane: padded bucketed dispatch.

Mixed-signature traffic fragments the executor's compile cache: every
distinct ``(solver, horizon, step count)`` combination is its own request
signature, its own jit executable, and its own (often shallow) tick stacks —
exactly the failure mode continuous batching is supposed to avoid.  This
module maps signatures onto a small set of canonical **buckets** so requests
that differ only in *horizon length* (or path count — slot padding was
always free) share one compiled executable AND can stack into the same tick
dispatch.

A bucket is :class:`BucketKey` ``(solver, t0, h, n_padded)``:

* ``h`` is the request's exact step size ``(t1 - t0) / n_steps`` as a
  Python double.  It stays **static** — closed into the executable — because
  that is what bitwise identity requires: a traced (or gathered) step size
  changes XLA's FMA formation in the step body and drifts results by an ulp.
  Requests coalesce exactly when their ``h`` doubles are bit-equal, i.e.
  when they differ only in how *many* steps they take, which is the mixed
  traffic this layer targets (same process / step-size config, varying
  horizons).
* ``n_padded`` is ``n_steps`` rounded up a powers-of-two ladder
  (:func:`ladder_rung`).  The executable integrates ``n_padded`` steps over
  a :meth:`~repro.core.grid.TimeGrid.padded_uniform` grid; the one traced
  operand is each tick's true step count (``active_steps`` in
  :func:`~repro.core.sdeint.sdeint_ticks`), and padding steps are skipped by
  a batch-uniform ``lax.cond`` whose live branch compiles to exactly the
  unpadded solve — results are **bitwise-identical** to exact dispatch
  (regression-tested across the solver zoo).

Eligibility (:func:`bucket_eligible`): fixed-grid requests with no saved
trajectory and no adaptive options.  Adaptive solves walk data-dependent
grids (padding is meaningless), and ``save_every``/``save_at`` outputs have
signature-dependent shapes; those requests keep their exact per-signature
executables (``group_key`` wraps them as ``("exact", signature)`` groups),
so turning bucketing on never changes *what* any request receives — only
how many executables a mixed stream compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import parse_solver_spec

__all__ = [
    "BucketingConfig",
    "BucketKey",
    "ladder_rung",
    "bucket_eligible",
    "bucket_key",
    "group_key",
]


@dataclasses.dataclass(frozen=True)
class BucketingConfig:
    """How the serving plane coalesces signatures.

    ``enabled=False`` is the exact opt-out: every request keeps its own
    per-signature executable (the pre-PR-8 behaviour).  ``min_steps`` is the
    smallest ladder rung — requests shorter than it still pad up to it, so
    tiny-horizon probes don't each mint an executable.
    """

    enabled: bool = True
    min_steps: int = 8

    def __post_init__(self):
        if self.min_steps < 1:
            raise ValueError(f"min_steps must be >= 1, got {self.min_steps}")


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """One compiled bucket: every request in it shares this executable.

    ``h`` is the exact (bit-equal Python double) step size; ``n_padded`` the
    ladder rung the executable integrates.  Hashable — this is the
    executor's compile-cache key and the scheduler's planning group.
    """

    solver: str
    t0: float
    h: float
    n_padded: int


def ladder_rung(n_steps: int, min_steps: int = 8) -> int:
    """The smallest power-of-two multiple of 1 at or above ``n_steps``,
    floored at ``min_steps``: the padded grid length for ``n_steps``."""
    rung = max(1, int(min_steps))
    while rung < n_steps:
        rung *= 2
    return rung


def bucket_eligible(signature: Tuple) -> bool:
    """Whether a request signature can run on a padded bucket executable.

    Fixed-grid, final-state-only requests qualify; adaptive solves and
    saved-trajectory requests (``save_every``/``save_at``) dispatch exact.
    """
    solver, _t0, _t1, _n_steps, save_every, rtol, atol, save_at = signature
    if save_every is not None or save_at is not None:
        return False
    if rtol is not None or atol is not None:
        return False
    if parse_solver_spec(solver)[1].get("adaptive", False):
        return False
    return True


def bucket_key(signature: Tuple,
               cfg: BucketingConfig) -> Optional[BucketKey]:
    """The bucket a signature coalesces into, or None (ineligible/disabled)."""
    if not cfg.enabled or not bucket_eligible(signature):
        return None
    solver, t0, t1, n_steps = signature[:4]
    # Exact double arithmetic: two signatures share a bucket iff this
    # division lands on the same bits — the condition for the static-h
    # executable to reproduce both bitwise.
    h = (t1 - t0) / n_steps
    return BucketKey(solver=solver, t0=t0, h=h,
                     n_padded=ladder_rung(n_steps, cfg.min_steps))


def group_key(signature: Tuple, cfg: BucketingConfig):
    """The scheduler's planning-group key for a signature: its
    :class:`BucketKey` when bucketable, else the exact signature (tagged, so
    a bucket and a raw signature can never collide as dict keys)."""
    bk = bucket_key(signature, cfg)
    return bk if bk is not None else ("exact", signature)
