"""Deterministic fault injection for the SDE serving plane.

The robustness layer (blow-up guard, retry ladder, deadlines, supervised
serve loop — see ``docs/robustness.md``) is only trustworthy if it can be
*exercised*: this module wraps a :class:`~repro.serving.executor.TickExecutor`
in a :class:`FaultyExecutor` that injects three failure modes into dispatch,
all driven by one seeded ``random.Random`` stream so every run of a test (or
of ``benchmarks/bench_resilience.py``) sees the identical fault schedule:

* **NaN trajectories** — corrupt chosen (tick, slot) cells of a dispatch's
  outputs *after* the real integration ran, flipping the corresponding
  ``diverged`` flag the way a genuine blow-up would.  Targeted cells
  (``nan_slots``) make isolation tests exact; a rate (``nan_rate``) drives
  statistical sweeps.
* **Executor crashes** — raise :class:`InjectedCrash` (marked ``transient``)
  *instead of* dispatching, before any device work: exactly the failure the
  sync engine's reservation unwind and the async plane's supervised restart
  must survive without losing or duplicating queued paths.
* **Artificial delays** — ``time.sleep`` before dispatching, for deadline
  and straggler scenarios.

The injector composes with both engines through :func:`inject_faults`
(swaps the executor on an engine that already exists), or by constructing a
``FaultyExecutor`` around an executor directly.  Because corruption happens
to the *outputs* of the real executor, the underlying samples, executable
caches, and dispatch counters stay exactly those of the clean plane — an
injected run differs from a clean run only where the schedule says so.

:class:`FakeClock` is the matching deterministic time source for deadline
tests: pass it as the engine's ``clock`` and ``advance()`` it explicitly —
no sleeps, no flaky wall-clock margins.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["InjectedCrash", "FaultConfig", "FaultyExecutor", "FakeClock",
           "inject_faults"]


class InjectedCrash(RuntimeError):
    """A dispatch-time crash injected by :class:`FaultyExecutor`.

    ``transient = True`` is the marker the async engine's supervised serve
    loop keys restarts on — a real (non-transient) executor error still
    fails the engine loudly."""

    transient = True


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Schedule of injected faults; all draws come from ``Random(seed)``.

    ``nan_rate`` / ``crash_rate`` / ``delay_rate`` are per-dispatch
    probabilities (one draw each per dispatch call, in that order, so a
    given seed yields one reproducible fault schedule regardless of which
    rates are zero).  A NaN fault corrupts one uniformly-drawn (tick, slot)
    cell; ``nan_slots`` instead names explicit ``(dispatch_index, tick,
    slot)`` cells to corrupt — exact, schedule-independent targeting for
    isolation tests (rates still apply on top if nonzero).  Likewise
    ``crash_dispatches`` names explicit dispatch indices to crash — e.g.
    ``(0,)`` for exactly one crash followed by a clean recovery, which is
    what supervised-restart tests need (a crash *rate* would also crash the
    restarted loop's first dispatch).  ``delay_s`` is the sleep injected by
    a delay fault."""

    seed: int = 0
    nan_rate: float = 0.0
    crash_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 0.0
    nan_slots: Optional[Tuple[Tuple[int, int, int], ...]] = None
    crash_dispatches: Optional[Tuple[int, ...]] = None


class FaultyExecutor:
    """Wrap a ``TickExecutor`` (or compatible) with deterministic faults.

    Everything not overridden here — ``warmup``, ``has_compiled``, the
    compiled-executable cache, the dispatch counters — delegates to the
    wrapped executor, so an engine cannot tell the difference until a fault
    fires.  Injection counters (``n_crashes`` / ``n_nans`` / ``n_delays`` /
    ``n_dispatch_calls``) record what actually fired, for asserting a test
    exercised what it meant to."""

    def __init__(self, inner, cfg: FaultConfig = FaultConfig()):
        self.inner = inner
        self.fault_cfg = cfg
        self.rng = random.Random(cfg.seed)
        self.n_dispatch_calls = 0
        self.n_crashes = 0
        self.n_nans = 0
        self.n_delays = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _corrupt(self, result, cells):
        """NaN the given (tick, slot) cells of a dispatch result, flipping
        the matching ``diverged`` flags — indistinguishable downstream from
        a genuine blow-up (which is the point)."""

        def nan_cell(leaf):
            arr = jnp.asarray(leaf)
            if not jnp.issubdtype(arr.dtype, jnp.inexact):
                return leaf
            for t, s in cells:
                arr = arr.at[t, s].set(jnp.nan)
            return arr

        updates: dict = {"y_final": jax.tree_util.tree_map(
            nan_cell, result.y_final)}
        if getattr(result, "ys", None) is not None:
            updates["ys"] = jax.tree_util.tree_map(nan_cell, result.ys)
        div = getattr(result, "diverged", None)
        if div is not None:
            for t, s in cells:
                div = div.at[t, s].set(True)
            updates["diverged"] = div
        return result._replace(**updates)

    def dispatch(self, key, tick_keys, active_steps=None):
        cfg = self.fault_cfg
        idx = self.n_dispatch_calls
        self.n_dispatch_calls += 1
        # One draw per rate per dispatch, fixed order: the schedule for a
        # seed is independent of which faults are enabled.
        crash = self.rng.random() < cfg.crash_rate
        nan = self.rng.random() < cfg.nan_rate
        delay = self.rng.random() < cfg.delay_rate
        n_ticks, slots = tick_keys.shape[0], tick_keys.shape[1]
        rand_cell = (self.rng.randrange(n_ticks), self.rng.randrange(slots))
        if crash or (cfg.crash_dispatches and idx in cfg.crash_dispatches):
            self.n_crashes += 1
            raise InjectedCrash(f"injected crash at dispatch {idx}")
        if delay and cfg.delay_s > 0:
            self.n_delays += 1
            time.sleep(cfg.delay_s)
        result = self.inner.dispatch(key, tick_keys, active_steps)
        cells = []
        if cfg.nan_slots:
            cells += [(t, s) for d, t, s in cfg.nan_slots
                      if d == idx and t < n_ticks and s < slots]
        if nan:
            cells.append(rand_cell)
        if cells:
            self.n_nans += len(cells)
            result = self._corrupt(result, cells)
        return result


def inject_faults(engine, cfg: FaultConfig = FaultConfig()) -> FaultyExecutor:
    """Swap ``engine``'s executor for a :class:`FaultyExecutor` around it.

    Works on both :class:`~repro.serving.sde_engine.SDESampleEngine` and
    :class:`~repro.serving.async_engine.AsyncSDESampleEngine` (whose
    ``executor`` attribute is a view over the inner sync engine's).
    Returns the injector so the caller can read its fired-fault counters."""
    faulty = FaultyExecutor(engine.executor, cfg)
    inner = getattr(engine, "_eng", engine)  # async façade wraps a sync core
    inner.executor = faulty
    if engine is not inner:
        engine.executor = faulty
    return faulty


class FakeClock:
    """Deterministic, manually-advanced clock for deadline tests.

    Callable (so it drops in for ``time.monotonic`` as an engine/scheduler
    ``clock``); ``advance(dt)`` moves time forward explicitly."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)
