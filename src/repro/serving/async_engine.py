"""Async continuous-batching serving plane over the scheduler/executor core.

:class:`AsyncSDESampleEngine` is the event-loop counterpart of the
synchronous :class:`~repro.serving.sde_engine.SDESampleEngine` façade: the
same host-side :class:`~repro.serving.scheduler.Scheduler` and device-side
:class:`~repro.serving.executor.TickExecutor` underneath, but driven by a
single asyncio serve task that keeps the device busy on a *continuous*
mixed-signature request stream instead of drain-style ``run()`` calls.
What the async plane adds:

* **Awaitable API** — ``rid = await eng.submit(...)`` and
  ``res = await eng.result(rid)``.  ``submit`` applies admission control
  with *backpressure*: when the bounded queue (``max_queue_requests`` /
  ``max_queue_paths`` in :class:`~repro.serving.sde_engine.SDESampleConfig`)
  is full, the coroutine waits for space instead of raising the
  :class:`~repro.serving.scheduler.QueueFull` a sync ``submit`` sees.
* **Cross-signature interleaving** — instead of exhausting one signature
  group before touching the next, the serve loop round-robins compiled
  stacks across the signature groups of the best pending priority class
  (``Scheduler.signatures``), so a long homogeneous burst cannot starve a
  different-signature request of its first tick for the whole burst.
* **Host-side double buffering** — jax dispatch is asynchronous, so right
  after stack N is handed to the device the loop scatters N's results
  *lazily* (device-resident slices) and immediately plans + key-packs stack
  N+1 on the host while the device integrates.  At most two dispatches are
  in flight: before dispatching N+2 the loop awaits N's buffers off-thread
  (``asyncio.to_thread``), which also keeps the event loop responsive for
  submitters.
* **Device-resident results** — delivery slices and stacks dispatch outputs
  as jax arrays (``Scheduler.deliver(..., stack=jnp.stack)``); nothing is
  copied to host numpy unless the caller asks
  (``await eng.result(rid, numpy=True)``), so a large ``n_paths`` drain
  whose consumer feeds another device computation never round-trips
  through the host.
* **Robustness (PR 9, see ``docs/robustness.md``)** — retirement and
  *finalization* are split: a retired request's divergence flags are read
  (and its retry-vs-surface decision made) only when the serve loop next
  lands a stack, so the blow-up guard costs no extra host sync in the
  dispatch hot path.  Deadlines wake their ``result`` waiters with
  ``TimeoutError``; a *transient* executor crash (e.g. an injected fault —
  :mod:`repro.serving.faults`) restarts the serve loop under supervision,
  and because async plans are unreserved and delivery is atomic per
  subplan, the replan after a crash re-issues exactly the undelivered
  ticks: no request is lost, duplicated, or left hanging.

Determinism is inherited, not re-proved: samples are pure functions of
``(seed, path index)`` and every slot-plan invariant is shared with the
sync engine, so the async plane returns results **bitwise-identical** to
``SDESampleEngine.run()`` for the same request stream — across dispatch
depths, priorities, and interleavings (regression-tested in
``tests/test_serving.py``).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .scheduler import STAT_FIELDS, QueueFull, SampleResult
from .sde_engine import SDESampleConfig, SDESampleEngine

__all__ = ["AsyncSDESampleEngine"]


def _result_leaves(res: SampleResult) -> List:
    fields = [res.y_final, res.ys] + [getattr(res, n) for n in STAT_FIELDS]
    return [x for x in jax.tree_util.tree_leaves(fields)]


class AsyncSDESampleEngine:
    """Serve a continuous stream of Monte-Carlo sampling requests.

    Construction mirrors :class:`~repro.serving.sde_engine.SDESampleEngine`
    (``term``/``y0`` define the process; :class:`SDESampleConfig` sizes the
    plane — ``max_queue_paths`` is what turns ``submit`` backpressure on;
    ``clock`` overrides the deadline clock, for deterministic tests).
    Use as an async context manager, or call :meth:`close` explicitly::

        async with AsyncSDESampleEngine(term, y0, cfg) as eng:
            rid = await eng.submit("ees25", t1=1.0, n_steps=32, n_paths=4096)
            res = await eng.result(rid)        # device-resident jax arrays

    The serve task starts lazily with the first ``submit`` and idles (no
    polling, no device work) whenever the queue is empty.
    """

    def __init__(self, term, y0, cfg: SDESampleConfig = SDESampleConfig(),
                 args=None, noise_shape=None, clock=None):
        self._eng = SDESampleEngine(term, y0, cfg, args=args,
                                    noise_shape=noise_shape, clock=clock)
        self.cfg = self._eng.cfg
        self.scheduler = self._eng.scheduler
        self.executor = self._eng.executor
        self._task: Optional[asyncio.Task] = None
        self._work = asyncio.Event()    # set: queue may hold plannable work
        self._space = asyncio.Event()   # set: admission capacity may exist
        self._waiters: Dict[int, asyncio.Future] = {}
        self._last_group = None
        self._closed = False
        # Retired-but-not-finalized request ids (append order = retirement
        # order).  An instance attribute — NOT serve-loop local state — so a
        # supervised restart after an injected crash still finalizes (and,
        # if diverged, retries) everything the crashed loop had delivered.
        self._pending_fin: List[int] = []

    # -- client surface ------------------------------------------------------

    @property
    def done(self) -> Dict[int, SampleResult]:
        """Completed results (device-resident jax arrays) by request id."""
        return self.scheduler.done

    def pending(self, detail: bool = False):
        return self._eng.pending(detail=detail)

    def warmup(self, signatures) -> int:
        """Ahead-of-time compile executables for expected traffic — see
        :meth:`SDESampleEngine.warmup` (synchronous: call before serving, or
        wrap in ``asyncio.to_thread`` from a live loop)."""
        return self._eng.warmup(signatures)

    async def submit(self, solver: str, *, t1: float, n_steps: int,
                     n_paths: int, t0: float = 0.0,
                     save_every: Optional[int] = None,
                     seed: Optional[int] = None,
                     rtol: Optional[float] = None,
                     atol: Optional[float] = None, save_at=None,
                     priority: int = 0,
                     deadline_ms: Optional[float] = None) -> int:
        """Queue a sampling request; returns its request id.

        Same options and validation as the sync engine's ``submit`` (plus
        the same ``priority`` / ``deadline_ms`` semantics), but admission
        control applies *backpressure*: a full bounded queue makes this
        coroutine wait for space — it only raises for malformed requests,
        never :class:`QueueFull`.  A request whose ``deadline_ms`` elapses
        before delivery wakes its :meth:`result` waiter with
        ``TimeoutError`` and frees its admission capacity."""
        if self._closed:
            raise RuntimeError("engine is closed")
        self._ensure_serving()
        while True:
            try:
                # Validation errors (bad spec, n_paths=0, save_at dtype, ...)
                # propagate immediately — only QueueFull waits.
                rid = self._eng.submit(
                    solver, t1=t1, n_steps=n_steps, n_paths=n_paths, t0=t0,
                    save_every=save_every, seed=seed, rtol=rtol, atol=atol,
                    save_at=save_at, priority=priority,
                    deadline_ms=deadline_ms,
                )
                break
            except QueueFull:
                # Single-threaded event loop: capacity can only appear via
                # the serve task (retirement/expiry) or cancel(), all of
                # which set the event after this clear — no lost wakeup.
                self._space.clear()
                await self._space.wait()
        self._work.set()
        return rid

    def _unfinalized(self, request_id: int) -> bool:
        """Whether ``request_id`` (a root id) has a retirement still awaiting
        finalization — its own, or a retry child's.  A result in ``done``
        for such an id is provisional: finalization may pull it back onto
        the queue as a degraded retry."""
        return any(self._eng._retry_parent.get(c, c) == request_id
                   for c in self._pending_fin)

    async def result(self, request_id: int, *, numpy: bool = False
                     ) -> SampleResult:
        """Await a request's :class:`SampleResult`.

        Returns device-resident jax arrays once every path is integrated
        (the await covers device completion, not just retirement) **and**
        the engine finalized it — its divergence flags read, any retry
        ladder run to completion;
        ``numpy=True`` additionally materialises host copies off-thread.
        Raises ``asyncio.CancelledError`` if the request was (or gets)
        cancelled, ``TimeoutError`` if its ``deadline_ms`` expired before
        delivery, ``KeyError`` for ids this engine never issued."""
        res = self.done.get(request_id)
        if res is not None and self._unfinalized(request_id):
            res = None  # provisional: the engine may still retry it
        if res is None:
            if request_id in self.scheduler._cancelled_ids:
                raise asyncio.CancelledError(
                    f"request {request_id} was cancelled")
            queued = any(p.request.request_id == request_id
                         for p in self.scheduler.queue)
            # A root mid-retry is absent from the queue (its degraded child
            # rides there under a negative internal id) — it is known via
            # the engine's attempt ledger, or via a pending finalization.
            if not (queued or request_id in self._eng._retry_attempt
                    or self._unfinalized(request_id)):
                raise KeyError(f"unknown request id {request_id}")
            self._ensure_serving()
            fut = self._waiters.get(request_id)
            if fut is None:
                fut = asyncio.get_running_loop().create_future()
                self._waiters[request_id] = fut
            res = await asyncio.shield(fut)
        if res.timed_out:
            raise TimeoutError(
                f"request {request_id} deadline expired before delivery")
        # Block on the device buffers off-thread so concurrent submitters
        # and the serve loop keep running while XLA finishes.
        await asyncio.to_thread(jax.block_until_ready, _result_leaves(res))
        if numpy:
            res = await asyncio.to_thread(self._to_numpy, res)
        return res

    def cancel(self, request_id: int) -> bool:
        """Cancel a queued request (see the sync engine's ``cancel``); any
        coroutine awaiting its result receives ``CancelledError``, and one
        blocked ``submit`` may be admitted into the freed capacity."""
        cancelled = self._eng.cancel(request_id)
        if cancelled:
            fut = self._waiters.pop(request_id, None)
            if fut is not None and not fut.done():
                fut.cancel(f"request {request_id} was cancelled")
            self._space.set()
        return cancelled

    async def drain(self) -> Dict[Any, Any]:
        """Await every currently queued request; returns a snapshot of
        ``done`` plus one extra ``"counters"`` entry — the engine-lifetime
        robustness counters (retries / timeouts / diverged / restarts), so
        load tests and operators see retries without parsing logs.
        Requests that get cancelled or time out mid-drain are skipped (both
        are terminal; a timeout's state is in ``done`` / the counters)."""
        roots = {self._eng._retry_parent.get(r, r) for r in self.pending()}
        for rid in sorted(roots):
            try:
                await self.result(rid)
            except (asyncio.CancelledError, TimeoutError):
                pass  # terminal either way; nothing owed
        out: Dict[Any, Any] = dict(self.done)
        out["counters"] = dict(self._eng.counters)
        return out

    async def close(self) -> None:
        """Stop the serve task.  Queued-but-unserved requests are abandoned:
        their ``result`` awaiters receive ``CancelledError`` (``drain``
        first for a graceful shutdown); completed results stay in ``done``."""
        self._closed = True
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            except Exception:
                # The serve loop already crashed: its exception was handed
                # to every waiter when it died — close() tearing down the
                # engine must not raise it a second time.
                pass
            self._task = None
        for fut in self._waiters.values():
            if not fut.done():
                fut.cancel("engine closed")
        self._waiters.clear()

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close()

    # -- serve loop ----------------------------------------------------------

    def _ensure_serving(self) -> None:
        if self._task is not None and self._task.done():
            # Surface a crashed serve loop to the caller instead of hanging.
            exc = self._task.exception()
            self._task = None
            if exc is not None:
                raise exc
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(
                self._serve(), name="sde-serve-loop")

    def _next_plan(self):
        """Round-robin compiled stacks across the planning groups of the
        best pending priority class — the continuous-batching interleave
        (a strict head-of-queue drain would starve other groups for a whole
        burst).  Groups are buckets where coalescing applies, so signatures
        sharing a bucket drain as one stream through one executable."""
        groups = self.scheduler.groups()
        if not groups:
            return None
        best = max(prio for _, prio in groups)
        top = [g for g, prio in groups if prio == best]
        if self._last_group in top and len(top) > 1:
            g = top[(top.index(self._last_group) + 1) % len(top)]
        else:
            g = top[0]
        self._last_group = g
        return self.scheduler.plan(self.cfg.slots,
                                   self.cfg.ticks_per_dispatch,
                                   group=g)

    def _deliver_device(self, plan, result) -> List[int]:
        """Scatter a dispatch lazily: slot slices and per-request stacks are
        jax operations on device buffers, so delivery never blocks on (or
        copies to) the host.  Retirement frees admission capacity right
        away; *finalization* — reading the diverged flags, deciding
        retry-vs-surface, waking waiters — is deferred to the serve loop's
        next buffer landing (:meth:`_finalize`), so the guard never forces
        a host sync against a stack still in flight."""
        outputs = {"y_final": result.y_final, "ys": result.ys}
        for name in STAT_FIELDS:
            outputs[name] = getattr(result, name, None)
        retired = self.scheduler.deliver(plan, outputs, stack=jnp.stack)
        for rid in retired:
            self._eng._key_cache.pop(rid, None)
        self._pending_fin.extend(retired)
        if retired:
            self._space.set()
        return retired

    async def _finalize(self, n: int) -> None:
        """Terminal bookkeeping for the first ``n`` retirements awaiting
        finalization: read their diverged flags (awaited off-thread — they
        may still be in flight after a crash-restart), let the engine book
        divergence and run the retry ladder, and wake ``result`` waiters
        with the terminal result.

        The device sync happens while the ids are STILL in
        ``_pending_fin``: the await yields the event loop, and a concurrent
        ``result()`` must keep seeing them as provisional (``_unfinalized``)
        or it would surface a diverged result the ladder is about to pull
        back as a retry.  Only the serve task appends to ``_pending_fin``
        and it is parked here, so the prefix is stable across the await;
        everything after the sync is await-free, so removal, retry
        enqueueing, and waiter wakeup are atomic w.r.t. the loop."""
        if not n:
            return
        rids = self._pending_fin[:n]
        flags = [self.done[r].diverged for r in rids
                 if self.done.get(r) is not None
                 and self.done[r].diverged is not None]
        if flags:
            await asyncio.to_thread(jax.block_until_ready, flags)
        del self._pending_fin[:n]
        retried = False
        for rid in rids:
            root = self._eng._finalize_retired(rid)
            if root is None:
                retried = True  # back on the queue, degraded
                continue
            fut = self._waiters.pop(root, None)
            if fut is not None and not fut.done():
                fut.set_result(self.done[root])
        if retried:
            self._work.set()

    def _expire_wake(self) -> None:
        """Retire deadline-expired requests and wake their waiters with
        ``TimeoutError``; expiry frees admission capacity, so one blocked
        ``submit`` may be admitted."""
        expired = self._eng._expire()
        for root in expired:
            fut = self._waiters.pop(root, None)
            if fut is not None and not fut.done():
                fut.set_exception(TimeoutError(
                    f"request {root} deadline expired before delivery"))
        if expired:
            self._space.set()

    async def _serve(self) -> None:
        while True:
            try:
                await self._serve_loop()
                return
            except asyncio.CancelledError:
                raise
            except Exception as exc:
                transient = getattr(exc, "transient", False)
                if (transient and self._eng.counters["restarts"]
                        < self.cfg.max_restarts):
                    # Supervised restart: async plans are unreserved and
                    # delivery is atomic per subplan, so replanning after
                    # the crash re-issues exactly the undelivered ticks —
                    # no request lost or duplicated (property-tested in
                    # tests/test_faults.py); _pending_fin survives, so
                    # already-delivered work still finalizes.
                    self._eng.counters["restarts"] += 1
                    continue
                # fail awaiters loudly, never hang them
                for fut in self._waiters.values():
                    if not fut.done():
                        fut.set_exception(exc)
                self._waiters.clear()
                raise

    async def _serve_loop(self) -> None:
        inflight: Optional[List] = None  # previous dispatch's device buffers
        while True:
            self._expire_wake()
            plan = self._next_plan()
            if plan is None:
                if inflight is not None or self._pending_fin:
                    if inflight is not None:
                        await asyncio.to_thread(jax.block_until_ready,
                                                inflight)
                        inflight = None
                    await self._finalize(len(self._pending_fin))
                    # a submit may have landed during the awaits, and a
                    # finalized retry is plannable work — loop, don't sleep.
                    continue
                self._work.clear()
                if self.scheduler.signatures():
                    continue  # raced with clear(): serve it, don't sleep
                await self._work.wait()
                continue
            keys = self._eng._plan_keys(plan)
            offset = 0
            subplans = self._eng._split_subplans(plan)
            for sp in subplans:
                sp_keys = keys if len(subplans) == 1 else \
                    keys[offset:offset + sp.n_ticks]
                offset += sp.n_ticks
                ek = self._eng._exec_key(sp)
                active = self._eng._active_steps(sp)
                if self.executor.has_compiled(ek, sp.n_ticks):
                    out = self.executor.dispatch(ek, sp_keys, active)
                else:
                    # First dispatch of a (bucket-or-signature, depth) pays
                    # XLA compile; run it off-thread so submit()/result()
                    # stay live meanwhile.
                    out = await asyncio.to_thread(
                        self.executor.dispatch, ek, sp_keys, active)
                # Only retirements from dispatches BEFORE this one become
                # finalizable once the previous stack lands; this dispatch's
                # own retirees wait for the next landing (their diverged
                # flags are still integrating on the device).
                n_ready = len(self._pending_fin)
                self._deliver_device(sp, out)
                if inflight is not None:
                    # Double-buffer depth 2: the *previous* stack must land
                    # before a third enters flight.  Until it does, the plan
                    # and key-pack work above already overlapped the device.
                    await asyncio.to_thread(jax.block_until_ready, inflight)
                await self._finalize(n_ready)
                # The diverged leaf rides in the landing set, so when the
                # next landing finalizes this dispatch's retirees their
                # flags are already past the device sync.
                inflight = jax.tree_util.tree_leaves(
                    (out.y_final, out.ys, getattr(out, "diverged", None)))
            # Let submitters/cancellers interleave between stacks even when
            # everything above completed synchronously.
            await asyncio.sleep(0)

    @staticmethod
    def _to_numpy(res: SampleResult) -> SampleResult:
        conv = lambda x: None if x is None else np.asarray(x)  # noqa: E731
        return dataclasses.replace(
            res, y_final=conv(res.y_final), ys=conv(res.ys),
            **{n: conv(getattr(res, n)) for n in STAT_FIELDS},
        )
