"""Neural SDE model zoo, losses and synthetic data (the paper's experiments)."""
from .losses import moment_mse, signature_mmd, wrapped_energy_score
from .models import (
    init_kuramoto_nsde,
    init_lsde,
    init_sphere_nsde,
    kuramoto_nsde_term,
    lsde_readout,
    lsde_term,
    sphere_nsde_term,
)
from .nets import init_linear, init_mlp, linear_apply, lipswish, mlp_apply

__all__ = [
    "moment_mse", "signature_mmd", "wrapped_energy_score",
    "init_lsde", "lsde_term", "lsde_readout",
    "init_kuramoto_nsde", "kuramoto_nsde_term",
    "init_sphere_nsde", "sphere_nsde_term",
    "init_mlp", "mlp_apply", "init_linear", "linear_apply", "lipswish",
]
