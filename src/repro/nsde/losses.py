"""Distribution-matching losses for NSDE training.

* marginal moment MSE — match per-time mean/std of generated vs target
  trajectories (the OU / GBM experiments).
* wrapped energy score — strictly proper multivariate score with angular
  wrapping on the torus components (the Kuramoto experiment; Gneiting &
  Raftery 2007, eq. as in paper Section 4).
* truncated signature MMD — distance between expected truncated signatures
  (level <= 3) of time-augmented paths (the stochastic-volatility
  experiments; the linear-kernel specialisation of the signature-kernel MMD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moment_mse", "wrapped_energy_score", "signature_mmd"]


def moment_mse(gen, target):
    """gen, target: (batch, time[, dim]) — match mean and std trajectories."""
    gm, gs = jnp.mean(gen, axis=0), jnp.std(gen, axis=0)
    tm, ts = jnp.mean(target, axis=0), jnp.std(target, axis=0)
    return jnp.mean((gm - tm) ** 2) + jnp.mean((gs - ts) ** 2)


def _wrap(x):
    return x - 2 * jnp.pi * jnp.round(x / (2 * jnp.pi))


def wrapped_energy_score(samples_th, samples_om, target_th, target_om):
    """Energy score ES = E d(X, y) - 1/2 E d(X, X') with the wrapped-on-theta
    distance d = sum|wrap(dth)| + sum|dom|.  samples: (m, N); target: (N,)."""

    def dist(th_a, om_a, th_b, om_b):
        return jnp.sum(jnp.abs(_wrap(th_a - th_b)), -1) + jnp.sum(jnp.abs(om_a - om_b), -1)

    m = samples_th.shape[0]
    term1 = jnp.mean(dist(samples_th, samples_om, target_th[None], target_om[None]))
    d2 = dist(
        samples_th[:, None], samples_om[:, None], samples_th[None], samples_om[None]
    )
    term2 = jnp.sum(d2) / (2 * m * (m - 1) + 1e-9)
    return term1 - term2


def _signature_l3(path):
    """Truncated signature (levels 1..3) of the piecewise-linear path (T, d).

    Level-k terms are iterated integrals; for a piecewise-linear path they
    reduce to iterated sums with the in-segment Chen corrections (1/2 at
    level 2; 1/2, 1/2, 1/6 at level 3).
    """
    dx = jnp.diff(path, axis=0)  # (T-1, d)
    s1 = jnp.sum(dx, axis=0)
    pre = jnp.cumsum(dx, axis=0) - dx  # increment strictly before each segment
    seg2 = jnp.einsum("ti,tj->tij", pre, dx) + 0.5 * jnp.einsum("ti,tj->tij", dx, dx)
    s2 = jnp.sum(seg2, axis=0)
    pre2 = jnp.cumsum(seg2, axis=0) - seg2  # level-2 signature before segment
    s3 = (
        jnp.einsum("tij,tk->ijk", pre2, dx)
        + 0.5 * jnp.einsum("ti,tj,tk->ijk", pre, dx, dx)
        + (1.0 / 6.0) * jnp.einsum("ti,tj,tk->ijk", dx, dx, dx)
    )
    return jnp.concatenate([s1.ravel(), s2.ravel(), s3.ravel()])


def signature_mmd(gen_paths, target_paths, times=None):
    """|| E sig(gen) - E sig(target) ||^2 over time-augmented paths.

    gen/target: (batch, T) or (batch, T, d).
    """
    if gen_paths.ndim == 2:
        gen_paths = gen_paths[..., None]
        target_paths = target_paths[..., None]
    T = gen_paths.shape[1]
    if times is None:
        times = jnp.linspace(0.0, 1.0, T)
    taug = lambda p: jnp.concatenate(
        [jnp.broadcast_to(times[:, None], (T, 1)), p], axis=-1
    )
    sig = jax.vmap(lambda p: _signature_l3(taug(p)))
    return jnp.sum((jnp.mean(sig(gen_paths), 0) - jnp.mean(sig(target_paths), 0)) ** 2)
