"""Fractional Brownian motion generation (for the rough-driver experiments).

Davies–Harte circulant embedding: exact fBm increments in O(n log n).
Falls back to Cholesky if the circulant eigenvalues go negative (only for
pathological (H, n) combinations).
"""
from __future__ import annotations

import numpy as np

__all__ = ["fbm_increments", "fbm_paths"]


def _autocov(k: np.ndarray, H: float) -> np.ndarray:
    """Autocovariance of unit-variance fGn: gamma(k)."""
    return 0.5 * (
        np.abs(k - 1) ** (2 * H) - 2 * np.abs(k) ** (2 * H) + np.abs(k + 1) ** (2 * H)
    )


def fbm_increments(rng: np.random.Generator, n: int, H: float, T: float = 1.0,
                   batch: int = 1) -> np.ndarray:
    """(batch, n) increments of fBm with Hurst H over [0, T] (exact in law)."""
    if abs(H - 0.5) < 1e-12:
        return rng.standard_normal((batch, n)) * (T / n) ** 0.5
    gamma = _autocov(np.arange(n, dtype=np.float64), H)
    row = np.concatenate([gamma, [0.0], gamma[-1:0:-1]])  # circulant first row, 2n
    eig = np.fft.fft(row).real
    if np.min(eig) < -1e-8:
        # Cholesky fallback (O(n^2) memory/time)
        cov = _autocov(np.subtract.outer(np.arange(n), np.arange(n)), H)
        L = np.linalg.cholesky(cov + 1e-12 * np.eye(n))
        z = rng.standard_normal((batch, n))
        out = z @ L.T
    else:
        eig = np.maximum(eig, 0.0)
        m = 2 * n
        z = rng.standard_normal((batch, m)) + 1j * rng.standard_normal((batch, m))
        w = np.fft.fft(z * np.sqrt(eig / (2 * m)), axis=1)
        out = w[:, :n].real * np.sqrt(2.0)
    return out * (T / n) ** H


def fbm_paths(rng, n: int, H: float, T: float = 1.0, batch: int = 1,
              dim: int = 1) -> np.ndarray:
    """(batch, n+1, dim) sample paths, starting at 0."""
    incs = np.stack(
        [fbm_increments(rng, n, H, T, batch) for _ in range(dim)], axis=-1
    )
    paths = np.concatenate(
        [np.zeros((batch, 1, dim)), np.cumsum(incs, axis=1)], axis=1
    )
    return paths
