"""Synthetic ground-truth dynamics from the paper's experiments.

* OU high-volatility (Section 4): nu=0.2, mu=0.1, sigma=2.
* Stiff GBM (Appendix H.1): A = Q diag(-20(1+i/d)) Q^T, sigma=0.1, d=25.
* Second-order stochastic Kuramoto on T*T^N (Section 4, eq. (5)).
* Rough Bergomi-style rough volatility driver (Appendix H.2, simplified to
  the lognormal rough-vol price process driven by fBm).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .fbm import fbm_increments

__all__ = ["ou_paths", "stiff_gbm_matrix", "gbm_paths", "kuramoto_paths", "rough_vol_paths"]


def ou_paths(rng, batch: int, n_steps: int, T: float = 10.0,
             nu: float = 0.2, mu: float = 0.1, sigma: float = 2.0):
    """(batch, n+1) exact OU sample paths (exact transition sampling)."""
    h = T / n_steps
    x = np.zeros((batch, n_steps + 1))
    x[:, 0] = rng.standard_normal(batch) * 0.1
    a = np.exp(-nu * h)
    sd = sigma * np.sqrt((1 - a * a) / (2 * nu))
    for n in range(n_steps):
        x[:, n + 1] = mu + (x[:, n] - mu) * a + sd * rng.standard_normal(batch)
    return x


def stiff_gbm_matrix(rng, d: int = 25) -> np.ndarray:
    lam = -20.0 * (1.0 + np.arange(d) / d)
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    return (Q * lam) @ Q.T


def gbm_paths(rng, A: np.ndarray, batch: int, n_steps: int, T: float = 1.0,
              sigma: float = 0.1):
    """dy = A y dt + sigma y dW (Stratonovich ~ Ito for this test scale),
    simulated with a fine-grid exponential-Euler reference."""
    d = A.shape[0]
    h = T / n_steps
    y = np.ones((batch, n_steps + 1, d))
    eAh = _expm(A * h)
    for n in range(n_steps):
        dW = rng.standard_normal((batch, 1)) * np.sqrt(h)
        y[:, n + 1] = (y[:, n] @ eAh.T) * np.exp(sigma * dW - 0.5 * sigma**2 * h)
    return y


def _expm(M):
    vals, vecs = np.linalg.eig(M)
    return (vecs @ np.diag(np.exp(vals)) @ np.linalg.inv(vecs)).real


def kuramoto_paths(rng, N: int, batch: int, n_steps: int, T: float = 5.0,
                   m: float = 1.0, K: float = 2.0, P: float = 0.5, D: float = 0.05,
                   subsample: int = 1):
    """Second-order stochastic Kuramoto (eq. (5)); returns (theta, omega)
    with shapes (batch, n//sub + 1, N).  Heun integration on a fine grid."""
    h = T / n_steps
    omega_nat = np.where(np.arange(N) % 2 == 0, P, -P)
    th = rng.uniform(-np.pi, np.pi, size=(batch, N))
    om = np.zeros((batch, N))
    ths = [th.copy()]
    oms = [om.copy()]

    def drift(th, om):
        sin_diff = np.sin(th[:, None, :] - th[:, :, None])
        coupling = K * sin_diff.mean(axis=2)
        return om, (-om + omega_nat + coupling) / m

    for n in range(n_steps):
        noise = np.sqrt(2 * D * h) * rng.standard_normal((batch, N)) / m
        d1_th, d1_om = drift(th, om)
        th_p = th + h * d1_th
        om_p = om + h * d1_om + noise
        d2_th, d2_om = drift(th_p, om_p)
        th = th + 0.5 * h * (d1_th + d2_th)
        om = om + 0.5 * h * (d1_om + d2_om) + noise
        th = np.mod(th + np.pi, 2 * np.pi) - np.pi
        if (n + 1) % subsample == 0:
            ths.append(th.copy())
            oms.append(om.copy())
    return np.stack(ths, axis=1), np.stack(oms, axis=1)


def rough_vol_paths(rng, batch: int, n_steps: int, T: float = 1.0,
                    H: float = 0.25, eta: float = 1.991, v0: float = 0.04,
                    s0: float = 1.0, rho: float = -0.848):
    """Rough-Bergomi-style price paths: v_t = v0 exp(eta W^H_t - eta^2 t^{2H}/2),
    dS/S = sqrt(v) dB with corr(B, driver of W^H) = rho."""
    h = T / n_steps
    t = np.arange(1, n_steps + 1) * h
    wh = np.cumsum(fbm_increments(rng, n_steps, H, T, batch), axis=1)
    v = v0 * np.exp(eta * wh - 0.5 * eta**2 * t ** (2 * H))
    z = rng.standard_normal((batch, n_steps))
    # cheap correlation proxy against the fGn increments
    g = np.diff(np.concatenate([np.zeros((batch, 1)), wh], axis=1), axis=1)
    g = g / (g.std() + 1e-12)
    dB = (rho * g + np.sqrt(1 - rho**2) * z) * np.sqrt(h)
    logS = np.cumsum(np.sqrt(v) * dB - 0.5 * v * h, axis=1)
    S = s0 * np.exp(np.concatenate([np.zeros((batch, 1)), logS], axis=1))
    return S, np.concatenate([np.full((batch, 1), v0), v], axis=1)
