"""Neural SDE model zoo (the paper's experiments).

* :class:`NeuralLSDE` — Neural Langevin SDE (Oh et al.):
  dz = g(z) dt + f(t) o dW, z0 = affine(x); readout to data space.
* :func:`kuramoto_nsde_term` — NSDE on T*T^N with MLP drift/diffusion over the
  periodic encoding (sin th, cos th, om), outputs in the Lie algebra R^{2N}.
* :func:`sphere_nsde_term` — latent SDE on S^{n-1} = SO(n)/SO(n-1) with an
  MLP so(n)-valued drift and basis diffusion (Zeng et al. setup, synthetic).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import ManifoldSDETerm, Product, SDETerm, SphereAction, Torus
from repro.core.lie import Euclidean

from .nets import init_linear, init_mlp, linear_apply, mlp_apply

__all__ = [
    "init_lsde",
    "lsde_term",
    "lsde_readout",
    "init_kuramoto_nsde",
    "kuramoto_nsde_term",
    "init_sphere_nsde",
    "sphere_nsde_term",
]


# ---------------------------------------------------------------------------
# Neural Langevin SDE (Euclidean; OU / GBM / vol experiments).
# ---------------------------------------------------------------------------

def init_lsde(key, d_obs: int, d_z: int = 32, width: int = 32):
    ks = jax.random.split(key, 4)
    return {
        "encoder": init_linear(ks[0], d_obs, d_z),
        "drift": init_mlp(ks[1], [d_z, width, width, d_z]),
        "diff": init_mlp(ks[2], [1, width, d_z]),  # f(t): additive noise
        "readout": init_linear(ks[3], d_z, d_obs),
    }


def lsde_term() -> SDETerm:
    def drift(t, z, p):
        return mlp_apply(p["drift"], z)

    def diffusion(t, z, p):
        tvec = jnp.broadcast_to(jnp.asarray(t)[None], z.shape[:-1] + (1,))
        return jax.nn.softplus(mlp_apply(p["diff"], tvec)) * 0.5 + 0.05

    return SDETerm(drift=drift, diffusion=diffusion, noise="diagonal")


def lsde_readout(p, z):
    return linear_apply(p["readout"], z)


# ---------------------------------------------------------------------------
# Kuramoto NSDE on T*T^N (Section 4).
# ---------------------------------------------------------------------------

def init_kuramoto_nsde(key, N: int, width: int = 128):
    ks = jax.random.split(key, 2)
    return {
        "drift": init_mlp(ks[0], [3 * N, width, width, 2 * N]),
        "diff": init_mlp(ks[1], [3 * N, width, N]),
    }


def kuramoto_nsde_term() -> ManifoldSDETerm:
    group = Product([Torus(), Euclidean()])

    def features(y):
        th, om = y
        return jnp.concatenate([jnp.sin(th), jnp.cos(th), om], axis=-1)

    def drift(t, y, p):
        out = mlp_apply(p["drift"], features(y))
        N = out.shape[-1] // 2
        return (out[..., :N], out[..., N:])

    def diffusion(t, y, p):
        th, om = y
        sig = 0.1 * jax.nn.softplus(mlp_apply(p["diff"], features(y)))
        return (jnp.zeros_like(th), sig)  # additive noise on omega only

    return ManifoldSDETerm(group=group, drift=drift, diffusion=diffusion, noise="diagonal")


# ---------------------------------------------------------------------------
# Latent SDE on the sphere S^{n-1} (Section 4, Zeng et al. setup).
# ---------------------------------------------------------------------------

def _skew_basis_map(n: int):
    iu = jnp.triu_indices(n, 1)

    def to_skew(v):
        S = jnp.zeros(v.shape[:-1] + (n, n), v.dtype)
        S = S.at[..., iu[0], iu[1]].set(v)
        return S - jnp.swapaxes(S, -1, -2)

    return to_skew, n * (n - 1) // 2


def init_sphere_nsde(key, n: int, width: int = 64, d_ctx: int = 0):
    _, m = _skew_basis_map(n)
    ks = jax.random.split(key, 2)
    return {
        "drift": init_mlp(ks[0], [n + 1 + d_ctx, width, width, m]),
        "log_sigma": jnp.zeros(()),
    }


def sphere_nsde_term(n: int, ctx=None) -> ManifoldSDETerm:
    group = SphereAction(n)
    to_skew, m = _skew_basis_map(n)

    def drift(t, y, p):
        tvec = jnp.broadcast_to(jnp.asarray(t)[None], y.shape[:-1] + (1,))
        feats = jnp.concatenate(
            [y, tvec] + ([ctx] if ctx is not None else []), axis=-1
        )
        return to_skew(0.5 * jnp.tanh(mlp_apply(p["drift"], feats)))

    def diffusion(t, y, p):
        return jnp.exp(p["log_sigma"]) * 0.1

    return ManifoldSDETerm(
        group=group,
        drift=drift,
        diffusion=diffusion,
        noise="general",
        noise_apply=lambda sig, dw: to_skew(sig * dw),
    )
