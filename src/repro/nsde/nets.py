"""Small neural nets for NSDE drift/diffusion fields (pure pytrees).

LipSwish activation (x * sigmoid(x) * 0.909) keeps the vector fields
Lipschitz — standard for neural SDEs (Kidger et al.).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["lipswish", "init_mlp", "mlp_apply", "init_linear", "linear_apply"]


def lipswish(x):
    return 0.909 * jax.nn.silu(x)


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32):
    k1, _ = jax.random.split(key)
    return {
        "w": (jax.random.normal(k1, (d_in, d_out)) / math.sqrt(d_in)).astype(dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def linear_apply(p, x):
    return x @ p["w"] + p["b"]


def init_mlp(key, sizes: Sequence[int], dtype=jnp.float32):
    keys = jax.random.split(key, len(sizes) - 1)
    return [init_linear(k, a, b, dtype) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(layers, x, final_activation=None):
    for i, p in enumerate(layers):
        x = linear_apply(p, x)
        if i < len(layers) - 1:
            x = lipswish(x)
    if final_activation is not None:
        x = final_activation(x)
    return x
