"""Adaptive grid *realization*: PI-controlled accept/reject over any driver.

The embedded estimator is Appendix D of the paper: the 2N recurrences admit a
three-register variant with a first-order companion — store the final internal
stage and advance it over the remaining fraction of the step with one Euler
update re-using the already-computed stage evaluation (no extra vector-field
evaluations).  Each solver exposes it as ``step_with_error`` (see
:class:`~repro.core.solvers.LowStorageSolver` /
:class:`~repro.core.solvers.ButcherSolver`).

Since PR 3 the adaptive path is **realize-then-solve**:

* :func:`realize_grid` (phase 1) drives the estimator with a PI step-size
  controller (Gustafsson) in a forward-only ``while_loop`` with gradients
  stopped, and emits the accepted-step grid as a
  :class:`~repro.core.grid.TimeGrid` (padded to the static trial budget with
  zero-length steps).  Rejected steps re-query the driver over a *smaller*
  interval, which is exactly what the
  :class:`~repro.core.brownian.VirtualBrownianTree` makes consistent: every
  query resolves against one fixed underlying path, so accept/reject
  decisions never perturb the Brownian motion being integrated.
* :func:`repro.core.adjoint.solve` (phase 2) then integrates over the
  realized grid — with **any** solver and **any** adjoint, including the
  O(1)-memory reversible adjoint: nothing about reversibility requires
  uniform steps, only that the backward pass replays the same realized step
  sequence, and rejection already happened in phase 1, so the two-register
  reverse sweep needs no third (3S*) register.

:func:`integrate_adaptive` composes the two phases (or runs a single
forward-only pass for sampling — ``bounded=False`` — which is bitwise
identical to realize-then-solve).  Dense output: ``save_at=ts`` records the
solution on an arbitrary time grid, linearly interpolated between accepted
steps (first-order dense output — matched to the schemes' strong order for
Brownian driving).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .grid import TimeGrid, fill_saves
from .pytree import resolve_solver, tree_select, tree_sub
from .williamson import LowStorage

__all__ = ["step_with_error", "realize_grid", "RealizedGrid",
           "integrate_adaptive", "AdaptiveResult"]

_ERR_FLOOR = 1e-10


def step_with_error(ls: LowStorage, term, y, t, h, dW, args):
    """One 2N step from raw coefficients, returning (y_next, embedded error).

    Convenience wrapper over :meth:`LowStorageSolver.step_with_error`, for
    callers holding a bare :class:`~repro.core.williamson.LowStorage`
    (analysis scripts, tests).
    """
    from .solvers import LowStorageSolver

    return LowStorageSolver(ls).step_with_error(term, y, t, h, dW, args)


class AdaptiveResult(NamedTuple):
    """Adaptive solve output.  ``y_final``/``ys`` mirror
    :class:`~repro.core.adjoint.SolveResult`; the rest are controller stats."""

    y_final: Any
    ys: Any                  # (len(save_at), ...) pytree, or None
    t_final: jnp.ndarray     # where integration actually stopped (== t1 normally)
    h_final: jnp.ndarray     # last proposed step size
    n_accepted: jnp.ndarray
    n_rejected: jnp.ndarray
    # Scalar bool blow-up flag (see SolveResult.diverged); None with guard off.
    diverged: Any = None


class RealizedGrid(NamedTuple):
    """Phase-1 output: the accepted-step grid plus controller statistics.

    ``grid.ts`` holds ``n_accepted + 1`` accepted times followed by
    ``t_final`` padding; ``grid.hs`` the matching step sizes (0 on padding).
    ``y_final`` is the realization's own terminal state — gradient-stopped
    (the grid is data), so use it for sampling/diagnostics and run
    :func:`~repro.core.adjoint.solve` over ``grid`` when you need gradients.
    """

    grid: TimeGrid
    y_final: Any
    t_final: jnp.ndarray
    h_final: jnp.ndarray
    n_accepted: jnp.ndarray
    n_rejected: jnp.ndarray


def _controller_loop(solver, term, y0, driver, args, *, t0, t1, rtol, atol,
                     h0, safety, icoeff, pcoeff, max_steps, save_at,
                     record_grid):
    """The one accept/reject loop: a ``while_loop`` over trial steps.

    ``record_grid=True`` additionally writes accepted ``(t, h)`` pairs into
    fixed ``max_steps``-sized buffers (grid realization); ``save_at`` fills a
    dense-output buffer at accept time (single-pass sampling).  Both modes
    walk the identical trial sequence, so their solutions agree bitwise.
    """
    span = t1 - t0
    has_noise = getattr(term, "noise", "diagonal") != "none"
    needs_levy = getattr(solver, "needs_levy_area", False)
    tdt = jnp.result_type(float)
    eps_end = 1e-9 * span
    h_floor = 1e-7 * span
    k_exp = 2.0  # embedded pair is (order, 1): exponent 1/(q+1) with q = 1

    if save_at is not None:
        save_ts = jnp.asarray(save_at, tdt)
        if save_ts.ndim != 1:
            raise ValueError(f"save_at must be 1-D, got shape {save_ts.shape}")

    def err_norm(err, y_old, y_new):
        parts = []
        for e, a, b in zip(jax.tree_util.tree_leaves(err),
                           jax.tree_util.tree_leaves(y_old),
                           jax.tree_util.tree_leaves(y_new)):
            sc = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
            parts.append(((e / sc) ** 2).ravel())
        ms = jnp.mean(jnp.concatenate(parts))
        # Clamp inside the sqrt: trial steps for vmap lanes that already
        # reached t1 run with h_eff == 0 and err == 0, and d(sqrt)/dx at 0 is
        # inf — which would leak NaN through the masking select (0 * inf).
        return jnp.sqrt(jnp.maximum(ms, _ERR_FLOOR * _ERR_FLOOR))

    def trial(carry):
        y, t, h, w, en_prev, na, nr, ys_out, ts_buf, hs_buf = carry
        h_eff = jnp.minimum(h, t1 - t)
        if has_noise:
            w_prop = driver.weval(t + h_eff)
            dW = tree_sub(w_prop, w)
            if needs_levy:
                # Levy-area solvers consume the (dW, dH) pair; rejected trials
                # re-query over a smaller interval, and the salted Levy family
                # keeps each query a pure function of its endpoints.
                dW = (dW, driver.levy_area(t, t + h_eff))
        else:
            w_prop, dW = w, None
        y_new, err = solver.step_with_error(term, y, t, h_eff, dW, args)
        # Detach the controller: the step-size sequence is treated as data,
        # so gradients are those of the discrete scheme on the realized grid.
        en = jax.lax.stop_gradient(err_norm(err, y, y_new))
        accept = en <= 1.0
        grow = safety * en ** (-(icoeff + pcoeff) / k_exp) \
            * jnp.maximum(en_prev, _ERR_FLOOR) ** (pcoeff / k_exp)
        shrink = safety * en ** (-1.0 / k_exp)
        factor = jnp.where(accept, jnp.clip(grow, 0.2, 2.0),
                           jnp.clip(shrink, 0.1, 1.0))
        h_next = jnp.maximum(h_eff * factor, h_floor)
        if save_at is not None:
            ys_out = fill_saves(ys_out, save_ts, accept, t, t + h_eff,
                                y, y_new, t1, eps_end, h_floor)
        if record_grid:
            ts_buf = ts_buf.at[na + 1].set(
                jnp.where(accept, t + h_eff, ts_buf[na + 1]))
            hs_buf = hs_buf.at[na].set(jnp.where(accept, h_eff, hs_buf[na]))
        y = tree_select(accept, y_new, y)
        w = tree_select(accept, w_prop, w)
        t = jnp.where(accept, t + h_eff, t)
        en_prev = jnp.where(accept, en, en_prev)
        return (y, t, h_next, w, en_prev,
                na + accept.astype(jnp.int32), nr + (~accept).astype(jnp.int32),
                ys_out, ts_buf, hs_buf)

    w0 = driver.weval(t0) if has_noise else 0.0  # exact zeros for a VBT
    ys0 = None
    if save_at is not None:
        ys0 = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (save_ts.shape[0],) + jnp.shape(l)), y0
        )
    ts0 = jnp.full((max_steps + 1,), t0, tdt) if record_grid else None
    hs0 = jnp.zeros((max_steps,), tdt) if record_grid else None
    init = (y0, jnp.asarray(t0, tdt), jnp.asarray(h0, tdt), w0,
            jnp.asarray(1.0, tdt), jnp.int32(0), jnp.int32(0), ys0, ts0, hs0)

    def cond(carry):
        return ((t1 - carry[1]) > eps_end) & (carry[5] + carry[6] < max_steps)

    return jax.lax.while_loop(cond, trial, init)


def _window(driver, t0, t1):
    if t0 is None:
        t0 = driver.t0 if driver is not None else 0.0
    if t1 is None:
        t1 = driver.t1 if driver is not None else 1.0
    t0, t1 = float(t0), float(t1)
    if not t1 > t0:
        raise ValueError(f"need t1 > t0, got t0={t0}, t1={t1}")
    return t0, t1


def _check_driver(term, driver):
    if getattr(term, "noise", "diagonal") != "none" and driver is None:
        raise ValueError(
            "term has noise but no driver was given; pass a "
            "VirtualBrownianTree (or set term.noise='none' for ODE mode)"
        )


def realize_grid(
    solver,
    term,
    y0,
    driver=None,
    args: Any = None,
    *,
    t0: Optional[float] = None,
    t1: Optional[float] = None,
    rtol: float = 1e-4,
    atol: float = 1e-6,
    h0: Optional[float] = None,
    safety: float = 0.9,
    icoeff: float = 0.7,
    pcoeff: float = 0.4,
    max_steps: int = 1024,
) -> RealizedGrid:
    """Phase 1: run the accept/reject controller once and emit the grid.

    Gradients are stopped at entry — the realized step sequence is *data*
    (differentiating through the controller compounds pow-rule factors and
    the Brownian tree's rough time-interpolation into astronomically
    ill-conditioned cotangents), so the ``while_loop`` realization is safe
    inside ``jax.grad``: phase 2
    (:func:`~repro.core.adjoint.solve` over ``result.grid``) carries all the
    gradients.

    Parameters mirror the classic controller: a step is accepted when the
    RMS of ``err / (atol + rtol * max(|y|, |y_new|))`` is <= 1; on acceptance
    the next step is scaled by the Gustafsson PI factor
    ``safety * err^-(icoeff+pcoeff)/2 * err_prev^(pcoeff/2)`` (clipped to
    [0.2, 2]); a rejection retries with the pure-I shrink factor.
    ``max_steps`` bounds *trial* steps (accepted + rejected) and is the
    static length of the emitted grid — unused tail entries are zero-length
    padding that every solve masks out.  If the budget is exhausted the grid
    stops short of ``t1`` (check ``result.t_final``).

    ``solver`` must expose ``step_with_error`` (EES 2N schemes, multi-stage
    Butcher RK).  Solvers without it — ``reversible_heun``, ``mcf-*`` — can
    still *solve over* the realized grid in phase 2.

    Example
    -------
    >>> rg = realize_grid("ees25", term, y0, vbt, args, rtol=1e-3)
    >>> out = solve(get_solver("reversible_heun"), term, y0, rg.grid, args,
    ...             adjoint="reversible")
    """
    solver = resolve_solver(solver, require_error_estimate=True)
    t0, t1 = _window(driver, t0, t1)
    _check_driver(term, driver)
    if h0 is None:
        h0 = (t1 - t0) / 16.0
    y0, args = jax.lax.stop_gradient((y0, args))
    final = _controller_loop(
        solver, term, y0, driver, args, t0=t0, t1=t1, rtol=rtol, atol=atol,
        h0=h0, safety=safety, icoeff=icoeff, pcoeff=pcoeff,
        max_steps=int(max_steps), save_at=None, record_grid=True,
    )
    y, t, h, _, _, na, nr, _, ts_buf, hs_buf = final
    # Entries past the last accept still hold their initial t0: pad with the
    # final time so padded steps are zero-length at the grid's end.
    idx = jnp.arange(ts_buf.shape[0])
    ts = jnp.where(idx <= na, ts_buf, t)
    grid = TimeGrid(ts, hs_buf, driver, t0, t1)
    return RealizedGrid(grid=grid, y_final=y, t_final=t, h_final=h,
                        n_accepted=na, n_rejected=nr)


def integrate_adaptive(
    solver,
    term,
    y0,
    driver=None,
    args: Any = None,
    *,
    t0: Optional[float] = None,
    t1: Optional[float] = None,
    rtol: float = 1e-4,
    atol: float = 1e-6,
    h0: Optional[float] = None,
    safety: float = 0.9,
    icoeff: float = 0.7,
    pcoeff: float = 0.4,
    max_steps: int = 1024,
    save_at=None,
    bounded: bool = True,
    adjoint: str = "full",
    remat_chunk: Optional[int] = None,
    bulk_increments: bool = True,
    guard: Optional[float] = None,
) -> AdaptiveResult:
    """PI-controlled adaptive integration of ``term`` over ``[t0, t1]``.

    Realize-then-solve: :func:`realize_grid` emits the accepted-step grid,
    then :func:`~repro.core.adjoint.solve` integrates over it under
    ``adjoint`` — ``"full"`` | ``"recursive"`` | ``"reversible"`` (the
    O(1)-memory reversible adjoint now runs on adaptive grids).

    Parameters
    ----------
    solver:
        Registry spec string, solver object with ``step_with_error``, or a
        raw :class:`~repro.core.williamson.LowStorage` coefficient set.
    driver:
        A :class:`~repro.core.brownian.BrownianDriver` queryable at arbitrary
        times — in practice a
        :class:`~repro.core.brownian.VirtualBrownianTree`.  ``None`` runs in
        ODE mode (``term.noise`` must be ``"none"``).
    t0, t1:
        Integration window; default to the driver's span.
    rtol, atol, h0, safety, icoeff, pcoeff, max_steps:
        Controller knobs — see :func:`realize_grid`.
    save_at:
        Optional array of output times in ``[t0, t1]``; the solution is
        linearly interpolated between accepted steps onto this grid
        (``AdaptiveResult.ys`` gains a leading ``len(save_at)`` axis; entries
        at or before ``t0`` hold ``y0``).
    bounded:
        ``True`` (default): realize-then-solve — reverse-mode differentiable
        under every adjoint.  ``False``: one forward-only controller pass
        (no second sweep — the fastest way to *sample*; the serving engine
        uses it), not reverse-differentiable.  Results are bitwise identical
        between the two modes.
    adjoint:
        Phase-2 adjoint (``bounded=True``): ``"full"`` (O(n) activations),
        ``"recursive"`` (remat at ``remat_chunk`` granularity), or
        ``"reversible"`` (O(1) memory — backward reconstruction along the
        realized grid).  Gradients are those of the discrete scheme on the
        realized grid (the controller is detached).
    bulk_increments:
        Phase-2 noise realization (``bounded=True``): ``True`` (default)
        generates every accepted step's increment in one batched
        level-sweep over the tree and streams the buffer through the solve
        (see :func:`~repro.core.adjoint.solve`); ``False`` re-queries the
        tree per step.  Bit-identical increments either way.
    guard:
        Blow-up guard threshold (see :func:`~repro.core.adjoint.solve`).
        ``bounded=True`` threads it through the phase-2 solve;
        ``bounded=False`` checks the controller's terminal state (the
        accept/reject loop already rejects its way around transient spikes,
        so the terminal check is the meaningful one).  ``None`` disables
        (``AdaptiveResult.diverged`` is ``None``).

    Example
    -------
    >>> vbt = virtual_brownian_tree(key, 0.0, 1.0, shape=(3,))
    >>> out = integrate_adaptive("ees25", term, y0, vbt, args, rtol=1e-3,
    ...                          adjoint="reversible")
    >>> out.y_final, int(out.n_accepted), int(out.n_rejected)
    """
    solver = resolve_solver(solver, require_error_estimate=True)
    if adjoint not in ("full", "recursive", "reversible"):
        raise ValueError(f"unknown adjoint {adjoint!r}")
    if not bounded and adjoint != "full":
        raise ValueError(
            f"bounded=False is the single forward-only controller pass and "
            f"cannot host the {adjoint!r} adjoint; use bounded=True "
            "(realize-then-solve) for gradients"
        )
    t0, t1 = _window(driver, t0, t1)
    _check_driver(term, driver)
    if h0 is None:
        h0 = (t1 - t0) / 16.0

    if not bounded:
        # Single pass: the controller loop IS the solve (gradients not
        # stopped, so an accidental jax.grad fails loudly at the while_loop
        # instead of silently returning zeros).
        final = _controller_loop(
            solver, term, y0, driver, args, t0=t0, t1=t1, rtol=rtol,
            atol=atol, h0=h0, safety=safety, icoeff=icoeff, pcoeff=pcoeff,
            max_steps=int(max_steps), save_at=save_at, record_grid=False,
        )
        y, t, h, _, _, na, nr, ys_out, _, _ = final
        div = None
        if guard is not None:
            from .pytree import tree_blowup

            div = tree_blowup(y, guard)
        return AdaptiveResult(y_final=y, ys=ys_out, t_final=t, h_final=h,
                              n_accepted=na, n_rejected=nr, diverged=div)

    from .adjoint import solve

    rg = realize_grid(
        solver, term, y0, driver, args, t0=t0, t1=t1, rtol=rtol, atol=atol,
        h0=h0, safety=safety, icoeff=icoeff, pcoeff=pcoeff,
        max_steps=int(max_steps),
    )
    out = solve(solver, term, y0, rg.grid, args, adjoint=adjoint,
                save_at=save_at, remat_chunk=remat_chunk,
                bulk_increments=bulk_increments, guard=guard)
    return AdaptiveResult(y_final=out.y_final, ys=out.ys, t_final=rg.t_final,
                          h_final=rg.h_final, n_accepted=rg.n_accepted,
                          n_rejected=rg.n_rejected, diverged=out.diverged)
