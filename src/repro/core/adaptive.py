"""Adaptive (accept/reject) SDE stepping over arbitrary-time Brownian drivers.

The embedded estimator is Appendix D of the paper: the 2N recurrences admit a
three-register variant with a first-order companion — store the final internal
stage and advance it over the remaining fraction of the step with one Euler
update re-using the already-computed stage evaluation (no extra vector-field
evaluations).  Each solver exposes it as ``step_with_error`` (see
:class:`~repro.core.solvers.LowStorageSolver` /
:class:`~repro.core.solvers.ButcherSolver`).

:func:`integrate_adaptive` drives that estimator with a PI step-size
controller (Gustafsson) over any driver implementing the
:class:`~repro.core.brownian.BrownianDriver` protocol.  Rejected steps
re-query the driver over a *smaller* interval, which is exactly what the
:class:`~repro.core.brownian.VirtualBrownianTree` makes consistent: every
query resolves against one fixed underlying path, so accept/reject decisions
never perturb the Brownian motion being integrated.

Dense output: ``save_at=ts`` records the solution on an arbitrary time grid,
linearly interpolated between accepted steps (first-order dense output —
matched to the schemes' strong order for Brownian driving).

As the paper's Limitations section notes, step rejection requires restoring
the previous state (a 3S* register), which is incompatible with the
two-register reversible implementation — so the reversible adjoint stays
fixed-grid; :func:`repro.core.sdeint.sdeint` raises on the combination.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .solvers import tree_sub
from .williamson import LowStorage

__all__ = ["step_with_error", "integrate_adaptive", "integrate_fixed",
           "AdaptiveResult"]

_ERR_FLOOR = 1e-10


def step_with_error(ls: LowStorage, term, y, t, h, dW, args):
    """One 2N step from raw coefficients, returning (y_next, embedded error).

    Convenience wrapper over :meth:`LowStorageSolver.step_with_error`, for
    callers holding a bare :class:`~repro.core.williamson.LowStorage`
    (analysis scripts, tests).
    """
    from .solvers import LowStorageSolver

    return LowStorageSolver(ls).step_with_error(term, y, t, h, dW, args)


class AdaptiveResult(NamedTuple):
    """Adaptive solve output.  ``y_final``/``ys`` mirror
    :class:`~repro.core.adjoint.SolveResult`; the rest are controller stats."""

    y_final: Any
    ys: Any                  # (len(save_at), ...) pytree, or None
    t_final: jnp.ndarray     # where integration actually stopped (== t1 normally)
    h_final: jnp.ndarray     # last proposed step size
    n_accepted: jnp.ndarray
    n_rejected: jnp.ndarray


def _resolve_solver(solver):
    if isinstance(solver, str):
        from .registry import get_solver

        solver = get_solver(solver)
    if isinstance(solver, LowStorage):
        from .solvers import LowStorageSolver

        solver = LowStorageSolver(solver)
    if not hasattr(solver, "step_with_error"):
        raise ValueError(
            f"solver {getattr(solver, 'name', solver)!r} has no embedded "
            "error estimate (step_with_error); adaptive stepping supports "
            "the EES 2N schemes and multi-stage Butcher-form RK — use a "
            "fixed grid for reversible_heun / mcf-* solvers"
        )
    return solver


def _tree_select(pred, a, b):
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def integrate_adaptive(
    solver,
    term,
    y0,
    driver=None,
    args: Any = None,
    *,
    t0: Optional[float] = None,
    t1: Optional[float] = None,
    rtol: float = 1e-4,
    atol: float = 1e-6,
    h0: Optional[float] = None,
    safety: float = 0.9,
    icoeff: float = 0.7,
    pcoeff: float = 0.4,
    max_steps: int = 1024,
    save_at=None,
    bounded: bool = True,
    checkpoint_steps: bool = False,
) -> AdaptiveResult:
    """PI-controlled adaptive integration of ``term`` over ``[t0, t1]``.

    Parameters
    ----------
    solver:
        Registry spec string, solver object with ``step_with_error``, or a
        raw :class:`~repro.core.williamson.LowStorage` coefficient set.
    driver:
        A :class:`~repro.core.brownian.BrownianDriver` queryable at arbitrary
        times — in practice a
        :class:`~repro.core.brownian.VirtualBrownianTree`.  ``None`` runs in
        ODE mode (``term.noise`` must be ``"none"``).
    t0, t1:
        Integration window; default to the driver's span.
    rtol, atol:
        The accept threshold: a step is accepted when the RMS of
        ``err / (atol + rtol * max(|y|, |y_new|))`` is <= 1.
    h0:
        Initial step size (default ``(t1 - t0) / 16``).
    safety, icoeff, pcoeff:
        Gustafsson PI controller: on acceptance the next step is scaled by
        ``safety * err^-(icoeff+pcoeff)/2 * err_prev^(pcoeff/2)`` (clipped to
        [0.2, 2]); a rejected step retries with the pure-I shrink factor.
        ``pcoeff=0`` recovers the classical I controller.
    max_steps:
        Trial-step budget (accepted + rejected).  With ``bounded=True`` this
        is also the *compiled* loop length.
    save_at:
        Optional array of output times in ``[t0, t1]``; the solution is
        linearly interpolated between accepted steps onto this grid
        (``AdaptiveResult.ys`` gains a leading ``len(save_at)`` axis; entries
        at or before ``t0`` hold ``y0``).
    bounded:
        ``True`` (default) runs a fixed-length masked ``lax.scan`` — fully
        reverse-mode differentiable, so the full/recursive adjoints work.
        ``False`` uses ``lax.while_loop`` — faster forward-only integration
        (stops at ``t1`` instead of padding to ``max_steps``) but not
        reverse-differentiable; use it for sampling and benchmarks.
    checkpoint_steps:
        Rematerialise each trial step on the backward pass
        (``jax.checkpoint``) — the recursive adjoint of the adaptive path.
        Requires ``bounded=True``.

    Example
    -------
    >>> vbt = virtual_brownian_tree(key, 0.0, 1.0, shape=(3,))
    >>> out = integrate_adaptive("ees25", term, y0, vbt, args, rtol=1e-3)
    >>> out.y_final, int(out.n_accepted), int(out.n_rejected)
    """
    solver = _resolve_solver(solver)
    if t0 is None:
        t0 = driver.t0 if driver is not None else 0.0
    if t1 is None:
        t1 = driver.t1 if driver is not None else 1.0
    t0, t1 = float(t0), float(t1)
    if not t1 > t0:
        raise ValueError(f"need t1 > t0, got t0={t0}, t1={t1}")
    span = t1 - t0
    if h0 is None:
        h0 = span / 16.0
    has_noise = getattr(term, "noise", "diagonal") != "none"
    if has_noise and driver is None:
        raise ValueError(
            "term has noise but no driver was given; pass a "
            "VirtualBrownianTree (or set term.noise='none' for ODE mode)"
        )
    if checkpoint_steps and not bounded:
        raise ValueError("checkpoint_steps requires bounded=True")

    tdt = jnp.result_type(float)
    eps_end = 1e-9 * span
    h_floor = 1e-7 * span
    k_exp = 2.0  # embedded pair is (order, 1): exponent 1/(q+1) with q = 1

    if save_at is not None:
        save_ts = jnp.asarray(save_at, tdt)
        if save_ts.ndim != 1:
            raise ValueError(f"save_at must be 1-D, got shape {save_ts.shape}")

    def err_norm(err, y_old, y_new):
        parts = []
        for e, a, b in zip(jax.tree_util.tree_leaves(err),
                           jax.tree_util.tree_leaves(y_old),
                           jax.tree_util.tree_leaves(y_new)):
            sc = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
            parts.append(((e / sc) ** 2).ravel())
        ms = jnp.mean(jnp.concatenate(parts))
        # Clamp inside the sqrt: the masked no-op trials after t reaches t1
        # run with h_eff == 0 and err == 0, and d(sqrt)/dx at 0 is inf —
        # which would leak NaN through the lax.scan select despite the
        # branch being discarded (0 * inf).
        return jnp.sqrt(jnp.maximum(ms, _ERR_FLOOR * _ERR_FLOOR))

    def fill_saves(ys_out, accept, t_old, t_new, y_old, y_new):
        frac = (save_ts - t_old) / jnp.maximum(t_new - t_old, h_floor)
        mask = (save_ts > t_old) & (save_ts <= t_new + eps_end) & accept

        def leaf(out, a, b):
            f = jnp.clip(frac, 0.0, 1.0).reshape((-1,) + (1,) * a.ndim)
            m = mask.reshape((-1,) + (1,) * a.ndim)
            return jnp.where(m, a + f.astype(a.dtype) * (b - a), out)

        return jax.tree_util.tree_map(leaf, ys_out, y_old, y_new)

    def trial(carry):
        y, t, h, w, en_prev, na, nr, ys_out = carry
        h_eff = jnp.minimum(h, t1 - t)
        if has_noise:
            w_prop = driver.weval(t + h_eff)
            dW = tree_sub(w_prop, w)
        else:
            w_prop, dW = w, None
        y_new, err = solver.step_with_error(term, y, t, h_eff, dW, args)
        # Detach the controller: the step-size sequence is treated as data,
        # so gradients are those of the discrete scheme on the realized grid.
        # Differentiating *through* the controller compounds pow-rule factors
        # (and the Brownian tree's rough time-interpolation) across steps
        # into astronomically ill-conditioned cotangents.
        en = jax.lax.stop_gradient(err_norm(err, y, y_new))
        accept = en <= 1.0
        grow = safety * en ** (-(icoeff + pcoeff) / k_exp) \
            * jnp.maximum(en_prev, _ERR_FLOOR) ** (pcoeff / k_exp)
        shrink = safety * en ** (-1.0 / k_exp)
        factor = jnp.where(accept, jnp.clip(grow, 0.2, 2.0),
                           jnp.clip(shrink, 0.1, 1.0))
        h_next = jnp.maximum(h_eff * factor, h_floor)
        if save_at is not None:
            ys_out = fill_saves(ys_out, accept, t, t + h_eff, y, y_new)
        y = _tree_select(accept, y_new, y)
        w = _tree_select(accept, w_prop, w)
        t = jnp.where(accept, t + h_eff, t)
        en_prev = jnp.where(accept, en, en_prev)
        return (y, t, h_next, w, en_prev,
                na + accept.astype(jnp.int32), nr + (~accept).astype(jnp.int32),
                ys_out)

    w0 = driver.weval(t0) if has_noise else 0.0  # exact zeros for a VBT
    ys0 = None
    if save_at is not None:
        ys0 = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (save_ts.shape[0],) + jnp.shape(l)), y0
        )
    init = (y0, jnp.asarray(t0, tdt), jnp.asarray(h0, tdt), w0,
            jnp.asarray(1.0, tdt), jnp.int32(0), jnp.int32(0), ys0)

    def not_done(carry):
        return (t1 - carry[1]) > eps_end

    if bounded:
        step = jax.checkpoint(trial) if checkpoint_steps else trial

        def body(carry, _):
            return _tree_select(not_done(carry), step(carry), carry), None

        final, _ = jax.lax.scan(body, init, None, length=max_steps)
    else:
        def cond(carry):
            return not_done(carry) & (carry[5] + carry[6] < max_steps)

        final = jax.lax.while_loop(cond, trial, init)

    y, t, h, _, _, na, nr, ys_out = final
    return AdaptiveResult(y_final=y, ys=ys_out, t_final=t, h_final=h,
                          n_accepted=na, n_rejected=nr)


def integrate_fixed(solver, term, y0, driver=None, n_steps: int = 64,
                    args: Any = None, *, t0: Optional[float] = None,
                    t1: Optional[float] = None):
    """Fixed-grid solve drawing increments from ``driver`` (matched-path runs).

    Integrates with ``n_steps`` uniform steps, each increment queried via
    ``driver.increment_over`` — so a fixed-grid solve and an adaptive solve
    over the *same* :class:`~repro.core.brownian.VirtualBrownianTree` see the
    same underlying Brownian path, which is what strong-error comparisons
    require.  ``driver=None`` runs in ODE mode (``term.noise`` must be
    ``"none"``; ``t0``/``t1`` default to 0/1).  Returns the final state only
    (use :func:`repro.core.sdeint.sdeint` for saved trajectories on a fixed
    grid).
    """
    solver = _resolve_solver(solver)
    if t0 is None:
        t0 = driver.t0 if driver is not None else 0.0
    if t1 is None:
        t1 = driver.t1 if driver is not None else 1.0
    t0, t1 = float(t0), float(t1)
    h = (t1 - t0) / n_steps
    has_noise = getattr(term, "noise", "diagonal") != "none"
    if has_noise and driver is None:
        raise ValueError(
            "term has noise but no driver was given; pass a Brownian driver "
            "(or set term.noise='none' for ODE mode)"
        )
    state0 = solver.init(term, t0, y0, args)

    def one(state, n):
        t = t0 + n * h
        dW = driver.increment_over(t, t + h) if has_noise else None
        return solver.step(term, state, t, h, dW, args), None

    state, _ = jax.lax.scan(one, state0, jnp.arange(n_steps))
    return solver.extract(state)
