"""Embedded error estimation + adaptive stepping for EES schemes.

Appendix D: the 2N recurrences admit a *three-register* low-storage variant
with a first-order embedded estimator — store the final internal stage
(at c_s, e.g. c_3 = 5/6 for EES(2,5;1/10)) and advance it over the remaining
fraction of the step with a single Euler update re-using the already-computed
stage evaluation:

    y_low = Y_{s-1} + (1 - c_s) * K_s,        err = y_{n+1} - y_low.

No extra vector-field evaluations.  As the paper's Limitations section notes,
step *rejection* requires restoring the previous state (a 3S* register), which
is incompatible with the two-register reversible implementation — so adaptive
stepping here is a forward-only integration mode (use the fixed-grid solver
for reversible-adjoint training).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .solvers import SDETerm, tree_axpy, tree_scale, tree_zeros_like
from .williamson import LowStorage

__all__ = ["step_with_error", "integrate_adaptive", "AdaptiveResult"]


def step_with_error(ls: LowStorage, term: SDETerm, y, t, h, dW, args):
    """One 2N step returning (y_next, embedded error pytree)."""
    delta = tree_zeros_like(y)
    y_prev = y
    k_last = None
    for l in range(ls.stages):
        k = term.increment(t + ls.c[l] * h, y, args, h, dW)
        delta = tree_axpy(ls.A[l], delta, k)
        y_prev = y
        k_last = k
        y = tree_axpy(ls.B[l], delta, y)
    c_last = ls.c[ls.stages - 1]
    y_low = tree_axpy(1.0 - c_last, k_last, y_prev)
    err = jax.tree_util.tree_map(jnp.subtract, y, y_low)
    return y, err


class AdaptiveResult(NamedTuple):
    y: jnp.ndarray
    t: jnp.ndarray
    n_accepted: jnp.ndarray
    n_rejected: jnp.ndarray
    h_final: jnp.ndarray


def integrate_adaptive(
    ls: LowStorage,
    term: SDETerm,
    y0,
    t0: float,
    t1: float,
    args=None,
    *,
    rtol: float = 1e-4,
    atol: float = 1e-6,
    h0: float = 1e-2,
    safety: float = 0.9,
    max_steps: int = 10_000,
):
    """ODE-mode adaptive integration (I-controller on the embedded error)."""

    def err_norm(err, y):
        flat_e = jnp.concatenate([e.ravel() for e in jax.tree_util.tree_leaves(err)])
        flat_y = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(y)])
        scale = atol + rtol * jnp.abs(flat_y)
        return jnp.sqrt(jnp.mean((flat_e / scale) ** 2))

    order = ls.order  # embedded pair is (order, 1); exponent 1/(order)

    def cond(state):
        y, t, h, na, nr, i = state
        return (t < t1) & (i < max_steps)

    def body(state):
        y, t, h, na, nr, i = state
        h_eff = jnp.minimum(h, t1 - t)
        y_new, err = step_with_error(ls, term, y, t, h_eff, None, args)
        en = err_norm(err, y_new)
        accept = en <= 1.0
        factor = jnp.clip(safety * en ** (-1.0 / order), 0.2, 5.0)
        h_next = h_eff * factor
        y = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), y_new, y
        )
        t = jnp.where(accept, t + h_eff, t)
        return (y, t, h_next, na + accept, nr + (1 - accept), i + 1)

    y, t, h, na, nr, _ = jax.lax.while_loop(
        cond,
        body,
        (y0, jnp.asarray(t0, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32),
         jnp.asarray(h0), jnp.asarray(0), jnp.asarray(0), jnp.asarray(0)),
    )
    return AdaptiveResult(y=y, t=t, n_accepted=na, n_rejected=nr, h_final=h)
