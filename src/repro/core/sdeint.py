"""Batched Monte-Carlo SDE integration: one call, many trajectories, any device.

``sdeint`` is the single entry point above the solver layer.  It owns the
plumbing every caller used to hand-roll — Brownian-driver construction, solver
resolution by registry name, ``jax.vmap`` fan-out over per-trajectory PRNG
keys, and (optionally) ``shard_map`` fan-out over a device-mesh axis — while
delegating the actual integration to ONE generalized
:func:`repro.core.adjoint.solve`.  A fixed grid solves directly; an adaptive
request (``adaptive=True`` or an ``"ees25:adaptive"``-style spec) first
*realizes* its accepted-step grid with the PI controller on a
:class:`~repro.core.brownian.VirtualBrownianTree`
(:func:`repro.core.adaptive.realize_grid`), then runs the same ``solve`` over
the realized grid — so every adjoint, including the O(1)-memory
``"reversible"`` one, works on adaptive grids.

Batching is *by key*: each trajectory draws its own counter-based Brownian
driver from its own key, so the batched result is bitwise identical to a
Python loop of single-trajectory calls over the same keys (tested, for both
the fixed-grid and the adaptive path).  That property is what lets serving
slice a request's paths across engine ticks, or a benchmark compare batch
sizes, without changing a single sample.

``sdeint_ticks`` lifts the same batch one level further: a ``(T, B, ...)``
stack of per-tick key batches runs through a single on-device ``lax.map``
loop over ticks — one host dispatch for ``T`` ticks — with tick ``t``
bitwise equal to ``sdeint(..., batch_keys=tick_keys[t])``.  This is the
serving executor's multi-tick entry (see ``repro.serving.executor``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .adaptive import integrate_adaptive
from .adjoint import SolveResult, solve
from .brownian import brownian_path, padded_brownian_path, virtual_brownian_tree
from .grid import TimeGrid
from .registry import get_solver

__all__ = ["sdeint", "sdeint_ticks", "path_keys"]


def path_keys(key: jax.Array, n_paths: int) -> jax.Array:
    """Per-path key batch by ``fold_in`` — THE path-batching convention.

    Path ``i`` of a Monte-Carlo batch always derives its key as
    ``fold_in(key, i)``; the serving engine, the trainer, and offline replay
    all share this function, so a request seed reproduces the same
    trajectories everywhere.  ``key`` may be a *traced* value (a scan carry,
    a per-step ``fold_in(base, step)`` inside a jit'd multi-step training
    chunk): ``fold_in`` is pure integer hashing, so the vmapped derivation
    works identically under ``jit``/``lax.scan`` as it does eagerly.
    """
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_paths))


def _infer_noise_shape(term, y0):
    """Default Brownian-increment shape from the term's noise structure."""
    noise = getattr(term, "noise", "diagonal")
    if noise == "none":
        return ()  # increments are drawn but never consumed
    if noise == "general":
        raise ValueError(
            "noise='general' needs an explicit noise_shape=(..., m) — the "
            "number of driving channels is not derivable from the state"
        )
    if noise == "scalar":
        return ()  # ONE shared channel: the increment is a scalar
    # diagonal/additive: dW matches the state pytree leaf-for-leaf (for a
    # bare-array state this unflattens straight back to its shape tuple)
    leaves, treedef = jax.tree_util.tree_flatten(y0)
    return jax.tree_util.tree_unflatten(treedef, [tuple(l.shape) for l in leaves])


def _infer_dtype(y0):
    for leaf in jax.tree_util.tree_leaves(y0):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf.dtype
    return jnp.float32


def _ambient_mesh():
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        raise ValueError(
            "mesh_axis given but no mesh: pass mesh=... or call inside "
            "`with mesh:` (see repro.launch.mesh.make_production_mesh)"
        )
    return mesh


def sdeint(
    term,
    solver,
    t0: float,
    t1: float,
    n_steps: int,
    y0,
    key: Optional[jax.Array] = None,
    *,
    args: Any = None,
    adjoint: str = "full",
    save_every: Optional[int] = None,
    remat_chunk: Optional[int] = None,
    adaptive: bool = False,
    save_at=None,
    rtol: Optional[float] = None,
    atol: Optional[float] = None,
    h0: Optional[float] = None,
    bm_tol: Optional[float] = None,
    bounded: bool = True,
    bulk_increments: bool = True,
    guard: Optional[float] = None,
    noise_shape=None,
    dtype=None,
    batch_keys: Optional[jax.Array] = None,
    mesh=None,
    mesh_axis: Optional[str] = None,
):
    """Integrate ``term`` over ``[t0, t1]``, fixed-grid or adaptively.

    Parameters
    ----------
    term:
        An :class:`~repro.core.solvers.SDETerm` (drift, diffusion, declared
        noise structure).
    solver:
        A registry spec string (``"ees25"``, ``"ees25:x=0.3"``,
        ``"ees25:adaptive"``, ``"reversible_heun"``, ``"mcf-rk4"``, ...) or a
        solver object.  The ``adaptive`` spec flag is equivalent to passing
        ``adaptive=True``.
    t0, t1:
        Integration window.
    n_steps:
        Fixed grid: the number of uniform steps.  Adaptive: the *trial-step
        budget* (accepted + rejected; also the static length of the realized
        grid, whose unused tail is zero-length padding) — if the controller
        exhausts it the result stops short of ``t1`` (check
        ``result.t_final``).
    y0:
        Initial state (pytree).  With ``batch_keys`` it is *shared* across
        trajectories; batch it yourself with an outer ``vmap`` if each
        trajectory starts differently.
    key:
        PRNG key for a single trajectory.  Ignored when ``batch_keys`` is
        given.
    args:
        Passed through to the drift/diffusion callables (typically the
        parameter pytree being trained).
    adjoint:
        ``"full"`` | ``"recursive"`` | ``"reversible"`` — see
        :func:`repro.core.adjoint.solve`.  All three work on both fixed and
        adaptive grids: an adaptive solve realizes its accepted-step grid
        first (gradient-stopped controller), then the chosen adjoint runs
        over the realized grid — the reversible backward sweep replays the
        same non-uniform step sequence, so step rejection never needs a
        third register.  The one unsupported combination is adaptive
        stepping with a solver that has no embedded error estimate
        (``reversible_heun`` / ``mcf-*`` / single-stage schemes) — grid
        *realization* needs ``step_with_error``; realize with an EES scheme
        via :func:`repro.core.adaptive.realize_grid` and solve with any
        solver if you need that pairing.
    save_every:
        Fixed grid only: save ``extract(state)`` every that many steps (must
        divide ``n_steps``); saved states land in ``result.ys``.
    remat_chunk:
        ``adjoint="recursive"``: checkpoint granularity (steps per
        rematerialised chunk, on either grid kind).
    adaptive:
        Integrate with PI-controlled accept/reject steps on a
        :class:`~repro.core.brownian.VirtualBrownianTree` instead of a fixed
        grid.  Returns an :class:`~repro.core.adaptive.AdaptiveResult`
        (``y_final`` / ``ys`` plus controller statistics).
    save_at:
        Adaptive only: 1-D array of output times in ``[t0, t1]``; the
        solution is interpolated between accepted steps onto this grid and
        returned as ``result.ys`` with a leading ``len(save_at)`` axis.
    rtol, atol, h0:
        Adaptive only: tolerances (defaults 1e-4 / 1e-6) and initial step for
        the controller (see
        :func:`repro.core.adaptive.integrate_adaptive`).  Setting any of
        them without ``adaptive`` raises — a tolerance request must not
        silently run a fixed grid.
    bm_tol:
        Adaptive only: leaf resolution of the Virtual Brownian Tree (default
        ``(t1 - t0) / 4096``).
    bounded:
        Adaptive only.  ``True`` (default): realize-then-solve — the grid
        realization runs forward-only, then the solve sweep carries the
        gradients, so every adjoint works.  ``False``: a single forward-only
        controller pass with no second sweep — the fastest way to *sample*
        (the serving engine uses this), not reverse-differentiable.  Results
        are bitwise identical between the two modes.
    bulk_increments:
        ``True`` (default): every step's Brownian increment is generated in
        one batched driver pass (stacked threefry on a fixed grid; one
        batched level-sweep over the Virtual Brownian Tree on a realized
        grid) and streamed through the solve's forward and
        reversible-backward sweeps — bit-identical increments (results and
        gradients match the per-step path to ulp-level), per-step RNG
        hoisted out of the sequential hot loop (see
        ``docs/performance.md``).  ``False`` restores per-step generation.
    guard:
        Blow-up guard threshold (see :func:`repro.core.adjoint.solve`): when
        set, the result carries a per-trajectory ``diverged`` bool (any
        non-finite state entry, or ``|y| > guard``, at any step) computed
        in-loop on device — no host sync, and bitwise-identical solutions
        with the guard on or off.  ``None`` (default) disables it.
    noise_shape:
        Shape of one Brownian increment.  Defaults to the state's shape for
        diagonal noise; required for ``noise="general"``.
    dtype:
        Brownian-increment dtype (defaults to the state's).
    batch_keys:
        ``(B, ...)`` stack of per-trajectory keys.  The result gains a
        leading ``B`` axis on every leaf and is bitwise equal to looping
        single-trajectory calls over the keys.
    mesh, mesh_axis:
        Shard the batch over ``mesh_axis`` of ``mesh`` with ``shard_map``
        (multi-device Monte Carlo).  ``mesh`` defaults to the ambient
        ``with mesh:`` context; the axis size must divide ``B``.  Requires
        ``batch_keys``.

    Returns
    -------
    :class:`~repro.core.adjoint.SolveResult` ``(y_final, ys)`` on a fixed
    grid; :class:`~repro.core.adaptive.AdaptiveResult` (same two fields plus
    ``t_final`` / ``h_final`` / ``n_accepted`` / ``n_rejected``) when
    adaptive.

    Example
    -------
    >>> keys = jax.random.split(jax.random.PRNGKey(0), 1024)
    >>> r = sdeint(term, "ees25", 0.0, 2.0, 64, y0, None, args=params,
    ...            adjoint="reversible", batch_keys=keys)   # (1024, ...) outputs
    >>> ts = jnp.linspace(0.0, 2.0, 33)
    >>> a = sdeint(term, "ees25:adaptive", 0.0, 2.0, 256, y0, None,
    ...            args=params, rtol=1e-3, save_at=ts, batch_keys=keys)
    >>> a.ys  # (1024, 33, ...) dense output on the save_at grid
    """
    one = _trajectory_fn(
        term, solver, t0, t1, n_steps, y0, args=args, adjoint=adjoint,
        save_every=save_every, remat_chunk=remat_chunk, adaptive=adaptive,
        save_at=save_at, rtol=rtol, atol=atol, h0=h0, bm_tol=bm_tol,
        bounded=bounded, bulk_increments=bulk_increments, guard=guard,
        noise_shape=noise_shape, dtype=dtype,
    )

    if batch_keys is None:
        if mesh_axis is not None or mesh is not None:
            raise ValueError("mesh fan-out requires batch_keys")
        if key is None:
            raise ValueError("pass key= for a single trajectory or batch_keys= for a batch")
        return one(key)

    n_batch = jax.tree_util.tree_leaves(batch_keys)[0].shape[0]
    batched = _batched_fn(jax.vmap(one), n_batch, mesh, mesh_axis)
    return batched(batch_keys)


def sdeint_ticks(
    term,
    solver,
    t0: float,
    t1: float,
    n_steps: int,
    y0,
    tick_keys: jax.Array,
    *,
    mesh=None,
    mesh_axis: Optional[str] = None,
    active_steps: Optional[jax.Array] = None,
    step_size: Optional[float] = None,
    **kwargs,
):
    """Integrate a *stack* of key batches in one on-device multi-tick loop.

    ``tick_keys`` is a ``(T, B, ...)`` stack of ``T`` per-tick key batches;
    each tick is exactly one :func:`sdeint` batch of ``B`` trajectories, and
    the ticks run inside a single ``lax.map`` loop — so a caller (the serving
    executor) pays ONE host dispatch for ``T`` ticks instead of one per tick.
    Every result leaf gains a leading ``(T, B)`` pair of axes, and tick ``t``
    is bitwise equal to ``sdeint(..., batch_keys=tick_keys[t])``: trajectories
    are pure functions of their keys, so looping on-device instead of from the
    host leaves no trace in the samples (regression-tested).

    ``mesh``/``mesh_axis`` shard each tick's **batch** axis over the device
    mesh exactly as in :func:`sdeint` (the tick axis stays sequential — ticks
    are the serving time dimension, not a parallel one).  All other keyword
    arguments are as for :func:`sdeint`.

    **Padded bucketed mode** (``active_steps`` + ``step_size``, PR 8): the
    stack becomes a *bucket* executable — ``n_steps`` is the padded grid
    length (the bucket's ladder rung), ``step_size`` the exact static step
    ``h`` every tick shares, and ``active_steps`` a ``(T,)`` int32 operand
    giving each tick's true (live) step count.  Tick ``t`` is then bitwise
    equal to ``sdeint(term, solver, t0, t0 + active_steps[t]*h,
    active_steps[t], ...)`` over the same keys: padding steps are skipped by
    a batch-uniform ``lax.cond`` whose live branch compiles to exactly the
    unpadded solve (see :meth:`~repro.core.grid.TimeGrid.padded_uniform`).
    One executable serves every horizon on the rung; ``t1`` is ignored in
    this mode (the window is ``t0 + n_steps*step_size`` padded).  Fixed-grid
    solves only — no ``save_every``/``save_at``/adaptive options.
    """
    leaf = jax.tree_util.tree_leaves(tick_keys)[0]
    # A typed key array ((T, B)-shaped, prng_key dtype) carries no trailing
    # key-data axis; raw uint32 keys do — so a flat single-tick batch is
    # rank 1 typed / rank 2 raw, and must go to sdeint instead.
    typed = jnp.issubdtype(leaf.dtype, jax.dtypes.prng_key)
    if leaf.ndim < (2 if typed else 3):
        raise ValueError(
            f"tick_keys must stack per-tick key batches — expected a "
            f"(n_ticks, batch, ...) key array, got shape {tuple(leaf.shape)} "
            f"(dtype {leaf.dtype}); for a single flat batch call "
            "sdeint(..., batch_keys=keys)"
        )

    if active_steps is not None:
        if step_size is None:
            raise ValueError(
                "active_steps (padded bucketed dispatch) requires step_size "
                "— the bucket's exact static step h shared by every tick"
            )
        active = jnp.asarray(active_steps, jnp.int32)
        if active.ndim != 1 or active.shape[0] != leaf.shape[0]:
            raise ValueError(
                f"active_steps must be a (n_ticks,) = ({leaf.shape[0]},) "
                f"int array (one live-step count per tick), got shape "
                f"{tuple(active.shape)}"
            )
        one = _padded_trajectory_fn(term, solver, t0, n_steps, y0,
                                    float(step_size), **kwargs)
        batched = _batched_fn(jax.vmap(one, in_axes=(0, None)),
                              leaf.shape[1], mesh, mesh_axis, n_operands=2)
        if leaf.shape[0] == 1:
            out = batched(jax.tree_util.tree_map(lambda k: k[0], tick_keys),
                          active[0])
            return jax.tree_util.tree_map(lambda x: x[None], out)
        return jax.lax.map(lambda kn: batched(kn[0], kn[1]),
                           (tick_keys, active))
    if step_size is not None:
        raise ValueError("step_size only applies with active_steps (padded "
                         "bucketed dispatch)")

    one = _trajectory_fn(term, solver, t0, t1, n_steps, y0, **kwargs)
    batched = _batched_fn(jax.vmap(one), leaf.shape[1], mesh, mesh_axis)
    if leaf.shape[0] == 1:
        # Serving-tail fast path: a depth-1 stack needs no on-device tick
        # loop — run the single batch directly and restore the tick axis.
        # Bitwise-identical: the lax.map body below is this same batched fn,
        # and per-tick bits are key-determined (regression-tested).
        out = batched(jax.tree_util.tree_map(lambda k: k[0], tick_keys))
        return jax.tree_util.tree_map(lambda x: x[None], out)
    return jax.lax.map(batched, tick_keys)


def _trajectory_fn(
    term, solver, t0, t1, n_steps, y0, *, args=None, adjoint="full",
    save_every=None, remat_chunk=None, adaptive=False, save_at=None,
    rtol=None, atol=None, h0=None, bm_tol=None, bounded=True,
    bulk_increments=True, guard=None, noise_shape=None, dtype=None,
):
    """Validate options and build the single-trajectory ``key -> result`` fn
    (shared by :func:`sdeint` and :func:`sdeint_ticks`)."""
    solver = get_solver(solver)
    adaptive = adaptive or getattr(solver, "adaptive", False)
    if adjoint not in ("full", "recursive", "reversible"):
        raise ValueError(f"unknown adjoint {adjoint!r}")
    if adaptive and not bounded and adjoint != "full":
        raise ValueError(
            f"bounded=False (single controller pass) is forward-only and "
            f"cannot host the {adjoint!r} adjoint; use bounded=True "
            "(realize-then-solve) for gradients"
        )
    if adaptive and save_every is not None:
        raise ValueError(
            "save_every indexes a fixed grid; with adaptive=True pass "
            "save_at=<array of times> instead"
        )
    if save_at is not None and not adaptive:
        raise ValueError(
            "save_at (arbitrary-time dense output) requires adaptive=True / "
            "an ':adaptive' solver spec; on a fixed grid use save_every"
        )
    if not adaptive:
        for opt_name, bad in (("rtol", rtol is not None),
                              ("atol", atol is not None),
                              ("h0", h0 is not None),
                              ("bm_tol", bm_tol is not None),
                              ("bounded", bounded is not True)):
            if bad:
                raise ValueError(
                    f"{opt_name} only applies to adaptive solves; pass "
                    "adaptive=True or an ':adaptive' solver spec — a "
                    "tolerance request must not silently run a fixed grid"
                )
    if noise_shape is None:
        noise_shape = _infer_noise_shape(term, y0)
    if dtype is None:
        dtype = _infer_dtype(y0)

    if adaptive:
        tols = {}
        if rtol is not None:
            tols["rtol"] = rtol
        if atol is not None:
            tols["atol"] = atol

        def one(k):
            vbt = virtual_brownian_tree(
                k, t0, t1, shape=noise_shape, dtype=dtype, tol=bm_tol
            )
            return integrate_adaptive(
                solver, term, y0, vbt, args, t0=t0, t1=t1,
                h0=h0, max_steps=int(n_steps), save_at=save_at,
                bounded=bounded, adjoint=adjoint, remat_chunk=remat_chunk,
                bulk_increments=bulk_increments, guard=guard,
                **tols,
            )
    else:
        def one(k):
            bm = brownian_path(k, t0, t1, n_steps, shape=noise_shape, dtype=dtype)
            return solve(
                solver, term, y0, bm, args,
                adjoint=adjoint, save_every=save_every, remat_chunk=remat_chunk,
                bulk_increments=bulk_increments, guard=guard,
            )

    return one


def _padded_trajectory_fn(
    term, solver, t0, n_padded, y0, h, *, args=None, adjoint="full",
    save_every=None, remat_chunk=None, adaptive=False, save_at=None,
    rtol=None, atol=None, h0=None, bm_tol=None, bounded=True,
    bulk_increments=True, guard=None, noise_shape=None, dtype=None,
):
    """Build the padded single-trajectory ``(key, n_active) -> result`` fn
    for bucketed dispatch: ``h`` is the bucket's exact static step size,
    ``n_padded`` its ladder rung, ``n_active`` the (traced, batch-uniform)
    true step count of one tick."""
    solver = get_solver(solver)
    if adaptive or getattr(solver, "adaptive", False):
        raise ValueError(
            "active_steps (padded bucketed dispatch) applies to fixed-grid "
            "solves only; adaptive requests must dispatch exact"
        )
    if save_every is not None or save_at is not None:
        raise ValueError(
            "padded bucketed dispatch carries no saved trajectories; "
            "save_every/save_at requests must dispatch exact"
        )
    for opt_name, bad in (("rtol", rtol is not None),
                          ("atol", atol is not None),
                          ("h0", h0 is not None),
                          ("bm_tol", bm_tol is not None),
                          ("bounded", bounded is not True)):
        if bad:
            raise ValueError(
                f"{opt_name} only applies to adaptive solves, which cannot "
                "run under padded bucketed dispatch"
            )
    if adjoint not in ("full", "recursive", "reversible"):
        raise ValueError(f"unknown adjoint {adjoint!r}")
    if noise_shape is None:
        noise_shape = _infer_noise_shape(term, y0)
    if dtype is None:
        dtype = _infer_dtype(y0)

    def one(k, n_active):
        bm = padded_brownian_path(k, t0, h, n_padded, shape=noise_shape,
                                  dtype=dtype)
        grid = TimeGrid.padded_uniform(t0, h, n_active, n_padded, bm)
        return solve(solver, term, y0, grid, args, adjoint=adjoint,
                     remat_chunk=remat_chunk,
                     bulk_increments=bulk_increments, guard=guard)

    return one


def _batched_fn(batched, n_batch: int, mesh, mesh_axis, n_operands: int = 1):
    """Wrap a vmap'd trajectory batch in shard_map when a mesh axis is named.

    ``n_operands > 1``: the batch fn takes extra *replicated* operands after
    the sharded key batch (the padded path's batch-uniform ``n_active``)."""
    if mesh_axis is None:
        if mesh is not None:
            raise ValueError("mesh given without mesh_axis; name the axis to shard over")
        return batched

    from jax.sharding import PartitionSpec as P

    mesh = mesh if mesh is not None else _ambient_mesh()
    axis_size = mesh.shape[mesh_axis]
    if n_batch % axis_size != 0:
        raise ValueError(
            f"mesh axis {mesh_axis!r} of size {axis_size} does not divide "
            f"the batch of {n_batch} trajectories"
        )
    spec = P(mesh_axis)
    in_specs = spec if n_operands == 1 else \
        (spec,) + (P(),) * (n_operands - 1)
    try:  # jax <= 0.5
        from jax.experimental.shard_map import shard_map

        return shard_map(batched, mesh=mesh, in_specs=in_specs,
                         out_specs=spec, check_rep=False)
    except ImportError:  # pragma: no cover — jax >= 0.6 (same shim as optim.compression)
        from jax import shard_map

        return shard_map(batched, mesh=mesh, in_specs=in_specs,
                         out_specs=spec)
