"""Batched Monte-Carlo SDE integration: one call, many trajectories, any device.

``sdeint`` is the single entry point above the solver layer.  It owns the
plumbing every caller used to hand-roll — Brownian-path construction, solver
resolution by registry name, ``jax.vmap`` fan-out over per-trajectory PRNG
keys, and (optionally) ``shard_map`` fan-out over a device-mesh axis — while
delegating the actual integration to :func:`repro.core.adjoint.solve`, so all
three adjoints (full / recursive / reversible) work unchanged, batched or not.

Batching is *by key*: each trajectory draws its own counter-based Brownian
path from its own key, so the batched result is bitwise identical to a Python
loop of single-trajectory ``solve`` calls over the same keys (tested).  That
property is what lets serving slice a request's paths across engine ticks, or
a benchmark compare batch sizes, without changing a single sample.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .adjoint import SolveResult, solve
from .brownian import brownian_path
from .registry import get_solver

__all__ = ["sdeint"]


def _infer_noise_shape(term, y0):
    """Default Brownian-increment shape from the term's noise structure."""
    noise = getattr(term, "noise", "diagonal")
    if noise == "none":
        return ()  # increments are drawn but never consumed
    if noise == "general":
        raise ValueError(
            "noise='general' needs an explicit noise_shape=(..., m) — the "
            "number of driving channels is not derivable from the state"
        )
    # diagonal: dW matches the state pytree leaf-for-leaf (for a bare-array
    # state this unflattens straight back to its shape tuple)
    leaves, treedef = jax.tree_util.tree_flatten(y0)
    return jax.tree_util.tree_unflatten(treedef, [tuple(l.shape) for l in leaves])


def _infer_dtype(y0):
    for leaf in jax.tree_util.tree_leaves(y0):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf.dtype
    return jnp.float32


def _ambient_mesh():
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        raise ValueError(
            "mesh_axis given but no mesh: pass mesh=... or call inside "
            "`with mesh:` (see repro.launch.mesh.make_production_mesh)"
        )
    return mesh


def sdeint(
    term,
    solver,
    t0: float,
    t1: float,
    n_steps: int,
    y0,
    key: Optional[jax.Array] = None,
    *,
    args: Any = None,
    adjoint: str = "full",
    save_every: Optional[int] = None,
    remat_chunk: Optional[int] = None,
    noise_shape=None,
    dtype=None,
    batch_keys: Optional[jax.Array] = None,
    mesh=None,
    mesh_axis: Optional[str] = None,
) -> SolveResult:
    """Integrate ``term`` over [t0, t1] in ``n_steps`` fixed steps.

    Parameters
    ----------
    solver:
        A registry spec string (``"ees25"``, ``"ees25:x=0.3"``,
        ``"reversible_heun"``, ``"mcf-rk4"``, ...) or a solver object.
    y0:
        Initial state (pytree).  With ``batch_keys`` it is *shared* across
        trajectories; batch it yourself with an outer ``vmap`` if each
        trajectory starts differently.
    key:
        PRNG key for a single trajectory.  Ignored when ``batch_keys`` is
        given.
    adjoint:
        ``"full"`` | ``"recursive"`` | ``"reversible"`` — see
        :func:`repro.core.adjoint.solve`.
    save_every:
        Save ``extract(state)`` every that many steps (must divide
        ``n_steps``); saved states land in ``SolveResult.ys``.
    noise_shape:
        Shape of one Brownian increment.  Defaults to the state's shape for
        diagonal noise; required for ``noise="general"``.
    batch_keys:
        ``(B, ...)`` stack of per-trajectory keys.  The result gains a
        leading ``B`` axis on every leaf and is bitwise equal to looping
        single-trajectory calls over the keys.
    mesh, mesh_axis:
        Shard the batch over ``mesh_axis`` of ``mesh`` with ``shard_map``
        (multi-device Monte Carlo).  ``mesh`` defaults to the ambient
        ``with mesh:`` context; the axis size must divide ``B``.  Requires
        ``batch_keys``.
    """
    solver = get_solver(solver)
    if noise_shape is None:
        noise_shape = _infer_noise_shape(term, y0)
    if dtype is None:
        dtype = _infer_dtype(y0)

    def one(k) -> SolveResult:
        bm = brownian_path(k, t0, t1, n_steps, shape=noise_shape, dtype=dtype)
        return solve(
            solver, term, y0, bm, args,
            adjoint=adjoint, save_every=save_every, remat_chunk=remat_chunk,
        )

    if batch_keys is None:
        if mesh_axis is not None or mesh is not None:
            raise ValueError("mesh fan-out requires batch_keys")
        if key is None:
            raise ValueError("pass key= for a single trajectory or batch_keys= for a batch")
        return one(key)

    batched = jax.vmap(one)
    if mesh_axis is None:
        if mesh is not None:
            raise ValueError("mesh given without mesh_axis; name the axis to shard over")
        return batched(batch_keys)

    from jax.sharding import PartitionSpec as P

    mesh = mesh if mesh is not None else _ambient_mesh()
    axis_size = mesh.shape[mesh_axis]
    n_batch = jax.tree_util.tree_leaves(batch_keys)[0].shape[0]
    if n_batch % axis_size != 0:
        raise ValueError(
            f"mesh axis {mesh_axis!r} of size {axis_size} does not divide "
            f"the batch of {n_batch} trajectories"
        )
    spec = P(mesh_axis)
    try:  # jax <= 0.5
        from jax.experimental.shard_map import shard_map

        mapped = shard_map(batched, mesh=mesh, in_specs=spec, out_specs=spec,
                           check_rep=False)
    except ImportError:  # pragma: no cover — jax >= 0.6 (same shim as optim.compression)
        from jax import shard_map

        mapped = shard_map(batched, mesh=mesh, in_specs=spec, out_specs=spec)
    return mapped(batch_keys)
