"""Butcher tableaux for explicit Runge-Kutta schemes, including the EES family.

The EES(n, m; x) schemes of Shmelev et al. are explicit RK methods of order n
whose composition ``Phi_{-h} o Phi_h`` recovers the initial condition up to
order m ("effective symmetry").  EES(2,5;x) is the 3-stage one-parameter family
of Proposition 2.1; the canonical member fixes x = 1/10 (minimal leading
error).  EES(2,7;x) is a 4-stage family; its canonical member is specified via
its Williamson 2N coefficients (Appendix D) from which we reconstruct the
Butcher tableau exactly (see :mod:`repro.core.williamson`).
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Tuple

import numpy as np

__all__ = [
    "Tableau",
    "ees25",
    "ees25_tableau",
    "ees27_tableau",
    "euler",
    "midpoint",
    "heun",
    "ralston3",
    "rk3",
    "rk4",
    "stability_poly",
    "order_residuals",
]


@dataclasses.dataclass(frozen=True)
class Tableau:
    """An explicit Butcher tableau.

    ``a`` is an (s, s) strictly-lower-triangular matrix, ``b`` the weights,
    ``c`` the abscissae.  ``order`` is the classical order, ``sym_order`` the
    effective-symmetry order m (with ``Phi_{-h} o Phi_h = id + O(h^{m+1})``);
    ``sym_order == order`` for schemes with no special symmetry property.
    """

    name: str
    a: Tuple[Tuple[float, ...], ...]
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int
    sym_order: int

    @property
    def stages(self) -> int:
        return len(self.b)

    def a_np(self) -> np.ndarray:
        return np.array(self.a, dtype=np.float64)

    def b_np(self) -> np.ndarray:
        return np.array(self.b, dtype=np.float64)

    def c_np(self) -> np.ndarray:
        return np.array(self.c, dtype=np.float64)


def _tab(name, a, b, order, sym_order=None) -> Tableau:
    a = tuple(tuple(float(x) for x in row) for row in a)
    b = tuple(float(x) for x in b)
    c = tuple(float(sum(row)) for row in a)
    return Tableau(name, a, b, c, order, sym_order if sym_order is not None else order)


# ---------------------------------------------------------------------------
# EES(2, 5; x): Proposition 2.1.
# ---------------------------------------------------------------------------

def ees25_tableau(x: float = 0.1) -> Tableau:
    """3-stage EES(2,5;x) Butcher tableau (paper, Proposition 2.1).

    Valid for x not in {1, 1/2, -1/2}.  The canonical member is x = 1/10.
    """
    if x in (1.0, 0.5, -0.5):
        raise ValueError(f"x={x} is not an admissible EES(2,5;x) parameter")
    xf = Fraction(x).limit_denominator(10**12)
    a21 = (1 + 2 * xf) / (4 * (1 - xf))
    a31 = (4 * xf - 1) ** 2 / (4 * (xf - 1) * (1 - 4 * xf**2))
    a32 = (1 - xf) / (1 - 4 * xf**2)
    b = (xf, Fraction(1, 2), Fraction(1, 2) - xf)
    a = ((0, 0, 0), (a21, 0, 0), (a31, a32, 0))
    return _tab(f"EES(2,5;{float(x):g})", a, b, order=2, sym_order=5)


#: Canonical EES(2,5) = EES(2,5; 1/10): a21 = 1/3, a31 = -5/48, a32 = 15/16,
#: b = (1/10, 1/2, 2/5), c = (0, 1/3, 5/6).
ees25 = ees25_tableau(0.1)


def ees27_tableau() -> Tableau:
    """Canonical 4-stage EES(2,7) tableau at x = (5 - 3*sqrt(2))/14, +sqrt(2) branch.

    Reconstructed exactly from the Williamson 2N coefficients of Appendix D via
    the unrolling ``a_{i,j} = sum_{l=j}^{i-1} beta_{l,j}``, ``b_j = sum_l beta_{l,j}``
    with ``beta_{l,i} = B_l A_l ... A_{i+1}``.
    """
    from .williamson import EES27_2N, butcher_from_2n  # local import, no cycle at runtime

    a, b = butcher_from_2n(EES27_2N.A, EES27_2N.B)
    return _tab("EES(2,7)", a, b, order=2, sym_order=7)


# ---------------------------------------------------------------------------
# Classical explicit schemes (baselines / MCF base methods).
# ---------------------------------------------------------------------------

euler = _tab("Euler", ((0,),), (1,), order=1)
midpoint = _tab("Midpoint", ((0, 0), (0.5, 0)), (0, 1), order=2)
heun = _tab("Heun", ((0, 0), (1, 0)), (0.5, 0.5), order=2)
ralston3 = _tab(
    "Ralston3",
    ((0, 0, 0), (0.5, 0, 0), (0, 0.75, 0)),
    (Fraction(2, 9), Fraction(1, 3), Fraction(4, 9)),
    order=3,
)
rk3 = _tab(
    "RK3",
    ((0, 0, 0), (0.5, 0, 0), (-1, 2, 0)),
    (Fraction(1, 6), Fraction(2, 3), Fraction(1, 6)),
    order=3,
)
rk4 = _tab(
    "RK4",
    ((0, 0, 0, 0), (0.5, 0, 0, 0), (0, 0.5, 0, 0), (0, 0, 1, 0)),
    (Fraction(1, 6), Fraction(1, 3), Fraction(1, 3), Fraction(1, 6)),
    order=4,
)


# ---------------------------------------------------------------------------
# Analysis helpers (pure numpy: used by tests and the stability module).
# ---------------------------------------------------------------------------

def stability_poly(tab: Tableau) -> np.ndarray:
    """Coefficients (ascending) of the linear stability polynomial R(rho).

    For an explicit RK scheme ``R(rho) = 1 + sum_k (b^T A^k 1) rho^{k+1}``.
    EES(2,5;x) yields ``1 + rho + rho^2/2 + rho^3/8`` independently of x
    (Theorem 2.2).
    """
    A, b = tab.a_np(), tab.b_np()
    s = tab.stages
    coeffs = [1.0]
    vec = np.ones(s)
    for _ in range(s):
        coeffs.append(float(b @ vec))
        vec = A @ vec
    return np.array(coeffs)


def order_residuals(tab: Tableau, up_to: int = 3) -> dict:
    """Residuals of the rooted-tree order conditions up to order ``up_to`` (<=4)."""
    A, b, c = tab.a_np(), tab.b_np(), tab.c_np()
    res = {}
    if up_to >= 1:
        res["t1"] = abs(b.sum() - 1.0)
    if up_to >= 2:
        res["t2"] = abs(b @ c - 0.5)
    if up_to >= 3:
        res["t31"] = abs(b @ c**2 - 1.0 / 3.0)
        res["t32"] = abs(b @ (A @ c) - 1.0 / 6.0)
    if up_to >= 4:
        res["t41"] = abs(b @ c**3 - 0.25)
        res["t42"] = abs((b * c) @ (A @ c) - 1.0 / 8.0)
        res["t43"] = abs(b @ (A @ c**2) - 1.0 / 12.0)
        res["t44"] = abs(b @ (A @ A @ c) - 1.0 / 24.0)
    return res
