"""Shared pytree linear algebra + solver-spec resolution.

One home for the tiny helpers every integration layer needs, so
``solvers.py`` / ``adaptive.py`` / ``adjoint.py`` / ``sdeint.py`` stop
carrying private copies:

* ``tree_add`` / ``tree_sub`` / ``tree_scale`` / ``tree_axpy`` /
  ``tree_zeros_like`` — leafwise linear algebra over arbitrary state pytrees;
* ``tree_select`` — leafwise ``jnp.where`` on a scalar predicate (the masked
  no-op step used by both the accept/reject controller and the padded
  realized-grid solve);
* ``tree_blowup`` — scalar blow-up predicate (any non-finite leaf entry, or
  any magnitude above a threshold) reduced over the inexact leaves of a
  state pytree — the in-loop divergence guard's one primitive;
* ``resolve_solver`` — spec string / raw coefficient set / solver object →
  solver object, with an optional loud check for the embedded error estimate
  that adaptive stepping requires.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_axpy",
    "tree_zeros_like",
    "tree_select",
    "tree_blowup",
    "resolve_solver",
]


def tree_add(x, y):
    return jax.tree_util.tree_map(jnp.add, x, y)


def tree_sub(x, y):
    return jax.tree_util.tree_map(jnp.subtract, x, y)


def tree_scale(a, x):
    return jax.tree_util.tree_map(lambda xi: a * xi, x)


def tree_axpy(a, x, y):
    """a * x + y."""
    return jax.tree_util.tree_map(lambda xi, yi: a * xi + yi, x, y)


def tree_zeros_like(x):
    return jax.tree_util.tree_map(jnp.zeros_like, x)


def tree_select(pred, a, b):
    """Leafwise ``where(pred, a, b)`` for a scalar (or broadcastable) pred."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_blowup(x, threshold=None):
    """Scalar bool: does any inexact leaf of ``x`` contain a non-finite entry
    (or, with ``threshold``, a magnitude above it)?

    Purely an observer — it reads the state, never feeds back into it — so
    wiring it alongside a solve loop cannot perturb the integration.  Integer
    and bool leaves are skipped (they cannot blow up).

    This runs once per solver step when the blow-up guard is on, so it is
    kept to a single comparison + reduce per leaf: for a finite threshold,
    ``~(|x| <= thr)`` flags NaN and ±Inf for free (they fail ``<=``), which
    is measurably cheaper inside a scan than ``~isfinite | (|x| > thr)``.
    """
    finite_thr = threshold is not None and not (
        isinstance(threshold, float) and math.isinf(threshold)
    )
    flags = []
    for leaf in jax.tree_util.tree_leaves(x):
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.inexact):
            continue
        if finite_thr:
            flags.append(~jnp.all(jnp.abs(arr) <= threshold))
        else:
            flags.append(~jnp.all(jnp.isfinite(arr)))
    if not flags:
        return jnp.asarray(False)
    out = flags[0]
    for f in flags[1:]:
        out = out | f
    return out


def resolve_solver(solver, *, require_error_estimate: bool = False):
    """Spec string / LowStorage coefficients / solver object → solver object.

    ``require_error_estimate=True`` additionally demands ``step_with_error``
    (the Appendix-D embedded estimator) and raises the canonical loud error
    otherwise — grid *realization* (accept/reject stepping) is impossible
    without it, for any adjoint.  Solvers without it (``reversible_heun``,
    ``mcf-*``) can still *solve over* an already-realized grid.
    """
    if isinstance(solver, str):
        from .registry import get_solver

        solver = get_solver(solver)
    from .williamson import LowStorage

    if isinstance(solver, LowStorage):
        from .solvers import LowStorageSolver

        solver = LowStorageSolver(solver)
    if require_error_estimate and not hasattr(solver, "step_with_error"):
        raise ValueError(
            f"solver {getattr(solver, 'name', solver)!r} has no embedded "
            "error estimate (step_with_error); adaptive grid realization "
            "supports the EES 2N schemes and multi-stage Butcher-form RK — "
            "realize the grid with one of those (or use a fixed grid), then "
            "any solver, including reversible_heun / mcf-*, can solve over "
            "the realized grid"
        )
    return solver
