"""CF-EES: commutator-free EES integrators on homogeneous spaces, plus the
geometric baselines (geometric Euler-Maruyama, Crouch-Grossman CG2, RKMK2).

One CF-EES step (eq. (4)/(16)) from ``y_n`` with driver increment
``dX = (h, dW)``::

    Y_0 = y_n,  delta_0 = 0
    K_l     = xi(Y_{l-1}) . dX                      (algebra increment)
    delta_l = A_l delta_{l-1} + K_l
    Y_l     = Lambda(exp(B_l delta_l), Y_{l-1}),    l = 1..s

Only ``(Y, delta)`` are live — the two-register Williamson pattern — and the
step costs exactly ``s`` vector-field evaluations and ``s`` exponentials
(Table 5: the 2N-CF optimum).  The reverse step is the same recurrence with
``(h, dW) -> (-h, -dW)``; by Theorem 3.2 it recovers ``y_n`` to order 5 (or 7),
which is what the reversible adjoint (Algorithm 2) consumes.

On :class:`~repro.core.lie.Euclidean` the action is translation and the step
is *identically* Euclidean 2N EES — tested bitwise.
"""
from __future__ import annotations

from typing import Optional

from .lie import ManifoldSDETerm
from .pytree import tree_axpy, tree_scale
from .williamson import EES25_2N, EES27_2N, LowStorage

__all__ = [
    "CFLowStorageSolver",
    "GeoEulerMaruyama",
    "CrouchGrossman2",
    "RKMK2",
    "cfees25_solver",
    "cfees27_solver",
]


class CFLowStorageSolver:
    """CF-EES(2,m;x): Bazavov's 2N commutator-free lift of a Williamson scheme."""

    def __init__(self, ls: LowStorage, name: Optional[str] = None):
        self.ls = ls
        self.name = name or ls.name.replace("EES", "CF-EES")
        self.evals_per_step = ls.stages
        self.exps_per_step = ls.stages
        self.is_reversible = ls.sym_order > ls.order

    def init(self, term: ManifoldSDETerm, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def step(self, term: ManifoldSDETerm, state, t, h, dW, args):
        ls = self.ls
        y = state
        delta = None
        for l in range(ls.stages):
            k = term.algebra_increment(t + ls.c[l] * h, y, args, h, dW)
            delta = k if delta is None else tree_axpy(ls.A[l], delta, k)
            y = term.group.exp_action(tree_scale(ls.B[l], delta), y)
        return y

    def reverse(self, term, state, t, h, dW, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


class GeoEulerMaruyama:
    """Geometric Euler-Maruyama: y' = Lambda(exp(xi(y).dX), y).  Order 1 weak."""

    name = "GeoEM"
    evals_per_step = 1
    exps_per_step = 1
    is_reversible = False

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def step(self, term, state, t, h, dW, args):
        inc = term.algebra_increment(t, state, args, h, dW)
        return term.group.exp_action(inc, state)

    def reverse(self, term, state, t, h, dW, args):
        # Only first-order accurate — GeoEM is not effectively symmetric.
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


class CrouchGrossman2:
    """CG2 (explicit midpoint Crouch-Grossman): 2 evals, 2 exponentials."""

    name = "CG2"
    evals_per_step = 2
    exps_per_step = 2
    is_reversible = False

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def step(self, term, state, t, h, dW, args):
        k1 = term.algebra_increment(t, state, args, h, dW)
        y_mid = term.group.exp_action(tree_scale(0.5, k1), state)
        k2 = term.algebra_increment(t + 0.5 * h, y_mid, args, h, dW)
        return term.group.exp_action(k2, state)

    def reverse(self, term, state, t, h, dW, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


class RKMK2:
    """RKMK trapezoidal rule of order 2 (dexpinv truncation is exact at this
    order, so no commutators appear): one exponential of the averaged slopes."""

    name = "RKMK2"
    evals_per_step = 2
    exps_per_step = 2
    is_reversible = False

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def step(self, term, state, t, h, dW, args):
        k1 = term.algebra_increment(t, state, args, h, dW)
        y1 = term.group.exp_action(k1, state)
        k2 = term.algebra_increment(t + h, y1, args, h, dW)
        avg = tree_scale(0.5, tree_axpy(1.0, k1, k2))
        return term.group.exp_action(avg, state)

    def reverse(self, term, state, t, h, dW, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


def cfees25_solver(x: float = 0.1) -> CFLowStorageSolver:
    if x == 0.1:
        return CFLowStorageSolver(EES25_2N, name="CF-EES(2,5)")
    from .williamson import ees25_2n

    return CFLowStorageSolver(ees25_2n(x))


def cfees27_solver() -> CFLowStorageSolver:
    return CFLowStorageSolver(EES27_2N, name="CF-EES(2,7)")
