"""The realized-grid abstraction: one time grid, any driver, any solver.

A :class:`TimeGrid` is the single object every solve in this repo integrates
over: an array of step times ``ts`` (possibly non-uniform, possibly with
zero-length padding steps at the tail), per-step sizes, and a Brownian driver
from which each step's ``(t, h, dW)`` triple is derived on demand.  Two
constructors cover the two ways grids come into existence:

* :meth:`TimeGrid.uniform` / :meth:`TimeGrid.from_path` — fixed grids.  A
  uniform grid keeps its step size as a *static* Python float, so the solve
  loop compiles to exactly the computation the fixed-grid stack always ran
  (bitwise-identical results, no masking).
* :func:`repro.core.adaptive.realize_grid` — adaptive grids.  The PI
  accept/reject controller runs once, forward-only and gradient-stopped, and
  emits the accepted-step times; the grid is padded to the static trial
  budget with zero-length steps (``h == 0``), which every solve masks out.

Nothing about reversibility requires uniform steps — only that the backward
pass replays the *same* grid, which ``ts`` pins down and the bitwise-
reproducible drivers guarantee (every ``dW`` is a pure function of
``(key, ts[n], ts[n+1])``).  That is what lets the reversible adjoint's
two-register backward sweep run over an adaptively realized grid: rejection
already happened during realization, so no third register is ever needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["TimeGrid", "fill_saves", "save_mask"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TimeGrid:
    """A (possibly non-uniform) step grid plus the driver that feeds it.

    ``ts`` has shape ``(n_steps + 1,)``; step ``n`` runs over
    ``[ts[n], ts[n+1]]`` with size ``h_of(n)`` and Brownian increment
    ``increment(n)``.  ``uniform_h`` is set (a static Python float) iff the
    grid is uniform — the fixed-grid fast path: ``h_of`` then returns the
    weakly-typed float the classic solve loop always used, and solves skip
    the padding mask entirely.  For realized grids ``hs`` holds the exact
    per-step sizes the controller accepted (``hs[n] == ts[n+1] - ts[n]`` up
    to the controller's own arithmetic; trailing padding steps have
    ``hs[n] == 0``).

    ``t0`` / ``t1`` are the *nominal* integration window as static floats
    (``ts[-1]`` may stop short of ``t1`` when a realization exhausted its
    trial budget).
    """

    ts: jax.Array                 # (n_steps + 1,) step times
    hs: Optional[jax.Array]       # (n_steps,) step sizes, or None if uniform
    driver: Any                   # BrownianDriver or None (ODE mode)
    t0: float
    t1: float
    uniform_h: Optional[float] = None
    # Padded-uniform grids (bucketed serving dispatch, PR 8): the number of
    # *live* steps as a traced int32 scalar; steps at or beyond it are
    # skipped with a lax.cond in the solve loop.  None for ordinary grids.
    n_active: Optional[jax.Array] = None

    # -- pytree plumbing (ts/hs/driver/n_active are children; the window is
    # static) --
    def tree_flatten(self):
        return ((self.ts, self.hs, self.driver, self.n_active),
                (self.t0, self.t1, self.uniform_h))

    @classmethod
    def tree_unflatten(cls, aux, children):
        ts, hs, driver, n_active = children
        t0, t1, uniform_h = aux
        return cls(ts, hs, driver, t0, t1, uniform_h, n_active)

    @property
    def n_steps(self) -> int:
        return self.ts.shape[0] - 1

    @property
    def is_uniform(self) -> bool:
        return self.uniform_h is not None

    @property
    def is_padded(self) -> bool:
        """True for a :meth:`padded_uniform` grid: uniform static step size,
        but only the first ``n_active`` of ``n_steps`` steps are live."""
        return self.n_active is not None

    def t_of(self, n):
        return self.ts[n]

    def h_of(self, n):
        if self.uniform_h is not None:
            return self.uniform_h
        return self.hs[n]

    def increment(self, n):
        """dW over step ``n`` (None in ODE mode)."""
        if self.driver is None:
            return None
        return self.driver.grid_increment(self.ts, n)

    def increments(self):
        """All per-step increments, stacked on a leading ``n_steps`` axis.

        The **bulk Brownian realization** every solve streams from by default
        (PR 4): one batched pass over the driver (stacked threefry for a
        :class:`~repro.core.brownian.BrownianPath`, one batched level-sweep
        for a :class:`~repro.core.brownian.VirtualBrownianTree`), with row
        ``n`` bitwise-equal to :meth:`increment`\\ ``(n)``.  Returns ``None``
        in ODE mode or for a custom driver without a bulk path (solves then
        fall back to per-step queries).
        """
        if self.driver is None or not hasattr(self.driver, "grid_increments"):
            return None
        return self.driver.grid_increments(self.ts)

    def _require_levy(self):
        if self.driver is None:
            raise ValueError(
                "this solver needs space-time Levy areas but the grid has no "
                "Brownian driver (ODE mode)"
            )
        if not hasattr(self.driver, "grid_levy_increment"):
            raise ValueError(
                f"this solver needs space-time Levy areas but driver "
                f"{type(self.driver).__name__} has no grid_levy_increment — "
                "use a BrownianPath or VirtualBrownianTree"
            )

    def levy_increment(self, n):
        """The ``(dW, dH)`` pair over step ``n`` for Levy-area solvers (SRK)."""
        self._require_levy()
        return self.driver.grid_levy_increment(self.ts, n)

    def levy_increments(self):
        """All per-step ``(dWs, dHs)`` pairs, stacked — the bulk realization
        for solvers that advertise ``needs_levy_area`` (see
        :meth:`increments` for the streaming contract)."""
        self._require_levy()
        if not hasattr(self.driver, "grid_levy_increments"):
            return None
        return self.driver.grid_levy_increments(self.ts)

    # -- constructors -------------------------------------------------------

    @classmethod
    def uniform(cls, t0: float, t1: float, n_steps: int, driver=None) -> "TimeGrid":
        """Uniform ``n_steps``-step grid over ``[t0, t1]``.

        With a :class:`~repro.core.brownian.VirtualBrownianTree` driver this
        is the matched-path fixed-grid solve (what ``integrate_fixed`` used
        to do); with ``driver=None`` it is ODE mode.
        """
        t0, t1 = float(t0), float(t1)
        n_steps = int(n_steps)
        if n_steps < 1:
            raise ValueError(f"need n_steps >= 1, got {n_steps}")
        h = (t1 - t0) / n_steps
        # Identical expression to the classic per-step `t0 + n * h` (int32
        # step index, weak Python-float h), vectorized — so grid times are
        # bitwise-equal to what the fixed-grid stack always computed.
        ts = t0 + jnp.arange(n_steps + 1, dtype=jnp.int32) * h
        return cls(ts, None, driver, t0, t1, uniform_h=h)

    @classmethod
    def from_path(cls, bm) -> "TimeGrid":
        """The native grid of a :class:`~repro.core.brownian.BrownianPath`."""
        return cls.uniform(bm.t0, bm.t1, bm.n_steps, driver=bm)

    @classmethod
    def padded_uniform(cls, t0: float, h: float, n_active, n_padded: int,
                       driver=None) -> "TimeGrid":
        """Uniform grid of ``n_padded`` static steps, only ``n_active`` live.

        The grid of **bucketed serving dispatch** (PR 8): the step size ``h``
        is an exact static Python float shared by every request in a bucket,
        ``n_padded`` is the bucket's ladder rung, and ``n_active`` — the one
        traced quantity — is the request's true step count.  Live entries of
        ``ts`` are ``t0 + n * h`` with the same int32-index arithmetic as
        :meth:`uniform` (bitwise-equal times); entries at or past
        ``n_active`` clamp to the final live time, and the solve loop skips
        those steps with a ``lax.cond`` whose live branch is exactly the
        unpadded computation — so a padded solve over ``n_active = k`` is
        bitwise-identical to :meth:`uniform`\\ ``(t0, t0 + k*h, k)``.
        ``uniform_h`` stays set: padding is masked by the conditional, never
        by zero-length steps.
        """
        t0, h = float(t0), float(h)
        n_padded = int(n_padded)
        if n_padded < 1:
            raise ValueError(f"need n_padded >= 1, got {n_padded}")
        n_active = jnp.asarray(n_active, jnp.int32)
        if n_active.ndim != 0:
            raise ValueError(
                f"n_active must be a scalar (one live-step count per grid), "
                f"got shape {n_active.shape}"
            )
        idx = jnp.arange(n_padded + 1, dtype=jnp.int32)
        ts = t0 + jnp.minimum(idx, n_active) * h
        return cls(ts, None, driver, t0, t0 + n_padded * h, uniform_h=h,
                   n_active=n_active)


def save_mask(save_ts, live, t_old, t_new, t1, eps_end):
    """Which save points step ``[t_old, t_new]`` covers — disjoint across steps.

    A step owns the half-open interval ``(t_old, t_new]``; only the *final*
    step (the one reaching ``t1``) extends its claim by ``eps_end``, so a
    save at exactly ``t1`` survives float rounding without any interior save
    ever being claimed by two adjacent steps.  The same mask gates the
    forward fill and the reversible backward cotangent injection — keeping
    them inverses of each other even at step-boundary save times.
    """
    slack = jnp.where(t_new >= t1 - eps_end, eps_end, 0.0)
    return (save_ts > t_old) & (save_ts <= t_new + slack) & live


def fill_saves(ys_out, save_ts, live, t_old, t_new, y_old, y_new,
               t1, eps_end, h_floor):
    """Write the save points covered by one step into the dense-output buffer.

    Linear interpolation between ``y_old`` (state at ``t_old``) and ``y_new``
    (state at ``t_new``), at every ``save_ts`` entry this step owns (see
    :func:`save_mask`); ``live`` gates out rejected trials and zero-length
    padding steps.  Shared verbatim by the accept/reject realization loop and
    the realized-grid solve, so the two produce bitwise-identical dense
    output.
    """
    frac = (save_ts - t_old) / jnp.maximum(t_new - t_old, h_floor)
    mask = save_mask(save_ts, live, t_old, t_new, t1, eps_end)

    def leaf(out, a, b):
        f = jnp.clip(frac, 0.0, 1.0).reshape((-1,) + (1,) * a.ndim)
        m = mask.reshape((-1,) + (1,) * a.ndim)
        return jnp.where(m, a + f.astype(a.dtype) * (b - a), out)

    return jax.tree_util.tree_map(leaf, ys_out, y_old, y_new)
