"""Euclidean SDE solvers: EES Runge-Kutta (Butcher + Williamson 2N forms),
Reversible Heun, and McCallum-Foster reversible couplings.

SDEs ``dy = f(y) dt + g(y) o dW`` are treated as RDEs driven by X = (t, W):
a Runge-Kutta tableau is applied with the vector-field increment

    F(t, y) . dX  =  f(t, y) h  +  g(t, y) . dW

in place of ``h f`` (the "simplified" Redmann-Riedel scheme, eq. (7)).  For
Brownian drivers this yields strong order 1/2 and weak order 1; for smoother
drivers (e.g. fBm with H > 1/2) higher rates follow from Theorem B.3.

All solvers expose a uniform interface:

    state  = solver.init(term, t0, y0, args)
    state' = solver.step(term, state, t, h, dW, args)      # t -> t + h
    state  = solver.reverse(term, state', t, h, dW, args)  # undo that step
    y      = solver.extract(state)

``reverse`` is *exact* (algebraic) for ReversibleHeun and MCF, and accurate to
O(h^{m+1}) per step for EES(2,m) schemes (effective symmetry).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .pytree import tree_add, tree_axpy, tree_scale, tree_sub, tree_zeros_like
from .tableaux import Tableau
from .williamson import EES25_2N, EES27_2N, LowStorage

# Fused step kernels (repro.kernels.sde_step): imported once at module level —
# never inside the step hot loop — and guarded so a stripped install without
# the kernels layer still runs every solver on the plain pytree path.
try:
    from repro.kernels.sde_step import ops as _fused_ops
except Exception:  # pragma: no cover — kernels layer absent
    _fused_ops = None
try:
    from repro.kernels.williamson2n.ops import williamson2n_update as _williamson2n_update
except Exception:  # pragma: no cover — kernels layer absent
    _williamson2n_update = None


def _rk_strong_orders(b, c):
    """Documented strong orders of a driver-weighted RK scheme, from b.c.

    The driver-weighted increment ``F.dX = f h + g dW`` makes the scheme's
    SDE limit a function of ``sum_i b_i c_i`` alone: 0 gives the Ito
    integral (Euler), 1/2 the Stratonovich one (every order->=2 scheme).
    Schemes with ``b.c = 1/2`` additionally reproduce the Milstein
    ``(1/2) g g' dW^2`` term through their stage evaluations, so they are
    strong order 1 for commutative (componentwise-diagonal / scalar) noise
    and order 1 for additive noise; ``b.c = 0`` stays at the Euler rates.
    General non-commutative noise is order 1/2 for all of them.
    """
    bc = float(sum(bi * ci for bi, ci in zip(b, c)))
    if abs(bc - 0.5) < 1e-12:
        return "stratonovich", {"diagonal": 1.0, "scalar": 1.0,
                                "additive": 1.0, "general": 0.5}
    if bc == 0.0:
        return "ito", {"diagonal": 0.5, "scalar": 0.5,
                       "additive": 1.0, "general": 0.5}
    return None, {"diagonal": 0.5, "scalar": 0.5,
                  "additive": 1.0, "general": 0.5}


def _resolve_use_kernels(use_kernels, use_kernel):
    """One boolean from the current flag and its pre-PR-4 spelling.

    An explicitly-set ``use_kernels`` wins (``get_solver`` overrides must be
    able to pin the fused path on/off against a config string using the old
    spelling); the legacy ``use_kernel`` applies only when the new flag was
    left at its ``None`` default.
    """
    if use_kernels is not None:
        return bool(use_kernels)
    if use_kernel is not None:
        return bool(use_kernel)
    return False

__all__ = [
    "SDETerm",
    "VALID_NOISE",
    "ButcherSolver",
    "LowStorageSolver",
    "ReversibleHeun",
    "MCFSolver",
    "Milstein",
    "SRKAdditive",
    "ees25_solver",
    "ees27_solver",
    # Re-exported from .pytree for backwards compatibility — the canonical
    # home of the pytree linear-algebra helpers is repro.core.pytree.
    "tree_add",
    "tree_scale",
    "tree_axpy",
    "tree_zeros_like",
]


# -- SDE term ----------------------------------------------------------------

#: Noise structures an :class:`SDETerm` may declare, from most to least
#: specialized: "none" (ODE), "scalar" (one shared channel), "additive"
#: (state/time-independent diffusion), "diagonal" (elementwise channels),
#: "general" (full (d, m) diffusion matrix).
VALID_NOISE = ("none", "diagonal", "additive", "scalar", "general")


@dataclasses.dataclass(frozen=True)
class SDETerm:
    """Drift + diffusion with a declared noise structure.

    noise:
      * "none"     — ODE; ``diffusion`` is ignored.
      * "diagonal" — ``diffusion(t,y,args)`` has the same pytree structure as
        ``y``; ``dW`` likewise; the product is elementwise.
      * "additive" — diagonal arithmetic, plus the *contract* that
        ``diffusion`` is independent of ``t`` and ``y`` (it may depend on
        ``args``, e.g. a learned constant).  Declaring it unlocks the bulk
        fast path: :func:`~repro.core.adjoint.solve` pre-weights the whole
        increment buffer ``g . dW`` in one pass and the step loop never
        evaluates ``diffusion`` again (bitwise-equal to the diagonal route).
      * "scalar"   — ONE Brownian channel shared by every state component:
        ``dW`` is a scalar, ``diffusion`` matches the state pytree, the
        product broadcasts.
      * "general"  — array state ``(..., d)``; ``diffusion`` returns
        ``(..., d, m)``; ``dW`` is ``(..., m)``.

    The mode is validated at construction (not mid-``combine``, mid-jit) so a
    typo fails with the offending name before any tracing starts.
    """

    drift: Callable[..., Any]
    diffusion: Optional[Callable[..., Any]] = None
    noise: str = "diagonal"

    def __post_init__(self):
        if self.noise not in VALID_NOISE:
            raise ValueError(
                f"unknown noise mode {self.noise!r} for SDETerm; valid modes: "
                + ", ".join(repr(n) for n in VALID_NOISE)
            )
        if self.noise != "none" and self.diffusion is None:
            raise ValueError(
                f"SDETerm(noise={self.noise!r}) requires a diffusion callable; "
                "only noise='none' (ODE mode) may omit it"
            )

    def evals(self, t, y, args):
        """Vector-field evaluation, returned as a (f, g) pair."""
        f = self.drift(t, y, args)
        g = None if self.noise == "none" else self.diffusion(t, y, args)
        return f, g

    def combine(self, f, g, h, dW, use_kernels: bool = False):
        """f * h + g . dW  (the driver-weighted increment).

        ``use_kernels=True`` routes diagonal/additive/general noise through
        the fused :mod:`repro.kernels.sde_step` op (single pass on TPU,
        ``ref.py``-twin arithmetic elsewhere); the default path is the classic
        tree_map chain, bitwise-unchanged.  Additive noise shares the
        diagonal kernel (identical elementwise arithmetic); scalar noise
        stays on the plain path (its ``dW`` is a broadcast scalar).
        """
        if self.noise == "none" or g is None:
            return tree_scale(h, f)
        if use_kernels and _fused_ops is not None and self.noise in (
                "diagonal", "additive", "general"):
            kernel_noise = "diagonal" if self.noise == "additive" else self.noise
            return _fused_ops.tree_increment(f, g, dW, h, noise=kernel_noise)
        out = tree_scale(h, f)
        if self.noise in ("diagonal", "additive"):
            return jax.tree_util.tree_map(lambda o, gi, wi: o + gi * wi, out, g, dW)
        if self.noise == "scalar":
            return jax.tree_util.tree_map(lambda o, gi: o + gi * dW, out, g)
        return jax.tree_util.tree_map(
            lambda o, gi, wi: o + jnp.einsum("...dm,...m->...d", gi, wi), out, g, dW
        )

    def increment(self, t, y, args, h, dW, use_kernels: bool = False):
        f, g = self.evals(t, y, args)
        return self.combine(f, g, h, dW, use_kernels=use_kernels)


@dataclasses.dataclass(frozen=True)
class _PrediffusedTerm:
    """An additive-noise term whose diffusion increments were pre-weighted.

    Built by :func:`repro.core.adjoint.solve` when an ``"additive"`` term
    meets the bulk Brownian buffer under the full/recursive adjoints: the
    whole ``g . dW`` buffer is computed in ONE pass (``g`` is t/y-independent
    by the additive contract) and the per-step ``dW`` handed to solvers is
    *already* the diffusion increment — ``combine`` is just ``f*h + w``,
    one fewer operand stream per stage (see the ``"prediffused"`` fused
    kernel variants).  Bitwise-equal to the standard additive route: the
    multiply ``g*dW`` is the same IEEE multiply, merely hoisted out of the
    scan.
    """

    base: SDETerm
    noise: str = "prediffused"

    @property
    def drift(self):
        return self.base.drift

    def evals(self, t, y, args):
        f = self.base.drift(t, y, args)
        # Placeholder diffusion: ``combine`` ignores it (dW is pre-weighted),
        # but solvers that gate their fused path on ``g is None`` (and
        # Reversible Heun, which carries g in its scan state) need an array.
        return f, jax.tree_util.tree_map(jnp.ones_like, f)

    def combine(self, f, g, h, dW, use_kernels: bool = False):
        if use_kernels and _fused_ops is not None:
            return _fused_ops.tree_increment(f, None, dW, h, noise="prediffused")
        return jax.tree_util.tree_map(lambda fi, wi: fi * h + wi, f, dW)

    def increment(self, t, y, args, h, dW, use_kernels: bool = False):
        f = self.base.drift(t, y, args)
        return self.combine(f, None, h, dW, use_kernels=use_kernels)


# -- Butcher-form RK solver ---------------------------------------------------

class ButcherSolver:
    """Classical (s+1)N-register explicit RK applied to the (h, dW) driver.

    ``use_kernels=True`` fuses each memory-bound chain of the stage loop —
    the driver-weighted increment and the a/b-row axpy combinations — into
    single :mod:`repro.kernels.sde_step` passes (same arithmetic as the
    ``ref.py`` twins on non-TPU backends; the default path is bitwise the
    classic tree_axpy chain).
    """

    def __init__(self, tab: Tableau, use_kernels: bool = False):
        self.tab = tab
        self.name = tab.name
        self.evals_per_step = tab.stages
        self.is_reversible = tab.sym_order > tab.order  # effectively symmetric
        self.use_kernels = bool(use_kernels) and _fused_ops is not None
        self.sde_form, self.strong_orders = _rk_strong_orders(tab.b, tab.c)

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def _weighted(self, y, incrs, coeffs):
        """y + sum_i coeffs[i] * incrs[i], skipping zero coefficients."""
        live = [(c, k) for c, k in zip(coeffs, incrs) if c != 0.0]
        if not live:
            return y
        if self.use_kernels:
            return _fused_ops.tree_axpy_chain(
                y, [k for _, k in live], [c for c, _ in live])
        for c, k in live:
            y = tree_axpy(c, k, y)
        return y

    def _stages(self, term, state, t, h, dW, args):
        """Run the stage loop once; return (y_next, stage increments)."""
        tab = self.tab
        y = state
        incrs = []
        for i in range(tab.stages):
            yi = self._weighted(y, incrs, tab.a[i][:i])
            incrs.append(term.increment(t + tab.c[i] * h, yi, args, h, dW,
                                        use_kernels=self.use_kernels))
        out = self._weighted(y, incrs, tab.b)
        return out, incrs

    def step(self, term, state, t, h, dW, args):
        return self._stages(term, state, t, h, dW, args)[0]

    def step_with_error(self, term, state, t, h, dW, args):
        """One step plus an embedded first-order error estimate.

        The low-order companion is the Euler step built from the (already
        computed) first stage increment, so the estimate costs no extra
        vector-field evaluations; ``err = y_high - y_euler`` is an O(|dX|^2)
        local-error proxy (the (p, 1) embedded pair).
        """
        if self.tab.stages < 2:
            raise ValueError(
                f"{self.name} has a single stage: the high- and low-order "
                "solutions coincide, so there is no embedded error estimate "
                "(pick a >=2-stage scheme for adaptive stepping)"
            )
        out, incrs = self._stages(term, state, t, h, dW, args)
        y_low = tree_add(state, incrs[0])
        err = tree_sub(out, y_low)
        return out, err

    def reverse(self, term, state, t, h, dW, args):
        # Near-reversible reconstruction: the same scheme with negated driver
        # increments, started from the end of the step (time t + h).
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- Williamson 2N solver ------------------------------------------------------

class LowStorageSolver:
    """Two-register Williamson form (eq. (2)): the paper's memory-optimal EES.

    ``use_kernels=True`` fuses the whole per-stage element stream — the
    driver-weighted increment ``k = f*h + g.dW`` *and* the two-register
    update — into one :mod:`repro.kernels.sde_step` pass per stage (Pallas on
    TPU, ``ref.py``-twin arithmetic elsewhere).  With no noise the stage
    falls back to the precomputed-``k`` ``kernels/williamson2n`` update.  The
    default path is bitwise the classic tree_axpy recurrence.
    """

    def __init__(self, ls: LowStorage, use_kernels: Optional[bool] = None,
                 use_kernel: Optional[bool] = None):
        self.ls = ls
        self.name = ls.name
        self.evals_per_step = ls.stages
        self.is_reversible = ls.sym_order > ls.order
        # `use_kernel` is the pre-PR-4 spelling, kept so existing spec
        # strings ("ees25:use_kernel=True") keep selecting the fused path.
        self.use_kernels = _resolve_use_kernels(use_kernels, use_kernel)
        # EES schemes are order 2 (b.c = 1/2): Stratonovich limit, order-1
        # strong rate for commutative noise (see _rk_strong_orders).
        self.sde_form = "stratonovich"
        self.strong_orders = {"diagonal": 1.0, "scalar": 1.0,
                              "additive": 1.0, "general": 0.5}

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def _update(self, a, b, delta, k, y):
        """delta' = a*delta + k ; y' = y + b*delta'  (optionally fused)."""
        if self.use_kernels and _williamson2n_update is not None:
            # Explicit flatten/unflatten: an is_leaf-on-tuples unzip would
            # misfire on states that are themselves tuples.
            d_leaves, treedef = jax.tree_util.tree_flatten(delta)
            pairs = [
                _williamson2n_update(d, kk, yy, a, b)
                for d, kk, yy in zip(d_leaves, treedef.flatten_up_to(k),
                                     treedef.flatten_up_to(y))
            ]
            delta2 = treedef.unflatten([p[0] for p in pairs])
            y2 = treedef.unflatten([p[1] for p in pairs])
            return delta2, y2
        delta2 = tree_axpy(a, delta, k)
        y2 = tree_axpy(b, delta2, y)
        return delta2, y2

    def _sweep(self, term, state, t, h, dW, args):
        """Run the 2N recurrence once; return (y_next, Y_{s-1}, K_s).

        The trailing pair costs nothing in ``step`` (Python references, no
        extra computation — unused outputs are dead-code-eliminated under
        jit) and is what the embedded estimator consumes.
        """
        ls = self.ls
        noise = getattr(term, "noise", "diagonal")
        # Additive noise shares the diagonal stage kernel (same elementwise
        # arithmetic); prediffused terms hit the cheaper f*h + w variant;
        # scalar noise stays on the plain path (its dW is a broadcast scalar).
        if noise == "additive":
            noise = "diagonal"
        fused = (self.use_kernels and _fused_ops is not None
                 and noise in ("diagonal", "general", "prediffused"))
        y = state
        delta = tree_zeros_like(y)
        y_prev = y
        k = None
        for l in range(ls.stages):
            y_prev = y
            if fused:
                f, g = term.evals(t + ls.c[l] * h, y, args)
                if g is None:
                    fused = False  # declared noise but no diffusion: plain path
                else:
                    delta_prev = delta
                    delta, y = _fused_ops.tree_ws_stage(
                        delta, y, f, g, dW, h, ls.A[l], ls.B[l], noise=noise)
                    # K_l = delta' - A_l * delta (for the embedded estimator;
                    # DCE'd in plain `step`).
                    k = tree_axpy(-ls.A[l], delta_prev, delta)
                    continue
            k = term.increment(t + ls.c[l] * h, y, args, h, dW,
                               use_kernels=self.use_kernels)
            delta, y = self._update(ls.A[l], ls.B[l], delta, k, y)
        return y, y_prev, k

    def step(self, term, state, t, h, dW, args):
        return self._sweep(term, state, t, h, dW, args)[0]

    def step_with_error(self, term, state, t, h, dW, args):
        """One 2N step plus the Appendix-D embedded first-order estimate.

        Store the second-to-last register state ``Y_{s-1}`` and advance it
        over the remaining fraction of the step with a single Euler update
        re-using the final stage evaluation::

            y_low = Y_{s-1} + (1 - c_s) * K_s,      err = y_{n+1} - y_low.

        No extra vector-field evaluations (the three-register variant of the
        paper's Limitations section).
        """
        y, y_prev, k_last = self._sweep(term, state, t, h, dW, args)
        c_last = self.ls.c[self.ls.stages - 1]
        y_low = tree_axpy(1.0 - c_last, k_last, y_prev)
        err = tree_sub(y, y_low)
        return y, err

    def reverse(self, term, state, t, h, dW, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- Reversible Heun (Kidger et al. 2021) --------------------------------------

class ReversibleHeun:
    """Algebraically reversible two-state Heun; one (f, g) evaluation per step.

    State: (y, yhat, f(t, yhat), g(t, yhat)).  Stability region is the segment
    lambda*h in [-i, i] (Theorem 2.1) — the instability the EES schemes fix.
    """

    name = "ReversibleHeun"
    evals_per_step = 1
    is_reversible = True
    # Trapezoidal in the driver (b.c = 1/2): Stratonovich limit.
    sde_form = "stratonovich"
    strong_orders = {"diagonal": 1.0, "scalar": 1.0,
                     "additive": 1.0, "general": 0.5}

    def __init__(self, use_kernels: bool = False):
        # Fused driver-weighted increments (repro.kernels.sde_step); the
        # algebraic reversibility argument only needs combine(-h, -dW) ==
        # -combine(h, dW), which holds exactly on the fused path too (IEEE
        # negation is exact).
        self.use_kernels = bool(use_kernels) and _fused_ops is not None

    def init(self, term, t0, y0, args):
        f, g = term.evals(t0, y0, args)
        if g is None:
            g = tree_zeros_like(f)
        return (y0, y0, f, g)

    def extract(self, state):
        return state[0]

    def step(self, term, state, t, h, dW, args):
        y, yh, fh, gh = state
        inc_prev = term.combine(fh, gh, h, dW, use_kernels=self.use_kernels)
        yh2 = tree_add(tree_sub(tree_scale(2.0, y), yh), inc_prev)
        f2, g2 = term.evals(t + h, yh2, args)
        if g2 is None:
            g2 = tree_zeros_like(f2)
        inc_next = term.combine(f2, g2, h, dW, use_kernels=self.use_kernels)
        y2 = tree_axpy(0.5, tree_add(inc_prev, inc_next), y)
        return (y2, yh2, f2, g2)

    def reverse(self, term, state, t, h, dW, args):
        # Exact: the scheme is its own inverse under (h, dW) -> (-h, -dW).
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- McCallum-Foster reversible coupling ----------------------------------------

class MCFSolver:
    """Reversible coupling of an arbitrary base RK method (McCallum & Foster).

        y' = lam*y + (1-lam)*z + Psi_{dX}(z)
        z' = z - Psi_{-dX}(y')

    with exact algebraic inverse.  ``Psi_dX`` is the base-method increment over
    the driver increment dX = (h, dW).  Costs 2x the base stages per step.
    """

    def __init__(self, base: Tableau, lam: float = 0.999, name: Optional[str] = None,
                 use_kernels: bool = False):
        self.base = ButcherSolver(base, use_kernels=use_kernels)
        self.lam = lam
        self.name = name or f"MCF-{base.name}"
        self.evals_per_step = 2 * base.stages
        self.is_reversible = True
        self.use_kernels = self.base.use_kernels
        self.sde_form = self.base.sde_form
        self.strong_orders = self.base.strong_orders

    def _psi(self, term, z, t, h, dW, args):
        return tree_sub(self.base.step(term, z, t, h, dW, args), z)

    def init(self, term, t0, y0, args):
        return (y0, y0)

    def extract(self, state):
        return state[0]

    def step(self, term, state, t, h, dW, args):
        y, z = state
        lam = self.lam
        y2 = tree_add(
            tree_axpy(lam, y, tree_scale(1.0 - lam, z)),
            self._psi(term, z, t, h, dW, args),
        )
        ndW = tree_scale(-1.0, dW)
        z2 = tree_sub(z, self._psi(term, y2, t + h, -h, ndW, args))
        return (y2, z2)

    def reverse(self, term, state, t, h, dW, args):
        y2, z2 = state
        lam = self.lam
        ndW = tree_scale(-1.0, dW)
        z = tree_add(z2, self._psi(term, y2, t + h, -h, ndW, args))
        y = tree_scale(
            1.0 / lam,
            tree_sub(
                tree_sub(y2, tree_scale(1.0 - lam, z)),
                self._psi(term, z, t, h, dW, args),
            ),
        )
        return (y, z)


# -- Noise-specialized schemes -------------------------------------------------

class Milstein:
    """Milstein's method: Euler-Maruyama plus the first-order noise correction.

        y' = y + f h + g dW + (1/2) (g . grad g) (dW^2 - h)     [Ito]
        y' = y + f h + g dW + (1/2) (g . grad g) dW^2           [Stratonovich]

    ``g . grad g`` is computed exactly with one ``jax.jvp`` of the diffusion
    at tangent ``g``.  Strong order 1 for scalar noise (any ``g``), for
    diagonal noise whose channels are componentwise (``g_i`` depends on
    ``y_i`` only — the standard diagonal assumption), and trivially for
    additive noise (the correction vanishes identically, recovering
    order-1 Euler-Maruyama).  General (non-commutative) noise would need
    full Levy areas and is rejected up front with the offending mode named.

    ``form`` selects the Ito or Stratonovich correction; the two limits
    differ by the usual ``-(1/2) g g' h`` drift conversion.

    ``reverse`` subtracts the full Milstein increment evaluated at the step's
    endpoint — O(h^{3/2}) per-step reconstruction error.  (The naive
    negated-driver replay used by the RK schemes would NOT work here: the
    correction is even in ``dW``, so it fails to cancel at O(h).)  Prefer the
    full/recursive adjoints for training; the reversible adjoint runs but
    reconstructs with O(sqrt h) accumulated drift.
    """

    evals_per_step = 2  # one drift + one diffusion (the jvp re-uses the latter)
    is_reversible = False
    # Reads term.diffusion directly (for the jvp) — opt out of the
    # prediffused additive fast path (see adjoint._maybe_prediffuse).
    needs_diffusion = True
    #: documented strong convergence order per supported noise mode
    strong_orders = {"diagonal": 1.0, "scalar": 1.0, "additive": 1.0}

    def __init__(self, form: str = "ito", use_kernels: bool = False):
        if form not in ("ito", "stratonovich"):
            raise ValueError(
                f"unknown Milstein form {form!r}; valid forms: 'ito', "
                "'stratonovich'"
            )
        self.form = form
        self.sde_form = form  # the correction pins the interpretation directly
        self.name = f"Milstein-{form}"
        self.use_kernels = bool(use_kernels) and _fused_ops is not None

    def init(self, term, t0, y0, args):
        noise = getattr(term, "noise", "diagonal")
        if noise not in ("none", "diagonal", "additive", "scalar"):
            raise ValueError(
                f"Milstein does not support noise={noise!r}: general "
                "(non-commutative) noise needs full Levy areas; supported "
                "modes: 'diagonal', 'additive', 'scalar', 'none'"
            )
        return y0

    def extract(self, state):
        return state

    def _correction(self, term, t, y, g, h, dW, args):
        """(1/2) (g . grad g) (dW^2 [- h]) as a pytree increment."""

        def g_fn(yy):
            return term.diffusion(t, yy, args)

        _, gdg = jax.jvp(g_fn, (y,), (g,))
        if getattr(term, "noise", "diagonal") == "scalar":
            w2 = dW * dW - h if self.form == "ito" else dW * dW
            return jax.tree_util.tree_map(lambda d: 0.5 * d * w2, gdg)
        if self.form == "ito":
            return jax.tree_util.tree_map(
                lambda d, w: 0.5 * d * (w * w - h), gdg, dW)
        return jax.tree_util.tree_map(lambda d, w: 0.5 * d * (w * w), gdg, dW)

    def _increment(self, term, y, t, h, dW, args):
        f, g = term.evals(t, y, args)
        inc = term.combine(f, g, h, dW, use_kernels=self.use_kernels)
        if g is None:
            return inc
        return tree_add(inc, self._correction(term, t, y, g, h, dW, args))

    def step(self, term, state, t, h, dW, args):
        return tree_add(state, self._increment(term, state, t, h, dW, args))

    def step_with_error(self, term, state, t, h, dW, args):
        """Milstein step with the Ito/Stratonovich correction as the embedded
        error estimate (the difference from the order-1/2 Euler companion)."""
        f, g = term.evals(t, state, args)
        euler = term.combine(f, g, h, dW, use_kernels=self.use_kernels)
        out = tree_add(state, euler)
        if g is None:
            return out, tree_zeros_like(out)
        corr = self._correction(term, t, state, g, h, dW, args)
        return tree_add(out, corr), corr

    def reverse(self, term, state, t, h, dW, args):
        # Subtract the increment re-evaluated at the endpoint (time t + h).
        return tree_sub(state, self._increment(term, state, t + h, h, dW, args))


class SRKAdditive:
    """SRA1 (Roessler 2010): strong order 1.5 for additive noise.

    Two drift stages plus the space-time Levy area ``DH`` (with
    ``DZ = h (DH + DW/2)`` the time-integrated Brownian bridge)::

        k1 = f(t, y)
        y2 = y + (3/4) h k1 + (3/2) g (DH + DW/2)
        y' = y + h (k1/3 + 2 k2/3) + g DW,     k2 = f(t + 3h/4, y2)

    The driver increment is the *pair* ``(dW, dH)`` — solvers advertising
    ``needs_levy_area`` receive it from the Levy-augmented driver queries
    (:meth:`repro.core.brownian.VirtualBrownianTree.levy_area` /
    ``grid_levy_increments``), so bulk realization, adaptive grids, and the
    reversible adjoint's backward re-queries all keep working.  ``reverse``
    replays with the whole pair negated (the scheme is a stage-2 RK in the
    driver, so the negated replay inverts to O(h^2) per step).
    """

    name = "SRA1"
    evals_per_step = 2
    is_reversible = False
    needs_levy_area = True
    # Reads term.diffusion directly — opt out of the prediffused fast path.
    needs_diffusion = True
    sde_form = "ito"  # == stratonovich: additive noise has no correction
    #: documented strong convergence order per supported noise mode
    strong_orders = {"additive": 1.5}

    def __init__(self, noise: str = "additive"):
        if noise != "additive":
            raise ValueError(
                f"srk supports noise='additive' only (t/y-independent "
                f"diffusion), got noise={noise!r}"
            )

    def init(self, term, t0, y0, args):
        noise = getattr(term, "noise", "diagonal")
        if noise != "additive":
            raise ValueError(
                f"SRA1 requires an SDETerm with noise='additive', got "
                f"noise={noise!r} — declare the term additive (diffusion "
                "independent of t and y) or pick another solver"
            )
        return y0

    def extract(self, state):
        return state

    def step(self, term, state, t, h, dW_pair, args):
        dW, dH = dW_pair
        y = state
        k1 = term.drift(t, y, args)
        g = term.diffusion(t, y, args)
        # DZ/h = dH + dW/2 (exact scalar weights; no h division).
        y2 = jax.tree_util.tree_map(
            lambda yi, ki, gi, wi, hi: yi + 0.75 * h * ki
            + 1.5 * gi * (hi + 0.5 * wi),
            y, k1, g, dW, dH)
        k2 = term.drift(t + 0.75 * h, y2, args)
        third = 1.0 / 3.0
        return jax.tree_util.tree_map(
            lambda yi, a, b, gi, wi: yi + h * (third * a + 2.0 * third * b)
            + gi * wi,
            y, k1, k2, g, dW)

    def step_with_error(self, term, state, t, h, dW_pair, args):
        """SRA1 step with its Euler companion as the embedded estimate."""
        dW, _ = dW_pair
        out = self.step(term, state, t, h, dW_pair, args)
        f, g = term.evals(t, state, args)
        y_low = tree_add(state, term.combine(f, g, h, dW))
        return out, tree_sub(out, y_low)

    def reverse(self, term, state, t, h, dW_pair, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW_pair), args)


def ees25_solver(x: float = 0.1, use_kernels: Optional[bool] = None,
                 use_kernel: Optional[bool] = None) -> LowStorageSolver:
    if x == 0.1:
        return LowStorageSolver(EES25_2N, use_kernels=use_kernels,
                                use_kernel=use_kernel)
    from .williamson import ees25_2n

    return LowStorageSolver(ees25_2n(x), use_kernels=use_kernels,
                            use_kernel=use_kernel)


def ees27_solver(use_kernels: Optional[bool] = None,
                 use_kernel: Optional[bool] = None) -> LowStorageSolver:
    return LowStorageSolver(EES27_2N, use_kernels=use_kernels,
                            use_kernel=use_kernel)
