"""Euclidean SDE solvers: EES Runge-Kutta (Butcher + Williamson 2N forms),
Reversible Heun, and McCallum-Foster reversible couplings.

SDEs ``dy = f(y) dt + g(y) o dW`` are treated as RDEs driven by X = (t, W):
a Runge-Kutta tableau is applied with the vector-field increment

    F(t, y) . dX  =  f(t, y) h  +  g(t, y) . dW

in place of ``h f`` (the "simplified" Redmann-Riedel scheme, eq. (7)).  For
Brownian drivers this yields strong order 1/2 and weak order 1; for smoother
drivers (e.g. fBm with H > 1/2) higher rates follow from Theorem B.3.

All solvers expose a uniform interface:

    state  = solver.init(term, t0, y0, args)
    state' = solver.step(term, state, t, h, dW, args)      # t -> t + h
    state  = solver.reverse(term, state', t, h, dW, args)  # undo that step
    y      = solver.extract(state)

``reverse`` is *exact* (algebraic) for ReversibleHeun and MCF, and accurate to
O(h^{m+1}) per step for EES(2,m) schemes (effective symmetry).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .pytree import tree_add, tree_axpy, tree_scale, tree_sub, tree_zeros_like
from .tableaux import Tableau
from .williamson import EES25_2N, EES27_2N, LowStorage

__all__ = [
    "SDETerm",
    "ButcherSolver",
    "LowStorageSolver",
    "ReversibleHeun",
    "MCFSolver",
    "ees25_solver",
    "ees27_solver",
    # Re-exported from .pytree for backwards compatibility — the canonical
    # home of the pytree linear-algebra helpers is repro.core.pytree.
    "tree_add",
    "tree_scale",
    "tree_axpy",
    "tree_zeros_like",
]


# -- SDE term ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SDETerm:
    """Drift + diffusion with a declared noise structure.

    noise:
      * "none"     — ODE; ``diffusion`` is ignored.
      * "diagonal" — ``diffusion(t,y,args)`` has the same pytree structure as
        ``y``; ``dW`` likewise; the product is elementwise.  (Additive noise is
        the special case where ``diffusion`` ignores ``y``.)
      * "general"  — array state ``(..., d)``; ``diffusion`` returns
        ``(..., d, m)``; ``dW`` is ``(..., m)``.
    """

    drift: Callable[..., Any]
    diffusion: Optional[Callable[..., Any]] = None
    noise: str = "diagonal"

    def evals(self, t, y, args):
        """Vector-field evaluation, returned as a (f, g) pair."""
        f = self.drift(t, y, args)
        g = None if self.noise == "none" else self.diffusion(t, y, args)
        return f, g

    def combine(self, f, g, h, dW):
        """f * h + g . dW  (the driver-weighted increment)."""
        out = tree_scale(h, f)
        if self.noise == "none" or g is None:
            return out
        if self.noise == "diagonal":
            return jax.tree_util.tree_map(lambda o, gi, wi: o + gi * wi, out, g, dW)
        if self.noise == "general":
            return jax.tree_util.tree_map(
                lambda o, gi, wi: o + jnp.einsum("...dm,...m->...d", gi, wi), out, g, dW
            )
        raise ValueError(f"unknown noise mode {self.noise!r}")

    def increment(self, t, y, args, h, dW):
        f, g = self.evals(t, y, args)
        return self.combine(f, g, h, dW)


# -- Butcher-form RK solver ---------------------------------------------------

class ButcherSolver:
    """Classical (s+1)N-register explicit RK applied to the (h, dW) driver."""

    def __init__(self, tab: Tableau):
        self.tab = tab
        self.name = tab.name
        self.evals_per_step = tab.stages
        self.is_reversible = tab.sym_order > tab.order  # effectively symmetric

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def _stages(self, term, state, t, h, dW, args):
        """Run the stage loop once; return (y_next, stage increments)."""
        tab = self.tab
        y = state
        incrs = []
        for i in range(tab.stages):
            yi = y
            for j in range(i):
                if tab.a[i][j] != 0.0:
                    yi = tree_axpy(tab.a[i][j], incrs[j], yi)
            incrs.append(term.increment(t + tab.c[i] * h, yi, args, h, dW))
        out = y
        for i in range(tab.stages):
            if tab.b[i] != 0.0:
                out = tree_axpy(tab.b[i], incrs[i], out)
        return out, incrs

    def step(self, term, state, t, h, dW, args):
        return self._stages(term, state, t, h, dW, args)[0]

    def step_with_error(self, term, state, t, h, dW, args):
        """One step plus an embedded first-order error estimate.

        The low-order companion is the Euler step built from the (already
        computed) first stage increment, so the estimate costs no extra
        vector-field evaluations; ``err = y_high - y_euler`` is an O(|dX|^2)
        local-error proxy (the (p, 1) embedded pair).
        """
        if self.tab.stages < 2:
            raise ValueError(
                f"{self.name} has a single stage: the high- and low-order "
                "solutions coincide, so there is no embedded error estimate "
                "(pick a >=2-stage scheme for adaptive stepping)"
            )
        out, incrs = self._stages(term, state, t, h, dW, args)
        y_low = tree_add(state, incrs[0])
        err = tree_sub(out, y_low)
        return out, err

    def reverse(self, term, state, t, h, dW, args):
        # Near-reversible reconstruction: the same scheme with negated driver
        # increments, started from the end of the step (time t + h).
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- Williamson 2N solver ------------------------------------------------------

class LowStorageSolver:
    """Two-register Williamson form (eq. (2)): the paper's memory-optimal EES."""

    def __init__(self, ls: LowStorage, use_kernel: bool = False):
        self.ls = ls
        self.name = ls.name
        self.evals_per_step = ls.stages
        self.is_reversible = ls.sym_order > ls.order
        # Optional fused Pallas update (beyond-paper TPU optimisation).
        self.use_kernel = use_kernel

    def init(self, term, t0, y0, args):
        return y0

    def extract(self, state):
        return state

    def _update(self, a, b, delta, k, y):
        """delta' = a*delta + k ; y' = y + b*delta'  (optionally fused)."""
        if self.use_kernel:
            from repro.kernels.williamson2n.ops import williamson2n_update

            def upd(d, kk, yy):
                return williamson2n_update(d, kk, yy, a, b)

            pairs = jax.tree_util.tree_map(upd, delta, k, y)
            delta2 = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                            is_leaf=lambda p: isinstance(p, tuple))
            y2 = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                        is_leaf=lambda p: isinstance(p, tuple))
            return delta2, y2
        delta2 = tree_axpy(a, delta, k)
        y2 = tree_axpy(b, delta2, y)
        return delta2, y2

    def _sweep(self, term, state, t, h, dW, args):
        """Run the 2N recurrence once; return (y_next, Y_{s-1}, K_s).

        The trailing pair costs nothing in ``step`` (Python references, no
        extra computation) and is what the embedded estimator consumes.
        """
        ls = self.ls
        y = state
        delta = tree_zeros_like(y)
        y_prev = y
        k = None
        for l in range(ls.stages):
            k = term.increment(t + ls.c[l] * h, y, args, h, dW)
            y_prev = y
            delta, y = self._update(ls.A[l], ls.B[l], delta, k, y)
        return y, y_prev, k

    def step(self, term, state, t, h, dW, args):
        return self._sweep(term, state, t, h, dW, args)[0]

    def step_with_error(self, term, state, t, h, dW, args):
        """One 2N step plus the Appendix-D embedded first-order estimate.

        Store the second-to-last register state ``Y_{s-1}`` and advance it
        over the remaining fraction of the step with a single Euler update
        re-using the final stage evaluation::

            y_low = Y_{s-1} + (1 - c_s) * K_s,      err = y_{n+1} - y_low.

        No extra vector-field evaluations (the three-register variant of the
        paper's Limitations section).
        """
        y, y_prev, k_last = self._sweep(term, state, t, h, dW, args)
        c_last = self.ls.c[self.ls.stages - 1]
        y_low = tree_axpy(1.0 - c_last, k_last, y_prev)
        err = tree_sub(y, y_low)
        return y, err

    def reverse(self, term, state, t, h, dW, args):
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- Reversible Heun (Kidger et al. 2021) --------------------------------------

class ReversibleHeun:
    """Algebraically reversible two-state Heun; one (f, g) evaluation per step.

    State: (y, yhat, f(t, yhat), g(t, yhat)).  Stability region is the segment
    lambda*h in [-i, i] (Theorem 2.1) — the instability the EES schemes fix.
    """

    name = "ReversibleHeun"
    evals_per_step = 1
    is_reversible = True

    def init(self, term, t0, y0, args):
        f, g = term.evals(t0, y0, args)
        if g is None:
            g = tree_zeros_like(f)
        return (y0, y0, f, g)

    def extract(self, state):
        return state[0]

    def step(self, term, state, t, h, dW, args):
        y, yh, fh, gh = state
        inc_prev = term.combine(fh, gh, h, dW)
        yh2 = tree_add(tree_sub(tree_scale(2.0, y), yh), inc_prev)
        f2, g2 = term.evals(t + h, yh2, args)
        if g2 is None:
            g2 = tree_zeros_like(f2)
        inc_next = term.combine(f2, g2, h, dW)
        y2 = tree_axpy(0.5, tree_add(inc_prev, inc_next), y)
        return (y2, yh2, f2, g2)

    def reverse(self, term, state, t, h, dW, args):
        # Exact: the scheme is its own inverse under (h, dW) -> (-h, -dW).
        return self.step(term, state, t + h, -h, tree_scale(-1.0, dW), args)


# -- McCallum-Foster reversible coupling ----------------------------------------

class MCFSolver:
    """Reversible coupling of an arbitrary base RK method (McCallum & Foster).

        y' = lam*y + (1-lam)*z + Psi_{dX}(z)
        z' = z - Psi_{-dX}(y')

    with exact algebraic inverse.  ``Psi_dX`` is the base-method increment over
    the driver increment dX = (h, dW).  Costs 2x the base stages per step.
    """

    def __init__(self, base: Tableau, lam: float = 0.999, name: Optional[str] = None):
        self.base = ButcherSolver(base)
        self.lam = lam
        self.name = name or f"MCF-{base.name}"
        self.evals_per_step = 2 * base.stages
        self.is_reversible = True

    def _psi(self, term, z, t, h, dW, args):
        return tree_sub(self.base.step(term, z, t, h, dW, args), z)

    def init(self, term, t0, y0, args):
        return (y0, y0)

    def extract(self, state):
        return state[0]

    def step(self, term, state, t, h, dW, args):
        y, z = state
        lam = self.lam
        y2 = tree_add(
            tree_axpy(lam, y, tree_scale(1.0 - lam, z)),
            self._psi(term, z, t, h, dW, args),
        )
        ndW = tree_scale(-1.0, dW)
        z2 = tree_sub(z, self._psi(term, y2, t + h, -h, ndW, args))
        return (y2, z2)

    def reverse(self, term, state, t, h, dW, args):
        y2, z2 = state
        lam = self.lam
        ndW = tree_scale(-1.0, dW)
        z = tree_add(z2, self._psi(term, y2, t + h, -h, ndW, args))
        y = tree_scale(
            1.0 / lam,
            tree_sub(
                tree_sub(y2, tree_scale(1.0 - lam, z)),
                self._psi(term, z, t, h, dW, args),
            ),
        )
        return (y, z)


def ees25_solver(x: float = 0.1, use_kernel: bool = False) -> LowStorageSolver:
    if x == 0.1:
        return LowStorageSolver(EES25_2N, use_kernel=use_kernel)
    from .williamson import ees25_2n

    return LowStorageSolver(ees25_2n(x), use_kernel=use_kernel)


def ees27_solver(use_kernel: bool = False) -> LowStorageSolver:
    return LowStorageSolver(EES27_2N, use_kernel=use_kernel)
