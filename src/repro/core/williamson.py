"""Williamson 2N-storage realisations of explicit Runge-Kutta schemes.

A Williamson 2N scheme runs one RK step with two registers::

    delta_l = A_l delta_{l-1} + F(Y_{l-1})
    Y_l     = Y_{l-1} + B_l delta_l,            l = 1..s,  A_1 = 0,

(eq. (2) of the paper, with ``F`` the driver-weighted vector-field increment).
Bazavov's Theorem 3.1 characterises which tableaux admit this form:

    a_{ij} (b_{j-1} - a_{j,j-1}) = (a_{i,j-1} - a_{j,j-1}) b_j,
        i = 3..s,  j = 2..i-1.

Proposition 3.1: EES(2,5;x) and EES(2,7;x) are Williamson-2N for every
admissible x.  This module provides the closed-form coefficients (Appendix D),
conversions in both directions, and the Bazavov condition check.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "LowStorage",
    "EES25_2N",
    "EES27_2N",
    "ees25_2n",
    "bazavov_residuals",
    "butcher_from_2n",
    "two_n_from_butcher",
    "cf_weights",
]


@dataclasses.dataclass(frozen=True)
class LowStorage:
    """Williamson 2N coefficients.  ``A[0]`` must be 0.

    ``c`` are the stage abscissae of the equivalent Butcher tableau, needed to
    evaluate non-autonomous vector fields at the correct stage times.
    """

    name: str
    A: Tuple[float, ...]
    B: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int
    sym_order: int

    @property
    def stages(self) -> int:
        return len(self.B)


def ees25_2n(x: float = 0.1) -> LowStorage:
    """Williamson 2N coefficients of EES(2,5;x) (Appendix D).

    At x = 1/10: B = (1/3, 15/16, 2/5), A = (0, -7/15, -35/32).
    """
    if x in (1.0, 0.5, -0.5):
        raise ValueError(f"x={x} inadmissible")
    B1 = (2 * x + 1) / (4 * (1 - x))
    B2 = (1 - x) / (1 - 4 * x * x)
    B3 = (1 - 2 * x) / 2
    A2 = (4 * x * x - 2 * x + 1) / (2 * (x - 1))
    A3 = -(4 * x * x - 2 * x + 1) / ((2 * x - 1) ** 2 * (2 * x + 1))
    A = (0.0, A2, A3)
    B = (B1, B2, B3)
    a, b = butcher_from_2n(A, B)
    c = tuple(float(sum(row)) for row in a)
    return LowStorage(f"EES(2,5;{x:g})-2N", A, B, c, order=2, sym_order=5)


# EES(2,7) canonical member: x = (5 - 3 sqrt(2))/14, +sqrt(2) branch (Appendix D).
_S2 = math.sqrt(2.0)
_EES27_B = (
    (2.0 - _S2) / 3.0,
    (4.0 + _S2) / 8.0,
    3.0 * (3.0 - _S2) / 7.0,
    (9.0 - 4.0 * _S2) / 14.0,
)
_EES27_A = (
    0.0,
    (-7.0 + 4.0 * _S2) / 3.0,
    -(4.0 + 5.0 * _S2) / 12.0,
    3.0 * (-31.0 + 8.0 * _S2) / 49.0,
)




# ---------------------------------------------------------------------------
# Conversions.
# ---------------------------------------------------------------------------

def cf_weights(A: Sequence[float], B: Sequence[float]) -> np.ndarray:
    """Unrolled weight matrix ``beta[l, i] = B_l A_l A_{l-1} ... A_{i+1}`` (i<l),
    ``beta[l, l] = B_l`` — the coefficients of ``K_1..K_l`` inside the l-th
    exponential of the commutator-free lift (Proposition D.1)."""
    s = len(B)
    beta = np.zeros((s, s))
    for l in range(s):
        beta[l, l] = B[l]
        prod = B[l]
        for i in range(l - 1, -1, -1):
            prod = prod * A[i + 1]
            beta[l, i] = prod
    return beta


def butcher_from_2n(A: Sequence[float], B: Sequence[float]):
    """Reconstruct the Butcher tableau from Williamson 2N coefficients.

    ``a_{i,j} = sum_{l=j}^{i-1} beta_{l,j}``, ``b_j = sum_{l=j}^{s} beta_{l,j}``
    (telescoping of the 2N recurrence; the final row of Proposition D.1).
    """
    beta = cf_weights(A, B)
    s = len(B)
    a = [[0.0] * s for _ in range(s)]
    for i in range(1, s):
        for j in range(i):
            a[i][j] = float(beta[j:i, j].sum())
    b = tuple(float(beta[j:, j].sum()) for j in range(s))
    return tuple(tuple(row) for row in a), b


def bazavov_residuals(a: np.ndarray, b: np.ndarray) -> float:
    """Max |residual| of Bazavov's 2N-representability conditions (Theorem 3.1)."""
    s = len(b)
    worst = 0.0
    for i in range(2, s):  # i = 3..s, 0-indexed 2..s-1
        for j in range(1, i):  # j = 2..i-1, 0-indexed 1..i-1
            lhs = a[i][j] * (b[j - 1] - a[j][j - 1])
            rhs = (a[i][j - 1] - a[j][j - 1]) * b[j]
            worst = max(worst, abs(lhs - rhs))
    # Note: the analogous condition with b as the (s+1)-th row is an algebraic
    # identity, so only the interior conditions constrain the tableau.
    return worst


def two_n_from_butcher(a: np.ndarray, b: np.ndarray):
    """Solve for (A, B) from a 2N-representable Butcher tableau.

    B_l = a_{l+1,l} for l < s and B_s = b_s;
    A_l = (a_{l+1,l-1} - a_{l,l-1}) / B_l for l in 2..s-1, A_s = (b_{s-1} - a_{s,s-1}) / b_s.
    (Appendix D gives exactly this pattern for EES(2,7;x).)
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    s = len(b)
    B = [a[l + 1, l] for l in range(s - 1)] + [b[s - 1]]
    A = [0.0]
    for l in range(1, s - 1):  # stages 2..s-1 (1-indexed)
        A.append((a[l + 1, l - 1] - a[l, l - 1]) / B[l])
    A.append((b[s - 2] - a[s - 1, s - 2]) / b[s - 1])
    return tuple(float(x) for x in A), tuple(float(x) for x in B)


# Module-level canonical instances (defined after the conversion helpers).
EES25_2N = ees25_2n(0.1)


def _ees27() -> LowStorage:
    a, b = butcher_from_2n(_EES27_A, _EES27_B)
    c = tuple(float(sum(row)) for row in a)
    return LowStorage("EES(2,7)-2N", _EES27_A, _EES27_B, c, order=2, sym_order=7)


EES27_2N = _ees27()
