"""Linear and mean-square stability analysis of explicit RK schemes.

For the linear test equation ``dy = lambda y dt`` the update factor is the
stability polynomial ``R(rho)``, ``rho = lambda h``.  For the stochastic test
equation ``dy = lambda y dt + mu y dW`` the scheme applied to the (h, dW)
driver multiplies the state by ``R(rho)`` with the *random* argument
``rho = lambda h + mu dW ~ N(lambda h, mu^2 h)``, and mean-square stability is
``E|R(rho)|^2 < 1`` (Section 3).  We evaluate that expectation by
Gauss-Hermite quadrature — exact here, because |R|^2 is a polynomial in the
Gaussian variable.
"""
from __future__ import annotations

import numpy as np

from .tableaux import Tableau, stability_poly

__all__ = [
    "stability_function",
    "is_linearly_stable",
    "mean_square_factor",
    "is_mean_square_stable",
    "ms_stability_region",
]


def stability_function(tab: Tableau):
    coeffs = stability_poly(tab)

    def R(rho):
        rho = np.asarray(rho, dtype=complex)
        out = np.zeros_like(rho)
        for k in range(len(coeffs) - 1, -1, -1):
            out = out * rho + coeffs[k]
        return out

    return R


def is_linearly_stable(tab: Tableau, rho) -> np.ndarray:
    return np.abs(stability_function(tab)(rho)) < 1.0


def mean_square_factor(tab: Tableau, lam, mu, h, n_quad: int = 64):
    """E|R(lam*h + mu*dW)|^2 with dW ~ N(0, h), via Gauss-Hermite quadrature."""
    R = stability_function(tab)
    nodes, weights = np.polynomial.hermite_e.hermegauss(n_quad)  # weight e^{-x^2/2}
    lam = complex(lam)
    mu = complex(mu)
    rho = lam * h + mu * np.sqrt(h) * nodes
    vals = np.abs(R(rho)) ** 2
    return float((weights * vals).sum() / np.sqrt(2.0 * np.pi))


def is_mean_square_stable(tab: Tableau, lam, mu, h) -> bool:
    return mean_square_factor(tab, lam, mu, h) < 1.0


def ms_stability_region(tab: Tableau, lam_h_grid, mu2_h_grid):
    """Boolean grid of mean-square stability over (lambda h, mu^2 h) cross-sections
    (as in Figure 3; real lambda, real mu)."""
    out = np.zeros((len(lam_h_grid), len(mu2_h_grid)), dtype=bool)
    for i, lh in enumerate(lam_h_grid):
        for j, m2h in enumerate(mu2_h_grid):
            # parameterise with h = 1: lam = lh, mu = sqrt(m2h)
            out[i, j] = is_mean_square_stable(tab, lh, np.sqrt(max(m2h, 0.0)), 1.0)
    return out
