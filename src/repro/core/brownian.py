"""Counter-based Brownian drivers: fixed-grid paths and the Virtual Brownian Tree.

Two constructions share one driver protocol (see :class:`BrownianDriver`):

* :class:`BrownianPath` — fixed grid.  The increment over step ``n`` is a
  deterministic function of ``fold_in(key, n)``, so any increment is
  recomputable in O(1) memory and O(1) time, in any order, on-device.  This is
  what the reversible adjoint's backward reconstruction sweep consumes.
* :class:`VirtualBrownianTree` — arbitrary query times.  The Brownian-bridge
  binary tree of Kidger et al., *Efficient and Accurate Gradients for Neural
  SDEs* (2021): ``W(t)`` for any ``t`` in ``[t0, t1]`` is resolved by
  descending a dyadic interval tree, sampling each midpoint from a bridge
  whose key is ``fold_in(key, node_index)``.  Every query is a pure function
  of ``(key, t)`` — bitwise-reproducible across calls, vmap lanes, and
  devices — in O(depth) time and O(1) memory, with no stored path.  This is
  what adaptive (accept/reject) stepping consumes: a rejected step re-queries
  a *smaller* interval and stays consistent with the same underlying path.

Both drivers accept a *pytree of shapes* (e.g. ``((N,), (N,))`` for a
product-group state); increments then form the matching pytree, each leaf
drawn from an independent stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = [
    "BrownianDriver",
    "BrownianPath",
    "brownian_path",
    "PaddedBrownianPath",
    "padded_brownian_path",
    "VirtualBrownianTree",
    "virtual_brownian_tree",
]


def _is_simple_shape(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


# Key-space salt separating the space-time Levy-area stream from the path
# stream: ``fold_in(key, _LEVY_SALT)`` derives an independent key family, so
# adding Levy queries never perturbs a single bit of the W draws ("LEVY" in
# ASCII; well inside int32 for fold_in).
_LEVY_SALT = 0x4C455659


# The bulk realizations run under their own jit so the generated *bits* are
# independent of the calling context: an eager caller runs the same compiled
# computation that an outer jit inlines (op-by-op execution would fuse the
# uniform->normal transform differently on CPU and drift by an ulp), keeping
# "batch == loop == offline replay" exact.  The driver is a pytree argument,
# so one compilation is shared per (structure, grid length).

@jax.jit
def _bulk_path_increments(bm: "BrownianPath"):
    return jax.vmap(bm.increment)(jnp.arange(bm.n_steps))


@jax.jit
def _bulk_tree_increments(tree: "VirtualBrownianTree", ts):
    w = jax.vmap(tree.weval)(ts)
    return jax.tree_util.tree_map(lambda x: x[1:] - x[:-1], w)


@jax.jit
def _bulk_path_levy(bm: "BrownianPath"):
    ns = jnp.arange(bm.n_steps)
    return jax.vmap(bm.increment)(ns), jax.vmap(bm.levy_area_step)(ns)


@jax.jit
def _bulk_tree_levy(tree: "VirtualBrownianTree", ts):
    w = jax.vmap(tree.weval)(ts)
    dWs = jax.tree_util.tree_map(lambda x: x[1:] - x[:-1], w)
    dHs = jax.vmap(tree.levy_area)(ts[:-1], ts[1:])
    return dWs, dHs




@runtime_checkable
class BrownianDriver(Protocol):
    """What a Brownian driver must provide: increments over time intervals.

    ``increment_over(s, t)`` returns ``W(t) - W(s)`` as a pytree matching the
    driver's ``shape``.  ``grid_increment(ts, n)`` is the step-indexed form:
    the increment over step ``n`` of the (possibly non-uniform) grid ``ts`` —
    O(1)-memory recomputable in any order, which is what the reversible
    adjoint's backward reconstruction sweep relies on.
    ``grid_increments(ts)`` is its **bulk** form and the solve default since
    PR 4: every per-step increment of the grid, stacked on a leading
    ``n_steps`` axis in ONE batched pass (stacked threefry for
    :class:`BrownianPath`, a single batched level-sweep for
    :class:`VirtualBrownianTree`), bitwise-equal entry-for-entry to the
    per-step calls — so solves stream noise from a precomputed buffer instead
    of paying per-step RNG inside the sequential scan.  Fixed-grid drivers
    additionally expose their native grid (``n_steps`` / ``t_of`` /
    ``increment``); the Virtual Brownian Tree additionally exposes point
    evaluation ``weval(t)``.
    """

    t0: float
    t1: float

    def increment_over(self, s, t): ...

    def grid_increment(self, ts, n): ...


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BrownianPath:
    """Fixed-grid Brownian driver over [t0, t1] with ``n_steps`` steps.

    ``shape`` is the shape of one increment (for diagonal noise: the state
    shape; for general noise: ``(..., m)`` noise channels).  All increments
    have standard deviation ``sqrt(h)``.
    """

    key: jax.Array
    t0: float
    t1: float
    n_steps: int
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32

    # -- pytree plumbing (key is a leaf; the rest is static) ----------------
    # The prefix-sum cache (see path()) is deliberately NOT a leaf: flatten
    # drops it, so vmap lanes / jit traces each start from a fresh instance
    # and the cache never smuggles concrete values across a trace boundary.
    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.n_steps, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, n_steps, shape, dtype = aux
        return cls(key, t0, t1, n_steps, shape, dtype)

    def __post_init__(self):
        object.__setattr__(self, "_path_cache", None)

    @property
    def h(self) -> float:
        return (self.t1 - self.t0) / self.n_steps

    def t_of(self, n) -> jax.Array:
        return self.t0 + n * self.h

    def increment(self, n):
        """dW over step n (t_n -> t_{n+1}); ``n`` may be a traced integer.

        ``shape`` may be a simple shape tuple or a *pytree of shapes* (e.g.
        ``((N,), (N,))`` for a product-group state) — the increments then form
        the matching pytree, each leaf drawn from an independent stream.
        """
        sub = jax.random.fold_in(self.key, n)
        scale = jnp.sqrt(jnp.asarray(self.h, self.dtype))
        if _is_simple_shape(self.shape):
            return scale * jax.random.normal(sub, self.shape, self.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(sub, len(leaves))
        outs = [scale * jax.random.normal(k, s, self.dtype) for k, s in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def levy_area_step(self, n):
        """Space-time Levy area ``DH`` over step ``n``: ``N(0, h/12)``.

        ``DH = DZ/h - DW/2`` with ``DZ`` the time integral of the bridged
        path — independent of ``DW`` with variance ``h/12``, drawn from the
        salted key family ``fold_in(fold_in(key, _LEVY_SALT), n)`` so the
        ``W`` bits are untouched.  Pure function of ``(key, n)``:
        recomputable in any order, which the reversible backward sweep and
        the bulk pass rely on.
        """
        sub = jax.random.fold_in(jax.random.fold_in(self.key, _LEVY_SALT), n)
        scale = jnp.sqrt(jnp.asarray(self.h / 12.0, self.dtype))
        if _is_simple_shape(self.shape):
            return scale * jax.random.normal(sub, self.shape, self.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(sub, len(leaves))
        outs = [scale * jax.random.normal(k, s, self.dtype) for k, s in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def grid_levy_increment(self, ts, n):
        """The ``(dW, dH)`` pair over step ``n`` (Levy-augmented driver query)."""
        return self.grid_increment(ts, n), self.levy_area_step(n)

    def grid_levy_increments(self, ts):
        """All per-step ``(dWs, dHs)`` pairs in one stacked threefry pass.

        Row ``n`` is bitwise-equal to :meth:`grid_levy_increment`\\ ``(ts, n)``
        (``ts`` must be this path's native grid)."""
        n_grid = ts.shape[0] - 1
        if n_grid != self.n_steps:
            raise ValueError(
                f"grid of {n_grid} steps does not match this BrownianPath's "
                f"native {self.n_steps}-step grid; increments are indexed by "
                "step (fold_in(key, n)) — use a VirtualBrownianTree for "
                "arbitrary (realized) grids"
            )
        return _bulk_path_levy(self)

    def increment_over(self, s, t):
        """W(t) - W(s) for *grid-aligned* s < t (driver-protocol entry point).

        ``s`` and ``t`` are rounded to the nearest grid node and the
        increment is read out of the prefix-sum path ``W_{t_n}``: one
        batched threefry draw + cumsum over the whole grid (all lanes in
        parallel, no sequential dependency) and two gathers, replacing the
        O(n1 - n0) *sequential* ``fori_loop`` accumulation this method used
        to run.  The prefix-sum path is realized once per driver and cached
        (see :meth:`path`), so repeated window queries cost two gathers
        each.  For any arbitrary-time query, use a
        :class:`VirtualBrownianTree` — O(depth) time and O(1) memory per
        query; the fixed-grid driver is built for step-indexed access.
        """
        n0 = jnp.round((s - self.t0) / self.h).astype(jnp.int32)
        n1 = jnp.round((t - self.t0) / self.h).astype(jnp.int32)
        w = self.path()
        return jax.tree_util.tree_map(lambda x: x[n1] - x[n0], w)

    def grid_increment(self, ts, n):
        """dW over step ``n`` of the grid ``ts`` — which must be this path's
        own uniform grid (``len(ts) == n_steps + 1``).

        The fixed-grid driver draws increments *by step index*
        (``fold_in(key, n)``), so a grid of any other length would silently
        rescale or reorder the noise; build such grids over a
        :class:`VirtualBrownianTree` instead.
        """
        n_grid = ts.shape[0] - 1
        if n_grid != self.n_steps:
            raise ValueError(
                f"grid of {n_grid} steps does not match this BrownianPath's "
                f"native {self.n_steps}-step grid; increments are indexed by "
                "step (fold_in(key, n)) — use a VirtualBrownianTree for "
                "arbitrary (realized) grids"
            )
        return self.increment(n)

    def grid_increments(self, ts):
        """All per-step increments of grid ``ts`` in one stacked threefry pass.

        One ``vmap`` over the step index: every ``fold_in(key, n)`` +
        ``normal`` draw runs in a single batched kernel, with row ``n``
        bitwise-equal to ``increment(n)`` — the bulk form every solve streams
        from by default (``ts`` must be this path's native grid, as for
        :meth:`grid_increment`).
        """
        n_grid = ts.shape[0] - 1
        if n_grid != self.n_steps:
            raise ValueError(
                f"grid of {n_grid} steps does not match this BrownianPath's "
                f"native {self.n_steps}-step grid; increments are indexed by "
                "step (fold_in(key, n)) — use a VirtualBrownianTree for "
                "arbitrary (realized) grids"
            )
        return _bulk_path_increments(self)

    def path(self) -> jax.Array:
        """Cumulative path W_{t_n}, shape (n_steps+1, *shape).

        Realized once per driver instance and cached (the driver is frozen:
        key and grid can never change under the cache), so repeated
        arbitrary-window ``increment_over`` queries pay the batched
        threefry + cumsum once instead of per call.  Cache hits return the
        *same* arrays — bitwise-equal to an uncached recompute by
        construction (regression-tested).  Traced results (a driver built
        eagerly but queried inside jit/vmap) are returned uncached: a
        tracer must not outlive its trace, and traced instances are rebuilt
        fresh by ``tree_unflatten`` anyway.
        """
        if self._path_cache is not None:
            return self._path_cache
        incs = jax.vmap(self.increment)(jnp.arange(self.n_steps))
        w = jax.tree_util.tree_map(lambda x: jnp.cumsum(x, axis=0), incs)
        w = jax.tree_util.tree_map(
            lambda x: jnp.concatenate([jnp.zeros_like(x[:1]), x], axis=0), w
        )
        if not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves((w, self.key))):
            object.__setattr__(self, "_path_cache", w)
        return w


def brownian_path(key, t0, t1, n_steps, shape=(), dtype=jnp.float32) -> BrownianPath:
    """Build a :class:`BrownianPath` (casts ``shape`` lists to tuples)."""
    if isinstance(shape, list):
        shape = tuple(shape)
    return BrownianPath(key, float(t0), float(t1), int(n_steps), shape, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedBrownianPath:
    """Fixed-grid Brownian driver parameterised by its *step size*, not its
    window — the driver of bucketed serving dispatch (PR 8).

    A :class:`BrownianPath` derives ``h`` from ``(t1 - t0) / n_steps``; this
    driver stores the exact Python-double ``h`` directly and extends the grid
    to ``n_steps`` *padded* steps.  Because ``h`` is static (closed into the
    executable, never traced), step ``n``'s increment —
    ``sqrt(h) * normal(fold_in(key, n))`` — is bitwise-identical to a
    ``BrownianPath`` over ``[t0, t0 + k*h]`` with the same key, for every
    live step ``n < k``: requests that differ only in horizon length can
    share one compiled solve whose padding steps are masked off by the grid
    (see :meth:`~repro.core.grid.TimeGrid.padded_uniform`), without
    perturbing a single bit of the samples.
    """

    key: jax.Array
    t0: float
    h: float                  # exact per-step size (static Python double)
    n_steps: int              # padded grid length
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32

    # -- pytree plumbing (key is a leaf; the rest is static) ----------------
    def tree_flatten(self):
        return (self.key,), (self.t0, self.h, self.n_steps, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, h, n_steps, shape, dtype = aux
        return cls(key, t0, h, n_steps, shape, dtype)

    @property
    def t1(self) -> float:
        """End of the *padded* window (live solves stop at ``t0 + k*h``)."""
        return self.t0 + self.n_steps * self.h

    def t_of(self, n) -> jax.Array:
        return self.t0 + n * self.h

    def _draw(self, sub, scale):
        if _is_simple_shape(self.shape):
            return scale * jax.random.normal(sub, self.shape, self.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(sub, len(leaves))
        outs = [scale * jax.random.normal(k, s, self.dtype) for k, s in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def increment(self, n):
        """dW over step ``n`` — bitwise-equal to the same step of an unpadded
        :class:`BrownianPath` sharing ``(key, t0, h)`` (same ``fold_in``
        indexing, same static-``h`` scale)."""
        sub = jax.random.fold_in(self.key, n)
        return self._draw(sub, jnp.sqrt(jnp.asarray(self.h, self.dtype)))

    def levy_area_step(self, n):
        """Space-time Levy area ``DH`` over step ``n`` — the same salted key
        family as :meth:`BrownianPath.levy_area_step` (``W`` bits untouched)."""
        sub = jax.random.fold_in(jax.random.fold_in(self.key, _LEVY_SALT), n)
        return self._draw(sub, jnp.sqrt(jnp.asarray(self.h / 12.0, self.dtype)))

    def _check_grid(self, ts):
        n_grid = ts.shape[0] - 1
        if n_grid != self.n_steps:
            raise ValueError(
                f"grid of {n_grid} steps does not match this "
                f"PaddedBrownianPath's {self.n_steps}-step padded grid"
            )

    def grid_increment(self, ts, n):
        self._check_grid(ts)
        return self.increment(n)

    def grid_increments(self, ts):
        """All padded per-step increments in one stacked threefry pass (row
        ``n`` bitwise-equal to :meth:`increment`\\ ``(n)``; dead rows are
        generated but masked off by the solve)."""
        self._check_grid(ts)
        return _bulk_path_increments(self)

    def grid_levy_increment(self, ts, n):
        self._check_grid(ts)
        return self.increment(n), self.levy_area_step(n)

    def grid_levy_increments(self, ts):
        self._check_grid(ts)
        return _bulk_path_levy(self)


def padded_brownian_path(key, t0, h, n_steps, shape=(),
                         dtype=jnp.float32) -> PaddedBrownianPath:
    """Build a :class:`PaddedBrownianPath` (casts ``shape`` lists to tuples)."""
    if isinstance(shape, list):
        shape = tuple(shape)
    return PaddedBrownianPath(key, float(t0), float(h), int(n_steps), shape, dtype)


# ---------------------------------------------------------------------------
# Virtual Brownian Tree.
# ---------------------------------------------------------------------------

# 2*node+1 must stay inside int32 for fold_in: node < 2^(depth+1).
_MAX_DEPTH = 28


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VirtualBrownianTree:
    """Brownian motion queryable at arbitrary ``t`` in O(1) memory.

    ``weval(t)`` descends ``depth`` levels of a dyadic bisection of
    ``[t0, t1]``; the bridge sample at each visited midpoint is drawn from
    ``fold_in(key, node)`` where ``node`` is the midpoint's heap index (root
    = 1, children ``2n`` / ``2n+1``), so the value at any ``t`` is a pure
    function of ``(key, t)``: re-queries, vmap lanes, and other devices all
    see identical bits.  Below the leaf resolution ``(t1-t0) * 2^-depth``
    (chosen from ``tol``) the path is completed by the bridge conditional
    mean — linear interpolation between the leaf endpoints — so queries are
    exact on the dyadic grid and accurate to ``tol`` in between.

    Increments telescope to floating-point rounding: ``increment_over(s, u)
    == increment_over(s, m) + increment_over(m, u)`` because all three resolve
    point values from the same tree, which is what makes accept/reject
    stepping (query a smaller interval after a rejection) consistent with one
    fixed underlying path.
    """

    key: jax.Array
    t0: float
    t1: float
    shape: Tuple[int, ...] = ()
    dtype: Any = jnp.float32
    tol: float = 2.0 ** -12

    # -- pytree plumbing (key is a leaf; the rest is static) ----------------
    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.shape, self.dtype, self.tol)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, shape, dtype, tol = aux
        return cls(key, t0, t1, shape, dtype, tol)

    @property
    def depth(self) -> int:
        span = self.t1 - self.t0
        return max(1, min(_MAX_DEPTH, int(math.ceil(math.log2(span / self.tol)))))

    def _leaf_eval(self, key, shape, t):
        """W(t) for one pytree leaf, from that leaf's independent key."""
        span = self.t1 - self.t0
        tdt = jnp.result_type(float)  # f64 when enabled: dyadic midpoints stay exact
        tau = jnp.clip((jnp.asarray(t, tdt) - self.t0) / span, 0.0, 1.0)
        w_end = jnp.sqrt(jnp.asarray(span, self.dtype)) * jax.random.normal(
            jax.random.fold_in(key, 0), shape, self.dtype
        )

        def descend(carry, _):
            s, u, ws, wu, node = carry
            m = 0.5 * (s + u)
            std = jnp.sqrt(jnp.asarray(0.25 * span, self.dtype)
                           * (u - s).astype(self.dtype))
            wm = 0.5 * (ws + wu) + std * jax.random.normal(
                jax.random.fold_in(key, node), shape, self.dtype
            )
            right = tau > m
            s2 = jnp.where(right, m, s)
            u2 = jnp.where(right, u, m)
            ws2 = jnp.where(right, wm, ws)
            wu2 = jnp.where(right, wu, wm)
            node2 = 2 * node + right.astype(jnp.int32)
            return (s2, u2, ws2, wu2, node2), None

        init = (jnp.asarray(0.0, tdt), jnp.asarray(1.0, tdt),
                jnp.zeros(shape, self.dtype), w_end, jnp.int32(1))
        (s, u, ws, wu, _), _ = jax.lax.scan(descend, init, None, length=self.depth)
        frac = ((tau - s) / (u - s)).astype(self.dtype)
        return ws + frac * (wu - ws)

    def weval(self, t):
        """W(t) - W(t0) as a pytree matching ``shape`` (``W(t0) == 0``)."""
        if _is_simple_shape(self.shape):
            return self._leaf_eval(self.key, self.shape, t)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(self.key, len(leaves))
        outs = [self._leaf_eval(k, s, t) for k, s in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def increment_over(self, s, t):
        """W(t) - W(s) for arbitrary ``t0 <= s <= t <= t1`` (two tree descents)."""
        ws, wt = self.weval(s), self.weval(t)
        return jax.tree_util.tree_map(jnp.subtract, wt, ws)

    def levy_area(self, s, t):
        """Space-time Levy area ``DH`` over ``[s, t]``: ``N(0, (t-s)/12)``.

        ``DH = DZ/(t-s) - DW/2`` (``DZ`` the time integral of the bridge
        deviation): mean zero, variance ``(t-s)/12``, independent of ``DW``
        over the same interval.  The draw is keyed on the interval's
        endpoints quantized to the tree's leaf resolution and salted into an
        independent key family (``fold_in(key, _LEVY_SALT)``), so it is a
        pure function of ``(key, s, t)`` — re-queries, the reversible
        backward sweep, and bulk realization all see identical bits, and the
        ``W`` stream itself is untouched.  Exact in law per queried interval
        (and jointly, across the disjoint steps of any one grid); unlike
        ``W``, the areas of ``[s, m]`` and ``[m, t]`` do not chain
        pathwise to the area of ``[s, t]`` — the standard
        independent-increment approximation for space-time areas.
        """
        span = self.t1 - self.t0
        tdt = jnp.result_type(float)
        res = jnp.asarray(2.0 ** self.depth, tdt)
        i0 = jnp.round((jnp.asarray(s, tdt) - self.t0) / span * res).astype(jnp.int32)
        i1 = jnp.round((jnp.asarray(t, tdt) - self.t0) / span * res).astype(jnp.int32)
        sub = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(self.key, _LEVY_SALT), i0), i1)
        h = jnp.maximum(jnp.asarray(t, tdt) - jnp.asarray(s, tdt), 0.0)
        scale = jnp.sqrt(h.astype(self.dtype) / 12.0)
        if _is_simple_shape(self.shape):
            return scale * jax.random.normal(sub, self.shape, self.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(sub, len(leaves))
        outs = [scale * jax.random.normal(k, sh, self.dtype)
                for k, sh in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def levy_increment_over(self, s, t):
        """The ``(dW, dH)`` pair over ``[s, t]`` (Levy-augmented query)."""
        return self.increment_over(s, t), self.levy_area(s, t)

    def grid_levy_increment(self, ts, n):
        """The ``(dW, dH)`` pair over step ``n`` of an arbitrary grid ``ts``."""
        return self.grid_increment(ts, n), self.levy_area(ts[n], ts[n + 1])

    def grid_levy_increments(self, ts):
        """All per-step ``(dWs, dHs)`` pairs in one batched pass; row ``n``
        is bitwise-equal to :meth:`grid_levy_increment`\\ ``(ts, n)``."""
        return _bulk_tree_levy(self, ts)

    def grid_increment(self, ts, n):
        """dW over step ``n`` of an arbitrary (realized) grid ``ts``.

        A pure function of ``(key, ts[n], ts[n+1])``: re-queries — including
        the reversible adjoint's backward sweep and a re-solve on the same
        realized grid — see identical bits.
        """
        return self.increment_over(ts[n], ts[n + 1])

    def grid_increments(self, ts):
        """All per-step increments of grid ``ts`` in one batched level-sweep.

        Evaluates ``W`` at every grid node with a single ``vmap`` over
        :meth:`weval` — the dyadic descent runs once per *node* (``n+1``
        descents, all lanes in parallel) instead of twice per *step* as a
        ``vmap`` of :meth:`increment_over` would — and differences adjacent
        nodes.  Since ``weval`` is a pure function of ``(key, t)``, each row
        ``n`` is bitwise-equal to ``grid_increment(ts, n)``.
        """
        return _bulk_tree_increments(self, ts)


def virtual_brownian_tree(key, t0, t1, shape=(), dtype=jnp.float32,
                          tol=None) -> VirtualBrownianTree:
    """Build a :class:`VirtualBrownianTree` over ``[t0, t1]``.

    ``tol`` is the leaf resolution in time units (default ``(t1-t0)/4096``);
    queries less than ``tol`` apart share bridge samples and interpolate.
    """
    if isinstance(shape, list):
        shape = tuple(shape)
    if tol is None:
        tol = (float(t1) - float(t0)) * 2.0 ** -12
    return VirtualBrownianTree(key, float(t0), float(t1), shape, dtype, float(tol))
