"""Counter-based Brownian motion for reversible solvers.

Reversible adjoints must regenerate the *same* Brownian increment ``dW_n``
during the backward reconstruction sweep without storing the path.  We use a
counter-based construction (the fixed-grid analogue of a virtual Brownian
tree): the increment over step ``n`` is a deterministic function of
``fold_in(key, n)``, so any increment is recomputable in O(1) memory and O(1)
time, in any order, on-device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["BrownianPath", "brownian_path"]


def _is_simple_shape(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BrownianPath:
    """Fixed-grid Brownian driver over [t0, t1] with ``n_steps`` steps.

    ``shape`` is the shape of one increment (for diagonal noise: the state
    shape; for general noise: ``(..., m)`` noise channels).  All increments
    have standard deviation ``sqrt(h)``.
    """

    key: jax.Array
    t0: float
    t1: float
    n_steps: int
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32

    # -- pytree plumbing (key is a leaf; the rest is static) ----------------
    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.n_steps, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, n_steps, shape, dtype = aux
        return cls(key, t0, t1, n_steps, shape, dtype)

    @property
    def h(self) -> float:
        return (self.t1 - self.t0) / self.n_steps

    def t_of(self, n) -> jax.Array:
        return self.t0 + n * self.h

    def increment(self, n):
        """dW over step n (t_n -> t_{n+1}); ``n`` may be a traced integer.

        ``shape`` may be a simple shape tuple or a *pytree of shapes* (e.g.
        ``((N,), (N,))`` for a product-group state) — the increments then form
        the matching pytree, each leaf drawn from an independent stream.
        """
        sub = jax.random.fold_in(self.key, n)
        scale = jnp.sqrt(jnp.asarray(self.h, self.dtype))
        if _is_simple_shape(self.shape):
            return scale * jax.random.normal(sub, self.shape, self.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(self.shape, is_leaf=_is_simple_shape)
        keys = jax.random.split(sub, len(leaves))
        outs = [scale * jax.random.normal(k, s, self.dtype) for k, s in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def path(self) -> jax.Array:
        """Cumulative path W_{t_n}, shape (n_steps+1, *shape) — for analysis only."""
        incs = jax.vmap(self.increment)(jnp.arange(self.n_steps))
        w = jax.tree_util.tree_map(lambda x: jnp.cumsum(x, axis=0), incs)
        return jax.tree_util.tree_map(
            lambda x: jnp.concatenate([jnp.zeros_like(x[:1]), x], axis=0), w
        )


def brownian_path(key, t0, t1, n_steps, shape=(), dtype=jnp.float32) -> BrownianPath:
    if isinstance(shape, list):
        shape = tuple(shape)
    return BrownianPath(key, float(t0), float(t1), int(n_steps), shape, dtype)
