"""One generalized ``solve()`` over any :class:`~repro.core.grid.TimeGrid`,
under the paper's three adjoints.

* **Full** (discretise-then-optimise): plain autodiff through ``lax.scan``;
  exact gradients of the discrete computation, O(n) activation memory.
* **Recursive** (checkpointed): segments of the scan are rematerialised
  (``jax.checkpoint``), giving the O(sqrt(n)) memory/compute trade.
* **Reversible**: O(1) memory.  The backward pass *reconstructs* the forward
  trajectory with the solver's algebraic reverse step (exact for Reversible
  Heun / MCF; O(h^{m+1})-accurate for EES(2,m)) and re-plays each step under
  ``jax.vjp`` — Algorithm 1 of the paper (and, composed with the CF-EES step
  on a manifold, Algorithm 2: the stage adjoints live on the cotangent bundle
  automatically because every group action is an ordinary JAX computation).

All three run over the *same* grid abstraction: a uniform grid (the classic
fixed-grid solve — the static step size compiles to exactly the computation
this module always ran) or an adaptively **realized** grid from
:func:`repro.core.adaptive.realize_grid` — per-step ``(t, h[n], dW[n])``
triples with zero-length padding steps masked out.  Since PR 4 the noise is
**bulk-realized** by default: every ``dW[n]`` is generated in one batched
driver pass before the scan (:meth:`~repro.core.grid.TimeGrid.increments`)
and streamed out of the buffer on the forward *and* reversible-backward
sweeps — bit-identical increments, with all per-step RNG hoisted out of
the sequential hot loop (``bulk_increments=False`` restores per-step
generation).  Reversibility never
needed uniform steps, only that the backward pass replays the same step
sequence; the grid's ``ts`` array pins that down, and the bitwise-
reproducible drivers make every ``dW[n]`` recomputable in O(1) memory during
the backward sweep.  Step rejection happened at realization time, so the
two-register reverse step needs no third (3S*) register.

Saved trajectories come in two forms, identical bitwise across adjoints:
``save_every`` (every k-th step, fixed grids) and ``save_at`` (dense output
linearly interpolated onto an arbitrary time grid — any grid, with the
cotangents of each save point injected along the reversible backward sweep).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .brownian import BrownianPath
from .grid import TimeGrid, fill_saves, save_mask
from .pytree import tree_add, tree_blowup, tree_select
from .solvers import _PrediffusedTerm

__all__ = ["SolveResult", "solve"]


class SolveResult(NamedTuple):
    y_final: Any
    ys: Any  # (n_saves, ...) pytree of saved states, or None
    # Scalar bool (per vmap lane): did the state ever go non-finite or exceed
    # the guard threshold during the solve?  None when the guard is off.
    diverged: Any = None


def _float0_like(tree):
    """Zero cotangents for a pytree that may contain non-inexact leaves."""

    def z(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)

    return jax.tree_util.tree_map(z, tree)


def _ct_add(a, b):
    def add(x, y):
        if hasattr(x, "dtype") and x.dtype == jax.dtypes.float0:
            return x
        return x + y

    return jax.tree_util.tree_map(add, a, b)


def _ct_mask(live, ct):
    """Zero a cotangent pytree where ``live`` is False (float0 passes through)."""

    def m(x):
        if hasattr(x, "dtype") and x.dtype == jax.dtypes.float0:
            return x
        return jnp.where(live, x, jnp.zeros_like(x))

    return jax.tree_util.tree_map(m, ct)


def _segment_counts(n_steps: int, save_every: Optional[int]):
    if save_every is None:
        return 1, n_steps
    if n_steps % save_every != 0:
        raise ValueError(f"n_steps={n_steps} not divisible by save_every={save_every}")
    return n_steps // save_every, save_every


def _as_grid(grid) -> TimeGrid:
    if isinstance(grid, TimeGrid):
        return grid
    if isinstance(grid, BrownianPath):
        return TimeGrid.from_path(grid)
    raise TypeError(
        f"solve() integrates over a TimeGrid (or a BrownianPath, wrapped "
        f"automatically); got {type(grid).__name__} — build one with "
        "TimeGrid.uniform(...) or realize_grid(...)"
    )


def _save_consts(grid: TimeGrid, save_at):
    """(save_ts, eps_end, h_floor) — same constants the realization loop uses,
    so realized-grid dense output is bitwise-identical to the single-pass
    accept/reject fill."""
    save_ts = jnp.asarray(save_at, jnp.result_type(float))
    if save_ts.ndim != 1:
        raise ValueError(f"save_at must be 1-D, got shape {save_ts.shape}")
    span = grid.t1 - grid.t0
    return save_ts, 1e-9 * span, 1e-7 * span


def _broadcast_saves(y0, n_saves: int):
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (n_saves,) + jnp.shape(l)), y0
    )


def _pick_step(dWs, n):
    """Step ``n``'s increment from the stacked bulk realization."""
    return jax.tree_util.tree_map(lambda x: x[n], dWs)


def _make_stepper(solver, term, grid: TimeGrid, args, masked, dWs=None):
    """One grid step ``((state, w), n) -> ((new_state, w_next), (t, h))``;
    zero-length padding steps of a realized grid are a no-op.

    ``dWs`` (the default — see :meth:`~repro.core.grid.TimeGrid.increments`)
    is the bulk Brownian realization: every step's increment was generated in
    one batched pass before the scan, and the step body just streams row
    ``n`` out of the buffer — no per-step threefry or tree descent inside
    the sequential loop.  With ``dWs=None`` the pre-bulk paths are kept:
    when the driver supports point evaluation (a Virtual Brownian Tree), the
    forward sweeps *stream* the path — ``w`` carries ``W(ts[n])`` so each
    step costs one tree descent instead of the two a fresh
    ``increment_over`` query pays — and otherwise each step queries
    ``grid.increment(n)``.  All three spellings produce bitwise-identical
    increments (``weval``/``fold_in`` are pure functions of their inputs).
    Returns ``(init_w, step)``; ``init_w()`` builds the initial carry
    element.
    """
    driver = grid.driver
    stream = dWs is None and driver is not None and hasattr(driver, "weval")
    needs_levy = getattr(solver, "needs_levy_area", False)

    if dWs is not None:
        # For Levy-area solvers the buffer is the stacked (dWs, dHs) pair
        # (see TimeGrid.levy_increments); _pick_step indexes the pair pytree.
        def init_w():
            return None

        def step(carry, n):
            state, w = carry
            t, h = grid.t_of(n), grid.h_of(n)
            new = solver.step(term, state, t, h, _pick_step(dWs, n), args)
            if masked:
                new = tree_select(h > 0, new, state)
            return (new, w), (t, h)
    elif stream:
        def init_w():
            return driver.weval(grid.ts[0])

        def step(carry, n):
            state, w = carry
            t, h = grid.t_of(n), grid.h_of(n)
            w_next = driver.weval(grid.ts[n + 1])
            dW = jax.tree_util.tree_map(jnp.subtract, w_next, w)
            if needs_levy:
                dW = (dW, driver.levy_area(grid.ts[n], grid.ts[n + 1]))
            new = solver.step(term, state, t, h, dW, args)
            if masked:
                new = tree_select(h > 0, new, state)
            return (new, w_next), (t, h)
    else:
        def init_w():
            return None

        def step(carry, n):
            state, w = carry
            t, h = grid.t_of(n), grid.h_of(n)
            dW = grid.levy_increment(n) if needs_levy else grid.increment(n)
            new = solver.step(term, state, t, h, dW, args)
            if masked:
                new = tree_select(h > 0, new, state)
            return (new, w), (t, h)

    if getattr(grid, "is_padded", False):
        # Padded-uniform grids (bucketed dispatch): skip steps at or past
        # n_active with a lax.cond.  The predicate is a batch-uniform scalar
        # — one n_active per grid, shared by every vmap lane — so it stays a
        # real conditional under vmap: dead padding steps genuinely skip the
        # solver body, and the live branch is its own computation, compiled
        # exactly as the unpadded solve loop (a tree_select over both
        # branches would change XLA's fusion of multi-register steps and
        # drift the last bits; the cond provably does not —
        # regression-tested bitwise across the solver zoo).
        inner_step = step
        n_active = grid.n_active

        def step(carry, n):
            return jax.lax.cond(
                n < n_active,
                lambda: inner_step(carry, n),
                lambda: (carry, (grid.t_of(n), grid.h_of(n))),
            )

    return init_w, step


def _saving_step(solver, term, grid: TimeGrid, args, masked, save_ts,
                 eps_end, h_floor, dWs=None):
    """Scan body over ``((state, w), ys)`` carrying the dense-output buffer —
    the ONE spelling of the step+fill invariant every adjoint's forward
    pass shares (bitwise-identical ``ys`` across adjoints)."""
    init_w, step = _make_stepper(solver, term, grid, args, masked, dWs)

    def one(carry, n):
        sw, ys = carry
        new_sw, (t, h) = step(sw, n)
        live = (h > 0) if masked else True
        ys = fill_saves(ys, save_ts, live, t, grid.ts[n + 1],
                        solver.extract(sw[0]), solver.extract(new_sw[0]),
                        grid.t1, eps_end, h_floor)
        return (new_sw, ys), None

    return init_w, one


# ---------------------------------------------------------------------------
# Full & recursive adjoints: scan-of-scans, optionally rematerialised.
# ---------------------------------------------------------------------------

def _solve_scan(solver, term, y0, grid: TimeGrid, args, save_every, remat_chunk,
                save_at=None, dWs=None, guard=None):
    masked = not grid.is_uniform
    guarded = guard is not None

    if save_at is not None:
        # Dense output on an arbitrary time grid: one flat scan carrying the
        # save buffer, filled by whichever step covers each save time.
        save_ts, eps_end, h_floor = _save_consts(grid, save_at)
        init_w, one = _saving_step(solver, term, grid, args, masked, save_ts,
                                   eps_end, h_floor, dWs)
        carry0 = ((solver.init(term, grid.t0, y0, args), init_w()),
                  _broadcast_saves(y0, len(save_at)))

        if remat_chunk is not None:
            if grid.n_steps % remat_chunk != 0:
                raise ValueError("n_steps must be divisible by remat_chunk")

            @jax.checkpoint
            def chunk(carry, c0):
                carry, _ = jax.lax.scan(one, carry, c0 + jnp.arange(remat_chunk))
                return carry, None

            starts = remat_chunk * jnp.arange(grid.n_steps // remat_chunk)
            final, _ = jax.lax.scan(chunk, carry0, starts)
        else:
            final, _ = jax.lax.scan(one, carry0, jnp.arange(grid.n_steps))
        ((state_f, _), ys) = final
        div = None
        if guarded:
            # The guard only *observes* the outputs — the scan itself is the
            # exact unguarded program, so guarded results stay
            # bitwise-identical.  Non-finites persist once they enter the
            # state, so checking the final state + save buffer outside the
            # loop detects every blow-up the per-step check would, at zero
            # in-loop cost.
            div = (tree_blowup(solver.extract(state_f), guard)
                   | tree_blowup(ys, guard))
        return SolveResult(solver.extract(state_f), ys, div)

    n_seg, seg_len = _segment_counts(grid.n_steps, save_every)
    init_w, step = _make_stepper(solver, term, grid, args, masked, dWs)

    def one_step(carry, n):
        return step(carry, n)[0], None

    if remat_chunk is None:
        def run_segment(sw, n0):
            sw, _ = jax.lax.scan(one_step, sw, n0 + jnp.arange(seg_len))
            return sw
    else:
        if seg_len % remat_chunk != 0:
            raise ValueError("segment length must be divisible by remat_chunk")

        @jax.checkpoint
        def chunk(carry, c0):
            carry, _ = jax.lax.scan(one_step, carry, c0 + jnp.arange(remat_chunk))
            return carry, None

        def run_segment(sw, n0):
            sw, _ = jax.lax.scan(
                chunk, sw, n0 + remat_chunk * jnp.arange(seg_len // remat_chunk)
            )
            return sw

    if guarded:
        # Guard reduces at save-segment boundaries, not every step: the inner
        # step scan is the exact unguarded program (guarded results stay
        # bitwise-identical) and a blown-up state cannot recover to a clean
        # one across a segment (non-finites persist; a genuine blow-up stays
        # above any threshold), so boundary checks detect everything the
        # per-step check would at ~1/seg_len the overhead.
        def segment(carry, n0):
            sw, div = carry
            sw = run_segment(sw, n0)
            div = div | tree_blowup(solver.extract(sw[0]), guard)
            return (sw, div), (solver.extract(sw[0]) if save_every else None)

        def state_of(carry):
            return carry[0][0]

        carry0 = ((solver.init(term, grid.t0, y0, args), init_w()),
                  jnp.asarray(False))
    else:
        def segment(carry, n0):
            sw = run_segment(carry, n0)
            return sw, (solver.extract(sw[0]) if save_every else None)

        def state_of(carry):
            return carry[0]

        carry0 = (solver.init(term, grid.t0, y0, args), init_w())
    starts = seg_len * jnp.arange(n_seg)
    final, ys = jax.lax.scan(segment, carry0, starts)
    div = final[1] if guarded else None
    return SolveResult(solver.extract(state_of(final)),
                       ys if save_every else None, div)


# ---------------------------------------------------------------------------
# Reversible adjoint (Algorithm 1 / 2).
# ---------------------------------------------------------------------------

def _solve_reversible(solver, term, y0, grid: TimeGrid, args, save_every,
                      save_at=None, dWs=None, guard=None):
    n_steps = grid.n_steps
    n_seg, seg_len = _segment_counts(n_steps, save_every)
    masked = not grid.is_uniform
    guarded = guard is not None
    needs_levy = getattr(solver, "needs_levy_area", False)
    if save_at is not None:
        save_ts, eps_end, h_floor = _save_consts(grid, save_at)

    def forward(grid, y0, args, dWs):
        state0 = solver.init(term, grid.t0, y0, args)

        if save_at is not None:
            init_w, one = _saving_step(solver, term, grid, args, masked,
                                       save_ts, eps_end, h_floor, dWs)
            carry0 = ((state0, init_w()), _broadcast_saves(y0, len(save_at)))
            final, _ = jax.lax.scan(one, carry0, jnp.arange(n_steps))
            ((state_f, _), ys) = final
            div = None
            if guarded:
                # Observer-only, post-loop (see _solve_scan): non-finites
                # persist, so final state + save buffer see every blow-up.
                div = (tree_blowup(solver.extract(state_f), guard)
                       | tree_blowup(ys, guard))
            return state_f, ys, div

        init_w, step = _make_stepper(solver, term, grid, args, masked, dWs)

        def one_step(carry, n):
            return step(carry, n)[0], None

        if guarded:
            # Save-segment-boundary guard, exactly as in _solve_scan: the
            # inner step scan is the unguarded program (bitwise-identical
            # results), divergence is reduced once per segment.
            def segment(carry, n0):
                sw, div = carry
                sw, _ = jax.lax.scan(one_step, sw, n0 + jnp.arange(seg_len))
                div = div | tree_blowup(solver.extract(sw[0]), guard)
                return (sw, div), (solver.extract(sw[0]) if save_every
                                   else None)

            def state_of(carry):
                return carry[0][0]

            carry0 = ((state0, init_w()), jnp.asarray(False))
        else:
            def segment(carry, n0):
                carry, _ = jax.lax.scan(one_step, carry,
                                        n0 + jnp.arange(seg_len))
                return carry, (solver.extract(carry[0]) if save_every
                               else None)

            def state_of(carry):
                return carry[0]

            carry0 = (state0, init_w())
        final, ys = jax.lax.scan(segment, carry0, seg_len * jnp.arange(n_seg))
        div = final[1] if guarded else None
        return state_of(final), (ys if save_every else None), div

    @jax.custom_vjp
    def run(grid, y0, args, dWs):
        state_f, ys, div = forward(grid, y0, args, dWs)
        return SolveResult(solver.extract(state_f), ys, div)

    def run_fwd(grid, y0, args, dWs):
        state_f, ys, div = forward(grid, y0, args, dWs)
        return SolveResult(solver.extract(state_f), ys, div), (grid, state_f,
                                                               args, dWs)

    def run_bwd(res, ct):
        # The backward sweep streams the SAME bulk realization the forward
        # consumed (it is a residual, not recomputed): increments are read in
        # reverse order from the buffer, keeping the O(1)-in-trajectory
        # reconstruction while dropping the per-step driver recompute.
        grid, state_f, args, dWs = res
        ct_yf, ct_ys = ct.y_final, ct.ys

        # Inject the terminal cotangent through `extract`.
        _, vjp_ex = jax.vjp(solver.extract, state_f)
        (ct_state,) = vjp_ex(ct_yf)
        ct_args = _float0_like(args)

        def body(carry, n):
            state, ct_state, ct_args = carry
            t, h = grid.t_of(n), grid.h_of(n)
            if dWs is None:
                dW = (grid.levy_increment(n) if needs_levy
                      else grid.increment(n))
            else:
                dW = _pick_step(dWs, n)
            live = (h > 0) if masked else True
            # 1. Reconstruct the pre-step state (O(h^{m+1}) drift for EES;
            #    exact for algebraically reversible solvers).  Padding steps
            #    were no-ops forward, so they are no-ops backward.
            prev = solver.reverse(term, state, t, h, dW, args)
            if masked:
                prev = tree_select(live, prev, state)
            # 2. Cotangents of saved outputs produced by this step.
            pick_old = None
            if save_every is not None:
                is_save = (n + 1) % seg_len == 0
                idx = jnp.clip((n + 1) // seg_len - 1, 0, n_seg - 1)
                picked = jax.tree_util.tree_map(
                    lambda a: a[idx] * jnp.asarray(is_save, a.dtype), ct_ys
                )
                _, vex = jax.vjp(solver.extract, state)
                (inc,) = vex(picked)
                ct_state = tree_add(ct_state, inc)
            if save_at is not None:
                # Forward wrote ys[j] = y_old + frac_j (y_new − y_old) at the
                # saves covered by this step (save_mask is disjoint across
                # steps, so exactly one step injects each save's cotangent);
                # split it into its y_new part (through the post-step state,
                # now) and its y_old part (directly onto the reconstructed
                # state, below).
                t_new = grid.ts[n + 1]
                m = save_mask(save_ts, live, t, t_new, grid.t1, eps_end)
                frac = jnp.clip(
                    (save_ts - t) / jnp.maximum(t_new - t, h_floor), 0.0, 1.0)
                w_new, w_old = m * frac, m * (1.0 - frac)

                def pick(w, c):
                    return jnp.einsum("s,s...->...", w.astype(c.dtype), c)

                _, vex = jax.vjp(solver.extract, state)
                (inc,) = vex(jax.tree_util.tree_map(
                    lambda c: pick(w_new, c), ct_ys))
                ct_state = tree_add(ct_state, inc)
                pick_old = jax.tree_util.tree_map(
                    lambda c: pick(w_old, c), ct_ys)
            # 3. Re-play the step under vjp for exact local cotangents.
            def step_fn(s, a):
                return solver.step(term, s, t, h, dW, a)

            _, vjp = jax.vjp(step_fn, prev, args)
            ct_prev, ct_args_inc = vjp(ct_state)
            if masked:
                ct_prev = tree_select(live, ct_prev, ct_state)
                ct_args_inc = _ct_mask(live, ct_args_inc)
            if pick_old is not None:
                _, vex_prev = jax.vjp(solver.extract, prev)
                (inc_prev,) = vex_prev(pick_old)
                ct_prev = tree_add(ct_prev, inc_prev)
            return (prev, ct_prev, _ct_add(ct_args, ct_args_inc)), None

        if getattr(grid, "is_padded", False):
            # Padding steps were skipped forward (lax.cond in the stepper);
            # skip them backward the same way — the carry passes through
            # untouched, so reconstruction and cotangents see only the live
            # prefix (same batch-uniform predicate, same bitwise guarantee).
            inner_body = body

            def body(carry, n):
                return jax.lax.cond(
                    n < grid.n_active,
                    lambda: inner_body(carry, n),
                    lambda: (carry, None),
                )

        (state0_rec, ct_state0, ct_args), _ = jax.lax.scan(
            body, (state_f, ct_state, ct_args), jnp.arange(n_steps - 1, -1, -1)
        )

        # Back out through `init` (matters for solvers whose init evaluates
        # the vector field, e.g. Reversible Heun).
        y0_rec = solver.extract(state0_rec)

        def init_fn(y, a):
            return solver.init(term, grid.t0, y, a)

        _, vjp0 = jax.vjp(init_fn, y0_rec, args)
        ct_y0, ct_args_inc = vjp0(ct_state0)
        ct_args = _ct_add(ct_args, ct_args_inc)
        if save_at is not None:
            # Save entries no step covered (at/before t0, or past where a
            # budget-exhausted realization stopped) still hold the broadcast
            # initial state — their cotangents flow straight to y0.  Exact
            # complement of the per-step save_mask coverage: the eps slack
            # exists only when the grid actually reached t1.
            t_final = grid.ts[-1]
            slack = jnp.where(t_final >= grid.t1 - eps_end, eps_end, 0.0)
            w0 = (save_ts <= grid.t0) | (save_ts > t_final + slack)
            ct_y0 = jax.tree_util.tree_map(
                lambda cy, c: cy + jnp.einsum(
                    "s,s...->...", w0.astype(c.dtype), c),
                ct_y0, ct_ys)
        # The grid is data: zero cotangents for ts/hs, the driver's key, and
        # the bulk noise buffer.
        return (_float0_like(grid), ct_y0, ct_args, _float0_like(dWs))

    run.defvjp(run_fwd, run_bwd)
    return run(grid, y0, args, dWs)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------

def _maybe_prediffuse(solver, term, y0, grid, args, adjoint, dWs):
    """Additive-noise fast path: hoist the diffusion out of the scan.

    With ``noise="additive"`` the diffusion matrix is independent of ``t``
    and ``y`` (the additive contract — it may still depend on ``args``), so
    ``g * dW[n]`` can be computed for every step in ONE broadcast pass over
    the bulk Brownian buffer instead of re-evaluating ``g`` inside the
    sequential loop.  The substituted :class:`_PrediffusedTerm` then combines
    ``f * h + w`` per step — the same IEEE multiply, hoisted, so results and
    gradients are bitwise-equal to the standard route.

    Excluded cases keep their general route:

    * ``adjoint="reversible"`` — its backward pass returns zero cotangents
      for the noise buffer (it is data), so gradients through a precomputed
      ``g(args) * dW`` buffer would cut the diffusion-parameter cotangents.
    * per-step generation (``dWs is None``) — nothing to hoist over.
    * solvers that read ``term.diffusion`` directly (Milstein, SRK) or
      consume Levy-area pairs — the buffer layout is not a plain increment.
    """
    if (
        dWs is None
        or getattr(term, "noise", None) != "additive"
        or adjoint not in ("full", "recursive")
        or getattr(solver, "needs_levy_area", False)
        or getattr(solver, "needs_diffusion", False)
    ):
        return term, dWs
    g0 = term.diffusion(grid.t0, y0, args)
    ws = jax.tree_util.tree_map(lambda gi, wi: gi * wi, g0, dWs)
    return _PrediffusedTerm(base=term), ws


def solve(
    solver,
    term,
    y0,
    grid,
    args=None,
    *,
    adjoint: str = "full",
    save_every: Optional[int] = None,
    save_at=None,
    remat_chunk: Optional[int] = None,
    bulk_increments: bool = True,
    guard: Optional[float] = None,
) -> SolveResult:
    """Integrate ``term`` over ``grid`` with ``solver`` — THE solve loop.

    Every integration in the repo bottoms out here: fixed uniform grids,
    matched-driver grids over a Virtual Brownian Tree, and adaptively
    realized (non-uniform) grids all run the same scan, under the same three
    adjoints.

    Parameters
    ----------
    solver:
        A solver *object* (``init`` / ``step`` / ``reverse`` / ``extract``)
        — resolve spec strings first with
        :func:`~repro.core.registry.get_solver`, or use
        :func:`~repro.core.sdeint.sdeint`, which owns that plumbing.
    term:
        :class:`~repro.core.solvers.SDETerm` (or a manifold term for CF-EES
        solvers).
    y0:
        Initial state pytree.
    grid:
        A :class:`~repro.core.grid.TimeGrid` — uniform
        (``TimeGrid.uniform(t0, t1, n, driver)``) or realized
        (:func:`~repro.core.adaptive.realize_grid`); a fixed-grid
        :class:`~repro.core.brownian.BrownianPath` is accepted directly and
        wrapped.  Zero-length padding steps of a realized grid are masked to
        no-ops in every adjoint.
    args:
        Passed to the drift/diffusion callables.
    adjoint:
      * ``"full"``       — O(n) memory, exact discrete gradients.
      * ``"recursive"``  — remat at ``remat_chunk`` granularity (default
        ~sqrt(segment)), O(sqrt n) memory.
      * ``"reversible"`` — O(1) memory via reverse reconstruction along the
        grid — uniform or realized alike (the backward sweep replays the
        same ``(t, h[n], dW[n])`` sequence; rejection already happened at
        realization time, so no third register is needed).
    save_every:
        Saves ``extract(state)`` every that many steps (must divide
        ``n_steps``; on a realized grid this counts padded trial slots, so
        prefer ``save_at`` there).  Mutually exclusive with ``save_at``.
    save_at:
        1-D array of output times: dense output linearly interpolated
        between the grid steps covering each time, under every adjoint
        (reversible injects each save cotangent during the backward sweep).
        Entries at or before ``t0`` (or beyond a budget-exhausted grid's
        end) hold ``y0``.
    bulk_increments:
        ``True`` (default): realize every step's Brownian increment in ONE
        batched driver pass before the scan
        (:meth:`~repro.core.grid.TimeGrid.increments` — stacked threefry /
        one batched level-sweep) and stream rows out of the buffer on both
        the forward and the reversible-backward sweeps.  The increments are
        bit-identical to the per-step draws; results and gradients match
        the per-step path to ulp-level (the scan body is a different XLA
        program, so FMA scheduling may differ in the last bit — all
        *within-mode* reproducibility guarantees are exact).  Trades
        O(n_steps x noise_shape) buffer memory for hoisting all RNG out of
        the sequential hot loop.  ``False`` restores per-step generation
        (the pre-PR-4 behavior — e.g. when the noise buffer itself would
        not fit).
    guard:
        Blow-up guard threshold.  When set, the state is checked at every
        save-segment boundary (non-finite entries, or any ``|y| > guard``;
        every ``save_every`` steps, or once at the solve's end when nothing
        is saved) and the OR of those checks is carried through the scan and
        returned as ``SolveResult.diverged`` — a scalar device bool per
        solve (per vmap lane under ``sdeint``), with no host sync.
        Boundary granularity loses nothing: non-finites persist once they
        enter the state and a genuine blow-up stays above any threshold, so
        every divergence the per-step check would flag reaches a boundary —
        while clean traffic pays one extra reduce per segment instead of
        per step (< 5% drain throughput, gated in CI).  ``float('inf')``
        checks non-finiteness only.  The guard is a pure observer: the step
        computation path is untouched, so guarded results are
        bitwise-identical to unguarded ones.  ``None`` (default) disables
        the check (``diverged`` is ``None``).

    Returns
    -------
    :class:`SolveResult` — ``y_final`` (state at the grid's end), ``ys``
    (the saved trajectory: ``(n_steps/save_every, ...)`` or
    ``(len(save_at), ...)``, or ``None``) and ``diverged`` (scalar bool when
    ``guard`` is set, else ``None``).

    Example
    -------
    >>> grid = TimeGrid.uniform(0.0, 1.0, 1000, brownian_path(key, 0.0, 1.0,
    ...                                                       1000, shape=(4,)))
    >>> out = solve(get_solver("ees25"), term, jnp.ones(4), grid, params,
    ...             adjoint="reversible")
    >>> out.y_final.shape
    (4,)
    """
    grid = _as_grid(grid)
    if save_at is not None and save_every is not None:
        raise ValueError("save_every and save_at are mutually exclusive")
    if grid.is_padded and (save_every is not None or save_at is not None):
        raise ValueError(
            "padded-uniform grids (bucketed dispatch) carry no saved "
            "trajectories — save_every/save_at requests must run on an "
            "exact (unpadded) grid"
        )
    if remat_chunk is not None and adjoint != "recursive":
        raise ValueError(
            f"remat_chunk configures the recursive adjoint's checkpoint "
            f"granularity and has no effect under adjoint={adjoint!r} — "
            "drop it or use adjoint='recursive'"
        )
    needs_levy = getattr(solver, "needs_levy_area", False)
    if bulk_increments:
        dWs = grid.levy_increments() if needs_levy else grid.increments()
    else:
        dWs = None
    term, dWs = _maybe_prediffuse(solver, term, y0, grid, args, adjoint, dWs)
    if adjoint == "full":
        return _solve_scan(solver, term, y0, grid, args, save_every, None,
                           save_at, dWs, guard)
    if adjoint == "recursive":
        if remat_chunk is None:
            seg = save_every if save_every is not None else grid.n_steps
            remat_chunk = max(1, int(math.isqrt(seg)))
            while seg % remat_chunk != 0:
                remat_chunk -= 1
        return _solve_scan(solver, term, y0, grid, args, save_every,
                           remat_chunk, save_at, dWs, guard)
    if adjoint == "reversible":
        return _solve_reversible(solver, term, y0, grid, args, save_every,
                                 save_at, dWs, guard)
    raise ValueError(f"unknown adjoint {adjoint!r}")
