"""The three adjoints of the paper.

* **Full** (discretise-then-optimise): plain autodiff through ``lax.scan``;
  exact gradients of the discrete computation, O(n) activation memory.
* **Recursive** (checkpointed): segments of the scan are rematerialised
  (``jax.checkpoint``), giving the O(sqrt(n)) memory/compute trade.
* **Reversible**: O(1) memory.  The backward pass *reconstructs* the forward
  trajectory with the solver's algebraic reverse step (exact for Reversible
  Heun / MCF; O(h^{m+1})-accurate for EES(2,m)) and re-plays each step under
  ``jax.vjp`` — Algorithm 1 of the paper (and, composed with the CF-EES step
  on a manifold, Algorithm 2: the stage adjoints live on the cotangent bundle
  automatically because every group action is an ordinary JAX computation).

All three share one calling convention built around segments of
``save_every`` steps, so the saved trajectory is identical bitwise across
adjoints (the solver steps are the same computation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .brownian import BrownianPath
from .solvers import tree_add, tree_scale

__all__ = ["SolveResult", "solve"]


class SolveResult(NamedTuple):
    y_final: Any
    ys: Any  # (n_saves, ...) pytree of saved states, or None


def _float0_like(tree):
    """Zero cotangents for a pytree that may contain non-inexact leaves."""

    def z(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)

    return jax.tree_util.tree_map(z, tree)


def _ct_add(a, b):
    def add(x, y):
        if hasattr(x, "dtype") and x.dtype == jax.dtypes.float0:
            return x
        return x + y

    return jax.tree_util.tree_map(add, a, b)


def _segment_counts(n_steps: int, save_every: Optional[int]):
    if save_every is None:
        return 1, n_steps
    if n_steps % save_every != 0:
        raise ValueError(f"n_steps={n_steps} not divisible by save_every={save_every}")
    return n_steps // save_every, save_every


# ---------------------------------------------------------------------------
# Full & recursive adjoints: scan-of-scans, optionally rematerialised.
# ---------------------------------------------------------------------------

def _solve_scan(solver, term, y0, bm: BrownianPath, args, save_every, remat_chunk):
    n_seg, seg_len = _segment_counts(bm.n_steps, save_every)
    h = bm.h

    def one_step(state, n):
        return (
            solver.step(term, state, bm.t_of(n), h, bm.increment(n), args),
            None,
        )

    if remat_chunk is None:
        def segment(state, n0):
            state, _ = jax.lax.scan(one_step, state, n0 + jnp.arange(seg_len))
            return state, (solver.extract(state) if save_every else None)
    else:
        if seg_len % remat_chunk != 0:
            raise ValueError("segment length must be divisible by remat_chunk")

        @jax.checkpoint
        def chunk(state, c0):
            state, _ = jax.lax.scan(one_step, state, c0 + jnp.arange(remat_chunk))
            return state, None

        def segment(state, n0):
            state, _ = jax.lax.scan(
                chunk, state, n0 + remat_chunk * jnp.arange(seg_len // remat_chunk)
            )
            return state, (solver.extract(state) if save_every else None)

    state0 = solver.init(term, bm.t0, y0, args)
    starts = seg_len * jnp.arange(n_seg)
    state_f, ys = jax.lax.scan(segment, state0, starts)
    return SolveResult(solver.extract(state_f), ys if save_every else None)


# ---------------------------------------------------------------------------
# Reversible adjoint (Algorithm 1 / 2).
# ---------------------------------------------------------------------------

def _solve_reversible(solver, term, y0, bm: BrownianPath, args, save_every):
    n_steps = bm.n_steps
    n_seg, seg_len = _segment_counts(n_steps, save_every)
    h = bm.h
    bm_static = dataclasses.replace(bm, key=None)  # template; key passed explicitly

    def forward(key, y0, args):
        b = dataclasses.replace(bm_static, key=key)

        def one_step(state, n):
            return solver.step(term, state, b.t_of(n), h, b.increment(n), args), None

        def segment(state, n0):
            state, _ = jax.lax.scan(one_step, state, n0 + jnp.arange(seg_len))
            return state, (solver.extract(state) if save_every else None)

        state0 = solver.init(term, b.t0, y0, args)
        state_f, ys = jax.lax.scan(segment, state0, seg_len * jnp.arange(n_seg))
        return state_f, (ys if save_every else None)

    @jax.custom_vjp
    def run(key, y0, args):
        state_f, ys = forward(key, y0, args)
        return SolveResult(solver.extract(state_f), ys)

    def run_fwd(key, y0, args):
        state_f, ys = forward(key, y0, args)
        return SolveResult(solver.extract(state_f), ys), (key, state_f, y0, args)

    def run_bwd(res, ct):
        key, state_f, y0, args = res
        ct_yf, ct_ys = ct.y_final, ct.ys
        b = dataclasses.replace(bm_static, key=key)

        # Inject the terminal cotangent through `extract`.
        _, vjp_ex = jax.vjp(solver.extract, state_f)
        (ct_state,) = vjp_ex(ct_yf)
        ct_args = _float0_like(args)

        def body(carry, n):
            state, ct_state, ct_args = carry
            t = b.t_of(n)
            dW = b.increment(n)
            # 1. Reconstruct the pre-step state (O(h^{m+1}) drift for EES;
            #    exact for algebraically reversible solvers).
            prev = solver.reverse(term, state, t, h, dW, args)
            # 2. If step n produced a saved output, add its cotangent now.
            if save_every is not None:
                is_save = (n + 1) % seg_len == 0
                idx = jnp.clip((n + 1) // seg_len - 1, 0, n_seg - 1)
                picked = jax.tree_util.tree_map(
                    lambda a: a[idx] * jnp.asarray(is_save, a.dtype), ct_ys
                )
                _, vex = jax.vjp(solver.extract, state)
                (inc,) = vex(picked)
                ct_state = tree_add(ct_state, inc)
            # 3. Re-play the step under vjp for exact local cotangents.
            def step_fn(s, a):
                return solver.step(term, s, t, h, dW, a)

            _, vjp = jax.vjp(step_fn, prev, args)
            ct_prev, ct_args_inc = vjp(ct_state)
            return (prev, ct_prev, _ct_add(ct_args, ct_args_inc)), None

        (state0_rec, ct_state0, ct_args), _ = jax.lax.scan(
            body, (state_f, ct_state, ct_args), jnp.arange(n_steps - 1, -1, -1)
        )

        # Back out through `init` (matters for solvers whose init evaluates
        # the vector field, e.g. Reversible Heun).
        y0_rec = solver.extract(state0_rec)

        def init_fn(y, a):
            return solver.init(term, b.t0, y, a)

        _, vjp0 = jax.vjp(init_fn, y0_rec, args)
        ct_y0, ct_args_inc = vjp0(ct_state0)
        ct_args = _ct_add(ct_args, ct_args_inc)
        ct_key = np.zeros(jnp.shape(key), dtype=jax.dtypes.float0)
        return (ct_key, ct_y0, ct_args)

    run.defvjp(run_fwd, run_bwd)
    return run(bm.key, y0, args)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------

def solve(
    solver,
    term,
    y0,
    bm: BrownianPath,
    args=None,
    *,
    adjoint: str = "full",
    save_every: Optional[int] = None,
    remat_chunk: Optional[int] = None,
) -> SolveResult:
    """Integrate ``term`` over the Brownian grid of ``bm`` with ``solver``.

    Parameters
    ----------
    solver:
        A solver *object* (``init`` / ``step`` / ``reverse`` / ``extract``)
        — resolve spec strings first with
        :func:`~repro.core.registry.get_solver`, or use
        :func:`~repro.core.sdeint.sdeint`, which owns that plumbing.
    term:
        :class:`~repro.core.solvers.SDETerm` (or a manifold term for CF-EES
        solvers).
    y0:
        Initial state pytree.
    bm:
        A fixed-grid :class:`~repro.core.brownian.BrownianPath`; its
        ``n_steps`` / span define the integration grid.
    args:
        Passed to the drift/diffusion callables.
    adjoint:
      * ``"full"``       — O(n) memory, exact discrete gradients.
      * ``"recursive"``  — remat at ``remat_chunk`` granularity (default
        ~sqrt(segment)), O(sqrt n) memory.
      * ``"reversible"`` — O(1) memory via reverse reconstruction.
    save_every:
        Saves ``extract(state)`` every that many steps (must divide
        ``n_steps``); the saved trajectory participates in autodiff under
        every adjoint mode.

    Returns
    -------
    :class:`SolveResult` — ``y_final`` (state at ``t1``) and ``ys`` (the
    ``(n_steps/save_every, ...)`` saved trajectory, or ``None``).

    Example
    -------
    >>> bm = brownian_path(key, 0.0, 1.0, 1000, shape=(4,))
    >>> out = solve(get_solver("ees25"), term, jnp.ones(4), bm, params,
    ...             adjoint="reversible")
    >>> out.y_final.shape
    (4,)
    """
    if adjoint == "full":
        return _solve_scan(solver, term, y0, bm, args, save_every, None)
    if adjoint == "recursive":
        if remat_chunk is None:
            seg = save_every if save_every is not None else bm.n_steps
            remat_chunk = max(1, int(math.isqrt(seg)))
            while seg % remat_chunk != 0:
                remat_chunk -= 1
        return _solve_scan(solver, term, y0, bm, args, save_every, remat_chunk)
    if adjoint == "reversible":
        return _solve_reversible(solver, term, y0, bm, args, save_every)
    raise ValueError(f"unknown adjoint {adjoint!r}")
