"""Lie groups, homogeneous spaces, and manifold-valued SDE terms.

A homogeneous space is represented by a :class:`Group` object supplying the
composed map ``exp_action(v, y) = Lambda(exp(v), y)`` for an algebra element
``v`` and a point ``y``.  Vector fields are specified through state-dependent
generators ``xi: (t, y, args) -> g`` (Section C.1).  On a flat space
(:class:`Euclidean`) ``exp_action(v, y) = y + v`` and every geometric scheme
collapses to its Euclidean counterpart — this is tested.

Points and algebra elements are pytrees; :class:`Product` combines groups
componentwise (e.g. ``T*T^N = Torus x Euclidean`` for the Kuramoto model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .pytree import tree_scale

__all__ = [
    "Group",
    "Euclidean",
    "Torus",
    "SO3",
    "SOn",
    "SphereAction",
    "Product",
    "ManifoldSDETerm",
    "skew_from_vec",
    "vec_from_skew",
    "rodrigues",
]

TWO_PI = 2.0 * jnp.pi


class Group:
    """Interface: ``exp_action(v, y)`` and a manifold-membership check."""

    name = "group"

    def exp_action(self, v, y):
        raise NotImplementedError

    def project(self, y):
        """Optional numerical re-projection onto the manifold (default: identity)."""
        return y

    def distance_from_manifold(self, y):
        """Scalar diagnostic: 0 iff y is on the manifold."""
        return jnp.zeros(())


class Euclidean(Group):
    """Translation group acting on R^d — the flat case."""

    name = "euclidean"

    def exp_action(self, v, y):
        return jax.tree_util.tree_map(jnp.add, y, v)


class Torus(Group):
    """T^d with angles stored in [-pi, pi).  exp_action wraps the translation.

    ``round`` has zero derivative, so gradients flow through the wrap as the
    identity — the correct chart derivative.
    """

    name = "torus"

    @staticmethod
    def wrap(x):
        return x - TWO_PI * jnp.round(x / TWO_PI)

    def exp_action(self, v, y):
        return jax.tree_util.tree_map(lambda yi, vi: self.wrap(yi + vi), y, v)

    def project(self, y):
        return jax.tree_util.tree_map(self.wrap, y)

    def distance_from_manifold(self, y):
        over = jax.tree_util.tree_map(
            lambda x: jnp.maximum(jnp.abs(x) - jnp.pi, 0.0).sum(), y
        )
        return sum(jax.tree_util.tree_leaves(over))


def skew_from_vec(w):
    """(..., 3) axis-angle vector -> (..., 3, 3) skew matrix."""
    wx, wy, wz = w[..., 0], w[..., 1], w[..., 2]
    zero = jnp.zeros_like(wx)
    return jnp.stack(
        [
            jnp.stack([zero, -wz, wy], axis=-1),
            jnp.stack([wz, zero, -wx], axis=-1),
            jnp.stack([-wy, wx, zero], axis=-1),
        ],
        axis=-2,
    )


def vec_from_skew(S):
    return jnp.stack([S[..., 2, 1], S[..., 0, 2], S[..., 1, 0]], axis=-1)


def rodrigues(w):
    """exp of so(3) via Rodrigues, numerically safe at theta -> 0.

    R = I + sinc(theta) K + (1 - cos theta)/theta^2 K^2 with K = skew(w).
    """
    theta2 = jnp.sum(w * w, axis=-1)
    theta = jnp.sqrt(theta2 + 1e-30)
    small = theta2 < 1e-8
    s = jnp.where(small, 1.0 - theta2 / 6.0, jnp.sin(theta) / theta)
    c = jnp.where(small, 0.5 - theta2 / 24.0, (1.0 - jnp.cos(theta)) / (theta2 + 1e-30))
    K = skew_from_vec(w)
    K2 = K @ K
    eye = jnp.broadcast_to(jnp.eye(3, dtype=w.dtype), K.shape)
    return eye + s[..., None, None] * K + c[..., None, None] * K2


class SO3(Group):
    """SO(3) acting on itself by left translation; algebra = axis-angle vectors."""

    name = "so3"

    def exp_action(self, v, y):
        return rodrigues(v) @ y

    def project(self, y):
        # Polar projection via Gram-Schmidt-free symmetric orthogonalisation.
        u, _, vt = jnp.linalg.svd(y)
        return u @ vt

    def distance_from_manifold(self, y):
        eye = jnp.eye(3, dtype=y.dtype)
        return jnp.max(jnp.abs(jnp.swapaxes(y, -1, -2) @ y - eye))


class SOn(Group):
    """SO(n) by left translation; algebra = (..., n, n) skew matrices."""

    name = "son"

    def __init__(self, n: int):
        self.n = n

    def exp_action(self, v, y):
        return jax.scipy.linalg.expm(v) @ y

    def distance_from_manifold(self, y):
        eye = jnp.eye(self.n, dtype=y.dtype)
        return jnp.max(jnp.abs(jnp.swapaxes(y, -1, -2) @ y - eye))


class SphereAction(Group):
    """S^{n-1} = SO(n)/SO(n-1): points are unit vectors (..., n), the algebra
    is so(n), and the action is ``y -> expm(V) y``.

    When the generator has rank-2 form ``V = a y^T - y a^T`` with ``a _|_ y``
    the exponential has the closed Rodrigues-like form used in tests; here we
    apply the generic matrix exponential so *any* so(n) generator is valid
    (isotropy components act trivially: Example C.1).
    """

    name = "sphere"

    def __init__(self, n: int):
        self.n = n

    def exp_action(self, v, y):
        return jnp.einsum("...ij,...j->...i", jax.scipy.linalg.expm(v), y)

    def project(self, y):
        return y / jnp.linalg.norm(y, axis=-1, keepdims=True)

    def distance_from_manifold(self, y):
        return jnp.max(jnp.abs(jnp.sum(y * y, axis=-1) - 1.0))


class Product(Group):
    """Direct product acting componentwise on tuples of points/algebra elems."""

    name = "product"

    def __init__(self, groups: Sequence[Group]):
        self.groups = tuple(groups)

    def exp_action(self, v, y):
        return tuple(g.exp_action(vi, yi) for g, vi, yi in zip(self.groups, v, y))

    def project(self, y):
        return tuple(g.project(yi) for g, yi in zip(self.groups, y))

    def distance_from_manifold(self, y):
        return sum(g.distance_from_manifold(yi) for g, yi in zip(self.groups, y))


# ---------------------------------------------------------------------------
# Manifold SDE term.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ManifoldSDETerm:
    """SDE on a homogeneous space: dy = (xi_f(y) dt + xi_g(y) . dW)_M.

    ``drift``/``diffusion`` return Lie-algebra elements (pytrees).  With
    ``noise='diagonal'`` the diffusion output is multiplied elementwise by a
    same-shaped ``dW``; ``noise_apply`` overrides that pairing (e.g. mapping an
    m-vector of noises onto a basis of so(n)).
    """

    group: Group
    drift: Callable[..., Any]
    diffusion: Optional[Callable[..., Any]] = None
    noise: str = "diagonal"
    noise_apply: Optional[Callable[[Any, Any], Any]] = None

    def algebra_increment(self, t, y, args, h, dW):
        out = tree_scale(h, self.drift(t, y, args))
        if self.noise == "none" or self.diffusion is None:
            return out
        g = self.diffusion(t, y, args)
        if self.noise_apply is not None:
            noise_part = self.noise_apply(g, dW)
            return jax.tree_util.tree_map(jnp.add, out, noise_part)
        return jax.tree_util.tree_map(lambda o, gi, wi: o + gi * wi, out, g, dW)
