"""String-keyed solver registry: solvers selectable from configs and requests.

Every entry point that integrates an SDE — benchmarks, training configs,
serving requests, CLI flags — names its solver by a *spec string* instead of
constructing solver objects by hand::

    get_solver("ees25")                 # canonical EES(2,5; x=1/10)
    get_solver("ees25:x=0.3")           # the one-parameter family member
    get_solver("ees27")
    get_solver("reversible_heun")
    get_solver("mcf-rk4")               # reversible coupling of RK4
    get_solver("mcf-midpoint:lam=0.99")
    get_solver("euler"), ("heun"), ("midpoint"), ("rk3"), ("rk4"), ...

A spec is ``name`` or ``name:key=val,key=val`` — the kwargs are passed to the
registered factory, so any tunable of the underlying solver (the EES family
parameter ``x``, the MCF contraction ``lam``, the fused-kernel toggle
``use_kernels``) is reachable from a plain string.  A bare word in the kwarg
tail is a boolean flag (``"ees25:adaptive"`` == ``"ees25:adaptive=True"``).
``get_solver`` is idempotent on non-strings: passing an already-constructed
solver object returns it unchanged, so APIs can accept either form.

``adaptive`` is a *mode flag*, not a factory kwarg: ``get_solver`` strips it
and marks the returned solver (``solver.adaptive == True``), which
:func:`repro.core.sdeint.sdeint` reads to realize an accepted-step grid first
(:func:`repro.core.adaptive.realize_grid`) and run the unified
:func:`repro.core.adjoint.solve` over it — under any adjoint, reversible
included — instead of a uniform grid.
"""
from __future__ import annotations

import ast
import inspect
from typing import Any, Callable, Dict, Optional, Tuple

from . import tableaux
from .solvers import (
    ButcherSolver,
    MCFSolver,
    Milstein,
    ReversibleHeun,
    SRKAdditive,
    ees25_solver,
    ees27_solver,
)

__all__ = ["register_solver", "get_solver", "list_solvers", "parse_solver_spec",
           "canonical_spec", "solver_kind", "select_solver"]


_REGISTRY: Dict[str, Tuple[Callable[..., Any], str]] = {}


def register_solver(name: str, factory: Optional[Callable[..., Any]] = None,
                    *, kind: str = "euclidean"):
    """Register ``factory`` under ``name`` (usable as a decorator).

    The factory is called with the kwargs parsed from the spec string; it must
    return an object with the solver interface (init/step/reverse/extract).
    ``kind`` declares which term type the solver integrates — ``"euclidean"``
    (:class:`~repro.core.solvers.SDETerm`) or ``"manifold"``
    (:class:`~repro.core.lie.ManifoldSDETerm`).  Re-registering an existing
    name overwrites it (latest wins), so user code can shadow built-ins.
    """
    key = _canon(name)

    def deco(f):
        _REGISTRY[key] = (f, kind)
        return f

    if factory is not None:
        return deco(factory)
    return deco


def list_solvers(kind: Optional[str] = None) -> Tuple[str, ...]:
    """Registered solver names, sorted.

    ``kind`` filters to ``"euclidean"`` or ``"manifold"`` entries.

    >>> "ees25" in list_solvers(kind="euclidean")
    True
    """
    return tuple(sorted(
        n for n, (_, k) in _REGISTRY.items() if kind is None or k == kind
    ))


def _canon(name: str) -> str:
    return name.strip().lower().replace("_", "-")


def _parse_value(text: str):
    try:
        return ast.literal_eval(text)
    except (ValueError, SyntaxError):
        return text  # bare strings, e.g. "mode=fast"


def parse_solver_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    """Split ``"name:k=v,k2=v2"`` into ``(name, kwargs)``.

    A bare identifier in the tail is a boolean flag: ``"ees25:adaptive"``
    parses to ``("ees25", {"adaptive": True})``.  Anything else without an
    ``=`` (e.g. a stray number) is malformed.

    >>> parse_solver_spec("MCF-RK4: lam=0.99")
    ('mcf-rk4', {'lam': 0.99})
    """
    name, _, tail = spec.partition(":")
    kwargs: Dict[str, Any] = {}
    if tail:
        for item in tail.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                if item.isidentifier():
                    kwargs[item] = True  # bare flag, e.g. "ees25:adaptive"
                    continue
                raise ValueError(
                    f"malformed solver spec {spec!r}: expected key=value or a "
                    f"bare flag, got {item!r}"
                )
            k, _, v = item.partition("=")
            kwargs[k.strip()] = _parse_value(v.strip())
    return _canon(name), kwargs


def _lookup(name: str) -> Tuple[Callable[..., Any], str]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; registered: {', '.join(list_solvers())}"
        ) from None


def canonical_spec(spec: str) -> str:
    """Normal form of a spec string: canonical name, sorted repr'd kwargs.

    Equivalent spellings (``"reversible_heun"`` / ``"Reversible-Heun"``,
    kwarg order) map to one string, so caches keyed on specs don't split.
    Raises ``KeyError`` for unregistered names.

    >>> canonical_spec("Reversible_Heun")
    'reversible-heun'
    >>> canonical_spec("ees25: adaptive, x=0.3")
    'ees25:adaptive=True,x=0.3'
    """
    name, kwargs = parse_solver_spec(spec)
    _lookup(name)
    if not kwargs:
        return name
    return name + ":" + ",".join(f"{k}={kwargs[k]!r}" for k in sorted(kwargs))


def solver_kind(spec: str) -> str:
    """The registered kind ("euclidean" | "manifold") of a spec's solver."""
    name, _ = parse_solver_spec(spec)
    return _lookup(name)[1]


def _check_spec_keys(name: str, factory: Callable[..., Any],
                     kwargs: Dict[str, Any]) -> None:
    """Reject unknown spec kwargs up front, naming the offending key.

    Without this, a typo'd flag key (``"ees25:use_kernel s=True"``,
    ``"milstein:from=ito"``) dies inside the factory call with a bare
    ``TypeError`` — here it fails at parse/resolve time with the valid keys
    listed.  Factories taking ``**kwargs`` opt out (they accept anything).
    """
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):  # pragma: no cover — builtins/C factories
        return
    params = sig.parameters.values()
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return
    valid = {p.name for p in params
             if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                           inspect.Parameter.KEYWORD_ONLY)}
    for key in kwargs:
        if key not in valid:
            raise ValueError(
                f"unknown option {key!r} for solver {name!r}; valid keys: "
                + (", ".join(sorted(valid) + ["adaptive"]))
            )


def get_solver(spec, **overrides):
    """Resolve a solver spec string (or pass a solver object through).

    Parameters
    ----------
    spec:
        Registry spec string (``"ees25"``, ``"ees25:x=0.3"``,
        ``"ees25:adaptive"``, ``"mcf-rk4:lam=0.99"``) or an
        already-constructed solver object (returned unchanged).
    overrides:
        Take precedence over kwargs parsed from the spec, so programmatic
        callers can pin e.g. ``use_kernels=True`` regardless of what the
        config string says.

    Returns
    -------
    A solver object (``init`` / ``step`` / ``reverse`` / ``extract``).  The
    ``adaptive`` flag is not passed to the factory; it marks the returned
    object (``solver.adaptive = True``) so :func:`repro.core.sdeint.sdeint`
    routes the solve through grid realization (realize-then-solve).

    Example
    -------
    >>> get_solver("ees25:x=0.3").ls.name
    'EES(2,5;0.3)-2N'
    """
    if not isinstance(spec, str):
        if overrides:
            raise ValueError(
                "overrides only apply to spec strings; got an already-"
                f"constructed solver {spec!r} with overrides {overrides}"
            )
        return spec  # already a solver object
    name, kwargs = parse_solver_spec(spec)
    factory, _ = _lookup(name)
    kwargs.update(overrides)
    adaptive = bool(kwargs.pop("adaptive", False))
    _check_spec_keys(name, factory, kwargs)
    solver = factory(**kwargs)
    if adaptive:
        try:
            solver.adaptive = True
        except AttributeError:
            raise ValueError(
                f"solver {name!r} does not support the adaptive flag"
            ) from None
    return solver


# ---------------------------------------------------------------------------
# Built-in entries.
# ---------------------------------------------------------------------------

register_solver("ees25", ees25_solver)
register_solver("ees27", ees27_solver)
register_solver("reversible-heun",
                lambda use_kernels=False: ReversibleHeun(use_kernels=use_kernels))


def _butcher_factory(tab):
    return lambda use_kernels=False: ButcherSolver(tab, use_kernels=use_kernels)


def _mcf_factory(tab):
    return lambda lam=0.999, use_kernels=False: MCFSolver(
        tab, lam=lam, use_kernels=use_kernels)


for _tab in (tableaux.euler, tableaux.midpoint, tableaux.heun,
             tableaux.ralston3, tableaux.rk3, tableaux.rk4):
    register_solver(_tab.name, _butcher_factory(_tab))
    register_solver(f"mcf-{_tab.name}", _mcf_factory(_tab))


def _ees25_butcher(x: float = 0.1):
    return ButcherSolver(tableaux.ees25_tableau(x))


register_solver("ees25-butcher", _ees25_butcher)
register_solver("ees27-butcher", lambda: ButcherSolver(tableaux.ees27_tableau()))


# -- noise-specialized schemes (PR 7) ----------------------------------------

def _milstein_factory(form):
    return lambda use_kernels=False: Milstein(form=form, use_kernels=use_kernels)


register_solver("milstein", _milstein_factory("ito"))
register_solver("strat-milstein", _milstein_factory("stratonovich"))
register_solver("srk", lambda noise="additive": SRKAdditive(noise=noise))


def select_solver(noise: str = "diagonal", stiffness: float = 0.0,
                  dt: Optional[float] = None) -> str:
    """Auto-select a registry spec from the request's noise/stiffness profile.

    The decision is by the *stability margin* ``z = |stiffness| * dt`` (how
    far a real-axis eigenvalue pushes one step into the stability region)
    first, then by noise structure:

    * ``z > 2.8`` — near/past EES25's real-axis limit (~3.2): ``"ees27"``,
      whose longer 2N sweep buys the larger region.
    * ``z > 1.0`` — stiffness-dominated but within range: ``"ees25"``.
      (Reversible Heun is never auto-selected for stiff drift: its stability
      region is the imaginary segment [-i, i] — Theorem 2.1 — so *any* real
      negative eigenvalue is unstable at any step size.)
    * otherwise — noise-specialized: ``"srk:noise=additive"`` (strong order
      1.5) for additive noise, ``"milstein"`` (strong order 1) for diagonal
      or scalar noise, ``"ees25"`` for everything else (none/general).

    Returns a spec string — resolve it with :func:`get_solver`; the serving
    engine calls this for ``"auto"`` request specs.

    >>> select_solver(noise="additive", stiffness=0.5, dt=0.01)
    'srk:noise=additive'
    >>> select_solver(noise="diagonal", stiffness=100.0, dt=0.05)
    'ees27'
    """
    if noise not in ("none", "diagonal", "additive", "scalar", "general"):
        raise ValueError(
            f"unknown noise mode {noise!r} for select_solver; valid modes: "
            "'none', 'diagonal', 'additive', 'scalar', 'general'"
        )
    z = abs(float(stiffness)) * float(dt) if dt is not None else 0.0
    if z > 2.8:
        return "ees27"
    if z > 1.0:
        return "ees25"
    if noise == "additive":
        return "srk:noise=additive"
    if noise in ("diagonal", "scalar"):
        return "milstein"
    return "ees25"


def _register_manifold():
    # Imported here (not at module top) only to dodge an import cycle:
    # cfees -> solvers would clash with solvers -> registry if registry ever
    # grows a solvers-side hook.  It runs eagerly at import time below.
    from .cfees import CrouchGrossman2, GeoEulerMaruyama, RKMK2, cfees25_solver, cfees27_solver

    register_solver("cfees25", cfees25_solver, kind="manifold")
    register_solver("cfees27", cfees27_solver, kind="manifold")
    register_solver("geo-em", lambda: GeoEulerMaruyama(), kind="manifold")
    register_solver("cg2", lambda: CrouchGrossman2(), kind="manifold")
    register_solver("rkmk2", lambda: RKMK2(), kind="manifold")


_register_manifold()
