"""repro.core — EES schemes for SDEs on Lie groups (the paper's contribution).

Public surface:
  tableaux   — Butcher tableaux (EES(2,5;x), EES(2,7), classical RK)
  williamson — Williamson 2N coefficients + Bazavov conditions
  brownian   — counter-based Brownian drivers (fixed grid + Virtual Brownian Tree)
  grid       — the realized-grid abstraction (TimeGrid: uniform or adaptive)
  pytree     — shared pytree linear algebra + solver-spec resolution
  solvers    — Euclidean SDE solvers (EES Butcher/2N, Reversible Heun, MCF)
  adjoint    — ONE solve() over any TimeGrid, under all three adjoints
  adaptive   — PI accept/reject grid realization + save_at dense output
  registry   — string-keyed solver registry ("ees25", "ees25:adaptive", ...)
  sdeint     — batched Monte-Carlo integration (vmap/shard_map fan-out)
  lie        — groups & homogeneous spaces (Torus, SO(3)/SO(n), S^{n-1}, products)
  cfees      — CF-EES and geometric baselines (GeoEM, CG2, RKMK2)
  stability  — linear & mean-square stability analysis
"""
from .adaptive import AdaptiveResult, RealizedGrid, integrate_adaptive, realize_grid
from .adjoint import SolveResult, solve
from .brownian import (
    BrownianPath,
    PaddedBrownianPath,
    VirtualBrownianTree,
    brownian_path,
    padded_brownian_path,
    virtual_brownian_tree,
)
from .grid import TimeGrid
from .registry import (
    canonical_spec,
    get_solver,
    list_solvers,
    parse_solver_spec,
    register_solver,
    select_solver,
    solver_kind,
)
from .sdeint import path_keys, sdeint, sdeint_ticks
from .cfees import (
    CFLowStorageSolver,
    CrouchGrossman2,
    GeoEulerMaruyama,
    RKMK2,
    cfees25_solver,
    cfees27_solver,
)
from .lie import (
    Euclidean,
    Group,
    ManifoldSDETerm,
    Product,
    SO3,
    SOn,
    SphereAction,
    Torus,
)
from .solvers import (
    VALID_NOISE,
    ButcherSolver,
    LowStorageSolver,
    MCFSolver,
    Milstein,
    ReversibleHeun,
    SDETerm,
    SRKAdditive,
    ees25_solver,
    ees27_solver,
)
from .tableaux import ees25, ees25_tableau, ees27_tableau, euler, heun, midpoint, rk3, rk4
from .williamson import EES25_2N, EES27_2N, bazavov_residuals, butcher_from_2n, ees25_2n

__all__ = [
    "solve",
    "path_keys",
    "sdeint",
    "sdeint_ticks",
    "SolveResult",
    "get_solver",
    "list_solvers",
    "parse_solver_spec",
    "register_solver",
    "canonical_spec",
    "solver_kind",
    "select_solver",
    "BrownianPath",
    "brownian_path",
    "PaddedBrownianPath",
    "padded_brownian_path",
    "VirtualBrownianTree",
    "virtual_brownian_tree",
    "TimeGrid",
    "AdaptiveResult",
    "RealizedGrid",
    "integrate_adaptive",
    "realize_grid",
    "SDETerm",
    "VALID_NOISE",
    "ButcherSolver",
    "LowStorageSolver",
    "ReversibleHeun",
    "MCFSolver",
    "Milstein",
    "SRKAdditive",
    "ees25_solver",
    "ees27_solver",
    "ManifoldSDETerm",
    "Group",
    "Euclidean",
    "Torus",
    "SO3",
    "SOn",
    "SphereAction",
    "Product",
    "CFLowStorageSolver",
    "GeoEulerMaruyama",
    "CrouchGrossman2",
    "RKMK2",
    "cfees25_solver",
    "cfees27_solver",
    "ees25",
    "ees25_tableau",
    "ees27_tableau",
    "euler",
    "heun",
    "midpoint",
    "rk3",
    "rk4",
    "EES25_2N",
    "EES27_2N",
    "ees25_2n",
    "bazavov_residuals",
    "butcher_from_2n",
]
