"""Pure-jnp oracle: materialised causal GQA attention."""
import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """q: (b, hq, sq, d); k, v: (b, hk, sk, d); returns (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    group = hq // hk
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
