"""Dispatching wrapper: Pallas flash kernel on TPU, reference path elsewhere.

The LM substrate calls :func:`attention`; the dry-run (CPU host, fake TPU
device count) and smoke tests take the reference path, a real TPU deployment
takes the kernel.  Both compute the same function (tested in interpret mode).
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def attention(q, k, v, *, causal=True, sm_scale=None, use_kernel: str = "auto"):
    """use_kernel: 'auto' (TPU backend only), 'never', 'interpret' (tests)."""
    if use_kernel == "interpret":
        return flash_attention(q, k, v, causal=causal, sm_scale=sm_scale, interpret=True)
    if use_kernel == "auto" and jax.default_backend() == "tpu":
        return flash_attention(q, k, v, causal=causal, sm_scale=sm_scale)
    return attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
