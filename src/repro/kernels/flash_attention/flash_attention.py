"""Causal GQA flash attention (TPU Pallas).

Online-softmax attention tiled for VMEM: the grid is
``(batch, q_heads, q_blocks, kv_blocks)`` with the kv axis innermost, so the
running (m, l, acc) statistics live in VMEM scratch across kv iterations and
each Q/K/V tile is loaded exactly once per (q-block, kv-block) pair.  GQA is
free: the K/V index maps divide the query-head index by the group size, so
grouped heads re-read the same KV tile (which XLA keeps resident — the tile
index is unchanged across group members).

Block sizes default to (128, 128): MXU-aligned, and 4 tiles of
128 x head_dim x 4B comfortably fit the ~16 MiB v5e VMEM for head_dim <= 256.

Fully-masked kv blocks (ik * bk > last row of the q block) skip the matmul
entirely — for causal attention that halves the FLOPs, matching the
cost_analysis numbers used in the roofline.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    block_q: int,
    block_k: int,
    sm_scale: float,
    causal: bool,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal block-level skip: the first row of this q block is iq*block_q; the
    # kv block is entirely in the future iff ik*block_k > iq*block_q + block_q - 1.
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    if causal:
        not_fully_masked = ik * block_k <= iq * block_q + block_q - 1
        pl.when(not_fully_masked)(compute)
    else:
        compute()

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (non-causal edge)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (batch, q_heads, seq_q, head_dim)
    k: jax.Array,  # (batch, kv_heads, seq_k, head_dim)
    v: jax.Array,  # (batch, kv_heads, seq_k, head_dim)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    batch, q_heads, seq_q, d = q.shape
    _, kv_heads, seq_k, _ = k.shape
    assert q_heads % kv_heads == 0
    group = q_heads // kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    assert seq_q % block_q == 0 and seq_k % block_k == 0

    grid = (batch, q_heads, seq_q // block_q, seq_k // block_k)
    kernel = functools.partial(_kernel, block_q, block_k, sm_scale, causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # (m, l) replicated across the lane dim for alignment; acc in f32.
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
