"""Pure-jnp oracle for the fused Williamson 2N update."""
import jax.numpy as jnp


def williamson2n_ref(delta, k, y, a: float, b: float):
    d2 = a * delta + k
    y2 = y + b * d2
    return d2, y2
