"""Fused Williamson 2N update kernel (TPU Pallas).

One EES stage is two chained AXPYs::

    delta' = a * delta + k
    y'     = y + b * delta'

Unfused, XLA materialises delta' between the two ops: 5 HBM reads + 2 writes
per element in the worst case.  Fused, each element is read once from each of
(delta, k, y) and written once to each of (delta', y'): 3 reads + 2 writes —
the bandwidth floor for this update.  The solver loop is HBM-bound for the
large-state NSDEs the paper targets (e.g. 192-atom MD: state 1152 floats x
batch), so this is the paper's compute hot-spot on TPU.

The kernel is shape-agnostic: ops.py flattens the state, pads to a multiple of
the (8, 128)-aligned tile, and reshapes to (rows, 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8


def _kernel(a: float, b: float, delta_ref, k_ref, y_ref, dout_ref, yout_ref):
    d2 = a * delta_ref[...] + k_ref[...]
    dout_ref[...] = d2
    yout_ref[...] = y_ref[...] + b * d2


@functools.partial(jax.jit, static_argnames=("a", "b", "block_rows", "interpret"))
def williamson2n_2d(
    delta: jax.Array,
    k: jax.Array,
    y: jax.Array,
    *,
    a: float,
    b: float,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Fused update on 2D (rows, LANE) arrays; rows must divide into blocks."""
    rows, lane = delta.shape
    assert lane == LANE, f"lane dim must be {LANE}, got {lane}"
    # ops.py pads to the (8, 128) tile, so rows is a multiple of 8 but not
    # necessarily of block_rows: shrink to the largest common divisor.
    block_rows = math.gcd(min(block_rows, rows), rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, a, b),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
            jax.ShapeDtypeStruct(y.shape, y.dtype),
        ],
        interpret=interpret,
    )(delta, k, y)
