"""Jitted wrapper for the fused 2N update: arbitrary-shape states, custom VJP.

The update is linear in (delta, k, y), so the VJP is closed-form::

    ct_delta = a * (ct_delta' + b * ct_y')
    ct_k     =      ct_delta' + b * ct_y'
    ct_y     =      ct_y'

which keeps the reversible adjoint's inner ``jax.vjp`` working through the
kernel without a Pallas transpose rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import williamson2n_ref
from .williamson2n import LANE, SUBLANE, williamson2n_2d

_TILE = LANE * SUBLANE


def _use_pallas(x: jax.Array) -> bool:
    # Only the TPU backend can lower the compiled kernel; everywhere else the
    # reference path is used (identical numerics), or interpret=True in tests.
    return jax.default_backend() == "tpu" and x.size >= _TILE


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def williamson2n_update(delta, k, y, a: float, b: float, interpret: bool = False):
    """delta' = a*delta + k; y' = y + b*delta'; fused on TPU.  Returns (delta', y')."""
    if not (interpret or _use_pallas(delta)):
        return williamson2n_ref(delta, k, y, a, b)
    shape, dtype = delta.shape, delta.dtype
    n = delta.size
    pad = (-n) % _TILE
    def to2d(x):
        flat = x.reshape(-1)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros(pad, dtype)])
        return flat.reshape(-1, LANE)

    d2, y2 = williamson2n_2d(to2d(delta), to2d(k), to2d(y), a=a, b=b, interpret=interpret)

    def back(x):
        return x.reshape(-1)[:n].reshape(shape)

    return back(d2), back(y2)


def _fwd(delta, k, y, a, b, interpret):
    return williamson2n_update(delta, k, y, a, b, interpret), None


def _bwd(a, b, interpret, _, ct):
    ct_d2, ct_y2 = ct
    common = ct_d2 + b * ct_y2
    return (a * common, common, ct_y2)


williamson2n_update.defvjp(_fwd, _bwd)
