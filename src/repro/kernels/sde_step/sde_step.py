"""Fused SDE step kernels (TPU Pallas): driver-weighted increment + RK update.

The solve hot loop spends its time in three memory-bound element streams per
stage (see ``core/solvers.py``):

1. the driver-weighted increment  ``k = f*h + g.dW``  (diagonal elementwise
   or general-noise einsum),
2. the Williamson 2N register update  ``delta' = a*delta + k;
   y' = y + b*delta'``  (eq. (2) of the paper),
3. the Butcher stage/output combination  ``y + sum_i coeff_i * k_i``.

Unfused, XLA materialises every intermediate between them: ``k`` round-trips
HBM once per stage, and each axpy in the Butcher chain re-reads its running
accumulator.  The kernels here fuse each chain into a single pass — every
element of every operand is read exactly once and every output written exactly
once, the bandwidth floor for the update.  ``ws_stage_*`` subsumes and extends
``kernels/williamson2n`` (which fuses only step 2, taking ``k`` precomputed).

All kernels are shape-agnostic via ``ops.py``: elementwise variants flatten
the state and pad to the (8, 128) tile, the general-noise variants flatten
batch dims to rows of ``(d, m)`` blocks.  The compiled path is TPU-only;
``interpret=True`` runs the same kernel bodies in Python (tests / CPU
bench-smoke), and every op falls back to its ``ref.py`` twin elsewhere.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8

_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda i: (0, 0))


def _row_spec(block_rows):
    return pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))


def _row_grid(rows, block_rows):
    # ops.py pads flat states to the (SUBLANE, LANE) tile, so `rows` is a
    # multiple of SUBLANE but not necessarily of block_rows (e.g. 320 rows
    # vs the default 256): shrink to the largest common divisor, which stays
    # a SUBLANE multiple.
    block_rows = math.gcd(min(block_rows, rows), rows)
    return (rows // block_rows,), block_rows


# -- 1. driver-weighted increment --------------------------------------------

def _increment_diag_kernel(f_ref, g_ref, dw_ref, h_ref, out_ref):
    h = h_ref[0, 0]
    out_ref[...] = f_ref[...] * h + g_ref[...] * dw_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def increment_diag_2d(f, g, dw, h, *, block_rows: int = 256, interpret: bool = False):
    """k = f*h + g*dw on 2D (rows, LANE) arrays; ``h`` is a (1, 1) scalar."""
    grid, block_rows = _row_grid(f.shape[0], block_rows)
    spec = _row_spec(block_rows)
    return pl.pallas_call(
        _increment_diag_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, _SCALAR_SPEC],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=interpret,
    )(f, g, dw, h)


def _increment_pre_kernel(f_ref, w_ref, h_ref, out_ref):
    h = h_ref[0, 0]
    out_ref[...] = f_ref[...] * h + w_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def increment_pre_2d(f, w, h, *, block_rows: int = 256, interpret: bool = False):
    """k = f*h + w (prediffused: ``w`` is the pre-weighted ``g.dW`` buffer row)."""
    grid, block_rows = _row_grid(f.shape[0], block_rows)
    spec = _row_spec(block_rows)
    return pl.pallas_call(
        _increment_pre_kernel,
        grid=grid,
        in_specs=[spec, spec, _SCALAR_SPEC],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=interpret,
    )(f, w, h)


def _increment_general_kernel(f_ref, g_ref, dw_ref, h_ref, out_ref):
    h = h_ref[0, 0]
    gdw = jax.lax.dot_general(
        g_ref[...], dw_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=f_ref.dtype,
    )
    out_ref[...] = f_ref[...] * h + gdw


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def increment_general_2d(f, g, dw, h, *, block_n: int = 128, interpret: bool = False):
    """k = f*h + g@dw: f (N, d), g (N, d, m), dw (N, m), h (1, 1) -> (N, d)."""
    n, d = f.shape
    m = dw.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        _increment_general_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            _SCALAR_SPEC,
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=interpret,
    )(f, g, dw, h)


# -- 2. fused increment + Williamson 2N register update ----------------------

def _ws_stage_diag_kernel(a, b, delta_ref, y_ref, f_ref, g_ref, dw_ref, h_ref,
                          dout_ref, yout_ref):
    h = h_ref[0, 0]
    k = f_ref[...] * h + g_ref[...] * dw_ref[...]
    d2 = a * delta_ref[...] + k
    dout_ref[...] = d2
    yout_ref[...] = y_ref[...] + b * d2


@functools.partial(jax.jit, static_argnames=("a", "b", "block_rows", "interpret"))
def ws_stage_diag_2d(delta, y, f, g, dw, h, *, a: float, b: float,
                     block_rows: int = 256, interpret: bool = False):
    """Fused ``k = f*h + g*dw; delta' = a*delta + k; y' = y + b*delta'``."""
    grid, block_rows = _row_grid(delta.shape[0], block_rows)
    spec = _row_spec(block_rows)
    return pl.pallas_call(
        functools.partial(_ws_stage_diag_kernel, a, b),
        grid=grid,
        in_specs=[spec] * 5 + [_SCALAR_SPEC],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
            jax.ShapeDtypeStruct(y.shape, y.dtype),
        ],
        interpret=interpret,
    )(delta, y, f, g, dw, h)


def _ws_stage_diag_bwd_kernel(a, b, ctd2_ref, cty2_ref, g_ref, dw_ref, h_ref,
                              ctdelta_ref, ctf_ref, ctg_ref, ctdw_ref):
    """Fused VJP of the diagonal stage (linear in every array operand)::

        common   = ct_delta' + b * ct_y'
        ct_delta = a * common       ct_f  = h * common
        ct_g     = dw * common      ct_dw = g * common

    (``ct_y = ct_y'`` needs no kernel; ``ct_h = <f, common>`` is a scalar
    reduction done by the caller.)
    """
    h = h_ref[0, 0]
    common = ctd2_ref[...] + b * cty2_ref[...]
    ctdelta_ref[...] = a * common
    ctf_ref[...] = h * common
    ctg_ref[...] = dw_ref[...] * common
    ctdw_ref[...] = g_ref[...] * common


@functools.partial(jax.jit, static_argnames=("a", "b", "block_rows", "interpret"))
def ws_stage_diag_bwd_2d(ct_d2, ct_y2, g, dw, h, *, a: float, b: float,
                         block_rows: int = 256, interpret: bool = False):
    grid, block_rows = _row_grid(ct_d2.shape[0], block_rows)
    spec = _row_spec(block_rows)
    shp = jax.ShapeDtypeStruct(ct_d2.shape, ct_d2.dtype)
    return pl.pallas_call(
        functools.partial(_ws_stage_diag_bwd_kernel, a, b),
        grid=grid,
        in_specs=[spec] * 4 + [_SCALAR_SPEC],
        out_specs=[spec] * 4,
        out_shape=[shp] * 4,
        interpret=interpret,
    )(ct_d2, ct_y2, g, dw, h)


def _ws_stage_pre_kernel(a, b, delta_ref, y_ref, f_ref, w_ref, h_ref,
                         dout_ref, yout_ref):
    h = h_ref[0, 0]
    k = f_ref[...] * h + w_ref[...]
    d2 = a * delta_ref[...] + k
    dout_ref[...] = d2
    yout_ref[...] = y_ref[...] + b * d2


@functools.partial(jax.jit, static_argnames=("a", "b", "block_rows", "interpret"))
def ws_stage_pre_2d(delta, y, f, w, h, *, a: float, b: float,
                    block_rows: int = 256, interpret: bool = False):
    """Fused prediffused stage: ``k = f*h + w; delta' = a*delta + k;
    y' = y + b*delta'`` — the additive fast path's one-fewer-stream variant
    (no diffusion operand; ``w`` is already ``g.dW``).  The backward pass is
    the plain XLA expression in ``ops.py`` (two outputs from four inputs is
    already bandwidth-optimal there, matching the general-noise precedent).
    """
    grid, block_rows = _row_grid(delta.shape[0], block_rows)
    spec = _row_spec(block_rows)
    return pl.pallas_call(
        functools.partial(_ws_stage_pre_kernel, a, b),
        grid=grid,
        in_specs=[spec] * 4 + [_SCALAR_SPEC],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
            jax.ShapeDtypeStruct(y.shape, y.dtype),
        ],
        interpret=interpret,
    )(delta, y, f, w, h)


def _ws_stage_general_kernel(a, b, delta_ref, y_ref, f_ref, g_ref, dw_ref,
                             h_ref, dout_ref, yout_ref):
    h = h_ref[0, 0]
    gdw = jax.lax.dot_general(
        g_ref[...], dw_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=f_ref.dtype,
    )
    d2 = a * delta_ref[...] + f_ref[...] * h + gdw
    dout_ref[...] = d2
    yout_ref[...] = y_ref[...] + b * d2


@functools.partial(jax.jit, static_argnames=("a", "b", "block_n", "interpret"))
def ws_stage_general_2d(delta, y, f, g, dw, h, *, a: float, b: float,
                        block_n: int = 128, interpret: bool = False):
    """Fused general-noise stage: state rows (N, d), diffusion (N, d, m)."""
    n, d = delta.shape
    m = dw.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    row = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ws_stage_general_kernel, a, b),
        grid=(n // block_n,),
        in_specs=[
            row, row, row,
            pl.BlockSpec((block_n, d, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            _SCALAR_SPEC,
        ],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
            jax.ShapeDtypeStruct(y.shape, y.dtype),
        ],
        interpret=interpret,
    )(delta, y, f, g, dw, h)


# -- 3. Butcher axpy chain ----------------------------------------------------

def _axpy_chain_kernel(coeffs, y_ref, incs_ref, out_ref):
    acc = y_ref[...]
    for i, c in enumerate(coeffs):
        acc = acc + c * incs_ref[i]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("coeffs", "block_rows", "interpret"))
def axpy_chain_2d(y, incs, *, coeffs, block_rows: int = 256,
                  interpret: bool = False):
    """y + sum_i coeffs[i] * incs[i]: y (rows, LANE), incs (s, rows, LANE).

    ``coeffs`` is a static tuple — the loop unrolls at trace time, so the
    whole chain is one read of each operand and one write of the output.
    """
    s = incs.shape[0]
    assert len(coeffs) == s, (len(coeffs), s)
    grid, block_rows = _row_grid(y.shape[0], block_rows)
    spec = _row_spec(block_rows)
    return pl.pallas_call(
        functools.partial(_axpy_chain_kernel, coeffs),
        grid=grid,
        in_specs=[spec, pl.BlockSpec((s, block_rows, LANE), lambda i: (0, i, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
        interpret=interpret,
    )(y, incs)
